"""Optional-hypothesis shim: property tests skip cleanly when absent.

The suite's property tests use ``hypothesis`` when it is installed; this
module degrades gracefully when it is not, so the tier-1 suite still
collects and runs everywhere.  Import ``given`` / ``st`` from here instead
of from ``hypothesis`` directly:

* with hypothesis installed — re-exports the real objects, unchanged;
* without it — ``st`` becomes an inert strategy stub (any attribute access
  or call chains to another stub) and ``@given(...)`` marks the test as
  skipped with an explanatory reason.
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, plain tests still run
    HAVE_HYPOTHESIS = False
    HealthCheck = None
    settings = None

    class _StrategyStub:
        """Absorbs strategy construction chains (st.lists(st.text())...)."""

        def __call__(self, *args, **kwargs):
            return _StrategyStub()

        def __getattr__(self, name):
            return _StrategyStub()

    strategies = _StrategyStub()

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped")(fn)
        return decorate

st = strategies

"""The loop-aware HLO analyzer: verified against programs with known costs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_static as HS


def _analyze(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return HS.analyze(hlo)


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    out = _analyze(lambda x, y: x @ y, a, b)
    want = 2 * 256 * 512 * 128
    assert abs(out["flops"] - want) / want < 0.01


def test_scan_multiplies_flops():
    """A scan of N matmuls must count N×, not 1× (the cost_analysis bug
    this module exists to fix)."""
    n = 7
    w = jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def fn(ws, x0):
        def body(c, wi):
            return c @ wi, None
        out, _ = jax.lax.scan(body, x0, ws)
        return out

    out = _analyze(fn, w, x)
    want = n * 2 * 128 ** 3
    assert abs(out["flops"] - want) / want < 0.05, out["flops"]


def test_nested_scan_trips_compound():
    n_out, n_in = 3, 5
    w = jax.ShapeDtypeStruct((n_out, n_in, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def fn(ws, x0):
        def outer(c, w_block):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, w_block)
            return c2, None
        out, _ = jax.lax.scan(outer, x0, ws)
        return out

    out = _analyze(fn, w, x)
    want = n_out * n_in * 2 * 64 ** 3
    assert abs(out["flops"] - want) / want < 0.05


def test_shape_parse():
    elems, bytes_ = HS._shape_elems_bytes("bf16[16,4096,448]{2,1,0}")
    assert elems == 16 * 4096 * 448 and bytes_ == elems * 2
    _, b2 = HS._shape_elems_bytes("(f32[8,8], s8[4])")
    assert b2 == 8 * 8 * 4 + 4

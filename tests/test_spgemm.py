"""Graphulo-style sparse matmul engine: 3-layer parity + fused epilogues.

The contract under test: ``Assoc.matmul == AssocTensor.matmul ==
DistAssoc.matmul`` for every registered semiring, across every execution
strategy (``dense`` / ``bsr`` / ``coo``), on rectangular shapes, empty
operands and capacity-overflow cases — and the fused ``matmul_reduce``
epilogues equal the unfused materialize-then-reduce oracle everywhere.
"""
import json
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import Assoc, AssocTensor, REGISTRY
from repro.core.spgemm import matmul_reduce, plan_matmul

rng = np.random.default_rng(7)


def _random_pair(n=60, nr=30, nk=30, nc=20, seed=3):
    r = np.random.default_rng(seed)
    rows = r.integers(0, nr, n).astype(str)
    cols = r.integers(0, nk, n).astype(str)
    vals = r.uniform(0.5, 5.0, n)
    rows2 = r.integers(0, nk, n).astype(str)
    cols2 = r.integers(0, nc, n).astype(str)
    vals2 = r.uniform(0.5, 5.0, n)
    ha = Assoc(rows, cols, vals, aggregate="sum")
    hb = Assoc(rows2, cols2, vals2, aggregate="sum")
    da = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                  capacity=64)
    db = AssocTensor.from_triples(rows2, cols2, vals2, aggregate="sum",
                                  capacity=64)
    return ha, hb, da, db


def _close(got: dict, want: dict, tol=1e-3):
    assert set(got) == set(want)
    for k in want:
        assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), \
            (k, got[k], want[k])


# --------------------------- matmul parity -----------------------------------

@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
@pytest.mark.parametrize("impl", ["dense", "bsr", "coo"])
def test_matmul_parity(sr_name, impl):
    sr = REGISTRY[sr_name]
    ha, hb, da, db = _random_pair()
    want = ha.matmul(hb, sr).to_dict()
    got = da.matmul(db, sr, impl=impl, use_kernel=False).to_assoc().to_dict()
    _close(got, want)


def test_matmul_rectangular_shapes():
    ha, hb, da, db = _random_pair(n=40, nr=50, nk=10, nc=5, seed=11)
    _close(da.matmul(db, impl="bsr", use_kernel=False).to_assoc().to_dict(),
           ha.matmul(hb).to_dict())


def test_matmul_empty_operands():
    ha, hb, da, db = _random_pair()
    empty_d = AssocTensor.from_triples(["x"], ["y"], [1.0], capacity=8)
    empty_d = empty_d[("zz", "zz"), :]   # no keys selected ⇒ nnz 0
    for impl in ("dense", "bsr", "coo"):
        out = da.matmul(empty_d, impl=impl, use_kernel=False)
        assert out.nnz_host() == 0
    # disjoint contraction keyspaces ⇒ empty product
    dc = AssocTensor.from_triples(["q"], ["zzz"], [1.0], capacity=8)
    for impl in ("dense", "bsr", "coo"):
        assert dc.matmul(db, impl=impl, use_kernel=False).nnz_host() == 0


def test_matmul_auto_matches_override():
    ha, hb, da, db = _random_pair(seed=13)
    want = da.matmul(db, impl="dense", use_kernel=False).to_assoc().to_dict()
    _close(da.matmul(db, use_kernel=False).to_assoc().to_dict(), want)


def test_bsr_path_never_densifies(monkeypatch):
    """The acceptance bound: the BSR strategy must not touch the dense adj."""
    ha, hb, da, db = _random_pair(seed=17)

    def boom(self, **kw):
        raise AssertionError("BSR path densified the adjacency")

    monkeypatch.setattr(AssocTensor, "to_dense_adj", boom)
    monkeypatch.setattr(AssocTensor, "from_dense_adj", staticmethod(boom))
    got = da.matmul(db, impl="bsr", use_kernel=False).to_assoc().to_dict()
    _close(got, ha.matmul(hb).to_dict())


def test_out_capacity_overflow_warns():
    ha, hb, da, db = _random_pair(seed=19)
    full = da.matmul(db, impl="bsr", use_kernel=False)
    nnz = full.nnz_host()
    assert nnz > 8 and not bool(full.overflow)
    for impl in ("bsr", "coo"):
        with pytest.warns(RuntimeWarning, match="capacity"):
            cut = da.matmul(db, impl=impl, use_kernel=False, out_capacity=8)
        assert cut.nnz_host() == 8 and bool(cut.overflow)
        # the kept prefix is the canonical (row, col) order head
        kept = cut.to_assoc().to_dict()
        assert set(kept).issubset(set(full.to_assoc().to_dict()))


def test_from_dense_adj_overflow_flag_and_warning():
    import jax.numpy as jnp
    from repro.core.keyspace import KeySpace

    ks = KeySpace(np.asarray(["a", "b", "c"]))
    dense = jnp.asarray(np.arange(1.0, 10.0).reshape(3, 3))
    with pytest.warns(RuntimeWarning, match="exceed capacity"):
        t = AssocTensor.from_dense_adj(dense, ks, ks, 4)
    assert bool(t.overflow) and t.nnz_host() == 4
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ok = AssocTensor.from_dense_adj(dense, ks, ks, 16)
    assert not bool(ok.overflow) and ok.nnz_host() == 9


# --------------------------- strategy heuristic ------------------------------

def test_plan_heuristic_sparse_picks_bsr():
    # 3 entries scattered over a 4096×4096 space: tiles ≪ dense
    a_r = np.asarray([0, 2000, 4000])
    a_c = np.asarray([1, 2001, 4001])
    plan = plan_matmul(a_r, a_c, a_c, a_r, 4096, 4096, 4096)
    assert plan.impl == "bsr"
    assert plan.bsr_cost < plan.dense_cost


def test_plan_heuristic_small_picks_dense():
    a_r = np.asarray([0, 1, 2, 3])
    a_c = np.asarray([0, 1, 2, 3])
    plan = plan_matmul(a_r, a_c, a_c, a_r, 8, 8, 8)
    assert plan.impl == "dense"


def test_plan_impl_override():
    a_r = np.asarray([0, 1])
    a_c = np.asarray([0, 1])
    assert plan_matmul(a_r, a_c, a_c, a_r, 8, 8, 8, impl="bsr").impl == "bsr"


def test_plan_products_exact():
    # A has 2 entries on k=0, B has 3 entries on k=0 ⇒ 6 products
    plan = plan_matmul(np.asarray([0, 1]), np.asarray([0, 0]),
                       np.asarray([0, 0, 0]), np.asarray([0, 1, 2]),
                       2, 1, 3)
    assert plan.products == 6


# --------------------------- fused epilogues ---------------------------------

def _reduce_oracle(ha, hb, sr, axis, space):
    """Unfused oracle: host matmul, then ⊕-fold its triples per key rank."""
    c = ha.matmul(hb, sr)
    out = np.full(len(space), sr.zero)
    r, cc, v = c.triples()
    keys = r if axis == 1 else cc
    rk, _ = space.rank(keys)
    sr.add_np.at(out, rk, v)
    return out


@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
@pytest.mark.parametrize("axis", [0, 1])
@pytest.mark.parametrize("impl", ["dense", "bsr", "coo"])
def test_matmul_reduce_parity(sr_name, axis, impl):
    sr = REGISTRY[sr_name]
    ha, hb, da, db = _random_pair(seed=23)
    space = da.row_space if axis == 1 else db.col_space
    want = _reduce_oracle(ha, hb, sr, axis, space)
    got = np.asarray(matmul_reduce(da, db, axis, sr, impl=impl))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
@pytest.mark.parametrize("axis", [0, 1])
def test_host_matmul_reduce_parity(sr_name, axis):
    sr = REGISTRY[sr_name]
    ha, hb, _, _ = _random_pair(seed=29)
    from repro.core.keyspace import KeySpace
    space = KeySpace.from_sorted_unique(ha.row if axis == 1 else hb.col)
    want = _reduce_oracle(ha, hb, sr, axis, space)
    got = ha.matmul_reduce(hb, axis, sr)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_sq_fused_vs_unfused():
    ha, _, da, _ = _random_pair(seed=31)
    want_out = _reduce_oracle(ha, ha.transpose(), REGISTRY["plus_times"], 1,
                              da.row_space)
    np.testing.assert_allclose(np.asarray(da.sqout(reduce=1)), want_out,
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(ha.sqout(reduce=1), want_out,
                               rtol=1e-6, atol=1e-6)
    # unfused square parity while we're here
    _close(da.sqout().to_assoc().to_dict(), ha.sqout().to_dict())
    _close(da.sqin().to_assoc().to_dict(), ha.sqin().to_dict())


def test_matmul_reduce_empty():
    _, _, da, db = _random_pair(seed=37)
    empty = da[("zz", "zz"), :]
    out = np.asarray(matmul_reduce(empty, db, 1))
    assert out.shape == (len(empty.row_space),)
    assert (out == 0.0).all()


# --------------------------- fused kernel (interpret) ------------------------

@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
@pytest.mark.parametrize("axis", [0, 1])
def test_bsr_spgemm_reduce_kernel_interpret(sr_name, axis):
    import jax.numpy as jnp
    from repro.kernels.bsr_spgemm.ops import bsr_spgemm_reduce
    from repro.kernels.bsr_spgemm.ref import bsr_spgemm_reduce_ref

    a = jnp.asarray(rng.normal(size=(256, 384)).astype(np.float32))
    mask = jnp.asarray((rng.random((2, 3)) > 0.4).astype(np.int32))
    b = jnp.asarray(rng.normal(size=(384, 256)).astype(np.float32))
    got = bsr_spgemm_reduce(a, mask, b, axis=axis, semiring=sr_name,
                            impl="interpret")
    want = bsr_spgemm_reduce_ref(a, mask, b, axis=axis, semiring=sr_name)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------- hybrid selector dispatch ------------------------

def test_hybrid_selection_uses_range_kernel():
    from repro.core.assoc_tensor import DISPATCH_STATS
    from repro.core.select import Keys, Match

    rows = [f"r{i % 10}" for i in range(18)]
    cols = [f"c{i % 9}" for i in range(18)]
    vals = np.arange(1.0, 19.0)
    host = Assoc(rows, cols, vals, aggregate="sum")
    dev = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                   capacity=24)
    # Match on a prefix block compiles to ONE contiguous rank interval;
    # a col set of FIVE singleton runs exceeds the ≤4-box multirange
    # budget, forcing that axis onto the gather path → hybrid
    row_sel = Match("^r[0-3]")
    col_sel = Keys(["c0", "c2", "c4", "c6", "c8"])
    before = dict(DISPATCH_STATS)
    got = dev[row_sel, col_sel].to_assoc().to_dict()
    assert DISPATCH_STATS["hybrid"] == before["hybrid"] + 1
    assert got == pytest.approx(host[row_sel, col_sel].to_dict())
    # both contiguous stays on the pure range path
    before = dict(DISPATCH_STATS)
    dev[Match("^r"), :]
    assert DISPATCH_STATS["range"] == before["range"] + 1
    # a few scattered keys → ≤4 rank boxes → the multirange OR path
    before = dict(DISPATCH_STATS)
    dev[Keys(["r0", "r5"]), Keys(["c0", "c2"])]
    assert DISPATCH_STATS["multirange"] == before["multirange"] + 1
    # both axes past the box budget stays on the pure gather path
    before = dict(DISPATCH_STATS)
    dev[Keys(["r0", "r2", "r4", "r6", "r8"]),
        Keys(["c0", "c2", "c4", "c6", "c8"])]
    assert DISPATCH_STATS["gather"] == before["gather"] + 1


def test_gather_replicated_keeps_zero_values():
    """A stored 0.0 (legit when the semiring zero is ±inf) must survive the
    broadcast-B gather — chained min_plus products depend on it."""
    import jax
    from repro.core import MIN_PLUS
    from repro.core.dist_assoc import DistAssoc

    mesh = jax.make_mesh((1,), ("data",))  # single-shard: runs in-process
    da = DistAssoc.from_triples(["a"], ["b"], [1.0], mesh)
    bt = AssocTensor.from_triples(["b"], ["c"], [-1.0], capacity=8)
    c = da.matmul(bt, MIN_PLUS)            # ('a','c') = 1 + (-1) = 0.0
    from repro.core import INT_SENTINEL
    g = c.gather_replicated()
    assert int(g.nnz) == 1
    assert float(g.vals[0]) == 0.0 and int(g.rows[0]) != INT_SENTINEL
    # and the chained product still sees it
    dt = AssocTensor.from_triples(["c"], ["d"], [3.0], capacity=8)
    chained = c.matmul(dt, MIN_PLUS).to_assoc()
    assert chained is not None and ("a", "d") in chained.to_dict()


# --------------------------- DistAssoc (multi-shard mesh) --------------------

DIST_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core.dist_assoc import DistAssoc
    from repro.core import Assoc, AssocTensor, REGISTRY

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 64
    rows = rng.integers(0, 40, n).astype(str)
    cols = rng.integers(0, 40, n).astype(str)
    vals = rng.uniform(0.5, 5.0, n)
    rows2 = rng.integers(0, 40, n).astype(str)
    cols2 = rng.integers(0, 30, n).astype(str)
    vals2 = rng.uniform(0.5, 5.0, n)

    da = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    ha = Assoc(rows, cols, vals, aggregate="sum")
    hb = Assoc(rows2, cols2, vals2, aggregate="sum")
    db = AssocTensor.from_triples(rows2, cols2, vals2, aggregate="sum",
                                  capacity=64)

    def close(got, want, tol=1e-3):
        assert set(got) == set(want), (len(got), len(want))
        for k in want:
            assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), k

    # 3-layer parity: host == single-device (bsr) == dist, per semiring
    for name in ("plus_times", "min_plus", "max_min"):
        sr = REGISTRY[name]
        want = ha.matmul(hb, sr).to_dict()
        close(da.matmul(db, sr).to_assoc().to_dict(), want)
        close(AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                       capacity=64)
              .matmul(db, sr, impl="bsr", use_kernel=False)
              .to_assoc().to_dict(), want)
        # fused epilogue vs unfused oracle
        for ax in (0, 1):
            space = da.local.row_space if ax == 1 else db.col_space
            want_v = np.full(len(space), sr.zero)
            r_, c_, v_ = ha.matmul(hb, sr).triples()
            rk, _ = space.rank(r_ if ax == 1 else c_)
            sr.add_np.at(want_v, rk, v_)
            got_v = np.asarray(da.matmul_reduce(db, ax, sr))
            np.testing.assert_allclose(got_v, want_v, rtol=1e-3, atol=1e-3)

    # DistAssoc × DistAssoc (gathered broadcast-B)
    db_dist = DistAssoc.from_triples(rows2, cols2, vals2, mesh,
                                     aggregate="sum")
    close(da.matmul(db_dist).to_assoc().to_dict(), ha.matmul(hb).to_dict())

    # per-shard capacity overflow warns instead of truncating silently
    import warnings as _w
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        cut = da.matmul(db, out_capacity_per_shard=2)
    assert cut.overflow and any("out_capacity_per_shard" in str(w.message)
                                for w in caught)

    # sqout + fused sqout + col_degree
    close(da.sqout().to_assoc().to_dict(), ha.sqout().to_dict())
    dense = np.zeros((len(da.local.row_space), len(da.local.col_space)))
    r, c, v = ha.triples()
    rr, _ = da.local.row_space.rank(r)
    cc, _ = da.local.col_space.rank(c)
    dense[rr, cc] = v
    sq = dense @ dense.T
    np.testing.assert_allclose(np.asarray(da.sqout(reduce=1)), sq.sum(1),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(da.col_degree()),
                                  (dense != 0).sum(0))
    # dtype-respecting dense matvec (satellite): f32 in, f32 out
    x = rng.uniform(0, 1, len(da.local.col_space)).astype(np.float32)
    y = da.matmul_dense_vec(jnp.asarray(x))
    assert y.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)
    print(json.dumps({"ok": True}))
""")


@pytest.mark.slow
def test_dist_matmul_parity_8dev():
    p = subprocess.run([sys.executable, "-c", DIST_PROG],
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    last = [l for l in p.stdout.strip().splitlines() if l.startswith("{")][-1]
    assert json.loads(last)["ok"], p.stdout

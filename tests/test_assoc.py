"""Host Assoc vs a dict-of-dicts oracle (the paper's semantics, §II)."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import Assoc

keys = st.text(alphabet="abcdefg", min_size=1, max_size=3)
vals_num = st.floats(min_value=-100, max_value=100, allow_nan=False,
                     allow_subnormal=False, width=32).filter(lambda v: abs(v) > 1e-3)

triples = st.lists(st.tuples(keys, keys, vals_num), min_size=0, max_size=30)


def oracle(ts, aggregate=min):
    d = {}
    for r, c, v in ts:
        if (r, c) in d:
            d[(r, c)] = aggregate(d[(r, c)], v)
        else:
            d[(r, c)] = v
    return {k: v for k, v in d.items() if v != 0}


def make(ts, aggregate=min):
    if not ts:
        return Assoc()
    r, c, v = zip(*ts)
    return Assoc(list(r), list(c), np.asarray(v, dtype=np.float64),
                 aggregate=aggregate)


@given(triples)
def test_constructor_min_agg(ts):
    assert make(ts).to_dict() == pytest.approx(oracle(ts))


@given(triples)
def test_constructor_sum_agg(ts):
    got = make(ts, aggregate="sum").to_dict()
    want = oracle(ts, aggregate=lambda a, b: a + b)
    assert got == pytest.approx(want)


@given(triples, triples)
def test_add(ts1, ts2):
    a, b = make(ts1), make(ts2)
    got = (a + b).to_dict()
    o1, o2 = oracle(ts1), oracle(ts2)
    want = {}
    for k in set(o1) | set(o2):
        s = o1.get(k, 0.0) + o2.get(k, 0.0)
        if abs(s) > 1e-9:
            want[k] = s
    assert got == pytest.approx(want)


@given(triples, triples)
def test_elementwise_mul(ts1, ts2):
    a, b = make(ts1), make(ts2)
    got = (a * b).to_dict()
    o1, o2 = oracle(ts1), oracle(ts2)
    want = {k: o1[k] * o2[k] for k in set(o1) & set(o2)
            if abs(o1[k] * o2[k]) > 1e-12}
    assert got == pytest.approx(want)


@given(triples, triples)
def test_matmul(ts1, ts2):
    a, b = make(ts1), make(ts2)
    got = (a @ b).to_dict()
    o1, o2 = oracle(ts1), oracle(ts2)
    want = {}
    for (r, k1), v1 in o1.items():
        for (k2, c), v2 in o2.items():
            if k1 == k2:
                want[(r, c)] = want.get((r, c), 0.0) + v1 * v2
    want = {k: v for k, v in want.items() if abs(v) > 1e-9}
    assert got == pytest.approx(want, rel=1e-6, abs=1e-9)


@given(triples)
def test_transpose_involution(ts):
    a = make(ts)
    assert a.T.T == a


@given(triples)
def test_logical(ts):
    a = make(ts)
    assert a.logical().to_dict() == {k: 1.0 for k in oracle(ts)}


def test_paper_fig_1_2_example():
    """The exact associative array of Fig. 1 and its Fig. 2 storage."""
    row = ["0294.mp3"] * 3 + ["1829.mp3"] * 3 + ["7802.mp3"] * 3
    col = ["artist", "duration", "genre"] * 3
    val = ["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01",
           "classical", "Taylor Swift", "10:12", "pop"]
    a = Assoc(row, col, val)
    assert a.row.tolist() == ["0294.mp3", "1829.mp3", "7802.mp3"]
    assert a.col.tolist() == ["artist", "duration", "genre"]
    # A.val is the sorted unique values; adj holds 1-based pointers
    assert a.val.tolist() == sorted(val)
    assert not a.numeric
    adj = a.adj.toarray()
    for i, r in enumerate(a.row):
        for j, c in enumerate(a.col):
            k = int(adj[i, j]) - 1
            assert a.val[k] == a.get(r, c)
    assert a.get("1829.mp3", "artist") == "Samuel Barber"


def test_getitem_string_slice_right_inclusive():
    a = Assoc(["a", "b", "c", "d"], ["x"] * 4, [1.0, 2.0, 3.0, 4.0])
    sub = a["a,:,c,", ":"]
    assert set(sub.row.tolist()) == {"a", "b", "c"}  # right-INCLUSIVE


def test_getitem_positional_ints():
    a = Assoc(["a", "b", "c"], ["x", "y", "z"], [1.0, 2.0, 3.0])
    sub = a[0:2, [0, 1]]  # slices/ints are POSITIONS (paper §II.B rule 2)
    assert sub.get("a", "x") == 1.0 and sub.get("b", "y") == 2.0
    assert sub.get("c", "z") is None


def test_getitem_int_selector_list_vs_ndarray_uniform():
    """Positional rule applies to BOTH python lists and numpy int arrays."""
    a = Assoc(["a", "b", "c"], ["x", "y", "z"], [1.0, 2.0, 3.0])
    want = a[[0, 2], [0, 2]].to_dict()
    got = a[np.array([0, 2]), np.array([0, 2])].to_dict()
    assert got == want == {("a", "x"): 1.0, ("c", "z"): 3.0}
    # numeric-KEYED array: float selectors are key lookups, int positional
    b = Assoc([10.0, 20.0, 30.0], [1.0, 1.0, 1.0], [5.0, 6.0, 7.0])
    assert b[np.array([20.0]), :].to_dict() == {(20.0, 1.0): 6.0}
    assert b[np.array([1]), :].to_dict() == {(20.0, 1.0): 6.0}  # position 1


def test_printfull_fig1_layout():
    """The paper's Fig. 1 table: per-column widths from one scatter-max pass."""
    row = ["0294.mp3"] * 3 + ["1829.mp3"] * 3 + ["7802.mp3"] * 3
    col = ["artist", "duration", "genre"] * 3
    val = ["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01",
           "classical", "Taylor Swift", "10:12", "pop"]
    s = Assoc(row, col, val).printfull()
    lines = s.splitlines()
    assert len(lines) == 4
    # header: row-label gutter then column keys padded to column width
    assert lines[0].startswith(" " * len("0294.mp3") + "  artist")
    assert lines[1].split() == ["0294.mp3", "Pink", "Floyd", "6:53", "rock"]
    assert lines[2].split() == ["1829.mp3", "Samuel", "Barber", "8:01",
                                "classical"]
    # columns align: every "genre"-column cell starts at the same offset
    off = lines[0].index("genre")
    assert lines[1][off:].startswith("rock")
    assert lines[3][off:].startswith("pop")


def test_printfull_single_row_and_empty():
    # numeric values render num2str-style (MATLAB D4M): "1", not "1.0"
    one = Assoc(["r"], ["c"], [1.0]).printfull()
    assert one.splitlines()[1].split() == ["r", "1"]
    assert Assoc().printfull() == "  "  # header gutter only, no crash


def test_printfull_numeric_left_justified():
    """Numeric arrays align exactly like string arrays: left-justified
    cells, widths from the widest cell/label per column (ROADMAP item)."""
    a = Assoc(["r1", "r2"], ["c1", "c1"], [1.0, 123456.75])
    a["r1", "c2"] = 2.5
    lines = a.printfull().splitlines()
    # the wide value "123456.75" sets column c1's width
    off_c2 = lines[0].index("c2")
    assert off_c2 > len("r1") + 2 + len("123456.75")
    # every c2 cell starts at the same offset, left-justified
    assert lines[1][off_c2:].startswith("2.5")
    # integral floats drop the trailing ".0" (num2str), fractions keep it
    assert lines[1].split() == ["r1", "1", "2.5"]
    assert lines[2].split() == ["r2", "123456.75"]


def test_setitem_assoc_value_overwrites():
    a = Assoc(["r1", "r2"], ["c", "c"], [1.0, 2.0])
    patch = Assoc(["r2", "r3"], ["c", "c"], [9.0, 3.0])
    a[:, :] = patch
    assert a.to_dict() == {("r1", "c"): 1.0, ("r2", "c"): 9.0,
                           ("r3", "c"): 3.0}


def test_host_semiring_algebra():
    """sqin/graph idioms run under registry semirings on host (paper §I.A)."""
    from repro.core import MAX_MIN, MIN_PLUS
    # min_plus matmul = one relaxation step of shortest paths
    e = Assoc(["a", "a", "b"], ["b", "c", "c"], [1.0, 5.0, 1.0])
    two_hop = e.matmul(e, MIN_PLUS)
    assert two_hop.get("a", "c") == 2.0       # a→b→c beats direct 5
    # max_min sqin = bottleneck similarity on column keys
    bn = e.sqin(MAX_MIN)
    assert bn.get("b", "c") == 1.0
    # element-wise min_plus add keeps the smaller entry
    m = e.add(Assoc(["a"], ["b"], [0.5]), MIN_PLUS)
    assert m.get("a", "b") == 0.5


def test_setitem():
    a = Assoc(["r"], ["c"], [1.0])
    a["r2", "c2"] = 5.0
    assert a.get("r2", "c2") == 5.0
    a["r", "c"] = 9.0   # overwrite (aggregate=last semantics)
    assert a.get("r", "c") == 9.0


def test_condense_removes_empty():
    a = Assoc(["a", "b"], ["x", "y"], [1.0, 2.0])
    b = Assoc(["a"], ["x"], [-1.0])
    s = a + b  # (a,x) cancels to zero → row a / col x become empty
    assert s.to_dict() == {("b", "y"): 2.0}
    assert s.row.tolist() == ["b"] and s.col.tolist() == ["y"]


def test_string_add_concat_and_min_combine():
    a = Assoc(["r"], ["c"], ["ab"])
    b = Assoc(["r"], ["c"], ["cd"])
    assert (a + b).get("r", "c") == "abcd"
    assert a.min(b).get("r", "c") == "ab"
    assert a.max(b).get("r", "c") == "cd"


def test_mixed_mul_mask_semantics():
    s = Assoc(["r1", "r2"], ["c", "c"], ["hello", "world"])
    m = Assoc(["r1"], ["c"], [1.0])
    masked = s * m                       # numeric masks string
    assert masked.to_dict() == {("r1", "c"): "hello"}
    num = Assoc(["r1", "r2"], ["c", "c"], [3.0, 4.0])
    out = num * s                        # string → logical() → numeric
    assert out.to_dict() == {("r1", "c"): 3.0, ("r2", "c"): 4.0}


def test_matmul_with_string_operand_uses_logical():
    s = Assoc(["r"], ["k"], ["word"])
    n = Assoc(["k"], ["c"], [7.0])
    assert (s @ n).to_dict() == {("r", "c"): 7.0}


def test_sqin_sqout():
    a = Assoc(["d1", "d1", "d2"], ["t1", "t2", "t1"], [1.0, 1.0, 1.0])
    co = a.sqin()   # AᵀA: term co-occurrence
    assert co.get("t1", "t1") == 2.0 and co.get("t1", "t2") == 1.0
    sim = a.sqout()  # AAᵀ: doc similarity
    assert sim.get("d1", "d2") == 1.0


def test_sum_axes():
    a = Assoc(["r1", "r1", "r2"], ["c1", "c2", "c1"], [1.0, 2.0, 3.0])
    assert a.sum() == 6.0
    cols = a.sum(axis=0)
    assert cols.get("sum", "c1") == 4.0 and cols.get("sum", "c2") == 2.0
    rows = a.sum(axis=1)
    assert rows.get("r1", "sum") == 3.0

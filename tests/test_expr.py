"""Lazy D4M expressions: planner rewrites, fusion, 3-layer parity, guards.

The contract under test: ``expr.collect()`` equals the eager chain on the
host ``Assoc``, the device ``AssocTensor`` and the sharded ``DistAssoc``
for every registered semiring — while the planner pushes selectors,
collapses ``MatMul→Reduce`` onto the fused epilogues, fuses ⊕ chains into
one canonicalize pass, hash-conses repeated subtrees (``PLAN_STATS``) and
NEVER materializes the sliced operands of a fused select+matmul.
"""
import jax
import numpy as np
import pytest

from repro.core import (Assoc, AssocTensor, DISPATCH_STATS, EwiseAdd,
                        EwiseMul, LazyExpr, MatMul, Mask, PLAN_STATS,
                        Positions, Range, Reduce, REGISTRY, Select, Source,
                        StartsWith, Transpose, lazy)
from repro.core import plan
from repro.core.dist_assoc import DistAssoc
from repro.core.select import All

rng = np.random.default_rng(17)


def _triples(seed, n=60, nr=30, nc=30):
    r = np.random.default_rng(seed)
    return (r.integers(0, nr, n).astype(str),
            r.integers(0, nc, n).astype(str),
            r.uniform(0.5, 5.0, n))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1,), ("data",))


@pytest.fixture(scope="module")
def layers(mesh):
    """(host, device, dist) triplets of two arrays A, B."""
    rows, cols, vals = _triples(3)
    rows2, cols2, vals2 = _triples(5, nc=20)
    ha = Assoc(rows, cols, vals, aggregate="sum")
    hb = Assoc(rows2, cols2, vals2, aggregate="sum")
    da = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                  capacity=64)
    db = AssocTensor.from_triples(rows2, cols2, vals2, aggregate="sum",
                                  capacity=64)
    Da = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    return ha, hb, da, db, Da


def _close(got: dict, want: dict, tol=1e-3):
    assert set(got) == set(want), set(got) ^ set(want)
    for k in want:
        assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), \
            (k, got[k], want[k])


def _vec_dict(vec, keys, zero):
    return {k: v for k, v in zip(keys, np.asarray(vec, np.float64).tolist())
            if v != zero and not (np.isinf(zero) and np.isinf(v)
                                  and (v < 0) == (zero < 0))}


SEL = Range("1", "2")


# ---------------------------------------------------------------------------
# 3-layer parity: collect() ≡ eager, full semiring registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
def test_parity_select_matmul(layers, sr_name):
    ha, hb, da, db, Da = layers
    sr = REGISTRY[sr_name]
    want = ha._select_eager((SEL, slice(None))).matmul(hb, sr).to_dict()
    got_h = ha.lazy()[SEL, :].matmul(hb.lazy(), semiring=sr).collect()
    _close(got_h.to_dict(), want)
    got_d = da.lazy()[SEL, :].matmul(db.lazy(), semiring=sr).collect()
    _close(got_d.to_assoc().to_dict(), want)
    got_D = Da.lazy()[SEL, :].matmul(db.lazy(), semiring=sr).collect()
    _close(got_D.to_assoc().to_dict(), want)


@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
def test_parity_fused_matmul_reduce(layers, sr_name):
    ha, hb, da, db, Da = layers
    sr = REGISTRY[sr_name]
    C = ha._select_eager((SEL, slice(None))).matmul(hb, sr)
    want = _vec_dict(plan.host_axis_reduce(C, 1, sr), C.row.tolist(), sr.zero)
    g_h = ha.lazy()[SEL, :].matmul(hb.lazy(), semiring=sr) \
            .sum(axis=1, semiring=sr).collect()
    _close(_vec_dict(g_h, ha.row.tolist(), sr.zero), want)
    g_d = da.lazy()[SEL, :].matmul(db.lazy(), semiring=sr) \
            .sum(axis=1, semiring=sr).collect()
    _close(_vec_dict(g_d, da.row_space.keys.tolist(), sr.zero), want)
    g_D = Da.lazy()[SEL, :].matmul(db.lazy(), semiring=sr) \
            .sum(axis=1, semiring=sr).collect()
    _close(_vec_dict(g_D, Da.local.row_space.keys.tolist(), sr.zero), want)


@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
def test_parity_ewise(layers, sr_name):
    ha, hb, da, db, _ = layers
    sr = REGISTRY[sr_name]
    want_add = ha.add(hb, sr).to_dict()
    _close(ha.lazy().add(hb.lazy(), semiring=sr).collect().to_dict(),
           want_add)
    _close(da.lazy().add(db.lazy(), semiring=sr).collect()
           .to_assoc().to_dict(), want_add)
    want_mul = ha.mul(hb, sr).to_dict()
    _close(ha.lazy().mul(hb.lazy(), semiring=sr).collect().to_dict(),
           want_mul)
    _close(da.lazy().mul(db.lazy(), semiring=sr).collect()
           .to_assoc().to_dict(), want_mul)


def test_parity_sum_axis(layers):
    ha, _, da, _, Da = layers
    want = {k[0]: v for k, v in ha.sum(axis=1).to_dict().items()}
    got_h = _vec_dict(ha.lazy().sum(axis=1).collect(), ha.row.tolist(), 0.0)
    _close(got_h, want)
    got_d = _vec_dict(da.lazy().sum(axis=1).collect(),
                      da.row_space.keys.tolist(), 0.0)
    _close(got_d, want, tol=1e-4)
    got_D = _vec_dict(Da.lazy().sum(axis=1).collect(),
                      Da.local.row_space.keys.tolist(), 0.0)
    _close(got_D, want, tol=1e-4)
    # axis=0 and scalar
    want0 = {k[1]: v for k, v in ha.sum(axis=0).to_dict().items()}
    _close(_vec_dict(da.lazy().sum(axis=0).collect(),
                     da.col_space.keys.tolist(), 0.0), want0, tol=1e-4)
    assert abs(float(ha.lazy().sum().collect()) - ha.sum()) < 1e-9
    assert abs(float(da.lazy().sum().collect()) - ha.sum()) < 1e-2


def test_parity_transpose_dist_ewise(layers, mesh):
    ha, _, da, _, Da = layers
    want = ha.transpose().to_dict()
    _close(ha.lazy().T.collect().to_dict(), want)
    _close(da.lazy().T.collect().to_assoc().to_dict(), want)
    # dist transpose gathers to a replicated device tensor (sqin rule)
    _close(Da.lazy().T.collect().to_assoc().to_dict(), want)
    # dist element-wise on shared keyspaces
    want2 = (ha + ha).to_dict()
    _close((Da.lazy() + Da.lazy()).collect().to_assoc().to_dict(), want2,
           tol=1e-4)


# ---------------------------------------------------------------------------
# planner rewrites
# ---------------------------------------------------------------------------

def _src():
    return Source(object())


def test_pushdown_through_transpose():
    e = plan.optimize(Transpose(_src())[StartsWith("a"), Range("b", "c")])
    assert isinstance(e, Transpose)
    inner = e.child
    assert isinstance(inner, Select)
    assert isinstance(inner.row_sel, Range)       # axes swapped
    assert isinstance(inner.col_sel, StartsWith)
    assert PLAN_STATS["pushdown"] == 1


def test_pushdown_through_ewise_and_matmul():
    e = plan.optimize(EwiseAdd(_src(), _src())[StartsWith("a"), :])
    assert isinstance(e, EwiseAdd)
    assert isinstance(e.a, Select) and isinstance(e.b, Select)
    m = plan.optimize(MatMul(_src(), _src())[StartsWith("a"), Range("b", "c")])
    assert isinstance(m, MatMul)
    assert isinstance(m.a, Select) and isinstance(m.a.row_sel, StartsWith)
    assert isinstance(m.a.col_sel, All)           # contraction untouched
    assert isinstance(m.b, Select) and isinstance(m.b.col_sel, Range)
    assert PLAN_STATS["pushdown"] == 2


def test_nested_selects_compose():
    e = plan.optimize(_src()[StartsWith("a"), :][Range("b", "c"), :])
    assert isinstance(e, Select) and isinstance(e.child, Source)


def test_positions_and_mask_not_pushed():
    e = plan.optimize(Transpose(_src())[Positions([0, 2]), :])
    assert isinstance(e, Select)                  # stayed on top
    assert isinstance(e.child, Transpose)
    m = plan.optimize(EwiseAdd(_src(), _src())[Mask(np.ones(3, bool)), :])
    assert isinstance(m, Select)
    assert PLAN_STATS["pushdown"] == 0


def test_matmul_reduce_fuses_only_on_matching_semiring():
    e = plan.optimize(MatMul(_src(), _src()).sum(axis=1))
    assert isinstance(e, plan._MatMulReduce)
    # mismatched ⊕ must NOT fuse: the user asked for a different monoid
    e2 = plan.optimize(MatMul(_src(), _src()).sum(axis=1, semiring="max_min"))
    assert isinstance(e2, Reduce)
    # full reduction (axis=None) keeps the product either
    e3 = plan.optimize(MatMul(_src(), _src()).sum())
    assert isinstance(e3, Reduce)


def test_ewise_chain_flattens():
    e = plan.optimize(_src() + _src() + _src() + _src())
    assert isinstance(e, plan._EwiseAddN)
    assert len(e.terms) == 4
    assert PLAN_STATS["ewise_fused"] == 1


# ---------------------------------------------------------------------------
# Reduce pushed through EwiseAdd (⊕-chain reduction without materializing
# the merged array)
# ---------------------------------------------------------------------------

def test_reduce_through_add_structural():
    from repro.core.semiring import get_semiring
    sr = get_semiring("plus_times")
    e = plan.optimize(Reduce(EwiseAdd(_src(), _src(), semiring=sr), 1, sr))
    assert isinstance(e, plan._ReduceAddN)
    assert len(e.terms) == 2
    assert PLAN_STATS["reduce_through_add"] == 1
    # a flattened 3-term chain fuses as one _ReduceAddN
    e3 = plan.optimize(Reduce(_src() + _src() + _src(), 0, sr))
    assert isinstance(e3, plan._ReduceAddN)
    assert len(e3.terms) == 3
    # mismatched ⊕ monoids must NOT fuse (sum-merge then max-reduce)
    e2 = plan.optimize(Reduce(EwiseAdd(_src(), _src(), semiring=sr), 1,
                              get_semiring("max_plus")))
    assert isinstance(e2, Reduce)
    # axis=None keeps the merged array (scalar reduce needs it whole)
    en = plan.optimize(Reduce(EwiseAdd(_src(), _src(), semiring=sr),
                              None, sr))
    assert isinstance(en, Reduce)


@pytest.mark.parametrize("sr_name", ["plus_times", "max_plus", "min_plus"])
@pytest.mark.parametrize("axis", [0, 1])
def test_reduce_through_add_parity(layers, sr_name, axis):
    ha, hb, da, db, Da = layers
    sr = REGISTRY[sr_name]
    merged = ha.add(hb, sr)
    keys = merged.row if axis == 1 else merged.col
    want = _vec_dict(plan.host_axis_reduce(merged, axis, sr),
                     keys.tolist(), sr.zero)

    got_h = (ha.lazy().add(hb.lazy(), semiring=sr)
             .sum(axis=axis, semiring=sr).collect())
    assert PLAN_STATS["reduce_through_add"] >= 1
    _close(_vec_dict(got_h, keys.tolist(), sr.zero), want)

    got_d = (da.lazy().add(db.lazy(), semiring=sr)
             .sum(axis=axis, semiring=sr).collect())
    dspace = da.row_space.union(db.row_space)[0] if axis == 1 else \
        da.col_space.union(db.col_space)[0]
    _close(_vec_dict(got_d, dspace.keys.tolist(), sr.zero), want, tol=1e-4)

    # dist ⊕ needs aligned keyspaces: A ⊕ A over the same DistAssoc
    want_s = _vec_dict(plan.host_axis_reduce(ha.add(ha, sr), axis, sr),
                       (ha.row if axis == 1 else ha.col).tolist(), sr.zero)
    got_D = ((Da.lazy().add(Da.lazy(), semiring=sr))
             .sum(axis=axis, semiring=sr).collect())
    Dspace = Da.local.row_space if axis == 1 else Da.local.col_space
    _close(_vec_dict(got_D, Dspace.keys.tolist(), sr.zero), want_s, tol=1e-4)


def test_reduce_through_add_string_fallback():
    # string ⊕ concatenates before logical() flattens — the scatter fast
    # path would double-count overlaps, so the planner's rewrite still
    # fires but the executor materializes the chain first
    a = Assoc(["r1", "r2"], ["c1", "c1"], ["x", "y"])
    b = Assoc(["r1", "r3"], ["c1", "c1"], ["z", "w"])
    # ("r1","c1") overlaps: concat-then-logical counts it ONCE; a naive
    # per-entry scatter would have counted 2
    want = plan.host_axis_reduce(a.add(b), 1)
    got = (a.lazy() + b.lazy()).sum(axis=1).collect()
    assert PLAN_STATS["reduce_through_add"] == 1      # rewrite fired…
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hash-consing (PLAN_STATS) + fusion counters on real executions
# ---------------------------------------------------------------------------

def test_hash_consing_repeated_subtree(layers):
    ha, hb, *_ = layers
    sq = ha.lazy() @ ha.lazy().T
    out = (sq * sq).collect()
    # the repeated AAᵀ subtree evaluates once: one hit, and the memoized
    # result feeds both EwiseMul operands
    assert PLAN_STATS["hits"] == 1
    want = (lambda c: (c * c).to_dict())(ha @ ha.T)
    _close(out.to_dict(), want)


def test_fusion_counters_fire(layers):
    ha, hb, da, db, _ = layers
    (ha.lazy()[SEL, :] @ hb.lazy()).sum(axis=1).collect()
    assert PLAN_STATS["fused_matmul_reduce"] == 1
    assert PLAN_STATS["fused_select_matmul"] == 1
    (da.lazy() + db.lazy() + da.lazy()).collect()
    assert PLAN_STATS["ewise_fused"] == 1


def test_ewise_chain_fusion_parity(layers):
    ha, hb, da, db, Da = layers
    want = (ha + hb + ha).to_dict()
    _close((ha.lazy() + hb.lazy() + ha.lazy()).collect().to_dict(), want)
    _close((da.lazy() + db.lazy() + da.lazy()).collect()
           .to_assoc().to_dict(), want)
    wantD = (ha + ha + ha).to_dict()
    _close((Da.lazy() + Da.lazy() + Da.lazy()).collect()
           .to_assoc().to_dict(), wantD, tol=1e-4)


# ---------------------------------------------------------------------------
# the never-materializes guard: fused select+matmul builds no sliced array
# ---------------------------------------------------------------------------

def _forbid_selection(monkeypatch):
    def boom(self, *a, **k):  # pragma: no cover - failure path
        raise AssertionError("sliced operand was materialized")
    monkeypatch.setattr(Assoc, "_select_eager", boom)
    monkeypatch.setattr(AssocTensor, "_compact", boom)
    monkeypatch.setattr(DistAssoc, "_select_eager", boom)


def test_never_materializes_fused_select_matmul(layers, monkeypatch):
    ha, hb, da, db, Da = layers
    want = ha._select_eager((SEL, slice(None))) \
        .matmul(ha._select_eager((slice(None), SEL)).T).to_dict()
    _forbid_selection(monkeypatch)
    got_h = (ha.lazy()[SEL, :] @ ha.lazy()[:, SEL].T).collect()
    got_d = (da.lazy()[SEL, :] @ da.lazy()[:, SEL].T).collect()
    got_D = (Da.lazy()[SEL, :] @ db.lazy()[SEL, :].T).collect()
    # fused reduce epilogue under the same guard
    vec = (da.lazy()[SEL, :] @ db.lazy()).sum(axis=1).collect()
    monkeypatch.undo()
    _close(got_h.to_dict(), want)
    _close(got_d.to_assoc().to_dict(), want)
    wantD = ha._select_eager((SEL, slice(None))) \
        .matmul(hb._select_eager((SEL, slice(None))).T).to_dict()
    _close(got_D.to_assoc().to_dict(), wantD)
    Cw = ha._select_eager((SEL, slice(None))) @ hb
    wantv = plan.host_axis_reduce(Cw, 1)
    gotv = _vec_dict(vec, da.row_space.keys.tolist(), 0.0)
    _close(gotv, _vec_dict(wantv, Cw.row.tolist(), 0.0))


# ---------------------------------------------------------------------------
# operators accept expression nodes (deferred, not collected)
# ---------------------------------------------------------------------------

def test_mixed_eager_lazy_operands(layers):
    ha, hb, da, db, _ = layers
    e = ha @ hb.lazy()
    assert isinstance(e, LazyExpr)                # deferred, not an Assoc
    _close(e.collect().to_dict(), (ha @ hb).to_dict())
    e2 = da + db.lazy()
    assert isinstance(e2, LazyExpr)
    _close(e2.collect().to_assoc().to_dict(), (da + db).to_assoc().to_dict())


def test_sqin_sqout_lazy(layers):
    ha, _, da, _, _ = layers
    _close(ha.lazy().sqin().collect().to_dict(), ha.sqin().to_dict())
    v = da.lazy().sqout(reduce=1).collect()
    np.testing.assert_allclose(np.asarray(v), np.asarray(da.sqout(reduce=1)),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# shared reduce path (satellite): eager sum/reduce_rows route through plan
# ---------------------------------------------------------------------------

def test_assoc_sum_semiring_generic():
    a = Assoc(["r1", "r1", "r2"], ["c1", "c2", "c1"], [1.0, 2.0, 3.0])
    assert a.sum() == 6.0
    assert a.sum(axis=1).get("r1", "sum") == 3.0
    mx = a.sum(axis=0, semiring="max_times")
    assert mx.get("sum", "c1") == 3.0 and mx.get("sum", "c2") == 2.0


def test_tensor_reduce_cols(layers):
    _, _, da, _, _ = layers
    want = np.asarray(da.transpose().reduce_rows())
    got = np.asarray(da.reduce_cols())
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_dist_row_reduce(layers):
    ha, _, _, _, Da = layers
    want = {k[0]: v for k, v in ha.sum(axis=1).to_dict().items()}
    got = _vec_dict(Da.row_reduce(), Da.local.row_space.keys.tolist(), 0.0)
    _close(got, want, tol=1e-4)


# ---------------------------------------------------------------------------
# DistAssoc.__setitem__ (satellite): shard-local selector assignment
# ---------------------------------------------------------------------------

def test_dist_setitem_parity(mesh):
    rows, cols, vals = _triples(11)
    dt = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                  capacity=64)
    Dd = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    dt[SEL, :] = 9.0
    Dd[SEL, :] = 9.0
    _close(Dd.to_assoc().to_dict(), dt.to_assoc().to_dict(), tol=1e-5)
    # scattered selector form too
    dt[Mask(np.arange(len(dt.row_space)) % 3 == 0), :] = 2.5
    Dd[Mask(np.arange(len(Dd.local.row_space)) % 3 == 0), :] = 2.5
    _close(Dd.to_assoc().to_dict(), dt.to_assoc().to_dict(), tol=1e-5)
    with pytest.raises(TypeError):
        Dd[SEL, :] = "nope"


# ---------------------------------------------------------------------------
# misc API
# ---------------------------------------------------------------------------

def test_plan_stats_exported():
    from repro.core import PLAN_STATS as ps
    assert set(ps) >= {"hits", "misses", "pushdown", "fused_matmul_reduce",
                       "fused_select_matmul", "ewise_fused"}
    assert all(v == 0 for v in ps.values())


def test_reduce_rejects_bad_axis(layers):
    ha, *_ = layers
    with pytest.raises(ValueError):
        ha.lazy().sum(axis=2)


def test_cross_layer_ewise_raises(layers):
    ha, _, da, _, _ = layers
    with pytest.raises(TypeError):
        (ha.lazy() + da.lazy()).collect()


def test_chained_reduce():
    a = Assoc(["r1", "r1", "r2"], ["c1", "c2", "c1"], [1.0, 2.0, 3.0])
    assert float((a.lazy() @ a.lazy().T).sum(axis=1).sum().collect()) == \
        pytest.approx(float(plan.host_axis_reduce(a @ a.T, None)))
    with pytest.raises(ValueError):
        a.lazy().sum(axis=1).sum(axis=0).collect()


# ---------------------------------------------------------------------------
# true multi-shard run (8 simulated devices, subprocess — the XLA device
# count locks at first jax init, so this cannot run in-process)
# ---------------------------------------------------------------------------

DIST_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.core import Assoc, AssocTensor, PLAN_STATS, Range, reset_plan_stats
from repro.core import plan
from repro.core.dist_assoc import DistAssoc

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
n = 96
rows = rng.integers(0, 40, n).astype(str)
cols = rng.integers(0, 40, n).astype(str)
vals = rng.uniform(0.5, 5.0, n)
D = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
H = Assoc(rows, cols, vals, aggregate="sum")
sel = Range("1", "2")

def close(g, w, tol=1e-3):
    assert set(g) == set(w), sorted(set(g) ^ set(w))
    for k in w:
        assert abs(g[k] - w[k]) <= tol * (1 + abs(w[k])), (k, g[k], w[k])

# fused select+matmul+reduce, shard-locally masked (zero collectives in
# the product, one in the reduce)
bt = H[sel, :].T.to_tensor()
reset_plan_stats()
vec = (D.lazy()[sel, :] @ bt.lazy()).sum(axis=1).collect()
assert PLAN_STATS["fused_select_matmul"] == 1, PLAN_STATS
assert PLAN_STATS["fused_matmul_reduce"] == 1, PLAN_STATS
C = H[sel, :] @ H[sel, :].T
want = dict(zip(C.row.tolist(), plan.host_axis_reduce(C, 1).tolist()))
got = {k: v for k, v in zip(D.local.row_space.keys.tolist(),
                            np.asarray(vec).tolist()) if v != 0}
close(got, want)

# unreduced fused select+matmul
g2 = (D.lazy()[sel, :] @ bt.lazy()).collect().to_assoc().to_dict()
close(g2, C.to_dict())

# __setitem__ parity against the single-device AssocTensor semantics
T = AssocTensor.from_triples(rows, cols, vals, aggregate="sum", capacity=128)
T[sel, "2,:,3,"] = 7.5
D[sel, "2,:,3,"] = 7.5
close(D.to_assoc().to_dict(), T.to_assoc().to_dict(), tol=1e-4)

# lazy sqin/sqout on a sharded array: the transpose gathers, and the
# still-sharded other operand must be pulled to replicated (eager rule)
D2 = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
close(D2.lazy().sqin().collect().to_assoc().to_dict(),
      H.sqin().to_dict(), tol=1e-3)
vq = D2.lazy().sqout(reduce=1).collect()
wq = D2.sqout(reduce=1)
assert np.allclose(np.asarray(vq), np.asarray(wq), rtol=1e-4, atol=1e-4)

# n-ary ewise fusion on 8 shards + row_reduce
g3 = (D2.lazy() + D2.lazy() + D2.lazy()).collect().to_assoc().to_dict()
close(g3, (H + H + H).to_dict(), tol=1e-4)
rr = {k: v for k, v in zip(D2.local.row_space.keys.tolist(),
                           np.asarray(D2.row_reduce()).tolist()) if v != 0}
close(rr, {k[0]: v for k, v in H.sum(axis=1).to_dict().items()}, tol=1e-4)

print(json.dumps({"ok": True}))
"""


def test_eight_shard_pipeline():
    import json
    import subprocess
    import sys

    env = {k: v for k, v in __import__("os").environ.items()
           if k != "XLA_FLAGS"}
    out = subprocess.run([sys.executable, "-c", DIST_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]

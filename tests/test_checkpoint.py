"""Checkpoint roundtrip, crash-safety, retention, async manager."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpoint import latest_step


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
                       "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))},
            "opt": {"m": {"w": jnp.zeros((4, 3)), "b": jnp.ones((3,))},
                    "count": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 42, s, extra={"pipeline": {"step": 9}})
    target = jax.tree_zeros_like(s) if False else _state(seed=99)
    restored, step, extra = restore_checkpoint(str(tmp_path), target)
    assert step == 42 and extra["pipeline"]["step"] == 9
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402


def test_crash_safety_tmp_not_visible(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    # simulate a crashed half-write
    os.makedirs(tmp_path / "step_00000002.tmp" / "arrays", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1  # tmp dir ignored


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_interval_steps=5)
    s = _state()
    for step in (5, 10, 15):
        assert mgr.should_save(step)
        mgr.save_async(step, s, extra={"step": step})
    mgr.wait()
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000010", "step_00000015"]  # keep=2
    restored, step, extra = mgr.restore_latest(_state(1))
    assert step == 15 and extra["step"] == 15


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"w": jnp.zeros((4,))})


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint written under one (trivial) mesh restores under another
    sharding layout — leaves are stored as GLOBAL arrays."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    s = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 3, s)
    shardings = {"w": NamedSharding(mesh, P("data"))}
    restored, step, _ = restore_checkpoint(str(tmp_path), s,
                                           shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8))
    assert restored["w"].sharding == shardings["w"]

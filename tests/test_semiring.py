"""Property tests: the registered semirings satisfy the §I.A axioms."""
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import semiring as SR

SEMIRINGS = [SR.PLUS_TIMES, SR.MAX_PLUS, SR.MIN_PLUS, SR.MAX_MIN, SR.MAX_TIMES]

# magnitudes ≥ 1e-6 (or exactly 0): XLA CPU flushes f32 subnormals to zero,
# which would falsify max(u, 0) == u for u ≈ 1e-40 — an FTZ artifact, not an
# algebra violation.
_mag = st.floats(min_value=2.0 ** -20, max_value=1e6, allow_nan=False,
                 allow_subnormal=False, width=32)
finite = st.one_of(st.just(0.0), _mag, _mag.map(lambda x: -x))
nonneg = st.one_of(st.just(0.0), _mag)


def _vals_for(sr):
    # max_times needs nonnegative values for ⊗-associativity w/ max
    return nonneg if sr.name == "max_times" else finite


@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
class TestAxioms:
    @given(data=st.data())
    def test_add_assoc_comm(self, sr, data):
        u, v, w = (data.draw(_vals_for(sr)) for _ in range(3))
        assert np.isclose(sr.add_py(sr.add_py(u, v), w),
                          sr.add_py(u, sr.add_py(v, w)), rtol=1e-5, atol=1e-4)
        assert sr.add_py(u, v) == sr.add_py(v, u)

    @given(data=st.data())
    def test_mul_assoc(self, sr, data):
        u, v, w = (data.draw(_vals_for(sr)) for _ in range(3))
        assert np.isclose(sr.mul_py(sr.mul_py(u, v), w),
                          sr.mul_py(u, sr.mul_py(v, w)), rtol=1e-4, atol=1e-3)

    @given(data=st.data())
    def test_identities_annihilator(self, sr, data):
        u = data.draw(_vals_for(sr))
        assert sr.add_py(u, sr.zero) == u
        assert np.isclose(sr.mul_py(u, sr.one), u, rtol=1e-6, atol=1e-6)
        assert sr.mul_py(u, sr.zero) in (sr.zero,) or np.isclose(
            sr.mul_py(u, sr.zero), sr.zero)

    @given(data=st.data())
    def test_distributivity(self, sr, data):
        u, v, w = (data.draw(_vals_for(sr)) for _ in range(3))
        lhs = sr.mul_py(u, sr.add_py(v, w))
        rhs = sr.add_py(sr.mul_py(u, v), sr.mul_py(u, w))
        assert np.isclose(lhs, rhs, rtol=1e-4, atol=1e-3)


def test_string_algebra():
    s = SR.STRING
    assert s.add_py("ab", "cd") == "abcd"          # ⊕ = concatenation
    assert s.mul_py("ab", "cd") == "ab"            # ⊗ = min (dict order)
    assert s.add_py("x", s.zero) == "x"            # ε identity
    # nonunital: no claimed ⊗ identity


def test_matmul_dense_matches_numpy():
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=(5, 7)), rng.normal(size=(7, 3))
    out = np.asarray(SR.PLUS_TIMES.matmul_dense(a, b))
    np.testing.assert_allclose(out, a @ b, rtol=1e-5)
    mp = np.asarray(SR.MAX_PLUS.matmul_dense(a, b))
    ref = (a[:, :, None] + b[None, :, :]).max(axis=1)
    np.testing.assert_allclose(mp, ref, rtol=1e-5)

"""Serve subsystem tests: registry, engine (admission batching, plan-cache
hits across requests), HTTP server/client end-to-end, and the
multithreaded hammer over the now-locked core caches."""
import json
import threading

import numpy as np
import pytest

from repro.core import (CACHE_STATS, PLAN_STATS, Assoc, Keys, StartsWith,
                        compile_selector, reset_all_stats)
from repro.serve import (D4MClient, D4MServer, Engine, ServerError, TableRef,
                         TableRegistry, WireError, start_server, to_wire)
from repro.serve.registry import generate_triples, load_triples_file


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def registry():
    return TableRegistry.from_specs([
        {"name": "edges", "generator": "random", "n": 64, "nnz": 512,
         "seed": 0, "layer": "device"},
        {"name": "feat", "generator": "random", "n": 64, "nnz": 512,
         "seed": 1, "layer": "device"},
        {"name": "hostt", "generator": "random", "n": 32, "nnz": 128,
         "seed": 2, "layer": "host"},
    ])


@pytest.fixture()
def engine(registry):
    with Engine(registry, workers=2, max_batch=4) as eng:
        yield eng


def _pipeline_payload(prefix="r0"):
    A, B = TableRef("edges"), TableRef("feat")
    return to_wire((A[StartsWith(prefix), :] @ B).sum(axis=1))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_load_triples_file(tmp_path):
    p = tmp_path / "t.tsv"
    p.write_text("# comment\nr0\tc0\t1.5\nr1\tc1\t2.5\n\nr0\tc1\t3.0\n")
    rows, cols, vals = load_triples_file(str(p))
    assert list(rows) == ["r0", "r1", "r0"]
    assert vals.dtype.kind == "f" and vals[2] == 3.0
    # comma fallback + string values
    q = tmp_path / "t.csv"
    q.write_text("a,b,blue\nc,d,red\n")
    _, _, v2 = load_triples_file(str(q))
    assert v2.dtype.kind == "U" and list(v2) == ["blue", "red"]
    # malformed line is a clear error
    bad = tmp_path / "bad.tsv"
    bad.write_text("only_one_field\n")
    with pytest.raises(ValueError, match="bad.tsv:1"):
        load_triples_file(str(bad))


def test_generate_triples_deterministic():
    a = generate_triples({"generator": "random", "n": 32, "nnz": 64,
                          "seed": 7})
    b = generate_triples({"generator": "random", "n": 32, "nnz": 64,
                          "seed": 7})
    assert list(a[0]) == list(b[0]) and np.allclose(a[2], b[2])


def test_registry_info_and_lookup(registry):
    assert len(registry) == 3 and "edges" in registry
    info = {i["name"]: i for i in registry.list_info()}
    assert info["edges"]["layer"] == "device"
    assert info["hostt"]["layer"] == "host"
    assert info["edges"]["nnz"] > 0
    with pytest.raises(WireError) as ei:
        registry.get("ghost")
    assert ei.value.code == "unknown_table"
    with pytest.raises(TypeError):
        registry.register("bad", object())


def test_registry_file_spec_roundtrip(tmp_path):
    p = tmp_path / "edges.tsv"
    p.write_text("r0\tc0\t1.0\nr1\tc1\t2.0\n")
    reg = TableRegistry.from_specs([{"name": "e", "path": str(p)}])
    assert isinstance(reg.get("e"), Assoc)
    assert reg.layer_of("e") == "host"


# ---------------------------------------------------------------------------
# engine: execution, batching, plan-cache behaviour, errors
# ---------------------------------------------------------------------------

def test_engine_executes_and_repeats_hit_plan_cache(engine):
    payload = _pipeline_payload()
    out1 = engine.query(payload)
    assert out1["result"]["kind"] == "vector"
    h0, m0 = PLAN_STATS["plan_hits"], PLAN_STATS["plan_misses"]
    out2 = engine.query(payload)
    assert PLAN_STATS["plan_hits"] == h0 + 1
    assert PLAN_STATS["plan_misses"] == m0
    assert out1["result"]["vals"] == out2["result"]["vals"]
    assert out2["timing"]["exec_s"] >= 0


def test_engine_triples_and_scalar_results(engine):
    A = TableRef("edges")
    out = engine.query(to_wire(A[StartsWith("r0"), :]))
    assert out["result"]["kind"] == "triples"
    assert out["result"]["nnz"] == len(out["result"]["rows"])
    out = engine.query(to_wire(A.sum(axis=None)))
    assert out["result"]["kind"] == "scalar"
    assert out["result"]["val"] > 0


def test_engine_result_truncation(engine):
    A = TableRef("edges")
    out = engine.query(to_wire(A[:, :]), options={"limit": 3})
    assert out["result"]["truncated"] is True
    assert len(out["result"]["rows"]) == 3
    assert out["result"]["nnz"] > 3       # true count still reported


def test_engine_malformed_rejected_synchronously(engine):
    with pytest.raises(WireError) as ei:
        engine.submit({"version": 1, "nodes": [{"op": "table",
                                                "name": "ghost"}],
                       "root": 0})
    assert ei.value.code == "unknown_table"


def test_engine_admission_key_groups_by_tables_and_layer(engine):
    k1 = engine._admission_key(_pipeline_payload("r0"))
    k2 = engine._admission_key(_pipeline_payload("r1"))
    assert k1 == k2                      # same tables, batchable
    k3 = engine._admission_key(to_wire(TableRef("hostt")[:, :]))
    assert k3 != k1                      # different table set / layer
    assert k3[0] == "query"              # disjoint from ("ingest", name)
    assert k3[2] == ("host",)


def test_engine_batches_compatible_requests(registry):
    # single worker + a large batch window: concurrent same-key submits
    # coalesce into one admitted batch
    with Engine(registry, workers=1, max_batch=8) as eng:
        # stall the worker with one slow-ish query, then pile up 4 more
        reqs = [eng.submit(_pipeline_payload()) for _ in range(5)]
        for r in reqs:
            r.wait(timeout=120)
        st = eng.stats()
        assert st["server"]["requests"] == 5
        # at least one admitted batch carried >1 request
        assert max(r.batch_size for r in reqs) > 1
        assert st["server"]["batch_mean"] > 1.0


def test_engine_stats_shape_and_reset(engine):
    engine.query(_pipeline_payload())
    st = engine.stats()
    assert {"server", "plan", "cache", "union", "dispatch",
            "queue_depth", "workers"} <= set(st)
    assert st["server"]["requests"] >= 1
    assert "p50_s" in st["server"] and "p99_s" in st["server"]
    engine.reset_stats()
    st2 = engine.stats()
    assert st2["server"].get("requests", 0.0) == 0.0
    assert st2["plan"]["plan_hits"] == 0


# ---------------------------------------------------------------------------
# HTTP server + client end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server(registry):
    srv = start_server(registry, workers=2)
    yield srv
    srv.close()


@pytest.fixture()
def client(server):
    return D4MClient(server.url, timeout=120)


def test_http_health_and_tables(client):
    h = client.health()
    assert h["status"] == "ok" and h["tables"] == 3
    names = {t["name"] for t in client.tables()}
    assert names == {"edges", "feat", "hostt"}


def test_http_query_roundtrip(client):
    A, B = TableRef("edges"), TableRef("feat")
    out = client.query((A[StartsWith("r0"), :] @ B).sum(axis=1))
    assert out["result"]["kind"] == "vector"
    assert out["batch"] >= 1


def test_http_stats_exposes_core_counters(client):
    client.reset_stats()
    expr = (TableRef("edges")[StartsWith("r0"), :]
            @ TableRef("feat")).sum(axis=1)
    client.query(expr)
    client.query(expr)
    st = client.stats()
    assert st["plan"]["plan_hits"] >= 1
    assert st["server"]["requests"] == 2.0


def test_http_malformed_is_400_not_500(client):
    with pytest.raises(ServerError) as ei:
        client.query({"version": 1, "nodes": [{"op": "table",
                                               "name": "ghost"}],
                      "root": 0})
    assert ei.value.status == 400 and ei.value.code == "unknown_table"
    with pytest.raises(ServerError) as ei:
        client.query({"version": 77, "nodes": [], "root": 0})
    assert ei.value.status == 400 and ei.value.code == "bad_version"
    with pytest.raises(ServerError) as ei:
        client._request("/query", {"not_expr": 1})
    assert ei.value.status == 400 and ei.value.code == "bad_payload"


def test_http_execution_error_is_422(client):
    # structurally valid wire payload whose execution fails: matmul with
    # mismatched inner keyspace types (string cols vs float rows is fine —
    # use a reduce of a matmul between incompatible tables instead)
    with pytest.raises(ServerError) as ei:
        client.query(TableRef("edges") @ TableRef("hostt"))
    assert ei.value.status in (422, 504)
    assert ei.value.code == "execution_error"


def test_http_404(client):
    with pytest.raises(ServerError) as ei:
        client._request("/nope")
    assert ei.value.status == 404


# ---------------------------------------------------------------------------
# acceptance: ≥4 concurrent clients, hot mix ⇒ plan_hits > plan_misses
# ---------------------------------------------------------------------------

def test_concurrent_hot_mix_plan_hits_exceed_misses(server):
    client = D4MClient(server.url, timeout=120)
    client.reset_stats()
    payload = _pipeline_payload()        # one hot multi-node pipeline
    client.query(payload)                # warm the plan once

    errs = []

    def worker():
        c = D4MClient(server.url, timeout=120)
        try:
            for _ in range(5):
                out = c.query(payload)
                assert out["result"]["kind"] == "vector"
        except Exception as exc:         # pragma: no cover
            errs.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs
    st = client.stats()
    assert st["server"]["requests"] == 21.0
    assert st["plan"]["plan_hits"] > st["plan"]["plan_misses"]


# ---------------------------------------------------------------------------
# hammer: the locked caches survive concurrent mutation pressure
# ---------------------------------------------------------------------------

def test_multithreaded_cache_hammer(registry):
    """Many threads pounding collect() + compile_selector concurrently:
    exercises _PLAN_CACHE, _COMPILE_CACHE, the union cache and the stats
    dicts under their new locks.  Without the locks this intermittently
    corrupts the OrderedDicts (KeyError/RuntimeError) or loses counts."""
    reset_all_stats()
    edges = registry.get("edges")
    feat = registry.get("feat")
    keys = edges.row_space.keys
    n_threads, n_iter = 8, 30
    errs = []
    barrier = threading.Barrier(n_threads)

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            barrier.wait(timeout=30)
            for i in range(n_iter):
                # rotate through a small set of selectors: repeats hit the
                # caches, fresh ones insert/evict
                lo = int(rng.integers(0, len(keys) - 8))
                sel = Keys(list(keys[lo:lo + 4]))
                compile_selector(sel, edges.row_space)
                if i % 3 == 0:
                    expr = (TableRef("edges")[StartsWith("r0"), :]
                            @ TableRef("feat")).sum(axis=1)
                    from repro.serve.wire import from_wire, to_wire
                    bound = from_wire(
                        to_wire(expr),
                        resolve=registry.resolve)
                    bound.collect()
        except Exception as exc:
            errs.append(exc)

    threads = [threading.Thread(target=worker, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=600)
    assert not errs, errs
    # locked counters lose no increments: every compile is a hit or miss
    assert (CACHE_STATS["hits"] + CACHE_STATS["misses"]
            >= n_threads * n_iter)
    # the hot pipeline planned once (or a few cold races), then hit
    assert PLAN_STATS["plan_hits"] > 0

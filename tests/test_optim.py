"""Optimizer: AdamW policies, schedules, clipping, q8 quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, dequantize_q8, quantize_q8,
                         wsd_schedule)


def _problem(seed=0, n=64):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(n,))
    x = rng.normal(size=(256, n))
    y = x @ w_true
    params = {"w": jnp.zeros((n,), jnp.float32)}

    def loss_fn(p):
        pred = jnp.asarray(x) @ p["w"]
        return jnp.mean((pred - jnp.asarray(y)) ** 2)

    return params, loss_fn


@pytest.mark.parametrize("policy", ["fp32", "bf16", "q8"])
def test_adamw_converges(policy):
    params, loss_fn = _problem()
    state = adamw_init(params, state_policy=policy)
    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = adamw_update(grads, state, params, lr=5e-2,
                                     weight_decay=0.0, state_policy=policy)
    l1 = float(loss_fn(params))
    assert l1 < 0.05 * l0, (policy, l0, l1)


def test_quantized_policies_track_fp32():
    """bf16/q8 moment storage stays close to the fp32 trajectory."""
    trajs = {}
    for policy in ["fp32", "bf16", "q8"]:
        params, loss_fn = _problem(seed=3)
        state = adamw_init(params, state_policy=policy)
        for _ in range(20):
            grads = jax.grad(loss_fn)(params)
            params, state = adamw_update(grads, state, params, lr=1e-2,
                                         weight_decay=0.01,
                                         state_policy=policy)
        trajs[policy] = np.asarray(params["w"])
    ref = trajs["fp32"]
    assert np.linalg.norm(trajs["bf16"] - ref) / np.linalg.norm(ref) < 0.05
    # q8 (int8 first moment) trades per-step precision for 4× memory; the
    # trajectory wanders but test_adamw_converges asserts it still solves
    # the problem — 8-bit Adam's standard contract.
    assert np.linalg.norm(trajs["q8"] - ref) / np.linalg.norm(ref) < 0.25


def test_q8_roundtrip():
    rng = np.random.default_rng(0)
    for shape in [(7,), (13, 300), (3, 5, 257)]:
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32) * 10)
        packed = quantize_q8(x)
        assert packed["q"].shape == x.shape   # shape-preserving (sharding!)
        back = dequantize_q8(packed, x.shape)
        err = np.abs(np.asarray(back) - np.asarray(x)).max()
        scale = np.abs(np.asarray(x)).max()
        assert err <= scale / 127 + 1e-6


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert np.isclose(float(gn), 10.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert np.isclose(total, 1.0, rtol=1e-5)


def test_wsd_schedule_shape():
    """Warmup-Stable-Decay (MiniCPM): flat stable phase, sharp tail."""
    kw = dict(peak_lr=1.0, warmup=10, total=100, decay_frac=0.2)
    lrs = np.asarray([float(wsd_schedule(t, **kw)) for t in range(101)])
    assert lrs[0] == 0.0 and lrs[9] < 1.0
    np.testing.assert_allclose(lrs[10:80], 1.0)          # stable
    assert lrs[85] < 1.0 and lrs[100] <= 0.02             # decay tail
    cos = np.asarray([float(cosine_schedule(t, peak_lr=1.0, warmup=10,
                                            total=100)) for t in range(101)])
    assert cos[55] < 1.0  # cosine decays immediately after warmup
    # WSD's stable phase is the contribution: it doesn't
    assert lrs[55] == 1.0


def test_adamw_matches_reference_manual():
    """One step vs hand-computed AdamW."""
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = adamw_init(p)
    p2, st2 = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.999, eps=1e-8,
                           weight_decay=0.0)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    step = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    want = np.asarray([1.0, -2.0]) - 0.1 * step
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)

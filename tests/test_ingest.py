"""Dynamic ingest tests: LSM delta buffers, merge-on-read parity across
all three layers and the full semiring registry, compaction (including
plan-cache invalidation), the /ingest HTTP path, admission ordering, and
the concurrent ingest+query hammer."""
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import Assoc, AssocTensor, DistAssoc, KeySpace, PLAN_STATS
from repro.core import keyspace as keyspace_mod
from repro.core.semiring import REGISTRY
from repro.ingest import Compactor, IngestTable
from repro.serve import (D4MClient, Engine, ServerError, TableRef,
                         TableRegistry, WireError, ingest_from_wire,
                         ingest_to_wire, start_server, to_wire)

REPO = Path(__file__).resolve().parent.parent


def _mesh1():
    import jax
    return jax.make_mesh((1,), ("data",))


# deliberately nasty triple mix: base↔delta key collisions, duplicates
# WITHIN one delta batch, brand-new row AND col keys sorting before/after
# the existing ranges
_BASE = (["b", "d", "f", "h"], ["x", "y", "x", "z"], [2.0, 3.0, 4.0, 5.0])
_DELTA = (["b", "b", "a", "zz", "d"], ["x", "x", "w", "z", "y"],
          [10.0, 20.0, 1.5, 7.0, 0.5])


def _build(layer, rows, cols, vals, aggregate):
    if layer == "host":
        return Assoc(rows, cols, vals, aggregate=aggregate)
    if layer == "device":
        return AssocTensor.from_triples(rows, cols, vals,
                                        aggregate=aggregate)
    return DistAssoc.from_triples(rows, cols, vals, _mesh1(),
                                  aggregate=aggregate)


def _as_dict(arr):
    a = arr.to_assoc() if not isinstance(arr, Assoc) else arr
    r, c, v = a.triples()
    return {(rk, ck): vv for rk, ck, vv in zip(list(r), list(c), list(v))}


@pytest.mark.parametrize("layer", ["host", "device", "dist"])
@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
def test_merge_on_read_parity_full_semiring_registry(layer, sr_name):
    """base ⊕ delta merge-on-read ≡ one-shot constructor over the
    concatenated triples, for every ⊕ monoid the semiring registry uses
    (collision aggregation order included: delta has in-batch dups AND
    base collisions)."""
    agg = REGISTRY[sr_name].add_kind
    base = _build(layer, *_BASE, agg)
    t = IngestTable(base, aggregate=agg)
    # two batches → multiple delta segments in one merge
    r, c, v = _DELTA
    t.insert(r[:2], c[:2], v[:2])
    t.insert(r[2:], c[2:], v[2:])
    got = _as_dict(t.snapshot())

    oracle = _build(layer, _BASE[0] + r, _BASE[1] + c, _BASE[2] + v, agg)
    want = _as_dict(oracle)
    assert set(got) == set(want)
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4), (k, agg)


def test_host_order_sensitive_aggregate():
    """Host tables accept any Assoc aggregator — 'concat' proves the
    base-first ⊕ ordering survives the overlay merge."""
    base = Assoc(["a", "a"], ["x", "x"], ["u", "v"], aggregate="concat")
    t = IngestTable(base, aggregate="concat")
    t.insert(["a", "b"], ["x", "y"], ["w", "q"])
    got = _as_dict(t.snapshot())
    assert got[("a", "x")] == "uvw"      # base value on the left
    assert got[("b", "y")] == "q"


def test_device_rejects_order_sensitive_aggregate():
    base = AssocTensor.from_triples(*_BASE, aggregate="sum")
    with pytest.raises(ValueError, match="max.*min.*sum"):
        IngestTable(base, aggregate="concat")


def test_snapshot_memoized_until_next_mutation():
    base = AssocTensor.from_triples(*_BASE, aggregate="sum")
    t = IngestTable(base, aggregate="sum")
    assert t.snapshot() is base          # empty delta: stable identity
    t.insert(["a"], ["w"], [1.0])
    s1 = t.snapshot()
    assert t.snapshot() is s1            # memo hit between mutations
    t.insert(["q"], ["w"], [2.0])
    s2 = t.snapshot()
    assert s2 is not s1                  # mutation invalidates the memo
    assert t.info()["merge_hit_rate"] > 0


def test_merge_kernel_matches_concat_oracle():
    """The overlay-scatter merge program ≡ the concat+dedup fallback on
    identical padded operands (the fallback is the semantic oracle)."""
    import jax.numpy as jnp
    from repro.ingest.merge import _merge_concat_prog, _merge_read_prog

    rng = np.random.default_rng(3)
    SENT = np.int32(2**31 - 1)

    def canon(cap, n, ncols):
        r = np.sort(rng.choice(cap * 4, n, replace=False)).astype(np.int32)
        c = rng.integers(0, ncols, n).astype(np.int32)
        v = rng.uniform(0.5, 2.0, n).astype(np.float32)
        pad = cap - n
        return (jnp.asarray(np.concatenate([r, np.full(pad, SENT,
                                                       np.int32)])),
                jnp.asarray(np.concatenate([c, np.full(pad, SENT,
                                                       np.int32)])),
                jnp.asarray(np.concatenate([v, np.zeros(pad, np.float32)])))

    ncols = 16
    br, bc, bv = canon(64, 40, ncols)
    dr, dc, dv = canon(32, 20, ncols)
    for agg in ("sum", "min", "max"):
        r1, c1, v1, n1 = _merge_read_prog(agg)(br, bc, bv, dr, dc, dv,
                                               jnp.int32(ncols))
        r2, c2, v2, n2 = _merge_concat_prog(agg)(br, bc, bv, dr, dc, dv)
        assert int(n1) == int(n2)
        k = int(n1)
        np.testing.assert_array_equal(np.asarray(r1)[:k],
                                      np.asarray(r2)[:k])
        np.testing.assert_array_equal(np.asarray(c1)[:k],
                                      np.asarray(c2)[:k])
        np.testing.assert_allclose(np.asarray(v1)[:k], np.asarray(v2)[:k],
                                   rtol=1e-5)


def test_compaction_preserves_content_and_bumps_version():
    base = DistAssoc.from_triples(*_BASE, _mesh1(), aggregate="sum")
    t = IngestTable(base, aggregate="sum")
    t.insert(*_DELTA)
    before = _as_dict(t.snapshot())
    out = t.compact()
    assert out["compacted"] == len(_DELTA[0]) and out["version"] == 1
    assert t.delta_depth == 0
    assert _as_dict(t.snapshot()) == before
    assert t.compact() == {"compacted": 0, "version": 1}   # idempotent
    # post-compact ingest still lands correctly (routing table refreshed)
    t.insert(["zz"], ["z"], [1.0])
    after = _as_dict(t.snapshot())
    assert after[("zz", "z")] == pytest.approx(before[("zz", "z")] + 1.0)


def test_compaction_invalidates_plan_cache():
    """Regression: plans keyed on a retired base's Source id must be
    dropped at compaction, and the next query must re-plan against the
    new base (stale plans would silently serve pre-ingest data)."""
    from repro.serve.wire import from_wire

    base = AssocTensor.from_triples(*_BASE, aggregate="sum")
    reg = TableRegistry()
    reg.register("t", IngestTable(base, aggregate="sum"))
    payload = to_wire(TableRef("t").sum(axis=None))

    def run():
        return float(from_wire(payload, resolve=reg.resolve)
                     .collect())

    v0 = run()
    assert run() == v0                   # second run is a plan hit
    inv0 = PLAN_STATS["plan_invalidations"]
    tab = reg.ingest_table("t")
    tab.insert(["a"], ["w"], [100.0])
    assert run() == pytest.approx(v0 + 100.0)
    tab.compact()
    assert PLAN_STATS["plan_invalidations"] > inv0
    assert run() == pytest.approx(v0 + 100.0)   # replanned, same answer


def test_registry_ingest_spec_and_resolution():
    reg = TableRegistry.from_specs([
        {"name": "mut", "generator": "random", "n": 16, "nnz": 32,
         "seed": 0, "layer": "device", "ingest": True,
         "compact_threshold": 99},
        {"name": "ro", "generator": "random", "n": 16, "nnz": 32,
         "seed": 1, "layer": "device"},
    ])
    assert reg.ingest_names() == ["mut"]
    assert reg.is_ingest("mut") and not reg.is_ingest("ro")
    assert reg.layer_of("mut") == "device"
    tab = reg.ingest_table("mut")
    assert tab.compact_threshold == 99 and tab.name == "mut"
    with pytest.raises(WireError) as ei:
        reg.ingest_table("ro")
    assert ei.value.code == "not_ingestable"
    # resolve() returns the snapshot (the base while the delta is empty)
    assert reg.resolve("mut") is tab.base
    info = reg.info("mut")
    assert info["ingest"] is True and info["delta_depth"] == 0


def test_wire_ingest_roundtrip_and_validation():
    p = ingest_to_wire("edges", ["r1", "r2"], ["c1", "c2"], [1.0, 2.0])
    name, r, c, v = ingest_from_wire(p)
    assert name == "edges" and list(r) == ["r1", "r2"]
    assert v.dtype.kind == "f" and v[1] == 2.0

    def code_of(payload):
        with pytest.raises(WireError) as ei:
            ingest_from_wire(payload)
        return ei.value.code

    assert code_of([1, 2]) == "bad_payload"
    assert code_of({"version": 99, "ingest": {}}) == "bad_version"
    assert code_of({"version": 1, "ingest": []}) == "bad_payload"
    base = {"table": "t", "rows": ["a"], "cols": ["b"], "vals": [1.0]}
    assert code_of({"version": 1,
                    "ingest": {**base, "table": ""}}) == "bad_batch"
    assert code_of({"version": 1,
                    "ingest": {**base, "rows": []}}) == "bad_batch"
    assert code_of({"version": 1,
                    "ingest": {**base, "vals": [1.0, 2.0]}}) == "bad_batch"
    assert code_of({"version": 1,
                    "ingest": {**base, "rows": ["a", 3]}}) == "bad_batch"


def test_admission_keys_ingest_vs_query_disjoint():
    """Satellite: a mutation must never share a batch key with reads on
    the table it mutates — and two mutations of the same table must."""
    reg = TableRegistry()
    reg.register("mut", IngestTable(
        AssocTensor.from_triples(*_BASE, aggregate="sum")))
    with Engine(reg, workers=1, compact_interval_s=0) as eng:
        qkey = eng._admission_key(to_wire(TableRef("mut")[:, :]))
        assert qkey[0] == "query"
        i1 = eng.submit_ingest(ingest_to_wire("mut", ["a"], ["b"], [1.0]))
        i2 = eng.submit_ingest(ingest_to_wire("mut", ["c"], ["d"], [2.0]))
        assert i1.batch_key == ("ingest", "mut") == i2.batch_key
        assert i1.batch_key != qkey
        i1.wait(30), i2.wait(30)


@pytest.fixture(scope="module")
def ingest_server():
    reg = TableRegistry()
    reg.register("mut", IngestTable(
        AssocTensor.from_triples(*_BASE, aggregate="sum"),
        aggregate="sum", compact_threshold=10_000))
    reg.register("ro", Assoc(*_BASE, aggregate="sum"))
    srv = start_server(reg, workers=2)
    yield srv
    srv.close()


def test_http_ingest_endpoint(ingest_server):
    c = D4MClient(ingest_server.url, timeout=120)
    total0 = c.query(to_wire(TableRef("mut").sum(axis=None)))
    r = c.ingest("mut", ["new1", "b"], ["w", "x"], [6.0, 1.0])
    assert r["result"]["kind"] == "ingest"
    assert r["result"]["accepted"] == 2
    total1 = c.query(to_wire(TableRef("mut").sum(axis=None)))
    assert total1["result"]["val"] == pytest.approx(
        total0["result"]["val"] + 7.0)
    st = c.stats()
    assert "mut" in st["ingest"]
    assert st["ingest"]["mut"]["insert_triples"] >= 2
    assert st["server"]["ingests"] >= 1


def test_http_ingest_errors(ingest_server):
    c = D4MClient(ingest_server.url, timeout=120)
    with pytest.raises(ServerError) as ei:
        c.ingest("ro", ["a"], ["b"], [1.0])
    assert ei.value.status == 400 and ei.value.code == "not_ingestable"
    with pytest.raises(ServerError) as ei:
        c.ingest("ghost", ["a"], ["b"], [1.0])
    assert ei.value.status == 400 and ei.value.code == "unknown_table"
    with pytest.raises(ServerError) as ei:
        c.ingest("mut", ["a"], ["b"], [])
    assert ei.value.status == 400 and ei.value.code == "bad_batch"
    with pytest.raises(ServerError) as ei:
        c.ingest("mut", ["a"], ["b"], ["str_val"])
    assert ei.value.code == "execution_error"   # device table is numeric


def test_http_concurrent_ingest_query_hammer():
    """8 threads — 4 streaming disjoint key ranges into one table, 4
    issuing sum queries THROUGHOUT — then the final state must equal the
    deterministic expected total (⊕=sum commutes, keys are disjoint per
    thread, so interleaving cannot change the answer)."""
    reg = TableRegistry()
    reg.register("mut", IngestTable(
        AssocTensor.from_triples(["seed"], ["c"], [1.0], aggregate="sum"),
        aggregate="sum", compact_threshold=64))
    srv = start_server(reg, workers=4)
    try:
        url = srv.url
        n_writers, n_readers, n_batches, bsz = 4, 4, 6, 8
        errs, partials = [], []
        barrier = threading.Barrier(n_writers + n_readers)

        def writer(wid):
            c = D4MClient(url, timeout=120)
            try:
                barrier.wait(timeout=30)
                for b in range(n_batches):
                    rows = [f"w{wid}r{b}k{i}" for i in range(bsz)]
                    cols = [f"c{i % 3}" for i in range(bsz)]
                    out = c.ingest("mut", rows, cols, [1.0] * bsz)
                    assert out["result"]["accepted"] == bsz
            except Exception as exc:
                errs.append(exc)

        def reader():
            c = D4MClient(url, timeout=120)
            payload = to_wire(TableRef("mut").sum(axis=None))
            try:
                barrier.wait(timeout=30)
                for _ in range(8):
                    partials.append(c.query(payload)["result"]["val"])
            except Exception as exc:
                errs.append(exc)

        threads = [threading.Thread(target=writer, args=(w,))
                   for w in range(n_writers)]
        threads += [threading.Thread(target=reader)
                    for _ in range(n_readers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert not errs, errs

        want = 1.0 + n_writers * n_batches * bsz
        c = D4MClient(url, timeout=120)
        final = c.query(to_wire(TableRef("mut").sum(axis=None)))
        assert final["result"]["val"] == pytest.approx(want)
        # mid-ingest reads saw monotonically plausible partial sums
        assert all(1.0 <= p <= want + 1e-6 for p in partials)
        # the background compactor ran (threshold 64 < 192 inserted)
        deadline = time.time() + 10
        while time.time() < deadline:
            info = c.stats()["ingest"]["mut"]
            if info["compactions"] >= 1 and info["delta_depth"] == 0:
                break
            time.sleep(0.1)
        assert info["compactions"] >= 1
        assert c.query(to_wire(TableRef("mut").sum(axis=None)))[
            "result"]["val"] == pytest.approx(want)
    finally:
        srv.close()


def test_background_compactor_idle_trigger():
    reg = TableRegistry()
    reg.register("mut", IngestTable(
        AssocTensor.from_triples(*_BASE, aggregate="sum"),
        compact_threshold=10_000))
    comp = Compactor(reg, interval_s=0.02, idle_s=0.05).start()
    try:
        reg.ingest_table("mut").insert(["a"], ["b"], [1.0])
        deadline = time.time() + 10
        while time.time() < deadline:
            if reg.ingest_table("mut").version == 1:
                break
            time.sleep(0.02)
        assert reg.ingest_table("mut").version == 1
        assert reg.ingest_table("mut").delta_depth == 0
    finally:
        comp.stop()


# ---------------------------------------------------------------------------
# satellite riders: union-cache eviction counter, compare.py bootstrap
# ---------------------------------------------------------------------------

def test_union_cache_eviction_counter():
    keyspace_mod.clear_union_cache()
    base = KeySpace(["aa", "bb"])
    for i in range(keyspace_mod._UNION_CACHE_CAP + 8):
        base.union(KeySpace([f"k{i:04d}"]))
    stats = keyspace_mod.UNION_STATS
    assert stats["evictions"] >= 8
    assert len(keyspace_mod._UNION_CACHE) <= keyspace_mod._UNION_CACHE_CAP
    keyspace_mod.clear_union_cache()
    assert keyspace_mod.UNION_STATS["evictions"] == 0


def test_compare_missing_baseline_warns_unless_strict(tmp_path, capsys):
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.compare import main as compare_main
    finally:
        sys.path.pop(0)
    new = tmp_path / "new.json"
    new.write_text('[{"bench": "x", "impl": "a", "n": 1, '
                   '"seconds": 1.0, "nnz": 100}]')
    missing = str(tmp_path / "nonexistent.json")
    assert compare_main(["--baseline", missing, "--new", str(new)]) == 0
    assert "WARNING" in capsys.readouterr().out
    assert compare_main(["--baseline", missing, "--new", str(new),
                         "--strict"]) == 1

"""d4mlint — the host-side AST anti-pattern rules (D4M101…D4M104)."""
import textwrap

from repro.analysis.lint import lint_file, lint_paths


def _lint(src, path="mod.py"):
    return lint_file(path, text=textwrap.dedent(src))


def _rules(findings):
    return sorted({f.rule for f in findings})


def test_numpy_in_jit_body_is_d4m101():
    f = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def go(x):
            return np.asarray(x) + 1
    """)
    assert _rules(f) == ["D4M101"]


def test_numpy_at_module_scope_is_fine():
    f = _lint("""
        import numpy as np
        TABLE = np.arange(16)

        def host_helper(x):
            return np.asarray(x)
    """)
    assert f == []


def test_host_roundtrip_in_shard_map_body_is_d4m102():
    # body passed BY NAME to shard_map — no decorator in sight
    f = _lint("""
        import jax
        from jax.experimental.shard_map import shard_map

        def body(x):
            x.block_until_ready()
            return x

        go = shard_map(body, mesh=None, in_specs=None, out_specs=None)
    """)
    assert _rules(f) == ["D4M102"]


def test_nnz_loop_in_device_scope_is_d4m103():
    f = _lint("""
        from functools import partial
        import jax

        @partial(jax.jit, static_argnames=("n",))
        def go(x, nnz, n):
            acc = 0
            for i in range(nnz):
                acc = acc + x[i]
            return acc
    """)
    assert _rules(f) == ["D4M103"]


def test_nested_def_inherits_device_scope():
    f = _lint("""
        import jax

        @jax.jit
        def outer(x):
            def inner(y):
                import numpy as np
                return np.sqrt(y)
            return inner(x)
    """)
    assert _rules(f) == ["D4M101"]


def test_kernel_ops_missing_triple_is_d4m104(tmp_path):
    d = tmp_path / "kernels" / "mykern"
    d.mkdir(parents=True)
    p = d / "ops.py"
    p.write_text('IMPLS = {"ref": 1, "interpret": 2}\n')  # no "pallas"
    f = lint_file(str(p))
    assert _rules(f) == ["D4M104"]
    assert "pallas" in f[0].message
    p.write_text('IMPLS = {"ref": 1, "interpret": 2, "pallas": 3}\n')
    assert lint_file(str(p)) == []


def test_non_kernel_ops_py_is_exempt(tmp_path):
    p = tmp_path / "ops.py"          # not under a kernels/ tree
    p.write_text("X = 1\n")
    assert lint_file(str(p)) == []


def test_file_level_disable_suppresses():
    f = _lint("""
        # d4mlint: disable=D4M101
        import jax
        import numpy as np

        @jax.jit
        def go(x):
            return np.asarray(x)
    """)
    assert f == []


def test_line_level_ignore_suppresses_only_that_line():
    f = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def go(x):
            a = np.asarray(x)  # d4mlint: ignore[D4M101]
            return np.asarray(a)
    """)
    assert len(f) == 1 and f[0].rule == "D4M101"


def test_repo_source_tree_is_clean():
    assert lint_paths(["src/repro"]) == []

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU asserting output shapes + finiteness, plus a decode step against the
static cache.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke, shapes_for
from repro.models import model as M

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.encdec:
        batch["enc_inputs"] = jax.random.normal(
            RNG, (b, cfg.encdec["enc_frames"], cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_smoke(arch).replace(remat="none")
    params, specs = M.init(RNG, cfg)
    # specs mirror params structurally
    jax.tree.map(lambda p, s: None, params,
                 jax.tree.map(lambda x: x, specs,
                              is_leaf=lambda t: isinstance(t, tuple)))
    batch = _batch(cfg)
    loss, aux = M.lm_loss(params, cfg, batch)
    assert jnp.isfinite(loss), (arch, loss)
    grads = jax.grad(lambda p: M.lm_loss(p, cfg, batch)[0])(params)
    gn = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logits_shape(arch):
    cfg = get_smoke(arch).replace(remat="none")
    params, _ = M.init(RNG, cfg)
    batch = _batch(cfg, b=2, s=32)
    logits, _, _ = M.forward(params, cfg, batch["tokens"], mode="train",
                             enc_inputs=batch.get("enc_inputs"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke(arch).replace(remat="none")
    params, _ = M.init(RNG, cfg)
    b, cache_len = 2, 32
    cache = M.init_cache(cfg, b, cache_len)
    tok = jax.random.randint(RNG, (b, 1), 0, cfg.vocab)
    logits, _, new_cache = M.forward(
        params, cfg, tok, mode="decode", cache=cache,
        positions=jnp.zeros((1,), jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mamba2_130m"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits ≈ full forward logits (same prefix)."""
    cfg = get_smoke(arch).replace(remat="none")
    params, _ = M.init(RNG, cfg)
    b, s = 1, 8
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab)
    full_logits, _, _ = M.forward(params, cfg, tokens, mode="train")
    cache = M.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, _, cache = M.forward(params, cfg, tokens[:, t:t + 1],
                                 mode="decode", cache=cache,
                                 positions=jnp.asarray([t], jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.15, atol=0.15)  # bf16 accumulation drift


def test_shapes_for_skips():
    # long_500k only for sub-quadratic decode archs
    assert "long_500k" not in [s.name for s in shapes_for("qwen3_1_7b")]
    assert "long_500k" in [s.name for s in shapes_for("mamba2_130m")]
    assert "long_500k" in [s.name for s in shapes_for("mixtral_8x22b")]  # SWA
    assert "long_500k" in [s.name for s in shapes_for("zamba2_7b")]


def test_full_configs_match_assignment():
    """The published numbers from the assignment block, verbatim."""
    c = get_config("chatglm3-6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 4096, 32, 2, 13696, 65024)
    c = get_config("qwen3-1.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 2048, 16, 8, 6144, 151936)
    c = get_config("starcoder2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4608, 36, 4, 18432, 49152)
    c = get_config("minicpm-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (40, 2304, 36, 5760, 122753)
    c = get_config("whisper-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (24, 1024, 16, 4096, 51865)
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert c.moe["n_experts"] == 256 and c.moe["top_k"] == 8
    assert c.mla["kv_lora_rank"] == 512
    c = get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (56, 6144, 48, 8, 32768)
    assert c.moe["n_experts"] == 8 and c.moe["top_k"] == 2
    c = get_config("chameleon-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 8192, 64, 8, 22016, 65536)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.vocab) == (24, 768, 50280)
    assert c.ssm["d_state"] == 128
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (81, 3584, 32, 14336, 32000)
    assert c.ssm["d_state"] == 64


def test_moe_load_balance_and_dispatch():
    """MoE dispatch ≈ dense per-token expert mixture (high capacity)."""
    from repro.models import moe as moe_lib
    cfg = get_smoke("mixtral_8x22b")
    cfg = cfg.replace(moe={**cfg.moe, "capacity_factor": 8.0})
    key = jax.random.PRNGKey(1)
    p, _ = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, aux, load = moe_lib.apply_moe(p, cfg, x)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    assert float(load.sum()) == 2 * 16 * cfg.moe["top_k"]
    # oracle: route manually, compute experts densely
    gates, idx, _, _ = moe_lib._route(p, cfg, x)
    def ffn(e, v):
        h = jax.nn.silu(v @ p["gate"][e]) * (v @ p["up"][e])
        return h @ p["down"][e]
    want = jnp.zeros_like(x)
    for b in range(2):
        for t in range(16):
            acc = jnp.zeros((cfg.d_model,), x.dtype)
            for k in range(cfg.moe["top_k"]):
                acc += gates[b, t, k] * ffn(int(idx[b, t, k]), x[b, t])
            want = want.at[b, t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_ssd_chunked_equals_stepwise():
    """Chunk-parallel SSD == exact per-token recurrence."""
    from repro.models import ssm as ssm_lib
    cfg = get_smoke("mamba2_130m")
    key = jax.random.PRNGKey(2)
    p, _ = ssm_lib.init_mamba2(key, cfg)
    x = jax.random.normal(key, (1, 32, cfg.d_model), jnp.float32) * 0.3
    y_chunk, _ = ssm_lib.mamba2_block(p, cfg, x, mode="train")
    # stepwise decode over the same inputs
    cache = ssm_lib.init_ssm_cache(cfg, 1)
    cache = jax.tree.map(lambda a: a.astype(jnp.float32), cache)
    outs = []
    for t in range(32):
        o, cache = ssm_lib.mamba2_block(p, cfg, x[:, t:t + 1], mode="decode",
                                        cache=cache)
        outs.append(o[:, 0])
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_deepseek_mtp_head():
    """MTP: extra block + shared head predicting t+2, train-time aux loss."""
    cfg = get_smoke("deepseek_v3_671b").replace(remat="none", mtp=True)
    params, _ = M.init(RNG, cfg)
    assert "mtp" in params
    tok = jax.random.randint(RNG, (2, 32), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}
    loss, m = M.lm_loss(params, cfg, batch)
    assert "mtp" in m and bool(jnp.isfinite(m["mtp"]))
    g = jax.grad(lambda p: M.lm_loss(p, cfg, batch)[0])(params)
    gn = sum(jnp.sum(x.astype(jnp.float32) ** 2)
             for x in jax.tree.leaves(g["mtp"]))
    assert float(gn) > 0


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "deepseek_v3_671b",
                                  "mamba2_130m", "zamba2_7b"])
def test_chunked_prefill_matches_one_shot(arch):
    """Window-wise cache build == one-shot prefill (long-context path)."""
    from repro.launch import steps as S
    cfg = get_smoke(arch).replace(remat="none")
    if cfg.moe:  # avoid capacity-drop divergence between window sizes
        cfg = cfg.replace(moe={**cfg.moe, "capacity_factor": 32.0})
    params, _ = M.init(RNG, cfg)
    toks = jax.random.randint(RNG, (2, 32), 0, cfg.vocab)
    l1, _ = S.make_prefill_step(cfg)(params, toks)
    l2, _ = S.make_prefill_step(cfg.replace(prefill_chunk=8))(params, toks)
    d = np.abs(np.asarray(l1, np.float32) - np.asarray(l2, np.float32)).max()
    assert d / (np.abs(np.asarray(l1)).max() + 1e-6) < 0.05, (arch, d)

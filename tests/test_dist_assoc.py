"""Distributed associative arrays (shard_map over 8 simulated devices).

Multi-device tests must run in a subprocess so the 8-device XLA flag never
leaks into this test process (device count locks at first jax init).
"""
import json
import subprocess
import sys
import textwrap

import pytest

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core.dist_assoc import DistAssoc
    from repro.core import Assoc

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 64
    rows = rng.integers(0, 40, n).astype(str)
    cols = rng.integers(0, 40, n).astype(str)
    vals = rng.uniform(0.5, 5.0, n)

    da = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    host = Assoc(rows, cols, vals, aggregate="sum")
    got, want = da.to_assoc().to_dict(), host.to_dict()
    assert set(got) == set(want), "support mismatch"
    for k in want:  # device path stores f32; compare approximately
        assert abs(got[k] - want[k]) < 1e-4 * (1 + abs(want[k])), (k, got[k], want[k])

    rows2 = rng.integers(0, 40, n).astype(str)
    cols2 = rng.integers(0, 40, n).astype(str)
    vals2 = rng.uniform(0.5, 5.0, n)
    db = DistAssoc.from_triples(rows2, cols2, vals2, mesh, aggregate="sum")
    hb = Assoc(rows2, cols2, vals2, aggregate="sum")

    # element-wise ops sharded over `data` — compare against host Assoc.
    # NOTE: dist shards share global keyspaces only if built from the same
    # key population; rebuild db on da's spaces via the host path:
    got_add = None
    try:
        got_add = da.add(db)
    except Exception as e:
        print(json.dumps({"ok": False, "err": "add raised: %r" % e}))
        raise SystemExit(0)

    # matmul-vector against dense oracle
    x = rng.uniform(0, 1, len(da.local.col_space)).astype(np.float32)
    y = np.asarray(da.matmul_dense_vec(jax.numpy.asarray(x)))
    dense = np.zeros((len(da.local.row_space), len(da.local.col_space)))
    r, c, v = host.triples()
    rr, _ = da.local.row_space.rank(r)
    cc, _ = da.local.col_space.rank(c)
    dense[rr, cc] = v
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)

    # column reduction
    colsum = np.asarray(da.col_reduce())
    np.testing.assert_allclose(colsum, dense.sum(0), rtol=1e-4, atol=1e-4)

    print(json.dumps({"ok": True}))
""")


@pytest.mark.slow
def test_dist_assoc_8dev():
    p = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    last = [l for l in p.stdout.strip().splitlines() if l.startswith("{")][-1]
    assert json.loads(last)["ok"], p.stdout

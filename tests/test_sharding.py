"""Sharding rules: TP/FSDP/EP translation, divisibility fallbacks."""
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd

def _amesh(sizes, names):
    """AbstractMesh across jax versions: new API takes (sizes, names),
    jax<=0.4.x takes a tuple of (name, size) pairs."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH1 = _amesh((16, 16), ("data", "model"))
MESH2 = _amesh((2, 16, 16), ("pod", "data", "model"))


class _Shape:
    def __init__(self, *s):
        self.shape = s


def _spec(shape, logical, cfg, mesh=MESH1, **kw):
    rules = shd.logical_rules(cfg, **kw)
    return shd.spec_for_shape(shape, logical, rules, mesh)


def test_tp_and_fsdp_basic():
    cfg = get_config("qwen3-1.7b")
    # mlp weight [d, ff]: embed→data (FSDP), mlp→model (TP)
    assert _spec((2048, 6144), ("embed", "mlp"), cfg) == P("data", "model")
    # vocab divisible → model
    assert _spec((151936, 2048), ("vocab", "embed"), cfg) == P("model", "data")


def test_vocab_indivisible_falls_back():
    cfg = get_config("minicpm-2b")   # vocab 122753 is not divisible by 16
    spec = _spec((122753, 2304), ("vocab", "embed"), cfg)
    assert spec == P(None, "data")


def test_layers_axis_never_sharded():
    cfg = get_config("qwen3-1.7b")
    spec = _spec((28, 2048, 6144), ("layers", "embed", "mlp"), cfg)
    assert spec == P(None, "data", "model")


def test_moe_ep_vs_tp():
    ds = get_config("deepseek-v3-671b")     # 256 experts ≥ 16 → EP
    spec = _spec((256, 7168, 2048), ("expert", "embed", "expert_mlp"), ds)
    assert spec == P("model", "data", None)
    mx = get_config("mixtral-8x22b")        # 8 experts < 16 → TP on hidden
    spec = _spec((8, 6144, 16384), ("expert", "embed", "expert_mlp"), mx)
    assert spec == P(None, "data", "model")


def test_fsdp_over_pod():
    cfg = get_config("deepseek-v3-671b")
    spec = _spec((7168, 1536), ("embed", None), cfg, mesh=MESH2,
                 fsdp_over_pod=True)
    assert spec == P(("pod", "data"), None)
    # dim only divisible by data (not pod*data) degrades to data alone
    spec2 = _spec((48, 16), ("embed", None), cfg, mesh=MESH2,
                  fsdp_over_pod=True)
    assert spec2 == P("data", None)


def test_no_double_axis_use():
    cfg = get_config("qwen3-1.7b")
    spec = _spec((2048, 2048), ("embed", "embed"), cfg)
    assert spec == P("data", None)  # second 'data' suppressed


def test_batch_spec_degradation():
    assert shd.batch_spec(256, _amesh((16, 16), ("data", "model"))) \
        == P(("data",), None)
    # batch=1 cannot shard → replicated
    assert shd.batch_spec(1, _amesh((16, 16), ("data", "model"))) \
        == P(None, None)
    assert shd.batch_spec(256, MESH2) == P(("pod", "data"), None)


def test_param_specs_tree():
    import jax
    from repro.launch.steps import M_init_specs
    cfg = get_config("qwen3-1.7b")
    shapes, logical = M_init_specs(cfg)
    specs = shd.param_specs(shapes, logical, cfg, MESH1)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in flat)
    # every spec's non-None axes divide the corresponding dim
    def check(shape_like, spec):
        for dim, ax in zip(shape_like.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            sz = int(np.prod([MESH1.shape[a] for a in axes]))
            assert dim % sz == 0, (shape_like.shape, spec)
    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))

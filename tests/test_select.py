"""The unified selector algebra: compilation, composition, 3-layer parity.

One D4M query language across the stack: every selector compiles against a
KeySpace (range or index-set form) and must return the same entries on the
host ``Assoc``, the device ``AssocTensor``, and the sharded ``DistAssoc``.
"""
import jax
import numpy as np
import pytest

from repro.core import (All, Assoc, AssocTensor, Keys, KeySpace, Mask, Match,
                        Positions, Range, StartsWith, Where)
from repro.core import keyspace as keyspace_mod
from repro.core import select
from repro.core.dist_assoc import DistAssoc
from repro.core.select import as_selector, compile_selector


# ---------------------------------------------------------------------------
# compilation against a KeySpace
# ---------------------------------------------------------------------------

KEYS = ["alpha", "beta", "bet", "gamma", "delta", "log-01", "log-02", "zz"]


@pytest.fixture
def space():
    return KeySpace(KEYS)


def _keys_of(comp, ks):
    return ks.keys[comp.positions()].tolist()


def test_keys_compile(space):
    c = compile_selector(Keys(["beta", "zz", "nope"]), space)
    assert _keys_of(c, space) == ["beta", "zz"]


def test_range_compile_right_inclusive(space):
    c = compile_selector(Range("bet", "delta"), space)
    assert _keys_of(c, space) == ["bet", "beta", "delta"]
    assert c.is_range


def test_range_exclusive_bounds(space):
    c = compile_selector(Range("bet", "delta", inclusive=(False, False)),
                         space)
    assert _keys_of(c, space) == ["beta"]


def test_range_open_ends(space):
    assert _keys_of(compile_selector(Range(None, "bet"), space), space) == \
        ["alpha", "bet"]
    assert _keys_of(compile_selector(Range("log-01", None), space), space) == \
        ["log-01", "log-02", "zz"]


def test_startswith_compile(space):
    c = compile_selector(StartsWith("log-"), space)
    assert _keys_of(c, space) == ["log-01", "log-02"]
    assert c.is_range  # prefix block is contiguous in sorted order
    # prefix list (D4M string-list form) → union of ranges
    c2 = compile_selector(StartsWith("bet,log-,"), space)
    assert _keys_of(c2, space) == ["bet", "beta", "log-01", "log-02"]


def test_startswith_next_string_carry():
    # a prefix ending in the maximal code point carries into the shorter one
    top = chr(0x10FFFF)
    ks = KeySpace(["a" + top, "a" + top + "x", "b"])
    c = compile_selector(StartsWith("a" + top), ks)
    assert _keys_of(c, ks) == ["a" + top, "a" + top + "x"]


def test_match_where_mask(space):
    assert _keys_of(compile_selector(Match(r"^log-\d+$"), space), space) == \
        ["log-01", "log-02"]
    assert _keys_of(compile_selector(Where(lambda k: k.endswith("a")), space),
                    space) == ["alpha", "beta", "delta", "gamma"]
    bits = np.zeros(len(space), bool)
    bits[[0, 3]] = True
    assert compile_selector(Mask(bits), space).positions().tolist() == [0, 3]


def test_mask_wrong_length_raises(space):
    with pytest.raises(ValueError):
        compile_selector(Mask(np.zeros(3, bool)), space)


def test_positions_and_slice(space):
    assert compile_selector(Positions([1, 3]), space).positions().tolist() == \
        [1, 3]
    assert compile_selector(slice(0, 3), space).positions().tolist() == \
        [0, 1, 2]
    assert compile_selector(Positions(-1), space).positions().tolist() == \
        [len(space) - 1]
    with pytest.raises(IndexError):
        compile_selector(Positions([99]), space)


def test_composition(space):
    sw = StartsWith("be")
    assert _keys_of(compile_selector(sw & Keys(["beta"]), space), space) == \
        ["beta"]
    assert _keys_of(compile_selector(sw | Keys(["zz"]), space), space) == \
        ["bet", "beta", "zz"]
    inv = compile_selector(~All(), space)
    assert inv.count == 0
    assert compile_selector(~Keys([]), space).count == len(space)


def test_contiguous_set_normalizes_to_range(space):
    # an index set that happens to be contiguous compiles to a rank range
    c = compile_selector(Keys(["log-01", "log-02"]), space)
    assert c.is_range


def test_as_selector_forms():
    assert isinstance(as_selector(":"), All)
    assert isinstance(as_selector(slice(None)), All)
    assert isinstance(as_selector("a,:,b,"), Range)
    assert isinstance(as_selector("a,b,"), Keys)
    assert isinstance(as_selector(("a", "b")), Range)
    assert isinstance(as_selector(np.array([1, 2])), Positions)
    assert isinstance(as_selector(np.array([1.5])), Keys)
    assert isinstance(as_selector(np.array([True, False])), Mask)


# ---------------------------------------------------------------------------
# compilation + union caches
# ---------------------------------------------------------------------------

def test_compile_cache_hits_on_repeat(space):
    select.clear_compile_cache()
    select.reset_cache_stats()
    sel = StartsWith("log-")
    compile_selector(sel, space)
    misses = select.CACHE_STATS["misses"]
    assert misses >= 1 and select.CACHE_STATS["hits"] == 0
    compile_selector(sel, space)
    assert select.CACHE_STATS["hits"] == 1
    assert select.CACHE_STATS["misses"] == misses
    # an equal-content KeySpace (different object) still hits: content hash
    compile_selector(sel, KeySpace(KEYS))
    assert select.CACHE_STATS["hits"] == 2


def test_assoc_repeated_query_hits_cache():
    a = Assoc(["a", "b", "c"], ["x", "y", "z"], [1.0, 2.0, 3.0])
    a["a,:,b,", :]
    select.reset_cache_stats()
    a["a,:,b,", :]
    assert select.CACHE_STATS["hits"] >= 2   # row range + col ":" both cached
    assert select.CACHE_STATS["misses"] == 0


def test_keys_cache_no_itemsize_collision(space):
    # ['ab'] and ['a','b'] have identical UTF-32 payloads; the cache key
    # must include the itemsize so they never share an entry
    select.clear_compile_cache()
    c1 = compile_selector(Keys(["ab"]), space)
    c2 = compile_selector(Keys(["a", "b"]), space)
    assert c1.positions().tolist() != c2.positions().tolist() or \
        c1.count == c2.count == 0
    ks = KeySpace(["a", "b", "ab"])
    assert _keys_of(compile_selector(Keys(["ab"]), ks), ks) == ["ab"]
    assert _keys_of(compile_selector(Keys(["a", "b"]), ks), ks) == ["a", "b"]


def test_cached_results_are_immutable(space):
    # cached Compiled index sets and union maps are shared process-wide;
    # caller mutation must fail loudly instead of poisoning the cache
    select.clear_compile_cache()
    c = compile_selector(Keys(["alpha", "bet", "zz"]), space)
    with pytest.raises(ValueError):
        c.positions()[:] = 0
    keyspace_mod.clear_union_cache()
    x, y = KeySpace(["a", "q"]), KeySpace(["b", "r"])
    _, s_map, _ = x.union(y)
    with pytest.raises(ValueError):
        s_map[:] = 99
    assert x.union(y)[1].tolist() == s_map.tolist()


def test_int_tuple_is_positions_not_range():
    # (0, 1) keeps the paper's ints-are-positions rule (like [0, 1]);
    # key-payload tuples are inclusive ranges
    a = Assoc(["r1", "r2", "r3"], ["c"] * 3, [1.0, 2.0, 3.0])
    assert a[(0, 1), :].to_dict() == a[[0, 1], :].to_dict()
    assert isinstance(as_selector((0, 1)), Positions)
    assert isinstance(as_selector(("a", "b")), Range)
    assert isinstance(as_selector((1.5, 2.5)), Range)


def test_range_open_bound_no_none_key_collision():
    # a keyspace containing the literal key "None" must not share a cache
    # entry with an open-bound Range
    select.clear_compile_cache()
    ks = KeySpace(["Alpha", "Beta", "None", "Zed"])
    open_lo = compile_selector(Range(None, "Zed"), ks)
    closed = compile_selector(Range("None", "Zed"), ks)
    assert open_lo.positions().tolist() == [0, 1, 2, 3]
    assert closed.positions().tolist() == [2, 3]


def test_setitem_tuple_and_mask_match_getitem_semantics():
    # 2-tuples mean inclusive Range and bool arrays mean Mask on BOTH the
    # get and set sides
    a = Assoc(["a", "b", "c"], ["x", "x", "x"], [1.0, 2.0, 3.0])
    a[("a", "c"), :] = 9.0
    assert a.to_dict() == {("a", "x"): 9.0, ("b", "x"): 9.0, ("c", "x"): 9.0}
    b = Assoc(["a", "b", "c"], ["x", "x", "x"], [1.0, 2.0, 3.0])
    b[np.array([True, False, True]), :] = 5.0
    assert b.get("a", "x") == 5.0 and b.get("c", "x") == 5.0
    assert b.get("b", "x") == 2.0
    # plain python bool LISTS are masks on both sides too
    c = Assoc(["a", "b", "c"], ["x", "x", "x"], [1.0, 2.0, 3.0])
    assert c[[True, False, True], :].to_dict() == \
        {("a", "x"): 1.0, ("c", "x"): 3.0}
    c[[True, False, True], :] = 7.0
    assert c.get("a", "x") == 7.0 and c.get("b", "x") == 2.0


def test_where_compiles_uncached(space):
    # per-query lambdas must not fill (or periodically wipe) the cache
    select.clear_compile_cache()
    select.reset_cache_stats()
    for _ in range(3):
        compile_selector(Where(lambda k: True), space)
    assert select.CACHE_STATS == {"hits": 0, "misses": 0}
    assert len(select._COMPILE_CACHE) == 0


def test_union_memo():
    keyspace_mod.clear_union_cache()
    x = KeySpace(["a", "b"])
    y = KeySpace(["b", "c"])
    x.union(y)
    assert keyspace_mod.UNION_STATS == {"hits": 0, "misses": 1,
                                        "evictions": 0}
    x.union(y)
    assert keyspace_mod.UNION_STATS == {"hits": 1, "misses": 1,
                                        "evictions": 0}
    # repeated device adds on the same keyspace pair reuse the merge
    d1 = AssocTensor.from_triples(["a"], ["x"], [1.0], capacity=8)
    d2 = AssocTensor.from_triples(["b"], ["y"], [2.0], capacity=8)
    d1.add(d2)
    before = keyspace_mod.UNION_STATS["hits"]
    d1.add(d2)
    assert keyspace_mod.UNION_STATS["hits"] > before


# ---------------------------------------------------------------------------
# 3-layer parity: Assoc == AssocTensor == DistAssoc for every selector form
# ---------------------------------------------------------------------------

ROWS = ["apple", "apricot", "banana", "cherry", "date", "fig", "grape",
        "kiwi", "lemon", "mango"]


def _triple_set():
    rng = np.random.default_rng(7)
    rows = np.asarray(ROWS * 3)
    cols = np.asarray([f"c{i % 5}" for i in range(len(rows))])
    vals = np.round(rng.uniform(0.5, 9.5, len(rows)), 2)
    return rows, cols, vals


@pytest.fixture(scope="module")
def layers():
    rows, cols, vals = _triple_set()
    host = Assoc(rows, cols, vals, aggregate="sum")
    dev = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                   capacity=64)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    return host, dev, dist


def _dict_close(a, b):
    if set(a) != set(b):
        return False
    return all(abs(a[k] - b[k]) < 1e-3 * (1 + abs(a[k])) for k in a)


MASK_BITS = np.zeros(len(set(ROWS)), bool)
MASK_BITS[[0, 4, 7]] = True

PARITY_SELECTORS = [
    ("explicit-keys", Keys(["banana", "kiwi", "nope"])),
    ("string-list", "banana,kiwi,"),
    ("range-string", "banana,:,fig,"),
    ("range-obj", Range("banana", "fig")),
    ("startswith", StartsWith("ap,")),
    ("match", Match("an")),
    ("where", Where(lambda k: len(k) == 4)),
    ("mask", Mask(MASK_BITS)),
    ("all", ":"),
    ("composed-or", StartsWith("ap,") | Keys(["mango"])),
    ("composed-and-not", StartsWith("a,b,") & ~Keys(["banana"])),
    ("empty", Keys(["nothing-matches"])),
]


@pytest.mark.parametrize("name,sel", PARITY_SELECTORS,
                         ids=[n for n, _ in PARITY_SELECTORS])
def test_three_layer_parity(layers, name, sel):
    host, dev, dist = layers
    want = host[sel, :].to_dict()
    got_dev = dev[sel, :].to_assoc().to_dict()
    got_dist = dist[sel, :].to_assoc().to_dict()
    assert _dict_close(got_dev, want), (name, got_dev, want)
    assert _dict_close(got_dist, want), (name, got_dist, want)


def test_parity_col_selector_and_both_axes(layers):
    host, dev, dist = layers
    want = host[StartsWith("ap,"), "c0,c3,"].to_dict()
    got_dev = dev[StartsWith("ap,"), "c0,c3,"].to_assoc().to_dict()
    got_dist = dist[StartsWith("ap,"), "c0,c3,"].to_assoc().to_dict()
    assert _dict_close(got_dev, want) and _dict_close(got_dist, want)


def test_parity_full_range_is_identity(layers):
    host, dev, dist = layers
    want = host.to_dict()
    assert _dict_close(host[":", ":"].to_dict(), want)
    assert _dict_close(dev[":", ":"].to_assoc().to_dict(), want)
    assert _dict_close(dist[":", ":"].to_assoc().to_dict(), want)


def test_parity_empty_result(layers):
    host, dev, dist = layers
    assert host["zzz,:,zzzz,", :].to_dict() == {}
    assert dev["zzz,:,zzzz,", :].to_assoc().to_dict() == {}
    assert dist["zzz,:,zzzz,", :].to_assoc().to_dict() == {}


# ---------------------------------------------------------------------------
# device specifics
# ---------------------------------------------------------------------------

def test_device_getitem_under_jit():
    dev = AssocTensor.from_triples(["a", "b", "c"], ["x", "x", "y"],
                                   [1.0, 2.0, 3.0], capacity=8)

    @jax.jit
    def q(t):
        return t[StartsWith("a,b,"), :]

    out = q(dev)
    assert out.to_assoc().to_dict() == {("a", "x"): 1.0, ("b", "x"): 2.0}
    # non-contiguous set → gather path, still jit-safe
    @jax.jit
    def q2(t):
        return t[Keys(["a", "c"]), :]

    assert q2(dev).to_assoc().to_dict() == {("a", "x"): 1.0, ("c", "y"): 3.0}


def test_device_setitem_scalar():
    dev = AssocTensor.from_triples(["a", "b"], ["x", "y"], [1.0, 2.0],
                                   capacity=8)
    dev[Keys(["b"]), :] = 9.0
    assert dev.to_assoc().to_dict() == {("a", "x"): 1.0, ("b", "y"): 9.0}
    with pytest.raises(TypeError):
        dev[Keys(["b"]), :] = "str"


def test_host_setitem_selector_fill():
    a = Assoc(["r1", "r2"], ["c1", "c2"], [1.0, 2.0])
    a[Keys(["r1", "r2"]), "c1,"] = 5.0
    assert a.get("r1", "c1") == 5.0 and a.get("r2", "c1") == 5.0
    assert a.get("r2", "c2") == 2.0
    a["r1,:,r2,", ":"] = 0.5     # range-string selector fill
    assert a.get("r2", "c2") == 0.5


def test_empty_assoc_and_numeric_keyspace_edges():
    assert Assoc()["a,:,b,", :].to_dict() == {}
    assert Assoc()[:, :].to_dict() == {}
    b = Assoc([10.0, 20.0, 30.0], [1.0, 1.0, 1.0], [5.0, 6.0, 7.0])
    # range syntax on numeric keys compares numerically (not lexically)
    assert b["10.0,:,20.0,", :].to_dict() == {(10.0, 1.0): 5.0,
                                              (20.0, 1.0): 6.0}
    assert b[Keys(["abc"]), :].to_dict() == {}   # unparseable → empty


def test_sorted_intersect_string_and_empty():
    """The timsort-merge intersection (satellite) on string + empty inputs."""
    from repro.core import sorted_intersect
    i = np.asarray(["ab", "cd", "zz"])
    j = np.asarray(["abcd", "cd", "zz"])
    k, im, jm = sorted_intersect(i, j)
    assert k.tolist() == ["cd", "zz"]
    np.testing.assert_array_equal(i[im], k)
    np.testing.assert_array_equal(j[jm], k)
    k2, _, _ = sorted_intersect(np.asarray([], dtype=np.int64),
                                np.asarray([1, 2]))
    assert len(k2) == 0

# ---------------------------------------------------------------------------
# dispatch-path coverage: the membership-gather fallback and the
# plan_boxes >4-interval-run spill, on BOTH device layers (DISPATCH_STATS
# pins which execution path actually ran; the autouse conftest fixture
# zeroes the counters before each test)
# ---------------------------------------------------------------------------

WIDE_ROWS = [f"r{i:02d}" for i in range(20)]
WIDE_COLS = [f"d{i:02d}" for i in range(20)]


@pytest.fixture(scope="module")
def wide_layers():
    """20×20 keyspace — wide enough that an every-other-key selection
    forms 10 interval runs (>4, the plan_boxes box budget)."""
    rng = np.random.default_rng(11)
    rows = np.asarray(WIDE_ROWS * 4)
    cols = np.asarray([WIDE_COLS[(3 * i) % 20] for i in range(len(rows))])
    vals = np.round(rng.uniform(0.5, 9.5, len(rows)), 2)
    host = Assoc(rows, cols, vals, aggregate="sum")
    dev = AssocTensor.from_triples(rows, cols, vals, aggregate="sum",
                                   capacity=128)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistAssoc.from_triples(rows, cols, vals, mesh, aggregate="sum")
    return host, dev, dist


SCATTER_ROWS = Keys(WIDE_ROWS[::2])          # ranks 0,2,…,18 → 10 runs
SCATTER_COLS = Keys(WIDE_COLS[::2])
# 5 runs of 2 — interval-decomposable but over the 4-box budget
SPILL_ROWS = Keys([k for i, k in enumerate(WIDE_ROWS) if i % 4 in (0, 1)])


def _q(arr, ij):
    got = arr[ij[0], ij[1]]
    return got.to_dict() if isinstance(got, Assoc) else \
        got.to_assoc().to_dict()


def _dispatch_of(arr, ij):
    from repro.core import DISPATCH_STATS, reset_all_stats
    reset_all_stats()
    got = _q(arr, ij)
    fired = [k for k, v in DISPATCH_STATS.items() if v]
    assert len(fired) == 1, DISPATCH_STATS
    return fired[0], got


@pytest.mark.parametrize("layer", ["device", "dist"])
def test_scattered_both_axes_takes_gather(wide_layers, layer):
    host, dev, dist = wide_layers
    arr = dev if layer == "device" else dist
    want = _q(host, (SCATTER_ROWS, SCATTER_COLS))
    kind, got = _dispatch_of(arr, (SCATTER_ROWS, SCATTER_COLS))
    assert kind == "gather"        # 10 runs/axis → no boxes fit → 2 masks
    assert _dict_close(got, want), (got, want)


@pytest.mark.parametrize("layer", ["device", "dist"])
def test_scattered_one_axis_takes_hybrid(wide_layers, layer):
    host, dev, dist = wide_layers
    arr = dev if layer == "device" else dist
    want = _q(host, (SCATTER_ROWS, All()))
    kind, got = _dispatch_of(arr, (SCATTER_ROWS, All()))
    assert kind == "hybrid"        # col axis one open box + row mask
    assert _dict_close(got, want), (got, want)


@pytest.mark.parametrize("layer", ["device", "dist"])
def test_run_spill_over_box_budget_falls_back(wide_layers, layer):
    # 5 interval runs is one over the 4-box budget: plan_boxes must spill
    # the row axis to a membership gather instead of dropping a run
    host, dev, dist = wide_layers
    arr = dev if layer == "device" else dist
    want = _q(host, (SPILL_ROWS, All()))
    kind, got = _dispatch_of(arr, (SPILL_ROWS, All()))
    assert kind == "hybrid"
    assert _dict_close(got, want), (got, want)
    # …and the same 5-run set on BOTH axes double-spills to plain gather
    want2 = _q(host, (SPILL_ROWS, Keys([k for i, k in enumerate(WIDE_COLS)
                                        if i % 4 in (0, 1)])))
    kind2, got2 = _dispatch_of(arr, (SPILL_ROWS,
                                     Keys([k for i, k in enumerate(WIDE_COLS)
                                           if i % 4 in (0, 1)])))
    assert kind2 == "gather"
    assert _dict_close(got2, want2), (got2, want2)


@pytest.mark.parametrize("layer", ["device", "dist"])
def test_box_product_spill_keeps_boxable_axis(wide_layers, layer):
    # 2 row runs × 3 col runs = 6 boxes > 4: the planner keeps the row
    # boxes (≤4) and spills only the col axis to a gather (counted as
    # "multirange" — >1 box; "hybrid" is reserved for the 1-box+gather
    # shape)
    host, dev, dist = wide_layers
    two_row_runs = Keys(WIDE_ROWS[0:3] + WIDE_ROWS[8:11])
    three_col_runs = Keys([WIDE_COLS[0], WIDE_COLS[5], WIDE_COLS[10]])
    want = _q(host, (two_row_runs, three_col_runs))
    arr = dev if layer == "device" else dist
    kind, got = _dispatch_of(arr, (two_row_runs, three_col_runs))
    assert kind == "multirange"
    assert _dict_close(got, want), (got, want)


@pytest.mark.parametrize("layer", ["device", "dist"])
def test_few_runs_stay_on_multirange(wide_layers, layer):
    # control: 2 runs × 2 runs = 4 boxes fits the budget → pure multirange
    host, dev, dist = wide_layers
    rows2 = Keys(WIDE_ROWS[0:2] + WIDE_ROWS[10:12])
    cols2 = Keys([WIDE_COLS[0], WIDE_COLS[9]])
    want = _q(host, (rows2, cols2))
    arr = dev if layer == "device" else dist
    kind, got = _dispatch_of(arr, (rows2, cols2))
    assert kind == "multirange"
    assert _dict_close(got, want), (got, want)

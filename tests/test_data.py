"""Data pipeline: determinism, exact resume, elastic shard equivalence,
and the D4M corpus-statistics idioms."""
import numpy as np

from repro.core import Assoc
from repro.data import ByteTokenizer, CorpusPipeline, synth_corpus


def test_tokenizer_roundtrip_words():
    docs = ["the cat sat", "the dog sat", "the cat ran"]
    tok = ByteTokenizer(vocab_size=300).fit(docs)
    ids = tok.encode("the cat sat")
    assert ids[0] == tok.bos_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == "the cat sat"


def test_pipeline_deterministic():
    docs = synth_corpus(16, seed=1)
    p1 = CorpusPipeline(docs, seq_len=32, batch_per_shard=2, seed=7)
    p2 = CorpusPipeline(docs, seq_len=32, batch_per_shard=2, seed=7)
    for _ in range(5):
        b1, b2 = p1.next_batch(), p2.next_batch()
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_pipeline_exact_resume():
    docs = synth_corpus(16, seed=2)
    p = CorpusPipeline(docs, seq_len=32, batch_per_shard=2, seed=5)
    for _ in range(3):
        p.next_batch()
    saved = p.state_dict()
    want = [p.next_batch() for _ in range(3)]

    p2 = CorpusPipeline(docs, seq_len=32, batch_per_shard=2, seed=5)
    p2.load_state_dict(saved)
    got = [p2.next_batch() for _ in range(3)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w["tokens"], g["tokens"])


def test_labels_are_shifted_tokens():
    docs = synth_corpus(8, seed=3)
    p = CorpusPipeline(docs, seq_len=16, batch_per_shard=1, seed=0)
    b = p.next_batch()
    # labels[t] == tokens[t+1] within the flat stream window
    assert b["tokens"].shape == (1, 16) and b["labels"].shape == (1, 16)
    np.testing.assert_array_equal(b["tokens"][0, 1:], b["labels"][0, :-1])


def test_sharding_disjoint_doc_ranges():
    docs = synth_corpus(10, seed=4)
    shards = [CorpusPipeline(docs, seq_len=8, batch_per_shard=1,
                             shard=s, n_shards=3, seed=0) for s in range(3)]
    ranges = [(p.doc_lo, p.doc_hi) for p in shards]
    covered = []
    for lo, hi in ranges:
        covered.extend(range(lo, hi))
    assert sorted(covered) == list(range(10))  # partition, no overlap


def test_corpus_statistics_vs_numpy():
    docs = ["a b a", "b c"]
    p = CorpusPipeline(docs, seq_len=4, batch_per_shard=1, seed=0)
    co = p.cooccurrence()          # AᵀA over position incidence
    td = p.term_doc()
    # doc0 has positions for 5 tokens incl bos/eos; check symmetry + diag
    r, c, v = co.triples()
    d = co.to_dict()
    for (i, j), val in d.items():
        assert d[(j, i)] == val     # AᵀA symmetric
    sim = p.doc_similarity()
    assert sim.get("doc000000", "doc000001") is not None  # share 'b'


def test_d4m_table_matches_tokens():
    docs = ["x y z"]
    p = CorpusPipeline(docs, seq_len=4, batch_per_shard=1, seed=0)
    ids = p.tokenizer.encode("x y z")
    r, c, v = p.table.triples()
    assert p.table.nnz() == len(ids)
    # stored value = token id + 1 (zero-avoidance offset)
    got = [int(x) - 1 for x in v[np.argsort(c.astype(float))]]
    assert got == ids.tolist()

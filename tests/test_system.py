"""End-to-end behaviour tests: the full drivers, wired like production."""
import subprocess
import sys

import pytest


def _run(mod, *args, timeout=560):
    p = subprocess.run(
        [sys.executable, "-m", mod, *args],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo")
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-3000:])
    return p.stdout


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    out = _run("repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
               "--steps", "6", "--seq-len", "32", "--batch", "2",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "3")
    assert "[train] 6 steps" in out
    assert "loss" in out


@pytest.mark.slow
def test_train_driver_survives_failure(tmp_path):
    out = _run("repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
               "--steps", "8", "--seq-len", "32", "--batch", "2",
               "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
               "--simulate-failure", "5")
    assert "restarts=1" in out


@pytest.mark.slow
def test_serve_driver_end_to_end():
    out = _run("repro.launch.serve", "--arch", "mamba2-130m", "--smoke",
               "--batch", "2", "--prompt-len", "8", "--gen", "8")
    assert "[serve]" in out and "ms/tok" in out


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """One real dry-run cell (the deliverable-(e) path) from scratch."""
    out = _run("repro.launch.dryrun", "--arch", "mamba2-130m",
               "--shape", "decode_32k")
    assert '"status": "ok"' in out

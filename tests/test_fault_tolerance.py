"""Fault-tolerance layer: heartbeats, stragglers, resilient step loop."""
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import CorpusPipeline, synth_corpus
from repro.distributed import (HeartbeatMonitor, MetricsStore, RestartPolicy,
                               StragglerMitigator, run_resilient)


def test_heartbeat_detects_dead_worker():
    t = {"now": 0.0}
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=10, clock=lambda: t["now"])
    t["now"] = 5.0
    mon.beat("w0")
    t["now"] = 12.0
    assert mon.dead_workers() == ["w1"]
    mon.beat("w1")
    assert mon.healthy()


def test_straggler_detector_flags_persistent_outlier():
    ws = [f"w{i}" for i in range(8)]
    det = StragglerMitigator(ws, mad_k=4.0, patience=3)
    flagged = []
    for step in range(5):
        times = {w: 1.0 + 0.01 * i for i, w in enumerate(ws)}
        times["w3"] = 10.0  # persistent straggler
        flagged.extend(det.record_step(times))
    assert flagged == ["w3"]   # flagged exactly once, after `patience` steps
    det.reassign("w3", "spare0")
    assert det.reassigned == {"w3": "spare0"}


def test_straggler_transient_not_flagged():
    ws = [f"w{i}" for i in range(8)]
    det = StragglerMitigator(ws, mad_k=4.0, patience=3)
    out = []
    for step in range(6):
        times = {w: 1.0 for w in ws}
        if step % 2 == 0:
            times["w1"] = 8.0  # flaps — strikes reset between
        out.extend(det.record_step(times))
    assert out == []


def test_restart_policy_budget():
    p = RestartPolicy(max_restarts=2, backoff_s=0.5)
    assert p.should_restart() and p.on_restart() == 0.5
    assert p.should_restart() and p.on_restart() == 1.0
    assert not p.should_restart()


def test_run_resilient_recovers_and_replays(tmp_path):
    """Step 7 dies once; the loop restores step-5 ckpt and replays the SAME
    batches (deterministic cursor) to completion."""
    docs = synth_corpus(8, seed=0)
    pipeline = CorpusPipeline(docs, seq_len=8, batch_per_shard=1, seed=3)
    mgr = CheckpointManager(str(tmp_path), save_interval_steps=5)
    metrics = MetricsStore("last")
    seen = []
    failed = {"done": False}

    def make_state():
        return {"acc": np.zeros(1)}

    def step_fn(state, batch):
        if (not failed["done"]) and len(seen) == 7:
            failed["done"] = True
            raise RuntimeError("boom")
        seen.append(batch["tokens"].copy())
        return {"acc": state["acc"] + batch["tokens"].sum()}, \
            {"ts": float(batch["tokens"].sum())}

    state, steps, restarts = run_resilient(
        n_steps=10, step_fn=step_fn, make_state=make_state,
        ckpt_manager=mgr, pipeline=pipeline,
        policy=RestartPolicy(max_restarts=2, backoff_s=0.0),
        metrics=metrics, sleep=lambda s: None)
    assert steps == 10 and restarts == 1
    # batches 5,6 were replayed identically after restore
    ref = CorpusPipeline(docs, seq_len=8, batch_per_shard=1, seed=3)
    want = [ref.next_batch()["tokens"] for _ in range(10)]
    # seen = steps 0..6 (pre-crash) + 5..9 (replay)
    np.testing.assert_array_equal(seen[7], want[5])
    np.testing.assert_array_equal(seen[8], want[6])
    np.testing.assert_array_equal(seen[-1], want[9])


def test_run_resilient_exhausts_budget(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_interval_steps=100)

    def step_fn(state, batch):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_resilient(n_steps=3, step_fn=step_fn,
                      make_state=lambda: {}, ckpt_manager=mgr,
                      pipeline=None,
                      policy=RestartPolicy(max_restarts=2, backoff_s=0.0),
                      sleep=lambda s: None)

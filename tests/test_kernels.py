"""Per-kernel allclose sweeps vs the pure-jnp ref oracles (interpret mode).

Each Pallas kernel is exercised over a shape/dtype grid; interpret=True
executes the kernel body on CPU (TPU is the deployment target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(42)


# --------------------------- semiring matmul --------------------------------
from repro.kernels.semiring_matmul.ops import semiring_matmul
from repro.kernels.semiring_matmul.ref import semiring_matmul_ref


@pytest.mark.parametrize("sr", ["plus_times", "max_plus", "min_plus",
                                "max_min", "max_times"])
@pytest.mark.parametrize("shape", [(32, 48, 16), (128, 128, 128),
                                   (70, 90, 130)])
def test_semiring_matmul(sr, shape):
    m, k, n = shape
    a = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    out = semiring_matmul(a, b, semiring=sr, impl="interpret")
    ref = semiring_matmul_ref(a, b, semiring=sr)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_semiring_matmul_dtypes(dtype):
    a = jnp.asarray(rng.normal(size=(64, 64)).astype(dtype))
    b = jnp.asarray(rng.normal(size=(64, 64)).astype(dtype))
    out = semiring_matmul(a, b, semiring="plus_times", impl="interpret")
    ref = semiring_matmul_ref(a, b, semiring="plus_times")
    np.testing.assert_allclose(out, ref, rtol=3e-3, atol=3e-3)


# --------------------------- flash attention --------------------------------
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("case", [
    dict(b=2, h=4, kv=2, sq=256, sk=256, d=64, causal=True, window=None),
    dict(b=1, h=4, kv=4, sq=512, sk=512, d=32, causal=True, window=128),
    dict(b=2, h=2, kv=1, sq=256, sk=512, d=64, causal=False, window=None),
    dict(b=1, h=8, kv=8, sq=128, sk=128, d=128, causal=True, window=None),
])
def test_flash_attention(case):
    c = dict(case)
    causal, window = c.pop("causal"), c.pop("window")
    q = jnp.asarray(rng.normal(size=(c["b"], c["h"], c["sq"], c["d"])).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(c["b"], c["kv"], c["sk"], c["d"])).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(c["b"], c["kv"], c["sk"], c["d"])).astype(np.float32))
    qo = c["sk"] - c["sq"] if (causal and c["sk"] > c["sq"]) else 0
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 q_off=qo, bq=128, bk=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window, q_off=qo)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True, bq=128, bk=128,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=0.05, atol=0.05)


# --------------------------- sorted merge -----------------------------------
from repro.kernels.sorted_merge.ops import merge_positions, rank_count
from repro.kernels.sorted_merge.ref import rank_count_ref


@pytest.mark.parametrize("ni,nj", [(64, 64), (300, 500), (8, 1024)])
def test_rank_count(ni, nj):
    i = jnp.asarray(np.unique(rng.integers(0, 10000, ni)).astype(np.int32))
    j = jnp.asarray(np.unique(rng.integers(0, 10000, nj)).astype(np.int32))
    r1, h1 = rank_count(i, j, impl="interpret")
    r2, h2 = rank_count_ref(i, j)
    np.testing.assert_array_equal(r1, r2)
    np.testing.assert_array_equal(h1, h2)


def test_merge_positions_union_semantics():
    i = jnp.asarray(np.asarray([1, 3, 5, 7], np.int32))
    j = jnp.asarray(np.asarray([2, 3, 8], np.int32))
    i_pos, j_pos, j_dup = merge_positions(i, j, impl="interpret")
    union = np.union1d(np.asarray(i), np.asarray(j))
    np.testing.assert_array_equal(union[np.asarray(i_pos)], np.asarray(i))
    np.testing.assert_array_equal(union[np.asarray(j_pos)], np.asarray(j))
    np.testing.assert_array_equal(np.asarray(j_dup), [False, True, False])


# --------------------------- segment reduce ---------------------------------
from repro.kernels.segment_reduce.ops import aggregate_runs, segment_scan
from repro.kernels.segment_reduce.ref import segment_scan_ref


@pytest.mark.parametrize("n,comb", [(256, "sum"), (1024, "min"),
                                    (2048, "max"), (256, "max")])
def test_segment_scan(n, comb):
    keys = jnp.asarray(np.sort(rng.integers(0, n // 8, n)).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    out = segment_scan(keys, vals, combine=comb, impl="interpret")
    ref = segment_scan_ref(keys, vals, combine=comb)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_aggregate_runs_sums():
    keys = jnp.asarray(np.asarray([0, 0, 1, 3, 3, 3], np.int32))
    vals = jnp.asarray(np.asarray([1., 2., 5., 1., 1., 1.], np.float32))
    k, v, heads = aggregate_runs(keys, vals, combine="sum", impl="ref")
    v, heads = np.asarray(v), np.asarray(heads)
    np.testing.assert_array_equal(heads, [True, False, True, True, False, False])
    assert v[0] == 3.0 and v[2] == 5.0 and v[3] == 3.0


# --------------------------- range extract ----------------------------------
from repro.kernels.range_extract.ops import range_mask
from repro.kernels.range_extract.ref import range_mask_ref


@pytest.mark.parametrize("n,box", [(64, (2, 9, 0, 50)), (300, (0, 300, 10, 20)),
                                   (1024, (5, 5, 0, 1)), (8, (0, 8, 0, 8))])
def test_range_mask(n, box):
    from repro.core.sorted_ops import INT_SENTINEL
    rows = np.sort(rng.integers(0, 32, n)).astype(np.int32)
    cols = rng.integers(0, 32, n).astype(np.int32)
    rows[-n // 4:] = INT_SENTINEL  # sentinel tail never kept
    cols[-n // 4:] = INT_SENTINEL
    bounds = jnp.asarray(box, jnp.int32)
    out = range_mask(jnp.asarray(rows), jnp.asarray(cols), bounds,
                     impl="interpret")
    ref = range_mask_ref(jnp.asarray(rows), jnp.asarray(cols),
                         bounds.reshape(1, 4))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    valid = rows != INT_SENTINEL
    want = (valid & (rows >= box[0]) & (rows < box[1])
            & (cols >= box[2]) & (cols < box[3])).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(out), want)


# --------------------------- bsr spgemm -------------------------------------
from repro.kernels.bsr_spgemm.ops import bsr_spgemm, make_block_mask
from repro.kernels.bsr_spgemm.ref import bsr_spgemm_ref


from repro.core.semiring import REGISTRY as _SR_REGISTRY


# semiring-generic accumulation: the block-skip kernel must match the jnp
# oracle for EVERY registered algebra, not just the MXU-friendly ones
@pytest.mark.parametrize("sr", sorted(_SR_REGISTRY))
@pytest.mark.parametrize("mb,kb,n", [(2, 2, 128), (4, 3, 256)])
def test_bsr_spgemm(sr, mb, kb, n):
    a = jnp.asarray(rng.normal(size=(mb * 128, kb * 128)).astype(np.float32))
    mask = jnp.asarray((rng.random((mb, kb)) > 0.5).astype(np.int32))
    b = jnp.asarray(rng.normal(size=(kb * 128, n)).astype(np.float32))
    out = bsr_spgemm(a, mask, b, semiring=sr, impl="interpret")
    ref = bsr_spgemm_ref(a, mask, b, semiring=sr)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_make_block_mask():
    rows = jnp.asarray(np.asarray([0, 130, 300], np.int32))
    cols = jnp.asarray(np.asarray([5, 200, 130], np.int32))
    valid = jnp.asarray(np.asarray([True, True, False]))
    m = np.asarray(make_block_mask(rows, cols, valid, 3, 2))
    assert m[0, 0] == 1 and m[1, 1] == 1 and m.sum() == 2

"""Scalar-prefetch pair-list BSR kernel + plan cache + multirange selection.

Covers the PR-6 surface: (a) the pair-list kernel body (interpret mode)
against the jnp reference oracle and the host CSR oracle across the full
semiring registry, incl. rectangular shapes, empty pair lists and
capacity overflow; (b) the output-capacity sketch estimator (exact small
cases + forced saturation warning); (c) multirange device selections
(``DISPATCH_STATS["multirange"]``) on ``AssocTensor`` and ``DistAssoc``;
(d) the cross-collect plan cache (second ``collect()`` of a structurally
identical graph is a pure cache hit).
"""
import json
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.core import Assoc, REGISTRY
from repro.core.assoc_tensor import DISPATCH_STATS
from repro.core.select import Keys, plan_boxes, compile_selector, All
from repro.core.spgemm import estimate_out_nnz, plan_matmul


def _random_pair(n=60, nr=30, nk=30, nc=20, seed=3):
    r = np.random.default_rng(seed)
    ha = Assoc(r.integers(0, nr, n).astype(str),
               r.integers(0, nk, n).astype(str),
               r.uniform(0.5, 5.0, n), aggregate="sum")
    hb = Assoc(r.integers(0, nk, n).astype(str),
               r.integers(0, nc, n).astype(str),
               r.uniform(0.5, 5.0, n), aggregate="sum")
    return ha, hb, ha.to_tensor(), hb.to_tensor()


def _close(got: dict, want: dict, tol=1e-3):
    assert set(got) == set(want)
    for k in want:
        assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), \
            (k, got[k], want[k])


# ----------------------- pair-list kernel parity -----------------------------

@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
@pytest.mark.parametrize("kernel_impl", ["ref", "interpret"])
def test_pairlist_matmul_parity(sr_name, kernel_impl):
    """Kernel body (interpret) == jnp oracle (ref) == host CSR oracle."""
    ha, hb, da, db = _random_pair()
    want = ha.matmul(hb, sr_name).to_dict()
    got = da.matmul(db, sr_name, impl="bsr",
                    kernel_impl=kernel_impl).to_assoc().to_dict()
    _close(got, want)


@pytest.mark.parametrize("kernel_impl", ["ref", "interpret", "chunked"])
def test_pairlist_rectangular(kernel_impl):
    """Rectangular blocks: >1 tile on every axis, all three dispatches."""
    ha, hb, da, db = _random_pair(n=300, nr=300, nk=260, nc=200, seed=11)
    want = ha.matmul(hb).to_dict()
    got = da.matmul(db, impl="bsr",
                    kernel_impl=kernel_impl).to_assoc().to_dict()
    _close(got, want)


def test_pairlist_empty_pair_list():
    """Disjoint contraction support → zero tile pairs → empty C, no crash."""
    ha = Assoc(["r0", "r1"], ["k0", "k1"], [1.0, 2.0])
    hb = Assoc(["k7", "k8"], ["c0", "c1"], [3.0, 4.0])
    da, db = ha.to_tensor(), hb.to_tensor()
    for kernel_impl in ("ref", "interpret", "chunked"):
        out = da.matmul(db, impl="bsr", kernel_impl=kernel_impl).to_assoc()
        assert out.to_dict() == {}


@pytest.mark.parametrize("axis", [0, 1])
@pytest.mark.parametrize("sr_name", sorted(REGISTRY))
def test_pairlist_reduce_parity(sr_name, axis):
    """Fused pair-list reduce (interpret) == materialize-then-reduce."""
    ha, hb, da, db = _random_pair(seed=5)
    sr = REGISTRY[sr_name]
    # oracle: the SAME device strategy, materialized then ⊕-folded
    c = da.matmul(db, sr_name, impl="bsr", kernel_impl="ref").to_assoc()
    adj = c.adj.toarray()
    mask = adj != 0
    # axis=1 folds over columns (vector over rows); axis=0 over rows
    if sr.add_kind == "sum":
        want = np.where(mask, adj, 0.0).sum(axis=axis)
    elif sr.add_kind == "max":
        want = np.where(mask, adj, -np.inf).max(axis=axis, initial=-np.inf)
    else:
        want = np.where(mask, adj, np.inf).min(axis=axis, initial=np.inf)
    got_full = np.asarray(da.matmul_reduce(db, axis, sr_name, impl="bsr",
                                           kernel_impl="interpret"))
    # compare on the support of C only (identity rows/cols differ)
    space = da.row_space if axis == 1 else db.col_space
    keys = list(c.row) if axis == 1 else list(c.col)
    idx, _ = space.rank(np.asarray(keys))
    np.testing.assert_allclose(got_full[idx], want, rtol=1e-3, atol=1e-3)


def test_pairlist_capacity_overflow_warns():
    """BSR path with a too-small out_capacity warns and flags overflow."""
    ha, hb, da, db = _random_pair(seed=9)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = da.matmul(db, impl="bsr", kernel_impl="ref", out_capacity=8)
    assert out.overflow
    assert any("capacity" in str(w.message).lower() for w in caught)


def test_pairlist_pairs_sorted_by_c():
    """plan_matmul's pair lists are grouped by pair_c (kernel contract)."""
    r = np.random.default_rng(2)
    n, m, k, nc = 400, 300, 300, 300
    ra, ca = r.integers(0, m, n), r.integers(0, k, n)
    rb, cb = r.integers(0, k, n), r.integers(0, nc, n)
    plan = plan_matmul(ra.astype(np.int32), ca.astype(np.int32),
                       rb.astype(np.int32), cb.astype(np.int32),
                       m, k, nc, impl="bsr")
    assert (np.diff(plan.pair_c) >= 0).all()


# ----------------------- output-capacity estimator ---------------------------

def test_estimator_upper_bounds_and_tightens():
    """Estimate ≥ true nnz(C); on hub-heavy inputs ≪ product count."""
    r = np.random.default_rng(4)
    n = 500
    # hub-heavy: every A col and B row is the same hub → products = n*n
    # but C support is only |rows(A)| x |cols(B)|
    ra = r.integers(0, 40, n).astype(np.int32)
    ca = np.zeros(n, np.int32)
    rb = np.zeros(n, np.int32)
    cb = r.integers(0, 40, n).astype(np.int32)
    plan = plan_matmul(ra, ca, rb, cb, 40, 1, 40, impl="bsr")
    est = estimate_out_nnz(plan)
    true_nnz = len(np.unique(ra)) * len(np.unique(cb))
    assert est >= true_nnz
    assert est < plan.products  # tighter than the raw product count


def test_estimator_saturation_warns_and_falls_back():
    """A sketch with absurdly few bins saturates → warn + provable bound."""
    r = np.random.default_rng(6)
    n = 2000
    ra = r.integers(0, 3000, n).astype(np.int32)
    ca = r.integers(0, 600, n).astype(np.int32)
    rb = r.integers(0, 600, n).astype(np.int32)
    cb = r.integers(0, 3000, n).astype(np.int32)
    plan = plan_matmul(ra, ca, rb, cb, 3000, 600, 3000, impl="bsr")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        est = estimate_out_nnz(plan, bins=8)
    assert est >= 1
    assert any("saturated" in str(w.message) for w in caught)


def test_estimator_capacity_never_truncates():
    """Default (estimator-sized) BSR matmul never loses entries."""
    for seed in (1, 2, 3):
        ha, hb, da, db = _random_pair(n=120, seed=seed)
        want = ha.matmul(hb).to_dict()
        got = da.matmul(db, impl="bsr", kernel_impl="ref").to_assoc().to_dict()
        _close(got, want)


# ----------------------- multirange selections -------------------------------

def _grid_tensor(nr=12, nc=10, seed=0):
    r = np.random.default_rng(seed)
    rows = [f"r{i:02d}" for i in range(nr)]
    cols = [f"c{i:02d}" for i in range(nc)]
    tr, tc = r.choice(rows, 6 * nr), r.choice(cols, 6 * nr)
    tv = r.uniform(1, 5, 6 * nr)
    return Assoc(tr, tc, tv, aggregate="sum")


def test_plan_boxes_two_runs():
    a = _grid_tensor()
    t = a.to_tensor()
    rc = compile_selector(Keys(["r01", "r02", "r07", "r08"]), t.row_space)
    cc = compile_selector(All(), t.col_space)
    boxes, rg, cg = plan_boxes(rc, cc, len(t.row_space), len(t.col_space))
    assert not rg and not cg
    assert boxes.shape == (2, 4)
    np.testing.assert_array_equal(boxes[:, 0], [1, 7])  # run starts


def test_plan_boxes_gather_fallback():
    """>4 boxes → membership gather, not an unbounded OR chain."""
    a = _grid_tensor(nr=20)
    t = a.to_tensor()
    scattered = [f"r{i:02d}" for i in range(0, 20, 2)]  # 10 singleton runs
    rc = compile_selector(Keys(scattered), t.row_space)
    cc = compile_selector(All(), t.col_space)
    boxes, rg, cg = plan_boxes(rc, cc, len(t.row_space), len(t.col_space))
    assert rg  # row axis falls back to gather


def test_multirange_dispatch_and_parity():
    a = _grid_tensor(seed=3)
    t = a.to_tensor()
    sel = ["r01", "r02", "r03", "r07", "r08"]
    before = dict(DISPATCH_STATS)
    sub = t[Keys(sel), :]
    assert DISPATCH_STATS["multirange"] == before["multirange"] + 1
    _close(sub.to_assoc().to_dict(), a[sel, :].to_dict())


def test_multirange_both_axes():
    """≤4 boxes from 2 row runs × 2 col runs, exact vs host oracle."""
    a = _grid_tensor(nr=16, nc=12, seed=5)
    t = a.to_tensor()
    rsel = ["r01", "r02", "r09", "r10"]
    csel = ["c00", "c01", "c06", "c07"]
    before = dict(DISPATCH_STATS)
    sub = t[Keys(rsel), Keys(csel)]
    assert DISPATCH_STATS["multirange"] == before["multirange"] + 1
    _close(sub.to_assoc().to_dict(), a[rsel, csel].to_dict())


DIST_MULTIRANGE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import Assoc
    from repro.core.assoc_tensor import DISPATCH_STATS
    from repro.core.dist_assoc import DistAssoc
    from repro.core.select import Keys

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    rows = [f"r{i:02d}" for i in range(16)]
    cols = [f"c{i:02d}" for i in range(10)]
    A = Assoc(rng.choice(rows, 80), rng.choice(cols, 80),
              rng.uniform(1, 5, 80), aggregate="sum")
    D = DistAssoc.from_assoc(A, mesh)
    sel = ["r01", "r02", "r03", "r09", "r10"]
    before = dict(DISPATCH_STATS)
    sub = D[Keys(sel), :]
    assert DISPATCH_STATS["multirange"] == before["multirange"] + 1
    got, want = sub.to_assoc().to_dict(), A[sel, :].to_dict()
    assert set(got) == set(want)
    for k in want:
        assert abs(got[k] - want[k]) < 1e-3 * (1 + abs(want[k]))

    # distributed bsr matmul parity while we have the mesh up
    B = Assoc(rng.choice(cols, 60), rng.choice(rows, 60),
              rng.uniform(1, 5, 60), aggregate="sum")
    Dt = B.to_tensor()
    want2 = A.matmul(B).to_dict()
    got2 = D.matmul(Dt, impl="bsr", kernel_impl="ref").to_assoc().to_dict()
    assert set(got2) == set(want2)
    for k in want2:
        assert abs(got2[k] - want2[k]) < 1e-3 * (1 + abs(want2[k]))
    print(json.dumps({"ok": True}))
""")


@pytest.mark.slow
def test_dist_multirange_and_bsr_8dev():
    p = subprocess.run([sys.executable, "-c", DIST_MULTIRANGE_PROG],
                       capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    last = [l for l in p.stdout.strip().splitlines() if l.startswith("{")][-1]
    assert json.loads(last)["ok"], p.stdout


def test_dist_bsr_matmul_parity_1dev():
    """Per-shard bsr strategy == coo strategy == host, on a 1-shard mesh."""
    from repro.core.dist_assoc import DistAssoc
    mesh = jax.make_mesh((1,), ("data",))
    ha, hb, _, db = _random_pair(seed=13)
    D = DistAssoc.from_assoc(ha, mesh)
    want = ha.matmul(hb).to_dict()
    for impl, kw in [("coo", {}), ("bsr", {"kernel_impl": "ref"}),
                     ("bsr", {"kernel_impl": "interpret"})]:
        got = D.matmul(db, impl=impl, **kw).to_assoc().to_dict()
        _close(got, want)


# ----------------------- cross-collect plan cache ----------------------------

def _pipeline(da, db):
    """A multi-node graph (single-node graphs take the planner-free fast
    path): (A @ B) ⊗ (A @ B) — the hash-consed square."""
    sq = da.lazy() @ db.lazy().T
    return sq * sq


def test_plan_cache_second_collect_hits():
    from repro.core import PLAN_STATS

    ha, hb, da, db = _random_pair(seed=21)
    r1 = _pipeline(da, db).collect()
    assert PLAN_STATS["plan_misses"] == 1
    assert PLAN_STATS["plan_hits"] == 0
    # structurally identical graph over the SAME sources → pure hit
    r2 = _pipeline(da, db).collect()
    assert PLAN_STATS["plan_misses"] == 1
    assert PLAN_STATS["plan_hits"] == 1
    _close(r2.to_assoc().to_dict(), r1.to_assoc().to_dict(), tol=1e-6)


def test_plan_cache_distinct_sources_miss():
    from repro.core import PLAN_STATS

    _, _, da, db = _random_pair(seed=22)
    _, _, da2, db2 = _random_pair(seed=23)
    _pipeline(da, db).collect()
    _pipeline(da2, db2).collect()  # different source arrays → new key
    assert PLAN_STATS["plan_misses"] == 2
    assert PLAN_STATS["plan_hits"] == 0


def test_plan_cache_clear_forces_miss():
    from repro.core import PLAN_STATS, clear_plan_cache

    _, _, da, db = _random_pair(seed=24)
    _pipeline(da, db).collect()
    clear_plan_cache()
    _pipeline(da, db).collect()
    assert PLAN_STATS["plan_misses"] == 2
    assert PLAN_STATS["plan_hits"] == 0

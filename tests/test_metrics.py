"""D4M telemetry: idempotent merges, series extraction."""
import numpy as np

from repro.distributed import MetricsStore


def test_log_and_series():
    ms = MetricsStore("last")
    ms.log(0, {"loss": 4.0, "lr": 0.1})
    ms.log(1, {"loss": 3.5, "lr": 0.1})
    steps, losses = ms.series("loss")
    np.testing.assert_array_equal(steps, [0.0, 1.0])
    np.testing.assert_array_equal(losses, [4.0, 3.5])


def test_merge_idempotent_under_retry():
    """Re-reporting the same step after a restart can't corrupt history —
    ⊕ = max is idempotent (the D4M argument for semiring telemetry)."""
    a = MetricsStore("max")
    a.log(5, {"tokens": 100.0})
    b = MetricsStore("max")
    b.log(5, {"tokens": 100.0})   # duplicated retry report
    merged = a.merge(b)
    _, v = merged.series("tokens")
    np.testing.assert_array_equal(v, [100.0])
    again = merged.merge(b)
    _, v2 = again.series("tokens")
    np.testing.assert_array_equal(v2, [100.0])


def test_cross_host_sum_merge():
    h0, h1 = MetricsStore("sum"), MetricsStore("sum")
    h0.log(1, {"examples": 8.0})
    h1.log(1, {"examples": 8.0})
    merged = h0.merge(h1)
    _, v = merged.series("examples")
    np.testing.assert_array_equal(v, [16.0])


def test_serialization_roundtrip():
    ms = MetricsStore("last")
    ms.log(2, {"loss": 1.5})
    ms2 = MetricsStore.from_dict(ms.to_dict())
    s, v = ms2.series("loss")
    np.testing.assert_array_equal(v, [1.5])


def test_log_is_buffered_one_combine_per_flush():
    """Regression for the O(n²) log path: N log() calls cost ZERO table
    rebuilds; a flush folds them with ONE batched construction + at most
    one combine against the existing table."""
    ms = MetricsStore("sum")
    for step in range(50):
        ms.log(step, {"loss": 1.0, "tok": 2.0})
    assert ms.combine_calls == 0            # nothing merged during logging
    table = ms.table                        # first read flushes
    assert ms.combine_calls == 0            # empty table: batch IS the table
    assert table.nnz() == 100
    for step in range(50, 100):
        ms.log(step, {"loss": 1.0})
    assert ms.table.nnz() == 150
    assert ms.combine_calls == 1            # second flush: exactly one ⊕
    ms.flush()                              # nothing pending: no combine
    assert ms.combine_calls == 1


def test_buffered_semantics_match_sequential():
    """Intra-batch collisions resolve by ⊕ in log order — identical to the
    old rebuild-per-log behaviour for every aggregate kind."""
    for agg, expect in [("last", 3.0), ("sum", 6.0), ("max", 3.0),
                        ("min", 1.0)]:
        ms = MetricsStore(agg)
        ms.log(0, {"m": 1.0})
        ms.log(0, {"m": 2.0})
        ms.log(0, {"m": 3.0})
        _, v = ms.series("m")
        np.testing.assert_array_equal(v, [expect], err_msg=agg)
        # and across a flush boundary (pending batch ⊕ existing table)
        ms.flush()
        ms.log(0, {"m": 2.0})
        _, v = ms.series("m")
        expect2 = {"last": 2.0, "sum": 8.0, "max": 3.0, "min": 1.0}[agg]
        np.testing.assert_array_equal(v, [expect2], err_msg=agg)


def test_concurrent_logging_threads():
    import threading

    ms = MetricsStore("sum")
    n_threads, n_iter = 8, 100

    def worker():
        for i in range(n_iter):
            ms.log(i, {"count": 1.0})

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    steps, vals = ms.series("count")
    assert len(steps) == n_iter
    np.testing.assert_array_equal(vals, np.full(n_iter, float(n_threads)))

"""D4M telemetry: idempotent merges, series extraction."""
import numpy as np

from repro.distributed import MetricsStore


def test_log_and_series():
    ms = MetricsStore("last")
    ms.log(0, {"loss": 4.0, "lr": 0.1})
    ms.log(1, {"loss": 3.5, "lr": 0.1})
    steps, losses = ms.series("loss")
    np.testing.assert_array_equal(steps, [0.0, 1.0])
    np.testing.assert_array_equal(losses, [4.0, 3.5])


def test_merge_idempotent_under_retry():
    """Re-reporting the same step after a restart can't corrupt history —
    ⊕ = max is idempotent (the D4M argument for semiring telemetry)."""
    a = MetricsStore("max")
    a.log(5, {"tokens": 100.0})
    b = MetricsStore("max")
    b.log(5, {"tokens": 100.0})   # duplicated retry report
    merged = a.merge(b)
    _, v = merged.series("tokens")
    np.testing.assert_array_equal(v, [100.0])
    again = merged.merge(b)
    _, v2 = again.series("tokens")
    np.testing.assert_array_equal(v2, [100.0])


def test_cross_host_sum_merge():
    h0, h1 = MetricsStore("sum"), MetricsStore("sum")
    h0.log(1, {"examples": 8.0})
    h1.log(1, {"examples": 8.0})
    merged = h0.merge(h1)
    _, v = merged.series("examples")
    np.testing.assert_array_equal(v, [16.0])


def test_serialization_roundtrip():
    ms = MetricsStore("last")
    ms.log(2, {"loss": 1.5})
    ms2 = MetricsStore.from_dict(ms.to_dict())
    s, v = ms2.series("loss")
    np.testing.assert_array_equal(v, [1.5])

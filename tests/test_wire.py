"""Wire-format tests: round trips over every node/selector/semiring, and
structured rejection of malformed payloads (never a bare exception)."""
import json

import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import (All, Keys, Mask, Match, Positions, Range, REGISTRY,
                        StartsWith, Where)
from repro.core.select import And, Not, Or
from repro.serve.wire import (TableRef, WireError, WIRE_VERSION, from_wire,
                              register_predicate, sel_from_wire, sel_to_wire,
                              table_names, to_wire)


def roundtrip_sel(sel):
    return sel_from_wire(sel_to_wire(sel))


def roundtrip(expr):
    return from_wire(to_wire(expr))


# ---------------------------------------------------------------------------
# Selector round trips — every selector kind in core/select.py
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sel", [
    All(),
    Keys(["r01", "r07", "r03"]),
    Keys(np.asarray([3.0, 1.0, 2.0])),
    Positions([0, 5, 2]),
    Positions(slice(2, 20, 3)),
    Range("a", "m"),
    Range("a", "m", inclusive=(True, False)),
    Range(None, "k"),
    Range(1.5, 9.0),
    StartsWith("r0"),
    StartsWith(["r0", "r1"]),
    Match(r"r0[0-4]$"),
    Mask([True, False, True, True]),
], ids=lambda s: type(s).__name__ + str(id(s) % 97))
def test_selector_roundtrip(sel):
    back = roundtrip_sel(sel)
    assert type(back) is type(sel)
    assert back.cache_key() == sel.cache_key()


def test_selector_compound_roundtrip():
    sel = (StartsWith("r0") & Match("r.[02468]")) | ~Keys(["r11"])
    back = roundtrip_sel(sel)
    assert back.cache_key() == sel.cache_key()


def test_selector_raw_forms_coerce():
    # raw __getitem__ arguments serialize through as_selector coercion
    assert roundtrip_sel("r05").cache_key() == Keys(["r05"]).cache_key()
    assert isinstance(roundtrip_sel(slice(None)), All)
    got = roundtrip_sel([2, 4, 6])
    assert got.cache_key() == Positions([2, 4, 6]).cache_key()


def test_where_crosses_by_registered_name_only():
    fn = lambda v: v > 2.0              # noqa: E731
    with pytest.raises(WireError) as ei:
        sel_to_wire(Where(fn))
    assert ei.value.code == "unserializable_selector"

    register_predicate("gt2", fn)
    back = roundtrip_sel(Where(fn))
    assert isinstance(back, Where)
    assert back.fn is fn

    with pytest.raises(WireError) as ei:
        sel_from_wire({"sel": "where", "name": "no_such_predicate"})
    assert ei.value.code == "unknown_predicate"


# ---------------------------------------------------------------------------
# Expression round trips — every node type × every registered semiring
# ---------------------------------------------------------------------------

def test_expr_roundtrip_every_node_type():
    A, B = TableRef("edges"), TableRef("feat")
    expr = ((A[StartsWith("r0"), :] @ B).sum(axis=1))
    back = roundtrip(expr)
    assert back.key() == expr.key()

    expr2 = (A + B) * A.T
    assert roundtrip(expr2).key() == expr2.key()

    expr3 = A[Range("a", "m"), Keys(["c01"])].sum(axis=None)
    assert roundtrip(expr3).key() == expr3.key()


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_expr_roundtrip_every_semiring(name):
    A, B = TableRef("edges"), TableRef("feat")
    expr = A.matmul(B, semiring=name).sum(axis=0, semiring=name)
    back = roundtrip(expr)
    assert back.key() == expr.key()


def test_shared_subtree_serializes_once():
    A = TableRef("edges")
    sub = A[StartsWith("r0"), :]
    expr = sub @ sub                # same structural subtree twice
    payload = to_wire(expr)
    sel_nodes = [n for n in payload["nodes"] if n["op"] == "select"]
    assert len(sel_nodes) == 1      # hash-consed: one node, referenced twice
    back = roundtrip(expr)
    assert back.key() == expr.key()
    assert back.a is back.b         # decoded back into one shared node


# -- property test: random expression graphs survive the full JSON trip ----

def _rand_selector(draw):
    kind = draw(st.integers(0, 4))
    if kind == 0:
        return All()
    if kind == 1:
        ks = draw(st.lists(st.integers(0, 63), min_size=1, max_size=6))
        return Keys([f"r{k:02d}" for k in ks])
    if kind == 2:
        lo, hi = sorted(draw(st.lists(st.integers(0, 63), min_size=2,
                                      max_size=2)))
        return Range(f"r{lo:02d}", f"r{hi:02d}")
    if kind == 3:
        return StartsWith(f"r{draw(st.integers(0, 9))}")
    return Positions(draw(st.lists(st.integers(0, 63), min_size=1,
                                   max_size=6)))


def _rand_expr(draw, depth):
    if depth <= 0 or draw(st.booleans()):
        return TableRef(draw(st.sampled_from(["edges", "feat", "other"])))
    op = draw(st.integers(0, 5))
    sr = draw(st.sampled_from(sorted(REGISTRY)))
    if op == 0:
        return _rand_expr(draw, depth - 1)[
            _rand_selector(draw), _rand_selector(draw)]
    if op == 1:
        return _rand_expr(draw, depth - 1).add(
            _rand_expr(draw, depth - 1), semiring=sr)
    if op == 2:
        return _rand_expr(draw, depth - 1).mul(
            _rand_expr(draw, depth - 1), semiring=sr)
    if op == 3:
        return _rand_expr(draw, depth - 1).matmul(
            _rand_expr(draw, depth - 1), semiring=sr)
    if op == 4:
        return _rand_expr(draw, depth - 1).sum(
            axis=draw(st.sampled_from([None, 0, 1])), semiring=sr)
    return _rand_expr(draw, depth - 1).T


@given(data=st.data())
def test_random_graph_json_roundtrip(data):
    expr = _rand_expr(data.draw, depth=4)
    payload = to_wire(expr)
    # through actual JSON text — what the HTTP layer ships
    back = from_wire(json.loads(json.dumps(payload)))
    assert back.key() == expr.key()


def test_table_names_admission_key():
    A, B = TableRef("edges"), TableRef("feat")
    payload = to_wire((A @ B) + A)
    assert table_names(payload) == ("edges", "feat")


# ---------------------------------------------------------------------------
# Malformed payloads: structured WireError codes, not arbitrary crashes
# ---------------------------------------------------------------------------

def _payload(nodes, root=None):
    return {"version": WIRE_VERSION, "nodes": nodes,
            "root": len(nodes) - 1 if root is None else root}


def _code(payload, resolve=None):
    with pytest.raises(WireError) as ei:
        from_wire(payload, resolve=resolve)
    return ei.value.code


def test_reject_bad_version():
    assert _code({"version": 99, "nodes": [], "root": 0}) == "bad_version"
    assert _code({"nodes": [{"op": "table", "name": "t"}],
                  "root": 0}) == "bad_version"


def test_reject_unknown_semiring():
    p = _payload([{"op": "table", "name": "t"},
                  {"op": "matmul", "a": 0, "b": 0,
                   "semiring": "frobnicate"}])
    assert _code(p) == "unknown_semiring"


def test_reject_unknown_op():
    assert _code(_payload([{"op": "quantum_join"}])) == "unknown_op"


def test_reject_cyclic_refs():
    # self reference
    p = _payload([{"op": "table", "name": "t"},
                  {"op": "transpose", "child": 1}])
    assert _code(p) == "cycle"
    # forward reference
    p = _payload([{"op": "transpose", "child": 1},
                  {"op": "table", "name": "t"}], root=0)
    assert _code(p) == "cycle"


def test_reject_structural_garbage():
    assert _code("not a dict") == "bad_payload"
    assert _code({"version": WIRE_VERSION, "nodes": [],
                  "root": 0}) == "bad_payload"
    assert _code(_payload([{"no_op": True}])) == "bad_payload"
    assert _code(_payload([{"op": "table", "name": ""}])) == "bad_payload"
    assert _code(_payload([{"op": "table", "name": "t"}],
                          root=7)) == "bad_payload"
    assert _code(_payload([{"op": "table", "name": "t"},
                           {"op": "select", "child": 0,
                            "row": {"sel": "martian"},
                            "col": {"sel": "all"}}])) == "bad_selector"
    assert _code(_payload([{"op": "table", "name": "t"},
                           {"op": "reduce", "child": 0,
                            "axis": 7}])) == "bad_payload"


def test_reject_unknown_table_via_resolver():
    from repro.serve.registry import TableRegistry
    reg = TableRegistry()
    p = _payload([{"op": "table", "name": "ghost"}])
    assert _code(p, resolve=reg.resolve) == "unknown_table"


def test_source_without_name_mapping_rejected():
    from repro.core import Assoc, lazy
    a = Assoc(["r0"], ["c0"], [1.0])
    with pytest.raises(WireError) as ei:
        to_wire(lazy(a))
    assert ei.value.code == "unknown_table"
    # with the mapping it serializes as a named table node
    payload = to_wire(lazy(a), names={id(a): "mytab"})
    assert table_names(payload) == ("mytab",)

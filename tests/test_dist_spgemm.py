"""Communication-optimal distributed spgemm: cost model + strategy parity.

Three tiers:

* pure-host unit tests for :func:`plan_dist_matmul` / :func:`suggest_grid`
  (no devices needed — the cost model is numpy-only metadata);
* in-process 1-device checks (strategy dispatch degenerates to replicate,
  PLAN_STATS counters, fused ``(A ⊕ B)[sel]``);
* an 8-shard subprocess run (device count locks at first jax init) that
  exercises ragged shard sizes, a non-divisible contraction range, empty
  shards, resident-``DistAssoc`` and staged-``AssocTensor`` B operands,
  every ``impl=`` override, 2D grid overrides and the fused reduce
  epilogues — all against the eager host ``Assoc`` oracle.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Assoc, AssocTensor, PLAN_STATS, Range, REGISTRY
from repro.core.coo import SENT
from repro.core.spgemm import plan_dist_matmul, suggest_grid

rng = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# cost model (host-only)
# ---------------------------------------------------------------------------

def _synthetic(P=4, cap=8, k=16, nnz_per_shard=3, nnz_b=20, seed=0):
    r = np.random.default_rng(seed)
    a_rows = np.full((P, cap), int(SENT), np.int64)
    a_cols = np.zeros((P, cap), np.int64)
    counts = np.zeros((P, cap), np.int64)
    for s in range(P):
        a_rows[s, :nnz_per_shard] = np.arange(nnz_per_shard)
        a_cols[s, :nnz_per_shard] = r.integers(0, k, nnz_per_shard)
        counts[s, :nnz_per_shard] = r.integers(1, 4, nnz_per_shard)
    b_rows = np.sort(r.integers(0, k, nnz_b))
    return a_rows, a_cols, counts, b_rows, k


def test_plan_single_shard_always_replicates():
    a_rows, a_cols, counts, b_rows, k = _synthetic(P=1)
    plan = plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 1)
    assert plan.strategy == "replicate"
    assert set(plan.costs) == {"replicate", "all_to_all", "2d"}
    assert set(plan.expands) == {"replicate", "all_to_all", "2d"}


def test_plan_large_b_prefers_sharded_strategy():
    # tiny A, huge B: replicating B to every shard is the one strategy
    # whose cost scales with P·nnz(B) — the model must not pick it.
    a_rows, a_cols, counts, _, k = _synthetic(P=8, nnz_per_shard=2)
    b_rows = np.sort(rng.integers(0, k, 100_000))
    plan = plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 8,
                            b_resident=True)
    assert plan.strategy in ("all_to_all", "2d")
    assert plan.costs[plan.strategy] < plan.costs["replicate"]
    # chosen strategy is the argmin of the published cost dict
    assert plan.costs[plan.strategy] == min(plan.costs.values())


def test_plan_resident_b_drops_staging_cost():
    a_rows, a_cols, counts, b_rows, k = _synthetic(P=4, nnz_b=50)
    res = plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 4,
                           b_resident=True)
    staged = plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 4,
                              b_resident=False)
    assert staged.costs["all_to_all"] - res.costs["all_to_all"] == len(b_rows)
    assert res.costs["replicate"] == staged.costs["replicate"]


def test_plan_forced_grid():
    a_rows, a_cols, counts, b_rows, k = _synthetic(P=4)
    plan = plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 4,
                            grid=(2, 2))
    assert plan.grid == (2, 2)
    with pytest.raises(ValueError):
        plan_dist_matmul(a_rows, a_cols, counts, b_rows, k, 4, grid=(3, 2))


def test_suggest_grid_tiles_mesh_and_sizes_blocks():
    a_rows, a_cols, counts, b_rows, k = _synthetic(P=8, nnz_b=64)
    (pr, pc), round_expand, block_cap, cost = suggest_grid(
        8, k, a_cols, counts, b_rows)
    assert pr * pc == 8
    assert round_expand >= 8 and block_cap >= 8
    # block_cap covers the fullest contraction block of the winning split
    bnds = np.linspace(0, k, pc + 1).astype(np.int64)
    assert block_cap >= int(np.diff(np.searchsorted(b_rows, bnds)).max())
    from repro.core.spgemm import _SORT_WEIGHT
    assert cost == (pr * len(b_rows) + 8 * (pc - 1) * block_cap
                    + _SORT_WEIGHT * pc * round_expand)


# ---------------------------------------------------------------------------
# 1-device dispatch + fused select⊕add (satellite)
# ---------------------------------------------------------------------------

def _triples(seed, n=60, nr=30, nc=30):
    r = np.random.default_rng(seed)
    return (r.integers(0, nr, n).astype(str),
            r.integers(0, nc, n).astype(str),
            r.uniform(0.5, 5.0, n))


@pytest.fixture(scope="module")
def mesh1():
    import jax
    return jax.make_mesh((1,), ("data",))


def _close(got, want, tol=1e-3):
    assert set(got) == set(want), sorted(set(got) ^ set(want))[:8]
    for k in want:
        assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), \
            (k, got[k], want[k])


def test_single_device_strategies_agree(mesh1):
    from repro.core.dist_assoc import DistAssoc
    ar, ac, av = _triples(3)
    br, bc, bv = _triples(5, nc=20)
    want = Assoc(ar, ac, av, aggregate="sum").matmul(
        Assoc(br, bc, bv, aggregate="sum")).to_dict()
    da = DistAssoc.from_triples(ar, ac, av, mesh1, aggregate="sum")
    bt = AssocTensor.from_triples(br, bc, bv, aggregate="sum", capacity=128)
    for impl in ("auto_dist", "replicate", "all_to_all", "2d", "coo", "bsr"):
        _close(da.matmul(bt, impl=impl).to_assoc().to_dict(), want)
    # P == 1: auto must degenerate to replicate, and every call is counted
    assert PLAN_STATS["dist_replicate"] >= 1
    assert (PLAN_STATS["dist_replicate"] + PLAN_STATS["dist_all_to_all"]
            + PLAN_STATS["dist_2d"]) == 6


def test_matmul_bad_impl_rejected(mesh1):
    from repro.core.dist_assoc import DistAssoc
    ar, ac, av = _triples(3)
    da = DistAssoc.from_triples(ar, ac, av, mesh1, aggregate="sum")
    bt = AssocTensor.from_triples(*_triples(5), aggregate="sum",
                                  capacity=128)
    with pytest.raises(ValueError):
        da.matmul(bt, impl="telepathy")


SEL = Range("1", "2")


def test_fused_select_add_parity(mesh1):
    from repro.core.dist_assoc import DistAssoc
    ar, ac, av = _triples(7)
    # DistAssoc ⊕ is alignment-free (shards assume shared keyspaces /
    # row_bounds, like the eager ``add``): draw B over the same key
    # population so all three layers compare against one host oracle
    perm = np.random.default_rng(9).permutation(len(ar))
    br, bc = ar[perm], ac[perm]
    bv = np.random.default_rng(13).uniform(0.5, 5.0, len(ar))
    ha, hb = (Assoc(ar, ac, av, aggregate="sum"),
              Assoc(br, bc, bv, aggregate="sum"))
    want = ha.add(hb)._select_eager((SEL, slice(None))).to_dict()

    # host layer: selected ⊕ runs in one canonicalize pass
    got_h = (ha.lazy().add(hb.lazy()))[SEL, :].collect()
    _close(got_h.to_dict(), want)
    assert PLAN_STATS["fused_select_ewise"] >= 1

    ta = AssocTensor.from_triples(ar, ac, av, aggregate="sum", capacity=128)
    tb = AssocTensor.from_triples(br, bc, bv, aggregate="sum", capacity=128)
    got_d = (ta.lazy().add(tb.lazy()))[SEL, :].collect()
    _close(got_d.to_assoc().to_dict(), want)

    Da = DistAssoc.from_triples(ar, ac, av, mesh1, aggregate="sum")
    Db = DistAssoc.from_triples(br, bc, bv, mesh1, aggregate="sum")
    got_D = (Da.lazy().add(Db.lazy()))[SEL, :].collect()
    _close(got_D.to_assoc().to_dict(), want)
    assert PLAN_STATS["fused_select_ewise"] >= 3

    # explicit pre-sliced form fuses too
    got_2 = ha.lazy()[SEL, :].add(hb.lazy()[SEL, :]).collect()
    _close(got_2.to_dict(), want)


# ---------------------------------------------------------------------------
# 8-shard subprocess: ragged shards, non-divisible k, empty shards
# ---------------------------------------------------------------------------

PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    from repro.core import Assoc, AssocTensor, PLAN_STATS, REGISTRY
    from repro.core.dist_assoc import DistAssoc

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(7)

    def close(got, want, tol=1e-3, tag=""):
        assert set(got) == set(want), (tag, sorted(set(got) ^ set(want))[:8])
        for k in want:
            assert abs(got[k] - want[k]) <= tol * (1 + abs(want[k])), \\
                (tag, k, got[k], want[k])

    # ragged: 37 row keys over 8 shards, k = 29 (neither divisible by 8)
    ar = rng.integers(0, 37, 140).astype(str)
    ac = rng.integers(0, 29, 140).astype(str)
    av = rng.uniform(0.5, 3.0, 140)
    br = rng.integers(0, 29, 170).astype(str)
    bc = rng.integers(0, 23, 170).astype(str)
    bv = rng.uniform(0.5, 3.0, 170)

    ha = Assoc(ar, ac, av, aggregate="sum")
    hb = Assoc(br, bc, bv, aggregate="sum")
    da = DistAssoc.from_triples(ar, ac, av, mesh, aggregate="sum")
    bt = AssocTensor.from_triples(br, bc, bv, aggregate="sum", capacity=256)
    db = DistAssoc.from_triples(br, bc, bv, mesh, aggregate="sum")

    want = ha.matmul(hb).to_dict()
    for impl in ("auto_dist", "replicate", "all_to_all", "2d", "coo", "bsr"):
        for tag, B in (("resident", db), ("staged", bt)):
            close(da.matmul(B, impl=impl).to_assoc().to_dict(), want,
                  tag=f"{impl}/{tag}")
    assert PLAN_STATS["dist_2d"] >= 2, PLAN_STATS
    assert PLAN_STATS["dist_all_to_all"] >= 2, PLAN_STATS

    # every legal grid override agrees
    for grid in ((8, 1), (4, 2), (2, 4), (1, 8)):
        close(da.matmul(db, impl="2d", grid=grid).to_assoc().to_dict(),
              want, tag=f"grid{grid}")

    # full-semiring parity on the sharded strategies (resident B)
    for name in sorted(REGISTRY):
        sr = REGISTRY[name]
        w = ha.matmul(hb, sr).to_dict()
        for impl in ("replicate", "all_to_all", "2d"):
            close(da.matmul(db, sr, impl=impl).to_assoc().to_dict(), w,
                  tag=f"{name}/{impl}")

    # fused reduce epilogues: replicate vs all-to-all, both axes
    for axis in (0, 1):
        rep = np.asarray(da.matmul_reduce(bt, axis=axis, impl="replicate"))
        a2a = np.asarray(da.matmul_reduce(bt, axis=axis,
                                          impl="all_to_all"))
        auto = np.asarray(da.matmul_reduce(bt, axis=axis))
        assert np.abs(rep).sum() > 0, axis
        np.testing.assert_allclose(a2a, rep, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(auto, rep, rtol=1e-4, atol=1e-4)

    # empty shards: 4 distinct row keys cannot populate 8 shards
    er = np.array([str(i % 4) for i in range(24)])
    ec = rng.integers(0, 29, 24).astype(str)
    ev = rng.uniform(0.5, 3.0, 24)
    de = DistAssoc.from_triples(er, ec, ev, mesh, aggregate="sum")
    we = Assoc(er, ec, ev, aggregate="sum").matmul(hb).to_dict()
    for impl in ("auto_dist", "replicate", "all_to_all", "2d"):
        close(de.matmul(bt, impl=impl).to_assoc().to_dict(), we,
              tag=f"empty/{impl}")

    print(json.dumps({"ok": True}))
""")


@pytest.mark.slow
def test_dist_spgemm_8dev():
    p = subprocess.run([sys.executable, "-c", PROG], capture_output=True,
                       text=True, timeout=900)
    assert p.returncode == 0, p.stderr[-4000:]
    last = [l for l in p.stdout.strip().splitlines() if l.startswith("{")][-1]
    assert json.loads(last)["ok"], p.stdout

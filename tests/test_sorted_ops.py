"""Padded sorted-set primitives vs numpy ground truth."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core import (INT_SENTINEL, sorted_intersect, sorted_intersect_padded,
                        sorted_union, sorted_union_padded)

sets = st.lists(st.integers(min_value=0, max_value=50), min_size=0,
                max_size=20).map(lambda xs: np.unique(xs).astype(np.int32))


def _pad(a, cap):
    out = np.full(cap, INT_SENTINEL, np.int32)
    out[:len(a)] = a
    return jnp.asarray(out)


@given(sets, sets)
def test_union_padded(i, j):
    cap_i, cap_j = 24, 24
    k, nk, imap, jmap = sorted_union_padded(_pad(i, cap_i), _pad(j, cap_j))
    k, nk = np.asarray(k), int(nk)
    want = np.union1d(i, j)
    assert nk == len(want)
    np.testing.assert_array_equal(k[:nk], want)
    # index maps: k[imap] == i elementwise
    imap = np.asarray(imap)[:len(i)]
    jmap = np.asarray(jmap)[:len(j)]
    np.testing.assert_array_equal(k[imap], i)
    np.testing.assert_array_equal(k[jmap], j)


@given(sets, sets)
def test_intersect_padded(i, j):
    k, nk, imap, jmap = sorted_intersect_padded(_pad(i, 24), _pad(j, 24))
    k, nk = np.asarray(k), int(nk)
    want = np.intersect1d(i, j)
    assert nk == len(want)
    np.testing.assert_array_equal(k[:nk], want)
    imap, jmap = np.asarray(imap)[:nk], np.asarray(jmap)[:nk]
    if nk:
        np.testing.assert_array_equal(i[imap], want)
        np.testing.assert_array_equal(j[jmap], want)


@given(sets, sets)
def test_host_union_intersect(i, j):
    k, imap, jmap = sorted_union(i, j)
    np.testing.assert_array_equal(k, np.union1d(i, j))
    np.testing.assert_array_equal(k[imap], i)
    np.testing.assert_array_equal(k[jmap], j)
    ki, imap2, jmap2 = sorted_intersect(i, j)
    np.testing.assert_array_equal(ki, np.intersect1d(i, j))
    if len(ki):
        np.testing.assert_array_equal(i[imap2], ki)
        np.testing.assert_array_equal(j[jmap2], ki)


def test_host_union_intersect_deterministic():
    """Plain (non-hypothesis) coverage of the host merge primitives."""
    rng = np.random.default_rng(5)
    for kind in ("int", "str"):
        for _ in range(10):
            i = np.unique(rng.integers(0, 40, rng.integers(0, 15)))
            j = np.unique(rng.integers(0, 40, rng.integers(0, 15)))
            if kind == "str":  # re-sort: "26" < "7" lexicographically
                i, j = np.sort(i.astype(str)), np.sort(j.astype(str))
            k, imap, jmap = sorted_union(i, j)
            np.testing.assert_array_equal(k, np.union1d(i, j))
            np.testing.assert_array_equal(k[imap], i)
            np.testing.assert_array_equal(k[jmap], j)
            ki, im2, jm2 = sorted_intersect(i, j)
            np.testing.assert_array_equal(ki, np.intersect1d(i, j))
            if len(ki):
                np.testing.assert_array_equal(i[im2], ki)
                np.testing.assert_array_equal(j[jm2], ki)


def test_host_union_mixed_string_widths():
    i = np.array(["ab", "zz"])
    j = np.array(["abcd"])
    k, imap, jmap = sorted_union(i, j)
    assert k.tolist() == ["ab", "abcd", "zz"]  # widths promote, no truncation
    np.testing.assert_array_equal(k[imap], i)
    np.testing.assert_array_equal(k[jmap], j)

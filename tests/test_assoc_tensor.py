"""Device AssocTensor vs the host Assoc (paper semantics on padded COO)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import Assoc, AssocTensor, MAX_PLUS, PLUS_TIMES

keys = st.text(alphabet="abcd", min_size=1, max_size=2)
vals = st.floats(min_value=0.5, max_value=50, allow_nan=False,
                 allow_subnormal=False, width=32)
triples = st.lists(st.tuples(keys, keys, vals), min_size=1, max_size=16)


def make_pair(ts, aggregate="min"):
    r, c, v = zip(*ts)
    host = Assoc(list(r), list(c), np.asarray(v), aggregate=aggregate)
    dev = AssocTensor.from_triples(np.asarray(r), np.asarray(c),
                                   np.asarray(v), aggregate=aggregate,
                                   capacity=64)
    return host, dev


@given(triples)
def test_roundtrip(ts):
    host, dev = make_pair(ts)
    assert dev.to_assoc().to_dict() == pytest.approx(host.to_dict())


@given(triples)
def test_constructor_sum(ts):
    host, dev = make_pair(ts, aggregate="sum")
    assert dev.to_assoc().to_dict() == pytest.approx(host.to_dict())


@given(triples, triples)
def test_add_matches_host(ts1, ts2):
    h1, d1 = make_pair(ts1)
    h2, d2 = make_pair(ts2)
    got = d1.add(d2).to_assoc().to_dict()
    assert got == pytest.approx((h1 + h2).to_dict())


@given(triples, triples)
def test_mul_matches_host(ts1, ts2):
    h1, d1 = make_pair(ts1)
    h2, d2 = make_pair(ts2)
    got = d1.mul(d2).to_assoc().to_dict()
    assert got == pytest.approx((h1 * h2).to_dict())


@given(triples, triples)
def test_matmul_matches_host(ts1, ts2):
    h1, d1 = make_pair(ts1)
    h2, d2 = make_pair(ts2)
    got = d1.matmul(d2, use_kernel=False).to_assoc().to_dict()
    assert got == pytest.approx((h1 @ h2).to_dict(), rel=1e-4, abs=1e-5)


def test_max_plus_add():
    d1 = AssocTensor.from_triples(["a"], ["x"], [3.0], capacity=8)
    d2 = AssocTensor.from_triples(["a"], ["x"], [5.0], capacity=8)
    out = d1.add(d2, semiring=MAX_PLUS).to_assoc()
    assert out.get("a", "x") == 5.0  # ⊕ = max


def test_string_values_pointer_scheme():
    dev = AssocTensor.from_triples(
        ["r1", "r2"], ["c", "c"], np.asarray(["beta", "alpha"]), capacity=8)
    assert not dev.numeric
    back = dev.to_assoc()
    assert back.get("r1", "c") == "beta" and back.get("r2", "c") == "alpha"
    # min-aggregation on ranks == dictionary min
    dup = AssocTensor.from_triples(
        ["r", "r"], ["c", "c"], np.asarray(["zeta", "alpha"]),
        aggregate="min", capacity=8)
    assert dup.to_assoc().get("r", "c") == "alpha"


def test_extract_rank_range():
    dev = AssocTensor.from_triples(["a", "b", "c"], ["x", "x", "x"],
                                   [1.0, 2.0, 3.0], capacity=8)
    sub = dev[("a", "b"), ":"]   # right-inclusive D4M range
    assert sub.to_assoc().to_dict() == {("a", "x"): 1.0, ("b", "x"): 2.0}


def test_reduce_rows():
    dev = AssocTensor.from_triples(["a", "a", "b"], ["x", "y", "x"],
                                   [1.0, 2.0, 4.0], aggregate="sum",
                                   capacity=8)
    vec = np.asarray(dev.reduce_rows())
    assert vec[0] == 3.0 and vec[1] == 4.0  # rows sorted: a, b


def test_matmul_with_kernel_interpret():
    d1 = AssocTensor.from_triples(["r", "r"], ["k1", "k2"], [2.0, 3.0],
                                  capacity=8)
    d2 = AssocTensor.from_triples(["k1", "k2"], ["c", "c"], [5.0, 7.0],
                                  capacity=8)
    # route through the Pallas semiring matmul in interpret mode
    from repro.kernels.semiring_matmul import ops as K
    import repro.core.assoc_tensor as AT

    out_ref = d1.matmul(d2, use_kernel=False).to_assoc().to_dict()
    assert out_ref == {("r", "c"): 31.0}

"""Static contract verification (the d4mcheck tentpole).

Two halves: (1) the registry sweep — every ``@contract``-decorated entry
point lowers its compiled program(s) on an AbstractMesh and the HLO
walker proves the declared invariants hold; (2) the checker has teeth —
deliberately broken programs (an injected psum, a densifying scatter, a
host callback, a while-of-psums) are each caught with the right
violation kind.  Everything here is static: nothing executes on devices.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.analysis import (CONTRACT_REGISTRY, Contract, analyze_program,
                            lower_hlo, verify_all, verify_entry)
from repro.analysis import contracts as contracts_mod
from repro.analysis import probes as probes_mod
from repro.analysis.contracts import RetraceAudit, Violation
from repro.analysis.hlo_contracts import parse_hlo


def _mesh():
    return AbstractMesh((("data", 8),))


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# the sweep: every declared contract verifies against its compiled HLO
# ---------------------------------------------------------------------------

EXPECTED_ENTRIES = {
    "AssocTensor.__getitem__", "AssocTensor.__setitem__",
    "spgemm.matmul", "spgemm.matmul_reduce",
    "DistAssoc.__getitem__", "DistAssoc.__setitem__",
    "DistAssoc.add", "DistAssoc.mul", "DistAssoc.matmul",
    "DistAssoc.matmul_reduce", "DistAssoc.sqin", "DistAssoc.sqout",
    "DistAssoc.col_reduce", "DistAssoc.row_reduce", "DistAssoc.col_degree",
    "DistAssoc.matmul_dense_vec",
}


def test_registry_covers_the_public_surface():
    contracts_mod._ensure_registry()
    assert EXPECTED_ENTRIES <= set(CONTRACT_REGISTRY), \
        EXPECTED_ENTRIES - set(CONTRACT_REGISTRY)


def test_sweep_all_contracts_hold():
    results = verify_all()
    bad = {k: [str(v) for v in vs] for k, vs in results.items() if vs}
    assert not bad, bad
    # the sweep actually checked the full registry, not a subset
    assert set(results) == set(CONTRACT_REGISTRY)


def test_shard_local_entries_declare_zero_collectives():
    contracts_mod._ensure_registry()
    for name in ("DistAssoc.__getitem__", "DistAssoc.__setitem__",
                 "DistAssoc.matmul", "AssocTensor.__getitem__"):
        assert CONTRACT_REGISTRY[name].collectives == 0, name
    # the fused reduce epilogues spend exactly ONE psum-family collective
    for name in ("DistAssoc.matmul_reduce", "DistAssoc.sqin",
                 "DistAssoc.sqout", "DistAssoc.col_reduce"):
        assert CONTRACT_REGISTRY[name].collectives == 1, name


# ---------------------------------------------------------------------------
# teeth: broken programs are caught with the right violation kind
# ---------------------------------------------------------------------------

def _kinds(violations):
    return sorted({v.kind for v in violations})


def test_injected_psum_is_caught():
    f = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=_mesh(),
                  in_specs=P("data"), out_specs=P(), check_rep=False)
    rep = analyze_program(lower_hlo(f, _sds((8, 16))))
    assert rep.collectives_total == 1
    viol = Contract(name="canary", collectives=0).check(rep)
    assert _kinds(viol) == ["collectives"]
    # the honest declaration passes
    assert Contract(name="ok", collectives=1).check(rep) == []


def test_while_of_psums_counts_trip_weighted():
    def body(x):
        def step(c, _):
            return c + jax.lax.psum(c, "data"), None
        out, _ = jax.lax.scan(step, x, None, length=5)
        return out
    f = shard_map(body, mesh=_mesh(), in_specs=P("data"), out_specs=P("data"),
                  check_rep=False)
    rep = analyze_program(lower_hlo(f, _sds((8, 16))))
    # a while of N psums is N collectives, not 1 — the walker multiplies
    # by the loop trip count
    assert rep.collective_counts.get("all-reduce") == pytest.approx(5.0)
    viol = Contract(name="canary", collectives=1).check(rep)
    assert _kinds(viol) == ["collectives"]


def test_densifying_scatter_is_caught():
    def densify(rows, cols, vals):
        return jnp.zeros((4096, 4096), jnp.float32).at[rows, cols].set(vals)
    rep = analyze_program(lower_hlo(
        densify, _sds((64,), jnp.int32), _sds((64,), jnp.int32),
        _sds((64,), jnp.float32)))
    assert rep.max_intermediate_elems >= 4096 * 4096
    viol = Contract(name="canary", collectives=None).check(rep)
    assert _kinds(viol) == ["densify"]
    # densify=True waives the budget
    assert Contract(name="ok", collectives=None, densify=True).check(rep) == []


def test_host_callback_is_caught():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a), _sds((16,), jnp.float32), x)
        return y * 2
    rep = analyze_program(lower_hlo(f, _sds((16,), jnp.float32)))
    assert rep.host_transfers >= 1
    viol = Contract(name="canary", collectives=None,
                    host_transfers=0).check(rep)
    assert _kinds(viol) == ["host_transfers"]


def test_partitioner_custom_calls_are_not_host_transfers():
    # Sharding/SPMDFullToShardShape markers in shard_map lowerings must
    # not count as host round-trips
    f = shard_map(lambda x: x * 2, mesh=_mesh(), in_specs=P("data"),
                  out_specs=P("data"), check_rep=False)
    rep = analyze_program(lower_hlo(f, _sds((8, 16))))
    assert rep.host_transfers == 0
    assert rep.collectives_total == 0


# ---------------------------------------------------------------------------
# verifier plumbing: probes, retrace audits, both HLO header dialects
# ---------------------------------------------------------------------------

def test_declared_but_unprobed_contract_is_a_violation(monkeypatch):
    monkeypatch.setitem(CONTRACT_REGISTRY, "synthetic.unprobed",
                        Contract(name="synthetic.unprobed", collectives=0))
    viol = verify_entry("synthetic.unprobed")
    assert _kinds(viol) == ["probe"]


def test_retrace_audit_flags_cache_growth(monkeypatch):
    monkeypatch.setitem(
        CONTRACT_REGISTRY, "synthetic.retrace",
        Contract(name="synthetic.retrace", collectives=None,
                 host_transfers=None))
    state = {"size": 0}

    def growing_probe():
        yield RetraceAudit(
            label="grows",
            first=lambda: state.__setitem__("size", 1),
            again=lambda: state.__setitem__("size", 2),
            size=lambda: state["size"])

    monkeypatch.setitem(probes_mod.PROBES, "synthetic.retrace",
                        growing_probe)
    viol = verify_entry("synthetic.retrace")
    assert _kinds(viol) == ["recompile"]

    def stable_probe():
        yield RetraceAudit(
            label="stable",
            first=lambda: state.__setitem__("size", 1),
            again=lambda: None,
            size=lambda: state["size"])

    monkeypatch.setitem(probes_mod.PROBES, "synthetic.retrace",
                        stable_probe)
    assert verify_entry("synthetic.retrace") == []


def test_parser_reads_both_header_dialects():
    # post-optimization headers carry a signature; pre-optimization
    # (`.lower().as_text()`) headers are bare — both must parse
    post = """
HloModule m

%helper (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  ROOT %r = f32[8] add(f32[8] %x, f32[8] %x)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %c = f32[8] call(f32[8] %p), to_apply=%helper
}
"""
    comps = parse_hlo(post)
    assert "__entry__" in comps and "helper" in comps

    pre = """
HloModule m

helper {
  x = f32[8] parameter(0)
  ROOT r = f32[8] add(x, x)
}

ENTRY main {
  p = f32[8] parameter(0)
  ROOT c = f32[8] call(p), to_apply=helper
}
"""
    comps = parse_hlo(pre)
    assert "__entry__" in comps and "helper" in comps
    rep = analyze_program(pre)
    assert rep.collectives_total == 0


def test_violation_str_is_actionable():
    v = Violation(entry="X.y[range]", kind="collectives", message="boom")
    assert "X.y[range]" in str(v) and "collectives" in str(v)

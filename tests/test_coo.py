"""The canonical COO/semiring core: host backend, and host ⇄ device parity.

Three contracts pinned here:

1. ``canonicalize_np`` (lexsort + duplicate-run ⊕-merge + compaction) matches
   a dict-of-dicts oracle for numeric and string values and every aggregator.
2. Round trip: ``AssocTensor.from_assoc(A).to_assoc() == A`` for numeric and
   string arrays (the host ⇄ device pipeline is lossless).
3. Host ``Assoc.add/mul/matmul`` agree with device ``AssocTensor`` on ALL
   registry semirings — one algebra, two backends.
"""
import numpy as np
import pytest

from repro.core import (REGISTRY, Assoc, AssocTensor, canonicalize_np,
                        intersect_pairs_np, spgemm_np)

# ---------------------------------------------------------------------------
# 1. canonicalize_np vs oracle
# ---------------------------------------------------------------------------


def _oracle(rows, cols, vals, combine):
    d = {}
    for r, c, v in zip(rows, cols, vals):
        d[(r, c)] = combine(d[(r, c)], v) if (r, c) in d else v
    return d


def _as_dict(r, c, v):
    return dict(zip(zip(r.tolist(), c.tolist()), v.tolist()))


RNG = np.random.default_rng(7)


@pytest.mark.parametrize("agg,fn", [
    ("min", min), ("max", max), ("sum", lambda a, b: a + b),
    (min, min), (sum, lambda a, b: a + b),
])
def test_canonicalize_numeric(agg, fn):
    rows = RNG.integers(0, 6, size=200)
    cols = RNG.integers(0, 6, size=200)
    vals = RNG.uniform(1, 9, size=200)
    r, c, v = canonicalize_np(rows, cols, vals, combine=agg)
    assert _as_dict(r, c, v) == pytest.approx(_oracle(rows, cols, vals, fn))
    # canonical: sorted by (row, col), unique pairs
    lin = r.astype(np.int64) * 6 + c
    assert (np.diff(lin) > 0).all()


@pytest.mark.parametrize("agg,fn", [
    ("concat", lambda a, b: a + b),
    ("min", min), ("max", max),
    ("first", lambda a, b: a), ("last", lambda a, b: b),
])
def test_canonicalize_string(agg, fn):
    rows = RNG.integers(0, 4, size=60)
    cols = RNG.integers(0, 4, size=60)
    vals = np.asarray(RNG.choice(list("abcdef"), size=60))
    r, c, v = canonicalize_np(rows, cols, vals, combine=agg)
    assert _as_dict(r, c, v) == _oracle(rows, cols, vals, fn)


def test_canonicalize_python_callable_fallback():
    rows = np.array([0, 0, 0, 1])
    cols = np.array([0, 0, 0, 0])
    vals = np.array([1.0, 2.0, 4.0, 8.0])
    r, c, v = canonicalize_np(rows, cols, vals,
                              combine=lambda a, b: a + 2 * b)
    # left-fold in sorted (stable) order: (1 + 2·2) + 2·4 = 13
    assert _as_dict(r, c, v) == {(0, 0): 13.0, (1, 0): 8.0}


def test_canonicalize_empty():
    r, c, v = canonicalize_np(np.empty(0, np.int64), np.empty(0, np.int64),
                              np.empty(0))
    assert len(r) == len(c) == len(v) == 0


def test_intersect_pairs():
    a = np.array([1, 5, 9, 40], np.int64)
    b = np.array([2, 5, 40], np.int64)
    ia, ib = intersect_pairs_np(a, b)
    np.testing.assert_array_equal(a[ia], [5, 40])
    np.testing.assert_array_equal(b[ib], [5, 40])


def test_spgemm_matches_dense():
    na, nb, nk = 5, 4, 6
    A = np.where(RNG.uniform(size=(na, nk)) < 0.5, RNG.uniform(1, 9, (na, nk)), 0)
    B = np.where(RNG.uniform(size=(nk, nb)) < 0.5, RNG.uniform(1, 9, (nk, nb)), 0)
    ar, ak = np.nonzero(A)
    bk, bc = np.nonzero(B)
    r, c, v = spgemm_np(ar, ak, A[ar, ak], bk, bc, B[bk, bc],
                        np.multiply, np.add)
    got = np.zeros((na, nb))
    got[r, c] = v
    np.testing.assert_allclose(got, A @ B)


# ---------------------------------------------------------------------------
# 2. host ⇄ device round trip
# ---------------------------------------------------------------------------


def test_roundtrip_numeric():
    a = Assoc(["a", "b", "c", "a"], ["x", "y", "x", "y"],
              [1.5, 2.0, -3.25, 4.0])
    assert AssocTensor.from_assoc(a).to_assoc() == a
    assert a.to_tensor().to_assoc() == a


def test_roundtrip_string():
    a = Assoc(["0294.mp3", "1829.mp3", "1829.mp3"],
              ["artist", "artist", "genre"],
              ["Pink Floyd", "Samuel Barber", "classical"])
    assert AssocTensor.from_assoc(a).to_assoc() == a
    assert a.to_tensor().to_assoc() == a


def test_roundtrip_empty():
    a = Assoc()
    assert a.to_tensor().to_assoc() == a


def test_roundtrip_random_numeric():
    rng = np.random.default_rng(3)
    for _ in range(5):
        n = int(rng.integers(1, 40))
        a = Assoc(rng.integers(0, 9, n).astype(str),
                  rng.integers(0, 9, n).astype(str),
                  rng.integers(1, 100, n).astype(np.float64))
        assert a.to_tensor().to_assoc() == a


# ---------------------------------------------------------------------------
# 3. host vs device agreement on every registry semiring
# ---------------------------------------------------------------------------


def _random_pair(seed):
    rng = np.random.default_rng(seed)
    def one():
        n = 20
        return Assoc(rng.integers(0, 6, n).astype(str),
                     rng.integers(0, 6, n).astype(str),
                     rng.integers(1, 9, n).astype(np.float64),
                     aggregate="min")
    return one(), one()


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_add_host_device_agree(name):
    sr = REGISTRY[name]
    a, b = _random_pair(11)
    host = a.add(b, sr).to_dict()
    dev = a.to_tensor(capacity=64).add(b.to_tensor(capacity=64), sr) \
           .to_assoc().to_dict()
    assert dev == pytest.approx(host)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_mul_host_device_agree(name):
    sr = REGISTRY[name]
    a, b = _random_pair(13)
    host = a.mul(b, sr).to_dict()
    dev = a.to_tensor(capacity=64).mul(b.to_tensor(capacity=64), sr) \
           .to_assoc().to_dict()
    assert dev == pytest.approx(host)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_matmul_host_device_agree(name):
    sr = REGISTRY[name]
    a, b = _random_pair(17)
    host = a.matmul(b, sr).to_dict()
    dev = a.to_tensor(capacity=64) \
           .matmul(b.to_tensor(capacity=64), sr, use_kernel=False) \
           .to_assoc().to_dict()
    assert dev == pytest.approx(host, rel=1e-5, abs=1e-5)


def test_semiring_algebra_preserves_stored_zero():
    """Under non-(+,×) semirings an explicit 0.0 is a legitimate stored
    value (e.g. a zero-cost min_plus path) and must survive host algebra."""
    e = Assoc(["a", "b"], ["b", "c"], [1.0, -1.0])
    m = e.matmul(e, "min_plus")          # a→b→c costs 1 + (-1) = 0.0
    assert m.get("a", "c") == 0.0
    # survives a union ⊕-merge with a disjoint operand
    out = m.add(Assoc(["z"], ["z"], [1.0]), "min_plus")
    assert out.get("a", "c") == 0.0 and out.get("z", "z") == 1.0
    # survives combine when the 0.0 entry is outside the fold intersection
    patched = m.combine(Assoc(["q"], ["q"], [7.0]), "min")
    assert patched.get("a", "c") == 0.0 and patched.get("q", "q") == 7.0
    # documented limitation: the device's 0-is-empty storage drops it
    assert m.to_tensor().to_assoc().get("a", "c") is None

import os

import pytest

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# subprocess); fail fast if something leaked the flag into the test env.
assert "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must not inherit the dry-run's 512-device XLA_FLAGS"


@pytest.fixture(autouse=True)
def _reset_telemetry():
    """Every test starts from zeroed UNION/CACHE/DISPATCH/PLAN counters so
    stats assertions never depend on collection order."""
    from repro import core
    core.reset_all_stats()
    yield

# hypothesis is optional: when missing, property tests skip (see
# tests/_hypothesis_compat.py) and the rest of the suite runs normally.
try:
    from hypothesis import HealthCheck, settings
except ImportError:
    pass
else:
    settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
    settings.load_profile("ci")

"""Gradient compression: error feedback keeps long-run bias bounded."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compress_tree, decompress_tree


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(300,)).astype(np.float32))}
    comp, err = compress_tree(g)
    deq = decompress_tree(comp, g)
    scale = np.abs(np.asarray(g["w"])).max()
    assert np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max() \
        <= scale / 127 + 1e-6


def test_error_feedback_unbiased_accumulation():
    """Σ dequantized ≈ Σ true gradients when errors are carried forward."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    err = None
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        comp, err = compress_tree(g, err)
        deq = decompress_tree(comp, g)
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(deq["w"])
    # residual carried in `err` is bounded → sums track each other
    resid = np.abs(np.asarray(err["w"])).max()
    np.testing.assert_allclose(deq_sum, true_sum,
                               atol=resid + 1e-4)

"""D4M-as-a-service demo: resident tables, wire queries, live metrics.

Boots the query server in-process on a loopback port, registers a small
device-layer table set, and runs three queries through the HTTP client —
one of them twice, to show the cross-request plan cache engaging (the
``/stats`` ``plan.plan_hits`` counter is the proof that a repeated wire
query re-uses its optimized plan instead of re-planning).

    PYTHONPATH=src python examples/serve_demo.py

Doubles as the CI client smoke: it exits nonzero if any endpoint
misbehaves or the repeated query fails to hit the plan cache.
"""
from repro.core import Keys, StartsWith
from repro.serve import D4MClient, TableRef, start_server, TableRegistry


def main() -> int:
    # -- 1. resident tables: loaded once, pinned for the server's life ----
    registry = TableRegistry.from_specs([
        {"name": "edges", "generator": "random", "n": 64, "nnz": 512,
         "seed": 0, "layer": "device"},
        {"name": "feat", "generator": "random", "n": 64, "nnz": 512,
         "seed": 1, "layer": "device"},
    ])
    server = start_server(registry, workers=2)
    print(f"serving {registry.names()} on {server.url}")

    try:
        client = D4MClient(server.url)
        assert client.health()["status"] == "ok"
        for t in client.tables():
            print(f"  table {t['name']}: layer={t['layer']} "
                  f"shape={t['shape']} nnz={t['nnz']}")

        # -- 2. three queries over TableRef leaves (no data client-side) --
        A, B = TableRef("edges"), TableRef("feat")

        q1 = A[StartsWith("r0"), :]                     # selection → triples
        out = client.query(q1)["result"]
        print(f"q1 select: {out['nnz']} triples")

        q2 = (A[StartsWith("r0"), :] @ B).sum(axis=1)   # pipeline → vector
        out = client.query(q2)
        print(f"q2 pipeline: vector n={out['result']['n']} "
              f"(exec {out['timing']['exec_s'] * 1e3:.1f} ms)")

        q3 = (A + B)[Keys(["r01", "r02"]), :]           # ⊕ then select
        out = client.query(q3)["result"]
        print(f"q3 ewise+select: {out['nnz']} triples")

        # -- 3. repeat q2: same wire structure ⇒ plan-cache hit -----------
        before = client.stats()["plan"]
        out = client.query(q2)
        after = client.stats()["plan"]
        print(f"q2 repeated: exec {out['timing']['exec_s'] * 1e3:.1f} ms, "
              f"plan_hits {before['plan_hits']} -> {after['plan_hits']}")
        assert after["plan_hits"] > before["plan_hits"], \
            "repeated query did not hit the plan cache"
        assert after["plan_misses"] == before["plan_misses"], \
            "repeated query re-planned"

        st = client.stats()["server"]
        print(f"server: {st['requests']:.0f} requests, "
              f"p50 {st['p50_s'] * 1e3:.1f} ms, "
              f"p99 {st['p99_s'] * 1e3:.1f} ms, "
              f"mean batch {st.get('batch_mean', 1.0):.2f}")
    finally:
        server.close()
    print("serve demo OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

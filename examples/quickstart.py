"""Quickstart: D4M associative arrays — the paper's Fig. 1 example and the
core algebra, host and device.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Assoc, AssocTensor, MAX_PLUS


def main():
    # --- the paper's Fig. 1 array -----------------------------------------
    row = ["0294.mp3"] * 3 + ["1829.mp3"] * 3 + ["7802.mp3"] * 3
    col = ["artist", "duration", "genre"] * 3
    val = ["Pink Floyd", "6:53", "rock", "Samuel Barber", "8:01",
           "classical", "Taylor Swift", "10:12", "pop"]
    A = Assoc(row, col, val)
    print("A (tabular):")
    A.printfull()
    print("\nA.val (sorted unique values, Fig. 2):", A.val.tolist())
    print("A.adj (1-based pointers):\n", A.adj.toarray())

    # --- extraction: right-inclusive string slices ------------------------
    sub = A["0294.mp3,:,1829.mp3,", ":"]
    print("\nA['0294.mp3,:,1829.mp3,', ':'] rows:", sub.row.tolist())

    # --- numeric algebra ---------------------------------------------------
    G = Assoc(["alice", "alice", "bob"], ["bob", "carol", "carol"],
              [1.0, 1.0, 1.0])          # a little social graph
    two_hop = G @ G                      # paths of length 2
    print("\ntwo-hop paths:", two_hop.to_dict())
    mutual = G.sqin()                    # AᵀA: shared in-neighbours
    print("shared in-neighbour counts:", mutual.to_dict())

    # --- device (TPU-native) arrays + semirings ----------------------------
    D = AssocTensor.from_triples(["a", "b", "a"], ["x", "y", "x"],
                                 [5.0, 2.0, 3.0], aggregate="sum",
                                 capacity=8)
    print("\ndevice roundtrip:", D.to_assoc().to_dict())
    E = AssocTensor.from_triples(["a", "c"], ["x", "z"], [7.0, 1.0],
                                 capacity=8)
    print("device ⊕ (max-plus):",
          D.add(E, semiring=MAX_PLUS).to_assoc().to_dict())
    print("device ⊗.⊕ matmul:",
          D.matmul(AssocTensor.from_triples(["x", "y"], ["c1", "c1"],
                                            [2.0, 4.0], capacity=8),
                   use_kernel=False).to_assoc().to_dict())


if __name__ == "__main__":
    main()

"""Dynamic ingest demo: streaming mutation with merge-on-read queries.

Boots the query server with one device-layer **ingest** table, then walks
the LSM lifecycle end to end through the HTTP client:

1. stream triple batches into ``POST /ingest`` (host-side delta buffer —
   no device work, no re-canonicalize on the write path);
2. query DURING ingest — reads see base ⊕ delta through the compiled
   overlay merge (merge-on-read), repeated reads between mutations reuse
   one merged snapshot;
3. wait for the background compactor to fold the delta into a new base
   (``/stats`` shows ``delta_depth`` returning to 0 and ``compactions``
   ticking up), and check reads are unchanged by compaction;
4. verify the final state against a one-shot oracle built from the
   concatenated triples — ingest order must not matter for ⊕ = sum.

    PYTHONPATH=src python examples/ingest_demo.py

Doubles as the CI ingest smoke: exits nonzero if any step misbehaves.
"""
import time

from repro.serve import D4MClient, TableRef, TableRegistry, start_server


def main() -> int:
    registry = TableRegistry.from_specs([
        {"name": "edges", "generator": "random", "n": 64, "nnz": 512,
         "seed": 0, "layer": "device", "ingest": True,
         "compact_threshold": 4096},
    ])
    server = start_server(registry, workers=2)
    print(f"serving {registry.names()} on {server.url}")

    try:
        client = D4MClient(server.url)
        assert client.health()["status"] == "ok"
        total_q = TableRef("edges").sum(axis=None)

        base_total = client.query(total_q)["result"]["val"]
        print(f"resident base: total weight {base_total:.1f}")

        # -- 1+2. stream batches, query between them ----------------------
        n_batches, bsz = 5, 32
        for b in range(n_batches):
            rows = [f"new{b}k{i:02d}" for i in range(bsz)]
            cols = [f"c{i % 4}" for i in range(bsz)]
            out = client.ingest("edges", rows, cols, [1.0] * bsz)["result"]
            live = client.query(total_q)["result"]["val"]
            print(f"batch {b}: accepted={out['accepted']} "
                  f"delta_depth={out['delta_depth']} "
                  f"live total={live:.1f}")
        want = base_total + n_batches * bsz
        assert abs(live - want) < 1e-3, (live, want)

        # -- 3. background compaction folds the delta away ----------------
        deadline = time.time() + 30
        while time.time() < deadline:
            info = client.stats()["ingest"]["edges"]
            if info["delta_depth"] == 0 and info["compactions"] >= 1:
                break
            time.sleep(0.1)
        assert info["delta_depth"] == 0, "compactor never folded the delta"
        print(f"compacted: version={info['version']} "
              f"compactions={info['compactions']} "
              f"merge_hit_rate={info['merge_hit_rate']:.2f}")

        post = client.query(total_q)["result"]["val"]
        assert abs(post - want) < 1e-3, (post, want)
        print(f"post-compaction total {post:.1f} == live total (reads "
              f"unchanged by compaction)")

        # -- 4. oracle: ingest ≡ one-shot construction --------------------
        from repro.core import AssocTensor
        from repro.serve.registry import generate_triples
        r0, c0, v0 = generate_triples({"generator": "random", "n": 64,
                                       "nnz": 512, "seed": 0})
        rows = list(r0) + [f"new{b}k{i:02d}" for b in range(n_batches)
                           for i in range(bsz)]
        cols = list(c0) + [f"c{i % 4}" for b in range(n_batches)
                           for i in range(bsz)]
        vals = list(v0) + [1.0] * (n_batches * bsz)
        oracle = AssocTensor.from_triples(rows, cols, vals,
                                          aggregate="sum")
        ot = float(oracle.to_assoc().sum(axis=None))
        assert abs(ot - post) < 1e-2, (ot, post)
        print(f"oracle total {ot:.1f} matches — streamed ingest ≡ "
              f"one-shot construction")
        print("OK")
        return 0
    finally:
        server.close()


if __name__ == "__main__":
    raise SystemExit(main())

"""Deferred D4M pipelines: one paper-style query, planned then executed.

The paper's exemplar analytics are one-line chains of selection,
element-wise ⊕/⊗ and array multiplication.  This demo builds one such
query as a lazy expression, shows the plan rewrites (selector pushdown,
matmul→reduce fusion, hash-consing) via ``PLAN_STATS``, and runs the same
deferred pipeline on the host, device and sharded layers:

    PYTHONPATH=src python examples/pipeline_demo.py
"""
import jax
import numpy as np

from repro.core import (Assoc, PLAN_STATS, Range, StartsWith,
                        reset_plan_stats)
from repro.core.dist_assoc import DistAssoc


def main():
    # an edge table: rows are documents, cols are terms (the paper's
    # term-document exemplar)
    rng = np.random.default_rng(0)
    docs = [f"doc-{i:03d}" for i in rng.integers(0, 40, 200)]
    terms = [f"term-{i:02d}" for i in rng.integers(0, 30, 200)]
    E = Assoc(docs, terms, np.ones(200), aggregate="sum")

    # ---- the deferred query ------------------------------------------------
    # "how strongly does each early document correlate with the doc-0x
    # block, restricted to the first half of the term dictionary?"
    sel_docs = StartsWith("doc-0")
    sel_terms = Range(None, "term-14")

    q = (E.lazy()[sel_docs, sel_terms]
         @ E.lazy()[:, sel_terms].T).sum(axis=1)
    print("expression graph:\n ", q, "\n")

    reset_plan_stats()
    deg = q.collect()
    print("PLAN_STATS after collect:", PLAN_STATS)
    print("  -> select+matmul fused (no slice arrays), reduce folded into")
    print("     the spgemm epilogue (the product C never materialized)\n")

    top = np.argsort(np.asarray(deg))[::-1][:5]
    print("top correlated docs:")
    for i in top:
        if deg[i] > 0:
            print(f"  {E.row[i]}: {deg[i]:g}")

    # ---- same pipeline, device layer --------------------------------------
    T = E.to_tensor()
    dv = (T.lazy()[sel_docs, sel_terms]
          @ T.lazy()[:, sel_terms].T).sum(axis=1).collect()
    print("\ndevice collect matches host:",
          bool(np.allclose(np.asarray(dv)[: len(E.row)],
                           np.asarray(deg), atol=1e-3)))

    # ---- same pipeline, sharded layer (zero collectives in the matmul) ----
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    r, c, v = E.triples()
    D = DistAssoc.from_triples(r, c, v, mesh, aggregate="sum")
    dd = (D.lazy()[sel_docs, sel_terms]
          @ T.lazy()[:, sel_terms].T).sum(axis=1).collect()
    print("dist collect matches host:  ",
          bool(np.allclose(np.asarray(dd), np.asarray(deg), atol=1e-3)))

    # ---- hash-consing: repeated subtrees run once -------------------------
    reset_plan_stats()
    sq = E.lazy() @ E.lazy().T
    (sq * sq).collect()
    print("\nrepeated-subtree demo: AAᵀ evaluated once,",
          f"PLAN_STATS hits={PLAN_STATS['hits']}")


if __name__ == "__main__":
    main()

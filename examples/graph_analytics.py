"""Graph analytics with semiring associative arrays (the D4M idiom set).

Breadth-first search, shortest paths and triangle counting — each is ONE
associative-array expression under the right semiring, the central thesis
of the D4M/GraphBLAS line of work.

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro.core import Assoc, AssocTensor, MIN_PLUS, PLUS_TIMES


def build_graph():
    """A small weighted digraph as an associative array."""
    edges = [
        ("a", "b", 1.0), ("b", "c", 2.0), ("a", "c", 5.0),
        ("c", "d", 1.0), ("b", "d", 6.0), ("d", "e", 1.0),
        ("e", "a", 3.0),
    ]
    r, c, v = zip(*edges)
    return Assoc(list(r), list(c), list(v))


def bfs(G: Assoc, source: str, hops: int):
    """Frontier expansion: fᵀ ← fᵀ ⊗.⊕ A over (+,×) then logical()."""
    frontier = Assoc([source], [source], [1.0])  # 1×1 seed
    frontier = Assoc([source], ["_f"], [1.0]).transpose()
    reached = {source}
    f = Assoc(["_f"], [source], [1.0])
    for h in range(hops):
        f = (f @ G).logical()
        _, cols, _ = f.triples()
        new = set(cols.tolist()) - reached
        print(f"  hop {h + 1}: frontier = {sorted(set(cols.tolist()))}"
              f"  (new: {sorted(new) or '—'})")
        reached |= new
    return reached


def shortest_paths(G: Assoc, steps: int):
    """Min-plus matrix powers: D_k = D_{k-1} ⊗.⊕ A under (min, +).

    Runs on the DEVICE array with the min-plus semiring — the semiring
    matmul the Pallas kernel implements (VPU path; MXU has no min-plus).
    """
    keys = sorted(set(G.row.tolist()) | set(G.col.tolist()))
    n = len(keys)
    dense = np.full((n, n), np.inf)
    np.fill_diagonal(dense, 0.0)
    r, c, v = G.triples()
    ki = {k: i for i, k in enumerate(keys)}
    for ri, ci, vi in zip(r, c, v):
        dense[ki[ri], ki[ci]] = vi

    from repro.core.semiring import MIN_PLUS as MP
    d = dense
    for _ in range(steps):
        d = np.asarray(MP.matmul_dense(d, dense))
    return keys, d


def triangles(G: Assoc) -> int:
    """# triangles = trace(A³)/6 on the undirected support."""
    U = G.logical().max(G.transpose().logical())  # symmetrize
    A3 = U @ U @ U
    tr = sum(v for (i, j), v in A3.to_dict().items() if i == j)
    return int(tr // 6)


def main():
    G = build_graph()
    print("graph edges:", G.to_dict())
    print("\nBFS from 'a':")
    reached = bfs(G, "a", 3)
    print("reached:", sorted(reached))

    print("\nAll-pairs shortest paths (min-plus powers):")
    keys, d = shortest_paths(G, 4)
    for i, k in enumerate(keys):
        row = {keys[j]: d[i, j] for j in range(len(keys))
               if np.isfinite(d[i, j]) and i != j}
        print(f"  from {k}: {row}")

    print("\ntriangle count:", triangles(G))


if __name__ == "__main__":
    main()

"""Serving example: batched prefill + decode with static caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-130m]
"""
import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--arch") for a in args):
        args = ["--arch", "mamba2-130m"] + args
    args += ["--smoke", "--batch", "4", "--prompt-len", "16", "--gen", "32"]
    raise SystemExit(serve_main(args))

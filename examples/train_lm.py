"""End-to-end driver: train a (reduced) LM for a few hundred steps with the
full production stack — D4M data pipeline, AdamW(+WSD for MiniCPM), async
checkpointing, fault-tolerant loop, D4M telemetry.

    PYTHONPATH=src python examples/train_lm.py [--arch minicpm-2b] [--steps 300]

This is a thin veneer over ``repro.launch.train`` (the real driver);
kept as an example entry point per the deliverables.
"""
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--arch") for a in args):
        args = ["--arch", "qwen3-1.7b"] + args
    if "--steps" not in " ".join(args):
        args += ["--steps", "300"]
    args += ["--smoke", "--seq-len", "128", "--batch", "4",
             "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "50"]
    raise SystemExit(train_main(args))

"""Querying associative arrays: one D4M selector algebra, three layers.

The same query — D4M string syntax or first-class ``Selector`` objects —
runs unchanged on the host ``Assoc``, the device ``AssocTensor`` and the
mesh-sharded ``DistAssoc``, and returns the same entries:

    PYTHONPATH=src python examples/query_demo.py
"""
import jax
import numpy as np

from repro.core import (Assoc, Keys, Mask, Match, Range, StartsWith, Where,
                        select)
from repro.core.dist_assoc import DistAssoc


def main():
    # a little log table: rows are log ids, columns are fields
    rows = [f"log-{i:02d}" for i in range(8)] + ["summary"]
    kinds = ["auth", "auth", "net", "net", "auth", "disk", "net", "auth", "-"]
    A = Assoc(rows * 2, ["kind"] * 9 + ["severity"] * 9,
              kinds + [float(i % 4) for i in range(8)] + [0.0])

    print("The table:")
    A.printfull()

    # --- D4M string syntax ------------------------------------------------
    print("\nA['log-02,:,log-05,', :]  (right-inclusive range):")
    A["log-02,:,log-05,", :].printfull()

    print("\nA['log-00,log-07,', :]  (explicit key list):")
    A["log-00,log-07,", :].printfull()

    # --- Selector objects — same compilation path -------------------------
    print("\nA[StartsWith('log-'), :]:")
    A[StartsWith("log-"), :].printfull()

    print("\nA[Match(r'0[13]$'), :]  (regex over row keys):")
    A[Match(r"0[13]$"), :].printfull()

    print("\nA[Where(len-9) & ~Keys(['summary']), :]  (composition):")
    A[Where(lambda k: len(k) > 5) & ~Keys(["summary"]), :].printfull()

    bits = np.zeros(len(A.row), bool)
    bits[::3] = True
    print("\nA[Mask(every 3rd row), :]:")
    A[Mask(bits), :].printfull()

    # --- the same queries on device and on a mesh --------------------------
    dev = A.to_tensor()
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    dist = DistAssoc.from_assoc(A, mesh)

    q = Range("log-02", "log-05")
    host_d = A[q, :].to_dict()
    dev_d = dev[q, :].to_assoc().to_dict()
    dist_d = dist[q, :].to_assoc().to_dict()
    print("\nhost == device == dist for Range('log-02','log-05'):",
          set(host_d) == set(dev_d) == set(dist_d))

    # repeated queries on the same keyspace hit the compilation cache
    select.reset_cache_stats()
    for _ in range(5):
        A[q, :]
    print("compile cache after 5 repeats:", dict(select.CACHE_STATS))


if __name__ == "__main__":
    main()

"""Jitted wrapper matching the model-side calling convention.

``repro.models.attention.chunked_attention`` calls this when
``cfg.attn_impl == "pallas"`` with [B, S, H, D]-layout tensors and
position arrays; we transpose to the kernel layout, dispatch, and
transpose back.  Decode (1-token query over a ring cache) stays on the
reference path — the kernel targets the S² train/prefill hot spot.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "sm_scale", "impl",
                                   "q_off"))
def flash_attention(q, k, v, *, q_positions=None, k_positions=None,
                    causal=True, window=None, k_valid_len=None,
                    sm_scale=None, impl: str = "auto", q_off: int = 0):
    """Model-layout entry: q [B,Sq,H,D], k/v [B,Sk,KV,D] → [B,Sq,H,D].

    Train/prefill assume contiguous positions starting at ``q_off``
    (``q_positions``/``k_positions`` arrays are accepted for signature
    parity with the reference path).  Decode over ring caches
    (``k_valid_len``) routes to the reference implementation.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "ref" or k_valid_len is not None:
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window,
                                  sm_scale=sm_scale, q_off=q_off)
    else:
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window, sm_scale=sm_scale,
            q_off=q_off, interpret=(impl == "interpret"))
    return out.transpose(0, 2, 1, 3)

"""Pallas TPU kernel: flash attention (online softmax) with GQA broadcast.

Softmax attention is itself a semiring-flavoured contraction: the online-
softmax recurrence maintains a running ``(max, Σexp)`` pair — a rescaled
``(max,+)`` fold over key blocks — which is why this kernel shares its tile
plumbing with ``semiring_matmul`` (K-sequential grid + VMEM accumulators).
It exists because the reference path materializes the S×S score matrix in
HBM, which the roofline analysis shows dominates the memory term for every
train/prefill cell; the kernel keeps scores in VMEM so HBM traffic drops to
Q/K/V/O only.

Layout: q [B, H, Sq, D], k/v [B, KV, Sk, D] (GQA: the index_map points each
q-head block at its kv group, never materializing repeated K/V).  Grid is
(B, H, Sq/bq, Sk/bk) with the key dimension innermost; scratch carries
(acc, running max m, running sum l).  Causal/window masks come from global
position offsets, so the same kernel serves train (q_off=0) and chunked
prefill.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window, bq: int, bk: int,
            nk: int, q_off: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, ...]                  # [bq, d]
    k = k_ref[0, 0, ...]                  # [bk, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]

    qpos = q_off + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                  # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)       # rescale factor for old state
    p = jnp.exp(s - m_cur[:, None])       # [bq, bk]
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    m_ref[:, 0] = m_cur
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0, ...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           sm_scale=None, q_off: int = 0,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False):
    """q [B,H,Sq,D], k/v [B,KV,Sk,D] → o [B,H,Sq,D] (same dtype as q)."""
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nk = sk // bk
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, q_off=q_off),
        grid=(b, h, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for flash attention: naive masked softmax attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, sm_scale=None,
                        q_off: int = 0):
    """q [B,H,Sq,D], k/v [B,KV,Sk,D] → [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qpos = q_off + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)

"""Jitted wrappers: union/intersection index maps from rank counts.

These back the device AssocTensor's keyspace alignment (the paper's §II.C
index maps).  ``merge_index_maps`` reproduces exactly the contract of
``repro.core.sorted_ops.sorted_union_padded`` but with the Pallas
rank-count kernel as the inner primitive.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sorted_ops import INT_SENTINEL
from .ref import rank_count_ref
from .sorted_merge import rank_count_pallas


@partial(jax.jit, static_argnames=("impl",))
def rank_count(i, j, *, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return rank_count_ref(i, j)
    pad_i = (-i.shape[0]) % 512 if i.shape[0] > 512 else (-i.shape[0]) % 8
    pad_j = (-j.shape[0]) % 512 if j.shape[0] > 512 else (-j.shape[0]) % 8
    ip = jnp.pad(i, (0, pad_i), constant_values=INT_SENTINEL)
    jp = jnp.pad(j, (0, pad_j), constant_values=INT_SENTINEL)
    bi = min(512, ip.shape[0])
    bj = min(512, jp.shape[0])
    rank, hit = rank_count_pallas(ip, jp, bi=bi, bj=bj,
                                  interpret=(impl == "interpret"))
    # sentinel tails in J inflate nothing (< any valid key is False), but
    # sentinel I entries count all valid J — callers mask by validity.
    return rank[:i.shape[0]], hit[:i.shape[0]]


@partial(jax.jit, static_argnames=("impl",))
def overlay_scatter(i, j, *, impl: str = "auto"):
    """Union destination slots for an LSM overlay merge (base ⊕ delta).

    ``i``/``j`` are sorted, repetition-free, sentinel-padded int32 key
    arrays (base and delta linearized (row, col) keys).  Returns
    ``(i_dst, j_dst, j_dup)``: scatter destinations into a
    ``len(i) + len(j)`` output where a key present in both collapses onto
    one shared slot (``j_dup`` flags those delta entries so the caller can
    ⊕-combine instead of overwrite), and sentinel entries are routed to
    the out-of-bounds slot so ``.at[dst].set(..., mode="drop")`` discards
    them without a mask pass."""
    i_pos, j_pos, j_dup = merge_positions(i, j, impl=impl)
    oob = jnp.int32(i.shape[0] + j.shape[0])
    i_dst = jnp.where(i != INT_SENTINEL, i_pos, oob)
    j_dst = jnp.where(j != INT_SENTINEL, j_pos, oob)
    return i_dst, j_dst, j_dup


@partial(jax.jit, static_argnames=("impl",))
def merge_positions(i, j, *, impl: str = "auto"):
    """UNION positions for two sorted, repetition-free, sentinel-padded
    int32 arrays — duplicates collapse onto one shared slot.

    A duplicate shrinks the union by one, so every element must also
    subtract the number of collapsed pairs BELOW it: that count is the
    exclusive cumsum of its own side's hit flags (both sides are sorted, so
    pairs below i[m] are exactly the matched i's before m)."""
    r_ij, hit_ij = rank_count(i, j, impl=impl)    # J below / matching each I
    r_ji, hit_ji = rank_count(j, i, impl=impl)    # I below / matching each J
    dup_below_i = jnp.cumsum(hit_ij) - hit_ij     # exclusive
    dup_below_j = jnp.cumsum(hit_ji) - hit_ji
    ni, nj = i.shape[0], j.shape[0]
    i_pos = jnp.arange(ni, dtype=jnp.int32) + r_ij - dup_below_i
    j_pos = jnp.arange(nj, dtype=jnp.int32) + r_ji - dup_below_j
    j_dup = hit_ji > 0
    return i_pos, j_pos, j_dup

"""Pallas TPU kernel: sorted-set index maps via tiled rank counting.

The paper's sorted union/intersection builds index maps with a scalar merge
loop — serial, branchy, hostile to vector units.  The TPU-native
reformulation: the merged position of ``i[m]`` is
``m + |{n : j[n] < i[m]}|`` (and the duplicate test is ``∃n : j[n] ==
i[m]``), so the whole merge becomes a *rank count* — for every I element,
count J elements below it.  The kernel tiles both arrays into VMEM blocks
and accumulates counts with O(bi·bj) vector compares on the VPU — compares
are cheap; random gathers are not.  A k-sequential grid accumulates across
J blocks exactly like the matmul kernels accumulate across K.

Output per I element: ``rank`` (# of J strictly below) and ``hit``
(1 if present in J).  Union positions / intersection maps derive from these
in ops.py with pure elementwise math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(i_ref, j_ref, rank_ref, hit_ref, acc_r, acc_h, *, nj: int):
    jb = pl.program_id(1)

    @pl.when(jb == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_h[...] = jnp.zeros_like(acc_h)

    iv = i_ref[...]            # [1, bi]
    jv = j_ref[...]            # [1, bj]
    less = (jv[0, None, :] < iv[0, :, None]).astype(jnp.int32)   # [bi, bj]
    eq = (jv[0, None, :] == iv[0, :, None]).astype(jnp.int32)
    acc_r[...] = acc_r[...] + less.sum(axis=1)[None, :]
    acc_h[...] = acc_h[...] + eq.sum(axis=1)[None, :]

    @pl.when(jb == nj - 1)
    def _flush():
        rank_ref[...] = acc_r[...]
        hit_ref[...] = acc_h[...]


def rank_count_pallas(i: jnp.ndarray, j: jnp.ndarray, *, bi: int = 512,
                      bj: int = 512, interpret: bool = False):
    """For each element of sorted i [Ni], its rank and hit count in j [Nj].

    Inputs are int32, sentinel-padded (sentinel = int32 max sorts last and
    never matches a valid key's `<` count incorrectly for valid elements).
    """
    ni, nj = i.shape[0], j.shape[0]
    bi = min(bi, ni)
    bj = min(bj, nj)
    assert ni % bi == 0 and nj % bj == 0
    rank, hit = pl.pallas_call(
        functools.partial(_kernel, nj=nj // bj),
        grid=(ni // bi, nj // bj),
        in_specs=[
            pl.BlockSpec((1, bi), lambda ib, jb: (0, ib)),
            pl.BlockSpec((1, bj), lambda ib, jb: (0, jb)),
        ],
        out_specs=[
            pl.BlockSpec((1, bi), lambda ib, jb: (0, ib)),
            pl.BlockSpec((1, bi), lambda ib, jb: (0, ib)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, ni), jnp.int32),
            jax.ShapeDtypeStruct((1, ni), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, bi), jnp.int32),
            pltpu.VMEM((1, bi), jnp.int32),
        ],
        interpret=interpret,
    )(i[None], j[None])
    return rank[0], hit[0]

"""Pure-jnp oracle for the rank-count kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rank_count_ref(i: jnp.ndarray, j: jnp.ndarray):
    """rank[m] = #{n : j[n] < i[m]};  hit[m] = #{n : j[n] == i[m]}."""
    rank = jnp.searchsorted(j, i, side="left").astype(jnp.int32)
    right = jnp.searchsorted(j, i, side="right").astype(jnp.int32)
    return rank, right - rank

"""Pallas TPU kernel: segmented reduction over sorted key runs.

The D4M constructor aggregates values whose (row, col) keys collide —
after lexsorting, collisions are contiguous *runs*.  This kernel computes,
for every position, the inclusive ⊕-combine of its run prefix, carrying
(last key, running value) across blocks through VMEM scratch so runs may
span block boundaries.  The run-LAST positions then hold each run's total;
``ops.py`` extracts them.  Within a block the scan is a Hillis-Steele
segmented doubling scan — log2(block) vector steps, no scalar loop.

Supported combines: sum / min / max (the aggregators device AssocTensors
use; string concat stays on host, see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_COMBINE = {
    "sum": (jnp.add, 0.0),
    "min": (jnp.minimum, float("inf")),
    "max": (jnp.maximum, float("-inf")),
}


def _kernel(keys_ref, vals_ref, out_ref, carry_k, carry_v, *,
            combine_name: str, bn: int, nb: int):
    ib = pl.program_id(0)
    comb, ident = _COMBINE[combine_name]

    @pl.when(ib == 0)
    def _init():
        carry_k[...] = jnp.full_like(carry_k, jnp.int32(-2147483648))
        carry_v[...] = jnp.full_like(carry_v, ident)

    keys = keys_ref[...]      # [1, bn] int32
    vals = vals_ref[...]      # [1, bn] f32

    # Hillis-Steele segmented inclusive scan within the block
    acc = vals
    seg = keys
    step = 1
    while step < bn:
        sh_acc = jnp.roll(acc, step, axis=1)
        sh_seg = jnp.roll(seg, step, axis=1)
        pos_ok = jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1) >= step
        same = (sh_seg == seg) & pos_ok
        acc = jnp.where(same, comb(acc, sh_acc), acc)
        step *= 2

    # merge the carry from the previous block into the leading run
    same_as_carry = keys == carry_k[0, 0]
    lead = jnp.cumprod(same_as_carry.astype(jnp.int32), axis=1).astype(bool)
    acc = jnp.where(lead, comb(acc, carry_v[0, 0]), acc)

    out_ref[...] = acc
    carry_k[0, 0] = keys[0, bn - 1]
    carry_v[0, 0] = acc[0, bn - 1]


def segment_scan_pallas(keys: jnp.ndarray, vals: jnp.ndarray, *,
                        combine: str = "sum", bn: int = 1024,
                        interpret: bool = False):
    """Inclusive segmented ⊕-scan of vals over sorted int32 key runs."""
    n = keys.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    out = pl.pallas_call(
        functools.partial(_kernel, combine_name=combine, bn=bn, nb=n // bn),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda ib: (0, ib)),
            pl.BlockSpec((1, bn), lambda ib: (0, ib)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda ib: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.int32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(keys[None], vals[None].astype(jnp.float32))
    return out[0]

"""Jitted wrapper: constructor-style aggregation of sorted COO runs."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ref import segment_scan_ref
from .segment_reduce import segment_scan_pallas


@partial(jax.jit, static_argnames=("combine", "impl"))
def segment_scan(keys, vals, *, combine: str = "sum", impl: str = "auto"):
    """Inclusive segmented ⊕-scan; run-last positions hold run totals."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return segment_scan_ref(keys, vals, combine=combine)
    n = keys.shape[0]
    pad = (-n) % 256
    kp = jnp.pad(keys, (0, pad), constant_values=jnp.int32(2**31 - 1))
    vp = jnp.pad(vals, (0, pad))
    out = segment_scan_pallas(kp, vp, combine=combine, bn=min(1024, kp.shape[0]),
                              interpret=(impl == "interpret"))
    return out[:n]


@partial(jax.jit, static_argnames=("combine", "impl"))
def aggregate_runs(keys, vals, *, combine: str = "sum", impl: str = "auto"):
    """(keys, aggregated value at each run head, head mask)."""
    scanned = segment_scan(keys, vals, combine=combine, impl=impl)
    n = keys.shape[0]
    run_last = jnp.concatenate(
        [keys[1:] != keys[:-1], jnp.array([True])])
    is_head = jnp.concatenate(
        [jnp.array([True]), keys[1:] != keys[:-1]])
    # value for each head = scanned value at its run's last position
    head_pos = jnp.flatnonzero(is_head, size=n, fill_value=n - 1)
    last_pos = jnp.flatnonzero(run_last, size=n, fill_value=n - 1)
    head_vals = jnp.zeros_like(scanned).at[head_pos].set(scanned[last_pos])
    return keys, jnp.where(is_head, head_vals, 0.0), is_head

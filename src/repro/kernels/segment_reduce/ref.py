"""Pure-jnp oracle: segmented inclusive scan over sorted key runs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_COMBINE = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


def segment_scan_ref(keys: jnp.ndarray, vals: jnp.ndarray, *,
                     combine: str = "sum"):
    comb = _COMBINE[combine]
    n = keys.shape[0]
    vals = vals.astype(jnp.float32)

    def assoc(a, b):
        (ka, va), (kb, vb) = a, b
        v = jnp.where(ka == kb, comb(va, vb), vb)
        return kb, v

    _, out = jax.lax.associative_scan(
        lambda x, y: assoc(x, y), (keys, vals))
    return out

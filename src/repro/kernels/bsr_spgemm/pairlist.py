"""Scalar-prefetch pair-list Pallas kernels: packed-tile BSR ⊗.⊕ BSR.

The Graphulo-style planner (``repro.core.spgemm``) reduces ``A ⊗.⊕ B``
to a *pair list*: packed present tiles ``a_tiles [nA, 128, 128]`` /
``b_tiles [nB, 128, 128]`` plus int32 arrays ``(pair_a, pair_b, pair_c)``
saying which A tile contracts with which B tile into which C tile.  The
previous execution gathered ``a_tiles[pair_a[p0:p0+chunk]]`` on host-driven
chunks and ⊕-scattered each einsum result — every pair's tiles were
**copied** into a fresh batched operand before the MXU ever saw them.

Here the pair list itself becomes the schedule: it rides in SMEM as
scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``) and drives a
1-D grid over pairs whose ``index_map``s read ``pair_a[p]``/``pair_b[p]``
directly — each step DMAs exactly the two 128² tiles it contracts, no
materialized gather.  The ⊕-scatter is fused in VMEM: pairs arrive
**grouped by ``pair_c``** (the planner sorts them), so a C tile lives in a
VMEM accumulator across its run of pairs and is flushed to HBM exactly
once — the accumulation trick of ``bsr_spgemm_reduce`` extended to full C.

Contract (asserted by the ``ops.py`` dispatch):

* ``pair_c`` is sorted ascending and covers ``0..n_c-1`` (every C tile
  has ≥1 contributing pair — true by construction in ``plan_matmul``);
  same for ``pair_o`` in the reduce variant.
* all three pair arrays are int32 of one length ``n_pairs ≥ 1``.

The ⊗-product runs on the MXU for ``mxu`` semirings and on VPU 32-wide
k-slabs otherwise, via the shared :func:`_tile_product`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.semiring import Semiring, get_semiring
from .bsr_spgemm import _tile_product


def _group_edges(pc_ref, p, n_pairs):
    """(first, last) flags for the run of equal ``pc`` values around p."""
    prev = pc_ref[jnp.maximum(p - 1, 0)]
    nxt = pc_ref[jnp.minimum(p + 1, n_pairs - 1)]
    first = (p == 0) | (pc_ref[p] != prev)
    last = (p == n_pairs - 1) | (pc_ref[p] != nxt)
    return first, last


def _pairlist_kernel(pa_ref, pb_ref, pc_ref, a_ref, b_ref, o_ref, acc_ref,
                     *, sr: Semiring, n_pairs: int):
    p = pl.program_id(0)
    first, last = _group_edges(pc_ref, p, n_pairs)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    part = _tile_product(a_ref[0], b_ref[0], sr=sr)
    acc_ref[...] = sr.add(acc_ref[...], part)

    @pl.when(last)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def bsr_pairlist_pallas(a_tiles: jnp.ndarray, b_tiles: jnp.ndarray,
                        pair_a: jnp.ndarray, pair_b: jnp.ndarray,
                        pair_c: jnp.ndarray, *, n_c: int,
                        semiring="plus_times",
                        interpret: bool = False) -> jnp.ndarray:
    """Pair-list contraction → packed C tiles ``[n_c, bm, bn]``.

    ``pair_c`` must be sorted ascending (one contiguous VMEM-resident run
    per C tile — the Pallas output-revisiting contract).
    """
    sr = get_semiring(semiring)
    n_pairs = pair_a.shape[0]
    bm, bk = a_tiles.shape[1], a_tiles.shape[2]
    bn = b_tiles.shape[2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda p, pa, pb, pc: (pa[p], 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda p, pa, pb, pc: (pb[p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda p, pa, pb, pc: (pc[p], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_pairlist_kernel, sr=sr, n_pairs=n_pairs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_c, bm, bn), jnp.float32),
        interpret=interpret,
    )(pair_a, pair_b, pair_c, a_tiles, b_tiles)


# ---------------------------------------------------------------------------
# Fused pair-list ⊕-reduce: per-output-block partial vectors, C never exists.
# ---------------------------------------------------------------------------

def _pairlist_reduce_kernel(pa_ref, pb_ref, po_ref, a_ref, b_ref, o_ref,
                            acc_ref, *, sr: Semiring, axis: int,
                            n_pairs: int):
    p = pl.program_id(0)
    first, last = _group_edges(po_ref, p, n_pairs)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    part = _tile_product(a_ref[0], b_ref[0], sr=sr)      # [bm, bn]
    if axis == 1:
        acc = acc_ref[...]                               # [bm, 128]
        for c0 in range(0, part.shape[1], 128):
            acc = sr.add(acc, part[:, c0:c0 + 128])
    else:
        acc = acc_ref[...]                               # [8, bn]
        for r0 in range(0, part.shape[0], 8):
            acc = sr.add(acc, part[r0:r0 + 8, :])
    acc_ref[...] = acc

    @pl.when(last)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def bsr_pairlist_reduce_pallas(a_tiles: jnp.ndarray, b_tiles: jnp.ndarray,
                               pair_a: jnp.ndarray, pair_b: jnp.ndarray,
                               pair_o: jnp.ndarray, *, n_o: int, axis: int,
                               semiring="plus_times",
                               interpret: bool = False) -> jnp.ndarray:
    """Pair-list fused reduce → lane/sublane partials per output block.

    ``pair_o`` groups pairs by output *block-row* (``axis=1``) or
    *block-col* (``axis=0``) and must be sorted ascending.  Returns
    ``[n_o, bm, 128]`` (axis=1) or ``[n_o, 8, bn]`` (axis=0) partials; the
    caller ⊕-folds the residual lanes/sublanes (exactly as
    :func:`bsr_spgemm_reduce_pallas`).
    """
    sr = get_semiring(semiring)
    assert axis in (0, 1), axis
    n_pairs = pair_a.shape[0]
    bm, bk = a_tiles.shape[1], a_tiles.shape[2]
    bn = b_tiles.shape[2]
    acc_shape = (bm, 128) if axis == 1 else (8, bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_pairs,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda p, pa, pb, po: (pa[p], 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda p, pa, pb, po: (pb[p], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,) + acc_shape,
                               lambda p, pa, pb, po: (po[p], 0, 0)),
        scratch_shapes=[pltpu.VMEM(acc_shape, jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_pairlist_reduce_kernel, sr=sr, axis=axis,
                          n_pairs=n_pairs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_o,) + acc_shape, jnp.float32),
        interpret=interpret,
    )(pair_a, pair_b, pair_o, a_tiles, b_tiles)

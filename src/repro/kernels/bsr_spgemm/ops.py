"""Jitted wrappers + block-mask construction from padded COO."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.semiring import get_semiring
from .bsr_spgemm import bsr_spgemm_pallas, bsr_spgemm_reduce_pallas
from .pairlist import bsr_pairlist_pallas, bsr_pairlist_reduce_pallas
from .ref import (bsr_pairlist_ref, bsr_pairlist_reduce_ref, bsr_spgemm_ref,
                  bsr_spgemm_reduce_ref)


def make_block_mask(rows, cols, valid, mb: int, kb: int, *, bm=128, bk=128):
    """Per-tile presence mask from COO coordinates (int32 [MB, KB])."""
    r = jnp.where(valid, rows // bm, mb)
    c = jnp.where(valid, cols // bk, kb)
    mask = jnp.zeros((mb + 1, kb + 1), jnp.int32).at[r, c].add(1, mode="drop")
    return (mask[:mb, :kb] > 0).astype(jnp.int32)


@partial(jax.jit, static_argnames=("semiring", "impl", "bm", "bn", "bk"))
def bsr_spgemm(a, block_mask, b, *, semiring="plus_times", impl="auto",
               bm: int = 128, bn: int = 128, bk: int | None = None):
    sr = get_semiring(semiring)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return bsr_spgemm_ref(a, block_mask, b, semiring=sr, bm=bm, bk=bk)
    return bsr_spgemm_pallas(a, block_mask, b, semiring=sr, bm=bm, bn=bn,
                             bk=bk, interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("axis", "semiring", "impl",
                                   "bm", "bn", "bk"))
def bsr_spgemm_reduce(a, block_mask, b, *, axis: int,
                      semiring="plus_times", impl="auto",
                      bm: int = 128, bn: int = 128, bk: int | None = None):
    """Fused ``⊕-reduce(A ⊗.⊕ B, axis)`` → vector ([M] for axis=1, [N] for 0).

    The product is never materialized: the Pallas kernel folds tile
    products into a VMEM vector-of-partials accumulator and this wrapper
    ⊕-folds the residual 128 lanes / 8 sublanes.  The jnp ref path is the
    unfused oracle (materialize-then-reduce) used on non-TPU backends.
    """
    sr = get_semiring(semiring)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return bsr_spgemm_reduce_ref(a, block_mask, b, axis=axis,
                                     semiring=sr, bm=bm, bk=bk)
    part = bsr_spgemm_reduce_pallas(a, block_mask, b, axis=axis, semiring=sr,
                                    bm=bm, bn=bn, bk=bk,
                                    interpret=(impl == "interpret"))
    return sr.add_reduce(part, axis=axis)


# ---------------------------------------------------------------------------
# Pair-list dispatch: the default BSR-strategy execution (see pairlist.py).
# Pairs MUST arrive grouped (sorted) by pair_c / pair_o — plan_matmul's
# invariant; the kernel's VMEM-resident output accumulation depends on it.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_c", "semiring", "impl"))
def bsr_pairlist(a_tiles, b_tiles, pair_a, pair_b, pair_c, *, n_c: int,
                 semiring="plus_times", impl="auto"):
    """Pair-list BSR contraction → packed C tiles ``[n_c, bm, bn]``."""
    sr = get_semiring(semiring)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return bsr_pairlist_ref(a_tiles, b_tiles, pair_a, pair_b, pair_c,
                                n_c=n_c, semiring=sr)
    return bsr_pairlist_pallas(a_tiles, b_tiles, pair_a, pair_b, pair_c,
                               n_c=n_c, semiring=sr,
                               interpret=(impl == "interpret"))


@partial(jax.jit, static_argnames=("n_o", "axis", "semiring", "impl"))
def bsr_pairlist_reduce(a_tiles, b_tiles, pair_a, pair_b, pair_o, *,
                        n_o: int, axis: int, semiring="plus_times",
                        impl="auto"):
    """Fused pair-list ``⊕-reduce(A ⊗.⊕ B, axis)`` → ``[n_o, 128]``
    per-output-block vectors (block-rows for axis=1, block-cols for 0).

    C tiles never exist: the Pallas kernel folds each tile product into a
    lane/sublane partial accumulator in VMEM, and this wrapper ⊕-folds the
    residual 128 lanes / 8 sublanes.
    """
    sr = get_semiring(semiring)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return bsr_pairlist_reduce_ref(a_tiles, b_tiles, pair_a, pair_b,
                                       pair_o, n_o=n_o, axis=axis,
                                       semiring=sr)
    part = bsr_pairlist_reduce_pallas(a_tiles, b_tiles, pair_a, pair_b,
                                      pair_o, n_o=n_o, axis=axis,
                                      semiring=sr,
                                      interpret=(impl == "interpret"))
    return sr.add_reduce(part, axis=2 if axis == 1 else 1)

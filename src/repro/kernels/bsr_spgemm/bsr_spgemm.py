"""Pallas TPU kernel: block-sparse (BSR) × dense semiring matmul.

The large-scale associative-array product (and MoE-style masked compute)
is block-sparse: most 128×128 tiles of the adjacency are entirely empty.
The kernel carries a per-tile presence mask in SMEM and **skips the MXU
work for empty tiles** (`@pl.when`) — the TPU analogue of CSR's "touch
only stored entries", lifted from element granularity (gather-hostile) to
MXU-tile granularity (systolic-friendly).

A is dense-stored but block-masked ([MB, KB] int32 mask); B is dense.
Skipped tiles still stream through VMEM (BlockSpec prefetch is
unconditional) — the win is MXU time, and HBM→VMEM for A could be further
elided with a scalar-prefetch index map (left as a §Perf note).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.semiring import Semiring, get_semiring


def _kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *, sr: Semiring, nk: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    present = mask_ref[i, k] != 0

    @pl.when(present)
    def _compute():
        a = a_ref[...]
        b = b_ref[...]
        if sr.mxu:
            acc_ref[...] = acc_ref[...] + jnp.dot(
                a, b, preferred_element_type=jnp.float32)
        else:
            # VPU path: sub-slab the 128-wide K tile so the broadcast
            # product stays within VMEM (128×32×128 f32 = 2 MiB per slab)
            acc = acc_ref[...]
            bk_tile = a.shape[1]
            for k0 in range(0, bk_tile, 32):
                prod = sr.mul(a[:, k0:k0 + 32, None], b[None, k0:k0 + 32, :])
                acc = sr.add(acc, sr.add_reduce(prod, axis=1))
            acc_ref[...] = acc

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsr_spgemm_pallas(a: jnp.ndarray, block_mask: jnp.ndarray,
                      b: jnp.ndarray, *, semiring="plus_times",
                      bm: int = 128, bn: int = 128, bk: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """a [M,K] (block-masked), block_mask [M/bm, K/bk] int32, b [K,N]."""
    sr = get_semiring(semiring)
    if bk is None:
        bk = 128  # mask granularity; non-MXU semirings sub-slab internally
    m, kdim = a.shape
    n = b.shape[1]
    assert m % bm == 0 and kdim % bk == 0 and n % bn == 0
    assert block_mask.shape == (m // bm, kdim // bk), block_mask.shape
    nk = kdim // bk

    return pl.pallas_call(
        functools.partial(_kernel, sr=sr, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(block_mask, a, b)

"""Pallas TPU kernels: block-sparse (BSR) × dense semiring matmul + fusions.

The large-scale associative-array product (and MoE-style masked compute)
is block-sparse: most 128×128 tiles of the adjacency are entirely empty.
Both kernels carry a per-tile presence mask in SMEM and **skip the MXU
work for empty tiles** (`@pl.when`) — the TPU analogue of CSR's "touch
only stored entries", lifted from element granularity (gather-hostile) to
MXU-tile granularity (systolic-friendly).

Two entry points:

* :func:`bsr_spgemm_pallas` — materializes ``C = A ⊗.⊕ B``.  A is
  dense-stored but block-masked ([MB, KB] int32 mask); B is dense.
* :func:`bsr_spgemm_reduce_pallas` — the **fused epilogue**: computes the
  row (``axis=1``) or column (``axis=0``) ⊕-reduction of C while holding
  only a vector-of-partials accumulator in VMEM — C itself never exists in
  any memory space.  Because ⊕ is associative and commutative,
  ``⊕_j ⊕_k A[i,k] ⊗ B[k,j]`` folds tile products straight into a
  [bm, 128]-lane (or [8, bn]-sublane) accumulator; the final 128-lane (or
  8-sublane) fold happens in jnp outside the kernel.  This is the Graphulo
  server-side-combine pushdown for ``sqin``/``sqout``/degree queries.

Accumulation is semiring-generic for every registered algebra: ``(+,×)``
contracts on the MXU, everything else on the VPU via 32-wide k-slabs (a
[bm, 32, bn] f32 broadcast is 2 MiB of VMEM).  Skipped tiles still stream
through VMEM (BlockSpec prefetch is unconditional) — the win is MXU/VPU
time, and HBM→VMEM for A could be further elided with a scalar-prefetch
index map (left as a §Perf note).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.semiring import Semiring, get_semiring


def _tile_product(a, b, *, sr: Semiring):
    """One-tile semiring contraction ``[bm, bk] ⊗.⊕ [bk, bn] → [bm, bn]``."""
    if sr.mxu:
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    # VPU path: sub-slab the K tile so the broadcast product stays in VMEM
    part = jnp.full((a.shape[0], b.shape[1]), sr.zero, jnp.float32)
    for k0 in range(0, a.shape[1], 32):
        prod = sr.mul(a[:, k0:k0 + 32, None], b[None, k0:k0 + 32, :])
        part = sr.add(part, sr.add_reduce(prod, axis=1))
    return part


def _kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *, sr: Semiring, nk: int):
    i = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    present = mask_ref[i, k] != 0

    @pl.when(present)
    def _compute():
        part = _tile_product(a_ref[...], b_ref[...], sr=sr)
        acc_ref[...] = sr.add(acc_ref[...], part)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsr_spgemm_pallas(a: jnp.ndarray, block_mask: jnp.ndarray,
                      b: jnp.ndarray, *, semiring="plus_times",
                      bm: int = 128, bn: int = 128, bk: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """a [M,K] (block-masked), block_mask [M/bm, K/bk] int32, b [K,N]."""
    sr = get_semiring(semiring)
    if bk is None:
        bk = 128  # mask granularity; non-MXU semirings sub-slab internally
    m, kdim = a.shape
    n = b.shape[1]
    assert m % bm == 0 and kdim % bk == 0 and n % bn == 0
    assert block_mask.shape == (m // bm, kdim // bk), block_mask.shape
    nk = kdim // bk

    return pl.pallas_call(
        functools.partial(_kernel, sr=sr, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(block_mask, a, b)


# ---------------------------------------------------------------------------
# Fused ⊗.⊕ + ⊕-reduce: the epilogue that never materializes C.
# ---------------------------------------------------------------------------

def _reduce_rows_kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *,
                        sr: Semiring, nj: int, nk: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    present = mask_ref[i, k] != 0

    @pl.when(present)
    def _compute():
        part = _tile_product(a_ref[...], b_ref[...], sr=sr)  # [bm, bn]
        acc = acc_ref[...]                                   # [bm, 128]
        for c0 in range(0, part.shape[1], 128):
            acc = sr.add(acc, part[:, c0:c0 + 128])
        acc_ref[...] = acc

    @pl.when((j == nj - 1) & (k == nk - 1))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _reduce_cols_kernel(mask_ref, a_ref, b_ref, o_ref, acc_ref, *,
                        sr: Semiring, ni: int, nk: int):
    i, k = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    present = mask_ref[i, k] != 0

    @pl.when(present)
    def _compute():
        part = _tile_product(a_ref[...], b_ref[...], sr=sr)  # [bm, bn]
        acc = acc_ref[...]                                   # [8, bn]
        for r0 in range(0, part.shape[0], 8):
            acc = sr.add(acc, part[r0:r0 + 8, :])
        acc_ref[...] = acc

    @pl.when((i == ni - 1) & (k == nk - 1))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsr_spgemm_reduce_pallas(a: jnp.ndarray, block_mask: jnp.ndarray,
                             b: jnp.ndarray, *, axis: int,
                             semiring="plus_times",
                             bm: int = 128, bn: int = 128,
                             bk: int | None = None,
                             interpret: bool = False) -> jnp.ndarray:
    """Fused ``⊕-reduce(A ⊗.⊕ B, axis)`` with C kept only as VMEM partials.

    Returns lane/sublane **partials**: ``[M, 128]`` for ``axis=1`` (caller
    ⊕-folds the 128 lanes) or ``[8, N]`` for ``axis=0`` (caller ⊕-folds the
    8 sublanes) — the tails the VPU cannot reduce across cheaply in-kernel.
    """
    sr = get_semiring(semiring)
    if bk is None:
        bk = 128
    m, kdim = a.shape
    n = b.shape[1]
    assert axis in (0, 1), axis
    assert m % bm == 0 and kdim % bk == 0 and n % bn == 0
    assert block_mask.shape == (m // bm, kdim // bk), block_mask.shape
    ni, nj, nk = m // bm, n // bn, kdim // bk

    if axis == 1:
        return pl.pallas_call(
            functools.partial(_reduce_rows_kernel, sr=sr, nj=nj, nk=nk),
            grid=(ni, nj, nk),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm, 128), lambda i, j, k: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((m, 128), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, 128), jnp.float32)],
            interpret=interpret,
        )(block_mask, a, b)

    return pl.pallas_call(
        functools.partial(_reduce_cols_kernel, sr=sr, ni=ni, nk=nk),
        grid=(nj, ni, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda j, i, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda j, i, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((8, bn), lambda j, i, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((8, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, bn), jnp.float32)],
        interpret=interpret,
    )(block_mask, a, b)

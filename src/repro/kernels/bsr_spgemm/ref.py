"""Pure-jnp oracles: mask-expanded semiring matmul (+ fused reduction),
plus the pair-list oracles — the old chunked-einsum contraction, kept as
the non-TPU backend and the interpret-mode parity reference."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.semiring import get_semiring, scatter_combine

# tile-pairs contracted per traced chunk: the MXU einsum touches
# chunk·(bm·bk + bk·bn + bm·bn) floats, the VPU path adds a [chunk, bm, 32,
# bn] broadcast slab — both bounded to a few tens of MiB
_CHUNK_MXU = 64
_CHUNK_VPU = 8


def bsr_spgemm_ref(a, block_mask, b, *, semiring="plus_times",
                   bm: int = 128, bk: int | None = None):
    sr = get_semiring(semiring)
    if bk is None:
        bk = 128
    m, kdim = a.shape
    mask_full = jnp.repeat(jnp.repeat(block_mask != 0, bm, axis=0), bk, axis=1)
    a_masked = jnp.where(mask_full, a.astype(jnp.float32), sr.zero)
    return sr.matmul_dense(a_masked, b.astype(jnp.float32)).astype(jnp.float32)


def bsr_spgemm_reduce_ref(a, block_mask, b, *, axis: int,
                          semiring="plus_times",
                          bm: int = 128, bk: int | None = None):
    """Unfused oracle: materialize C, then ⊕-reduce it along ``axis``."""
    sr = get_semiring(semiring)
    c = bsr_spgemm_ref(a, block_mask, b, semiring=sr, bm=bm, bk=bk)
    return sr.add_reduce(c, axis=axis)


# ---------------------------------------------------------------------------
# Pair-list oracles: gather + batched einsum per chunk + ⊕-scatter.  This
# is the pre-kernel execution of the BSR strategy verbatim — under jit the
# chunks trace into one fused program, on TPU the scalar-prefetch kernel
# (pairlist.py) replaces it entirely.
# ---------------------------------------------------------------------------

def chunk_products(a_part: jnp.ndarray, b_part: jnp.ndarray,
                   sr) -> jnp.ndarray:
    """Batched tile contraction [c,bm,bk] ⊗.⊕ [c,bk,bn] → [c,bm,bn]."""
    if sr.mxu:
        return jnp.einsum("cik,ckj->cij", a_part, b_part,
                          preferred_element_type=jnp.float32)
    bk = a_part.shape[2]
    out = jnp.full((a_part.shape[0], a_part.shape[1], b_part.shape[2]),
                   sr.zero, jnp.float32)
    for k0 in range(0, bk, 32):  # VPU slab: keep the broadcast in budget
        prod = sr.mul(a_part[:, :, k0:k0 + 32, None],
                      b_part[:, None, k0:k0 + 32, :])
        out = sr.add(out, sr.add_reduce(prod, axis=2))
    return out


def bsr_pairlist_ref(a_tiles, b_tiles, pair_a, pair_b, pair_c, *, n_c: int,
                     semiring="plus_times") -> jnp.ndarray:
    """Pair-list contraction oracle → packed C tiles ``[n_c, bm, bn]``."""
    sr = get_semiring(semiring)
    bm, bn = a_tiles.shape[1], b_tiles.shape[2]
    c_tiles = jnp.full((n_c, bm, bn), sr.zero, jnp.float32)
    chunk = _CHUNK_MXU if sr.mxu else _CHUNK_VPU
    for p0 in range(0, pair_a.shape[0], chunk):
        parts = chunk_products(a_tiles[pair_a[p0:p0 + chunk]],
                               b_tiles[pair_b[p0:p0 + chunk]], sr)
        c_tiles = scatter_combine(c_tiles, pair_c[p0:p0 + chunk], parts, sr)
    return c_tiles


def bsr_pairlist_reduce_ref(a_tiles, b_tiles, pair_a, pair_b, pair_o, *,
                            n_o: int, axis: int,
                            semiring="plus_times") -> jnp.ndarray:
    """Pair-list fused-reduce oracle → per-block vectors ``[n_o, 128]``."""
    sr = get_semiring(semiring)
    width = a_tiles.shape[1] if axis == 1 else b_tiles.shape[2]
    out = jnp.full((n_o, width), sr.zero, jnp.float32)
    chunk = _CHUNK_MXU if sr.mxu else _CHUNK_VPU
    for p0 in range(0, pair_a.shape[0], chunk):
        parts = chunk_products(a_tiles[pair_a[p0:p0 + chunk]],
                               b_tiles[pair_b[p0:p0 + chunk]], sr)
        pvec = sr.add_reduce(parts, axis=2 if axis == 1 else 1)
        # scatter whole per-pair vectors into their output-block rows
        out = scatter_combine(out, pair_o[p0:p0 + chunk], pvec, sr)
    return out

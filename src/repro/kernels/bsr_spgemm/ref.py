"""Pure-jnp oracles: mask-expanded semiring matmul (+ fused reduction)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.semiring import get_semiring


def bsr_spgemm_ref(a, block_mask, b, *, semiring="plus_times",
                   bm: int = 128, bk: int | None = None):
    sr = get_semiring(semiring)
    if bk is None:
        bk = 128
    m, kdim = a.shape
    mask_full = jnp.repeat(jnp.repeat(block_mask != 0, bm, axis=0), bk, axis=1)
    a_masked = jnp.where(mask_full, a.astype(jnp.float32), sr.zero)
    return sr.matmul_dense(a_masked, b.astype(jnp.float32)).astype(jnp.float32)


def bsr_spgemm_reduce_ref(a, block_mask, b, *, axis: int,
                          semiring="plus_times",
                          bm: int = 128, bk: int | None = None):
    """Unfused oracle: materialize C, then ⊕-reduce it along ``axis``."""
    sr = get_semiring(semiring)
    c = bsr_spgemm_ref(a, block_mask, b, semiring=sr, bm=bm, bk=bk)
    return sr.add_reduce(c, axis=axis)

"""Pallas TPU kernel: COO selection masks for rank-range queries.

Device selection must never densify: a D4M range query ``A['a,:,b,', :]``
compiles on host to rank bounds and executes on device as a *mask over the
padded COO triples* — four vector compares per entry, no scatter onto a
dense adjacency.  This kernel tiles the (rows, cols) rank arrays through
VMEM and emits the keep mask; the dynamic bounds ride in SMEM as a
``(1, 4)`` scalar block (``row_lo, row_hi, col_lo, col_hi``).

The same kernel serves every layer: ``AssocTensor.extract_ranges`` calls
it directly, and ``DistAssoc.__getitem__``'s shard-local extraction runs
it per shard (bounds are shard-invariant, compiled once on host).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sorted_ops import INT_SENTINEL


def _kernel(bounds_ref, rows_ref, cols_ref, keep_ref):
    r = rows_ref[...]                       # [1, bn] int32
    c = cols_ref[...]
    row_lo = bounds_ref[0, 0]
    row_hi = bounds_ref[0, 1]
    col_lo = bounds_ref[0, 2]
    col_hi = bounds_ref[0, 3]
    valid = r != jnp.int32(INT_SENTINEL)
    keep = (valid & (r >= row_lo) & (r < row_hi)
            & (c >= col_lo) & (c < col_hi))
    keep_ref[...] = keep.astype(jnp.int32)


def range_mask_pallas(rows: jnp.ndarray, cols: jnp.ndarray,
                      bounds: jnp.ndarray, *, bn: int = 1024,
                      interpret: bool = False) -> jnp.ndarray:
    """keep[t] = rows[t] ∈ [row_lo, row_hi) ∧ cols[t] ∈ [col_lo, col_hi).

    ``rows``/``cols``: int32[N] sentinel-padded rank arrays (N % bn == 0);
    ``bounds``: int32[1, 4] = (row_lo, row_hi, col_lo, col_hi).
    Returns int32[N] (1 = kept).  Sentinel entries are never kept.
    """
    n = rows.shape[0]
    bn = min(bn, n)
    assert n % bn == 0
    keep = pl.pallas_call(
        _kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda b: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bn), lambda b: (0, b)),
            pl.BlockSpec((1, bn), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(bounds, rows[None], cols[None])
    return keep[0]

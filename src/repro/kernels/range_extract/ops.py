"""Jitted wrapper: COO keep-masks for compiled rank-range selections.

``range_mask`` is the device half of the selector algebra's range fast
path (:mod:`repro.core.select`): the host compiles a selector to
``[lo, hi)`` rank bounds, the device masks its padded COO triples — the
selection never densifies.  Dispatch mirrors ``sorted_merge.ops``:
Pallas on TPU, the jnp ref elsewhere, ``impl="interpret"`` in tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sorted_ops import INT_SENTINEL
from .ref import range_mask_ref
from .range_extract import range_mask_pallas


@partial(jax.jit, static_argnames=("impl",))
def range_mask(rows: jnp.ndarray, cols: jnp.ndarray, bounds: jnp.ndarray,
               *, impl: str = "auto") -> jnp.ndarray:
    """keep[t] ∈ {0, 1}: triple t inside the (row, col) rank box.

    ``rows``/``cols``: int32[N] sentinel-padded; ``bounds``: int32 array
    of 4 entries (row_lo, row_hi, col_lo, col_hi), any shape.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    bounds = bounds.reshape(1, 4).astype(jnp.int32)
    if impl == "ref":
        return range_mask_ref(rows, cols, bounds)
    n = rows.shape[0]
    pad = (-n) % 1024 if n > 1024 else (-n) % 8
    rp = jnp.pad(rows, (0, pad), constant_values=INT_SENTINEL)
    cp = jnp.pad(cols, (0, pad), constant_values=INT_SENTINEL)
    bn = min(1024, rp.shape[0])
    keep = range_mask_pallas(rp, cp, bounds, bn=bn,
                             interpret=(impl == "interpret"))
    return keep[:n]

from .ops import range_mask

__all__ = ["range_mask"]

"""Pure-jnp oracle for the range-mask kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.sorted_ops import INT_SENTINEL


def range_mask_ref(rows: jnp.ndarray, cols: jnp.ndarray,
                   bounds: jnp.ndarray) -> jnp.ndarray:
    """keep[t] = rows[t] ∈ [b[0], b[1]) ∧ cols[t] ∈ [b[2], b[3])."""
    b = bounds.reshape(-1)
    valid = rows != jnp.int32(INT_SENTINEL)
    keep = (valid & (rows >= b[0]) & (rows < b[1])
            & (cols >= b[2]) & (cols < b[3]))
    return keep.astype(jnp.int32)

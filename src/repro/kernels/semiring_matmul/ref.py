"""Pure-jnp oracle for the semiring matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.semiring import get_semiring


def semiring_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, *,
                        semiring="plus_times") -> jnp.ndarray:
    sr = get_semiring(semiring)
    return sr.matmul_dense(a.astype(jnp.float32),
                           b.astype(jnp.float32)).astype(jnp.float32)

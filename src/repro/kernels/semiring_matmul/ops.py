"""Jitted public wrapper: pads to tile multiples, dispatches kernel/ref.

On CPU (tests, dry-run) the kernel runs in interpret mode or falls back to
the jnp reference — Pallas-on-TPU is the deployment target; interpret=True
executes the same kernel body for correctness validation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.semiring import get_semiring
from .ref import semiring_matmul_ref
from .semiring_matmul import semiring_matmul_pallas


def _pad_to(x, mult_r, mult_c, fill):
    r = (-x.shape[0]) % mult_r
    c = (-x.shape[1]) % mult_c
    if r or c:
        x = jnp.pad(x, ((0, r), (0, c)), constant_values=fill)
    return x


@partial(jax.jit, static_argnames=("semiring", "impl", "bm", "bn", "bk"))
def semiring_matmul(a: jnp.ndarray, b: jnp.ndarray, *, semiring="plus_times",
                    impl: str = "auto", bm: int = 128, bn: int = 128,
                    bk: int | None = None) -> jnp.ndarray:
    """Semiring contraction with shape-padding; returns [M, N] fp32.

    impl: "pallas" (TPU), "interpret" (kernel body on CPU), "ref" (jnp),
    "auto" (pallas on TPU backend, ref elsewhere).
    """
    sr = get_semiring(semiring)
    m, n = a.shape[0], b.shape[1]
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return semiring_matmul_ref(a, b, semiring=sr)
    kb = bk or (128 if sr.mxu else 32)
    ap = _pad_to(a.astype(jnp.float32), bm, kb, sr.zero)
    bp = _pad_to(b.astype(jnp.float32), kb, bn, sr.zero)
    out = semiring_matmul_pallas(ap, bp, semiring=sr, bm=bm, bn=bn, bk=kb,
                                 interpret=(impl == "interpret"))
    return out[:m, :n]

"""Pallas TPU kernel: blocked dense semiring matmul.

The paper's associative-array multiplication ``C = A ⊗.⊕ B`` reduces to a
sparse-matrix product on the adjacency matrices.  On TPU we densify onto
MXU-aligned tiles (see DESIGN.md §2) and contract with the semiring:

  * ``(+,×)``  — ``jnp.dot`` on the 128×128 MXU, fp32 accumulation;
  * ``(max,+) / (min,+) / (max,min) / (max,×)`` — no MXU analogue exists
    (the systolic array hard-wires multiply-accumulate), so the contraction
    runs on the VPU as a broadcast ⊗ over a k-slab followed by an ⊕-reduce.
    k-slabs are kept small (``bk=32``) so the [bm, bk, bn] broadcast stays
    within VMEM.

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost/sequential; a
VMEM scratch accumulator carries partial ⊕ results across K steps and is
flushed to the output tile on the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.semiring import Semiring, get_semiring


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, sr: Semiring, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, sr.zero)

    a = a_ref[...]
    b = b_ref[...]
    if sr.mxu:
        part = jnp.dot(a, b, preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] + part
    else:
        # VPU path: ⊗ broadcast over the k slab, ⊕ reduce, ⊕ into acc
        prod = sr.mul(a[:, :, None], b[None, :, :])      # [bm, bk, bn]
        part = sr.add_reduce(prod, axis=1)
        acc_ref[...] = sr.add(acc_ref[...], part)

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def semiring_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray, *,
                           semiring="plus_times",
                           bm: int = 128, bn: int = 128,
                           bk: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """C[i,j] = ⊕_k A[i,k] ⊗ B[k,j].  A: [M,K], B: [K,N] (padded multiples)."""
    sr = get_semiring(semiring)
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2, (a.shape, b.shape)
    if bk is None:
        bk = 128 if sr.mxu else 32
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (m, n, kdim, bm, bn, bk)
    nk = kdim // bk

    return pl.pallas_call(
        functools.partial(_kernel, sr=sr, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)

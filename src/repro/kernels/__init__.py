"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships three files:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling,
  ops.py    — jitted public wrapper (padding, impl dispatch),
  ref.py    — pure-jnp oracle used by the allclose test sweeps.

Kernels are validated in interpret mode on CPU; TPU is the deployment
target.  See DESIGN.md §2 for the CPU-scipy → TPU adaptation story.
"""

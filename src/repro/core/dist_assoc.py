"""Distributed associative arrays: the "Distributed" D of D4M on a mesh.

Historically D4M distributes via Accumulo tablet servers: tables are
row-range-partitioned and algebra pushes down to the servers (Graphulo).
The mesh-native mapping: a ``DistAssoc`` is an ``AssocTensor`` whose COO
triples are **row-rank-range partitioned over the `data` axis** (tablet ↔
shard), and the paper's operations decompose as:

  * element-wise ⊕ / ⊗ — row partitions are disjoint and aligned, so both
    are embarrassingly parallel ``shard_map`` calls (zero collectives);
  * array product ``A ⊗.⊕ B`` — contraction keys live on the row axis of B,
    so each shard computes a LOCAL product against its B-rows and partial
    results combine with a ⊕ ``psum`` over `data` — the Graphulo
    server-side-combine pattern as one collective;
  * global reductions (row/col ⊕-sums) — local reduce + ``psum``.

Shards keep the full keyspaces (host-side, cheap) and static capacity
``cap / n_shards``; re-sharding for elasticity is a host-side split by
row-rank ranges (same code path the checkpoint restore uses).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .assoc_tensor import AssocTensor
from .coo import SENT, dedup_sorted_coo
from .keyspace import KeySpace
from .semiring import PLUS_TIMES, get_semiring

# semirings whose ⊕ is max (vs min) — picks the scatter/collective pair
_MAX_LIKE = ("max_plus", "max_min", "max_times", "and_or")

__all__ = ["DistAssoc"]


class DistAssoc:
    """Row-partitioned AssocTensor over a mesh's ``data`` axis."""

    def __init__(self, local: AssocTensor, mesh: Mesh, *,
                 row_bounds: np.ndarray):
        """``local``: stacked per-shard COO [n_shards, cap_local] arrays
        (leading axis sharded over `data`).  ``row_bounds``: shard row-rank
        boundaries, len n_shards+1."""
        self.local = local
        self.mesh = mesh
        self.row_bounds = row_bounds

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_triples(rows, cols, vals, mesh: Mesh, *, aggregate="min",
                     capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        n_shards = mesh.shape["data"]
        row_space = KeySpace(np.asarray(rows))
        col_space = KeySpace(np.asarray(cols))
        r, _ = row_space.rank(np.asarray(rows))
        # contiguous rank ranges (tablet splits)
        bounds = np.linspace(0, len(row_space), n_shards + 1).astype(np.int64)
        shard_of = np.searchsorted(bounds[1:], r, side="right")
        cap = capacity_per_shard or int(
            max(8, np.ceil(max(np.bincount(shard_of, minlength=n_shards).max(), 1) / 8) * 8))

        locs = []
        rows_np, cols_np, vals_np = (np.asarray(rows), np.asarray(cols),
                                     np.asarray(vals))
        for s in range(n_shards):
            m = shard_of == s
            locs.append(AssocTensor.from_triples(
                rows_np[m] if m.any() else rows_np[:0],
                cols_np[m] if m.any() else cols_np[:0],
                vals_np[m] if m.any() else vals_np[:0],
                aggregate=aggregate, capacity=cap,
                row_space=row_space, col_space=col_space))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *locs)
        sharded = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, P(*( ("data",) + (None,) * (x.ndim - 1))))),
            stacked)
        return DistAssoc(sharded, mesh, row_bounds=bounds)

    @staticmethod
    def from_assoc(a, mesh: Mesh, *, aggregate="min",
                   capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Shard a host Assoc over the mesh (host ⇄ device ⇄ dist pipeline)."""
        r, c, v = a.triples()
        return DistAssoc.from_triples(
            r, c, v, mesh, aggregate=aggregate,
            capacity_per_shard=capacity_per_shard)

    # -- conversions -----------------------------------------------------------
    def to_assoc(self):
        """Gather all shards to a host Assoc (small-data paths/tests)."""
        from .assoc import Assoc
        n_shards = self.mesh.shape["data"]
        merged = None
        for s in range(n_shards):
            local = jax.tree.map(lambda x: x[s], self.local)
            a = local.to_assoc()
            merged = a if merged is None else merged + a if a.nnz() else merged
        return merged

    def _local_spec(self):
        """Per-shard COO dict + its shard_map PartitionSpec tree."""
        a_dict = {"rows": self.local.rows, "cols": self.local.cols,
                  "vals": self.local.vals, "nnz": self.local.nnz}
        spec = {k: P(*(("data",) + (None,) * (v.ndim - 1)))
                for k, v in a_dict.items()}
        return a_dict, spec

    # -- element-wise (alignment-free: row ranges are disjoint) -----------------
    def _ewise(self, other: "DistAssoc", op: str, semiring) -> "DistAssoc":
        sr = get_semiring(semiring)
        a_dict, spec = self._local_spec()

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(spec, spec), out_specs=spec,
                 check_rep=False)
        def go(a, b):
            # keyspaces are host metadata; inside shard_map the algebra runs
            # on raw rank arrays via the same canonicalization primitive the
            # single-device AssocTensor uses.
            a0 = jax.tree.map(lambda x: x[0], a)
            b0 = jax.tree.map(lambda x: x[0], b)
            if op == "add":
                rows = jnp.concatenate([a0["rows"], b0["rows"]])
                cols = jnp.concatenate([a0["cols"], b0["cols"]])
                vals = jnp.concatenate([a0["vals"], b0["vals"]])
                r, c, v, n = dedup_sorted_coo(rows, cols, vals, sr.add,
                                              zero=sr.zero)
                out = {"rows": r, "cols": c, "vals": v, "nnz": n}
            else:
                src = jnp.concatenate([
                    jnp.zeros(a0["rows"].shape[0], jnp.int32),
                    jnp.ones(b0["rows"].shape[0], jnp.int32)])
                rows = jnp.concatenate([a0["rows"], b0["rows"]])
                cols = jnp.concatenate([a0["cols"], b0["cols"]])
                vals = jnp.concatenate([a0["vals"], b0["vals"]])
                r, c, v, n = dedup_sorted_coo(
                    rows, cols, vals, sr.add, zero=sr.zero,
                    require_pair=True, pair_op=sr.mul, src=src)
                cap = min(a0["rows"].shape[0], b0["rows"].shape[0])
                out = {"rows": r[:cap], "cols": c[:cap], "vals": v[:cap],
                       "nnz": jnp.minimum(n, cap)}
            return {"rows": out["rows"][None], "cols": out["cols"][None],
                    "vals": out["vals"][None], "nnz": out["nnz"][None]}

        b_dict = {"rows": other.local.rows, "cols": other.local.cols,
                  "vals": other.local.vals, "nnz": other.local.nnz}
        out = go(a_dict, b_dict)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    def add(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "add", semiring)

    def mul(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "mul", semiring)

    # -- selection (the D4M query surface, sharded) ------------------------------
    def __getitem__(self, ij) -> "DistAssoc":
        """D4M selection ``A[row_sel, col_sel]`` on a sharded array.

        The selector compiles **once on host** against the (replicated)
        keyspaces — every selector form the host ``Assoc`` takes works
        here — then executes shard-locally with zero collectives: row
        partitions are disjoint, so each shard masks and compacts its own
        COO triples.  Contiguous rank boxes run the shared Pallas
        range-mask kernel (``repro.kernels.range_extract``); general index
        sets run one membership gather per shard.  Nothing densifies.
        """
        from .assoc_tensor import coo_compact, coo_mask_keep, coo_range_keep
        from .select import compile_selector

        rc = compile_selector(ij[0], self.local.row_space)
        cc = compile_selector(ij[1], self.local.col_space)
        as_range = rc.is_range and cc.is_range
        if as_range:
            row_arg = jnp.asarray([rc.lo, rc.hi, cc.lo, cc.hi], jnp.int32)
            col_arg = jnp.zeros((1,), jnp.int32)  # unused placeholder
        else:
            nr = max(len(self.local.row_space), 1)
            nc = max(len(self.local.col_space), 1)
            row_arg = jnp.asarray(np.pad(rc.mask(), (0, nr - rc.n)))
            col_arg = jnp.asarray(np.pad(cc.mask(), (0, nc - cc.n)))

        a_dict, spec = self._local_spec()

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(spec, P(), P()), out_specs=spec,
                 check_rep=False)
        def go(a, rsel, csel):
            a0 = jax.tree.map(lambda x: x[0], a)
            # same raw-array primitives as AssocTensor — layers cannot drift
            if as_range:
                keep = coo_range_keep(a0["rows"], a0["cols"], rsel)
            else:
                keep = coo_mask_keep(a0["rows"], a0["cols"], rsel, csel)
            r, c, v, nnz = coo_compact(a0["rows"], a0["cols"], a0["vals"],
                                       keep)
            out = {"rows": r, "cols": c, "vals": v, "nnz": nnz}
            return {k: x[None] for k, x in out.items()}

        out = go(a_dict, row_arg, col_arg)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    # -- global reductions --------------------------------------------------------
    def col_reduce(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕ over rows per column → dense [n_cols] (psum over data)."""
        sr = get_semiring(semiring)
        nc = len(self.local.col_space)

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(P("data"), P("data"), P("data")),
                 out_specs=P(), check_rep=False)
        def go(cols, vals, rows):
            ok = rows[0] != SENT
            if sr.name == "plus_times":
                vec = jnp.zeros((nc,), jnp.float32)
                vec = vec.at[jnp.where(ok, cols[0], nc)].add(
                    jnp.where(ok, vals[0], 0.0), mode="drop")
                return jax.lax.psum(vec, "data")
            vec = jnp.full((nc,), sr.zero, jnp.float32)
            if sr.name in _MAX_LIKE:
                vec = vec.at[jnp.where(ok, cols[0], nc)].max(
                    jnp.where(ok, vals[0], sr.zero), mode="drop")
                return jax.lax.pmax(vec, "data")
            vec = vec.at[jnp.where(ok, cols[0], nc)].min(
                jnp.where(ok, vals[0], sr.zero), mode="drop")
            return jax.lax.pmin(vec, "data")

        return go(self.local.cols, self.local.vals, self.local.rows)

    def matmul_dense_vec(self, x: jnp.ndarray, semiring=PLUS_TIMES) -> jnp.ndarray:
        """y = A ⊗.⊕ x for a dense vector over the column keyspace.

        Row partitions are disjoint: every shard produces its own y rows;
        combining is a concatenation expressed as a psum of disjoint
        supports (the Graphulo pushdown pattern).
        """
        sr = get_semiring(semiring)
        nr = len(self.local.row_space)

        @partial(shard_map, mesh=self.mesh,
                 in_specs=(P("data"), P("data"), P("data"), P()),
                 out_specs=P(), check_rep=False)
        def go(rows, cols, vals, xv):
            ok = rows[0] != SENT
            contrib = sr.mul(jnp.where(ok, vals[0], sr.zero),
                             xv[jnp.clip(cols[0], 0, xv.shape[0] - 1)])
            y = jnp.full((nr,), sr.zero, jnp.float32)
            if sr.name == "plus_times":
                y = jnp.zeros((nr,), jnp.float32).at[
                    jnp.where(ok, rows[0], nr)].add(
                    jnp.where(ok, contrib, 0.0), mode="drop")
                return jax.lax.psum(y, "data")
            if sr.name in _MAX_LIKE:
                y = y.at[jnp.where(ok, rows[0], nr)].max(
                    jnp.where(ok, contrib, sr.zero), mode="drop")
                return jax.lax.pmax(y, "data")
            y = y.at[jnp.where(ok, rows[0], nr)].min(
                jnp.where(ok, contrib, sr.zero), mode="drop")
            return jax.lax.pmin(y, "data")

        return go(self.local.rows, self.local.cols, self.local.vals, x)

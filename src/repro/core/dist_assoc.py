"""Distributed associative arrays: the "Distributed" D of D4M on a mesh.

Historically D4M distributes via Accumulo tablet servers: tables are
row-range-partitioned and algebra pushes down to the servers (Graphulo).
The mesh-native mapping: a ``DistAssoc`` is an ``AssocTensor`` whose COO
triples are **row-rank-range partitioned over the `data` axis** (tablet ↔
shard), and the paper's operations decompose as:

  * element-wise ⊕ / ⊗ — row partitions are disjoint and aligned, so both
    are embarrassingly parallel ``shard_map`` calls (zero collectives);
  * array product ``A ⊗.⊕ B`` — contraction keys live on the row axis of B,
    so with B **broadcast** (replicated triples) each shard computes a
    LOCAL sparse product against its own rows: an expand-join on rank
    triples (:func:`repro.core.coo.expand_join_coo`) plus one canonical
    merge, never densifying.  Row supports are disjoint ⇒ the result is
    row-sharded on the same boundaries with **zero collectives** — the
    Graphulo server-side pattern with the combine elided entirely;
  * fused reductions (``matmul_reduce`` / ``sqout(reduce=)`` / degree) —
    each shard ⊕-folds its products straight into a dense vector and the
    partials merge with exactly **one** psum-family collective
    (:func:`repro.core.semiring.mesh_combine`);
  * global reductions (row/col ⊕-sums) — local segment scatter + the same
    one collective.

Shards keep the full keyspaces (host-side, cheap) and static capacity
``cap / n_shards``; re-sharding for elasticity is a host-side split by
row-rank ranges (same code path the checkpoint restore uses).

The product supports three *communication strategies*, chosen per multiply
by the host cost model (:func:`repro.core.spgemm.plan_dist_matmul`) from
the exact per-block product counts the planner already computes:

  * ``replicate`` — broadcast-B as above: **0** collectives, moves
    ``P·nnz(B)`` triples at staging.  Wins while B is small.
  * ``all_to_all`` — B stays sharded by contraction range (a resident
    ``DistAssoc`` B is reused *in place*: the monotone
    :meth:`KeySpace.union` rank maps keep its row partition a contiguous
    contraction partition); each shard expand-joins the replicated A
    triples against its own B block, buckets the partial products by
    destination row shard, and **one** packed ``all_to_all`` delivers
    them for the ⊕-merge.  B's triples never replicate.
  * ``2d`` — SUMMA-flavored grid ``(pr, pc)`` picked by
    :func:`repro.core.spgemm.suggest_grid`: B splits into ``pc``
    contraction blocks (each staged to ``pr`` shards), A never moves, and
    ``pc`` rounds of shard-local expand-join interleave with ``pc−1``
    ring ``ppermute`` shifts of the packed block.  Wins the square /
    hub-heavy regime where both replication and bucket padding hurt.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.contracts import contract

from .assoc_tensor import (AssocTensor, DISPATCH_STATS, _bump_dispatch,
                           coo_axis_mask_keep, coo_compact, coo_mask_keep,
                           coo_range_keep)
from .coo import (SENT, bucket_coo_by_range, dedup_sorted_coo,
                  expand_join_coo)
from .expr import EwiseAdd, EwiseMul, MatMul, Select, Source
from .keyspace import KeySpace
from .semiring import (PLUS_TIMES, get_semiring, mesh_combine,
                       scatter_combine)
from .spgemm import (BSR_AUTO_EXPAND, TILE, _round_up, pad_to_cap,
                     plan_dist_matmul)

__all__ = ["DistAssoc"]


# ---------------------------------------------------------------------------
# Cached shard_map programs.  A bare shard_map call re-traces and re-lowers
# on EVERY invocation (there is no dispatch cache outside jit) — on an
# 8-shard CPU mesh that is seconds per call.  The matmul-family programs are
# pure functions of (mesh, semiring, static sizes), so one lru_cache'd
# jit(shard_map(...)) per signature makes repeated products dispatch-cheap.
# Semiring is a frozen dataclass and Mesh is hashable: both key cleanly.
# ---------------------------------------------------------------------------

_COO_SPEC = ("rows", "cols", "vals")

def _local_coo_spec():
    """PartitionSpec tree of the per-shard COO dict (``_local_spec``'s
    static twin, so cached program builders need no instance)."""
    return {"rows": P("data", None), "cols": P("data", None),
            "vals": P("data", None), "nnz": P("data")}

# auto-strategy crossover for DistAssoc.matmul: below this per-shard
# expand-join size the jit-safe coo shard_map program wins (one fused
# dispatch, no host loop); above it the tiled pair-list strategy's
# O(products-touched) work beats the full expansion buffer.  Lives in
# spgemm so the distribution cost model can price the switch (its host
# planning rescans B per shard).
_BSR_AUTO_EXPAND = BSR_AUTO_EXPAND


@functools.lru_cache(maxsize=256)
def _matmul_prog(mesh: Mesh, sr, expand: int, out_cap: int):
    spec = {k: P("data", None) for k in _COO_SPEC}
    out_spec = {"rows": P("data", None), "cols": P("data", None),
                "vals": P("data", None), "nnz": P("data"),
                "true_nnz": P("data")}

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=out_spec, check_rep=False)
    def go(a, br, bc, bv):
        pr, pc, pv, _ = expand_join_coo(
            a["rows"][0], a["cols"][0], a["vals"][0], br, bc, bv,
            sr.mul, zero=sr.zero, expand=expand)
        r, c, v, nnz = dedup_sorted_coo(pr, pc, pv, sr.add, zero=sr.zero)
        r, c, v = pad_to_cap(r, c, v, out_cap, sr.zero)
        # true (pre-clamp) nnz rides along so the eager caller can surface
        # per-shard capacity overflow instead of truncating silently
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": jnp.minimum(nnz, out_cap)[None],
                "true_nnz": nnz[None]}

    return go


@functools.lru_cache(maxsize=256)
def _matmul_reduce_prog(mesh: Mesh, sr, expand: int, n_out: int, axis: int):
    spec = {k: P("data", None) for k in _COO_SPEC}

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=P(), check_rep=False)
    def go(a, br, bc, bv):
        pr, pc, pv, _ = expand_join_coo(
            a["rows"][0], a["cols"][0], a["vals"][0], br, bc, bv,
            sr.mul, zero=sr.zero, expand=expand)
        keys = pr if axis == 1 else pc
        vec = jnp.full((n_out,), sr.zero, jnp.float32)
        vec = scatter_combine(vec, keys, pv, sr)  # SENT keys drop
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _col_reduce_prog(mesh: Mesh, sr, nc: int, dt):
    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), P("data"), P("data")),
             out_specs=P(), check_rep=False)
    def go(cols, vals, rows):
        ok = rows[0] != SENT
        vec = jnp.full((nc,), sr.zero, dt)
        vec = scatter_combine(vec, jnp.where(ok, cols[0], nc),
                              jnp.where(ok, vals[0], sr.zero), sr)
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _col_degree_prog(mesh: Mesh, nc: int):
    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=P(), check_rep=False)
    def go(cols, rows):
        ok = rows[0] != SENT
        vec = jnp.zeros((nc,), jnp.int32)
        vec = vec.at[jnp.where(ok, cols[0], nc)].add(
            jnp.where(ok, 1, 0).astype(jnp.int32), mode="drop")
        return jax.lax.psum(vec, "data")

    return go


@functools.lru_cache(maxsize=256)
def _matvec_prog(mesh: Mesh, sr, nr: int, dt):
    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), P("data"), P("data"), P()),
             out_specs=P(), check_rep=False)
    def go(rows, cols, vals, xv):
        ok = rows[0] != SENT
        contrib = sr.mul(jnp.where(ok, vals[0], sr.zero).astype(dt),
                         xv[jnp.clip(cols[0], 0, xv.shape[0] - 1)]
                         .astype(dt))
        y = jnp.full((nr,), sr.zero, dt)
        y = scatter_combine(y, jnp.where(ok, rows[0], nr),
                            jnp.where(ok, contrib, sr.zero), sr)
        return mesh_combine(y, "data", sr)

    return go


def _shard_selection_keep(a0, row_gather: bool, col_gather: bool,
                          bnds, rm, cm):
    """Shard-local keep mask for a compiled selection — the one dispatch
    body shared by ``__getitem__`` and ``__setitem__`` (range kernel /
    multirange OR / hybrid / double-gather, exactly as
    ``AssocTensor._selection_keep``).  ``bnds`` is the ``[k, 4]`` box list
    from ``select.plan_boxes`` (k static inside the shard_map trace)."""
    if row_gather and col_gather:
        return coo_mask_keep(a0["rows"], a0["cols"], rm, cm)
    keep = coo_range_keep(a0["rows"], a0["cols"], bnds[0])
    for i in range(1, bnds.shape[0]):
        keep = keep | coo_range_keep(a0["rows"], a0["cols"], bnds[i])
    if row_gather:
        keep = keep & coo_axis_mask_keep(a0["rows"], rm)
    if col_gather:
        keep = keep & coo_axis_mask_keep(a0["cols"], cm)
    return keep


@functools.lru_cache(maxsize=256)
def _reduce_add_n_prog(mesh: Mesh, sr, axis: int, n_out: int, n_terms: int):
    """Fused ``⊕-reduce(t₁ ⊕ t₂ ⊕ …, axis)`` over aligned sharded terms.

    The planner's Reduce-through-EwiseAdd rewrite lands here: instead of
    materializing the ⊕-merged array (a concat + sort per shard) and then
    reducing it, every term's triples scatter straight into one dense
    partial vector and the partials merge with exactly **one** psum-family
    collective — same contract as ``_matmul_reduce_prog``.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec,) * n_terms,
             out_specs=P(), check_rep=False)
    def go(*parts):
        vec = jnp.full((n_out,), sr.zero, jnp.float32)
        for p in parts:
            ok = p["rows"][0] != SENT
            keys = p["rows"][0] if axis == 1 else p["cols"][0]
            vec = scatter_combine(vec, jnp.where(ok, keys, n_out),
                                  jnp.where(ok, p["vals"][0], sr.zero), sr)
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _select_prog(mesh: Mesh, row_gather: bool, col_gather: bool):
    """Shard-local selection program (``__getitem__``'s executor).

    Cached by dispatch kind only: the box list / masks ride in as traced
    arguments, so every selection with the same (mesh, dispatch) shape
    reuses one compiled program instead of re-tracing a bare shard_map
    per call.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=spec, check_rep=False)
    def go(a, bnds, rm, cm):
        a0 = jax.tree.map(lambda x: x[0], a)
        # same raw-array primitives as AssocTensor — layers cannot drift
        keep = _shard_selection_keep(a0, row_gather, col_gather,
                                     bnds, rm, cm)
        r, c, v, nnz = coo_compact(a0["rows"], a0["cols"], a0["vals"], keep)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": nnz[None]}

    return go


@functools.lru_cache(maxsize=256)
def _setvals_prog(mesh: Mesh, row_gather: bool, col_gather: bool):
    """Selector-targeted value overwrite (``__setitem__``'s executor).

    The scalar rides in as a traced argument — assigning a different
    value hits the same compiled program.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P(), P()),
             out_specs=P("data", None), check_rep=False)
    def go(a, bnds, rm, cm, val):
        a0 = jax.tree.map(lambda x: x[0], a)
        keep = _shard_selection_keep(a0, row_gather, col_gather,
                                     bnds, rm, cm)
        return jnp.where(keep, val.astype(a0["vals"].dtype),
                         a0["vals"])[None]

    return go


@functools.lru_cache(maxsize=256)
def _ewise_prog(mesh: Mesh, sr, op: str):
    """Element-wise ⊕ / ⊗ program: disjoint aligned row partitions, so the
    whole operation is one shard-local canonical merge, zero collectives."""
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
             check_rep=False)
    def go(a, b):
        # keyspaces are host metadata; inside shard_map the algebra runs
        # on raw rank arrays via the same canonicalization primitive the
        # single-device AssocTensor uses.
        a0 = jax.tree.map(lambda x: x[0], a)
        b0 = jax.tree.map(lambda x: x[0], b)
        rows = jnp.concatenate([a0["rows"], b0["rows"]])
        cols = jnp.concatenate([a0["cols"], b0["cols"]])
        vals = jnp.concatenate([a0["vals"], b0["vals"]])
        if op == "add":
            r, c, v, n = dedup_sorted_coo(rows, cols, vals, sr.add,
                                          zero=sr.zero)
            out = {"rows": r, "cols": c, "vals": v, "nnz": n}
        else:
            src = jnp.concatenate([
                jnp.zeros(a0["rows"].shape[0], jnp.int32),
                jnp.ones(b0["rows"].shape[0], jnp.int32)])
            r, c, v, n = dedup_sorted_coo(
                rows, cols, vals, sr.add, zero=sr.zero,
                require_pair=True, pair_op=sr.mul, src=src)
            cap = min(a0["rows"].shape[0], b0["rows"].shape[0])
            out = {"rows": r[:cap], "cols": c[:cap], "vals": v[:cap],
                   "nnz": jnp.minimum(n, cap)}
        return {"rows": out["rows"][None], "cols": out["cols"][None],
                "vals": out["vals"][None], "nnz": out["nnz"][None]}

    return go


# ---------------------------------------------------------------------------
# Sharded-B communication strategies.  The partial-product exchange and the
# ring shift both move ONE packed int32 array (rows, cols, bitcast values
# stacked on a trailing axis) — three separate collectives would triple the
# trip count the contracts pin down.
# ---------------------------------------------------------------------------

def _pack_coo(rows, cols, vals):
    """Stack COO triples into one int32 array (vals bitcast) — the unit a
    single collective can move."""
    return jnp.stack(
        [rows, cols,
         jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.int32)],
        axis=-1)


def _unpack_coo(packed):
    return (packed[..., 0], packed[..., 1],
            jax.lax.bitcast_convert_type(packed[..., 2], jnp.float32))


@contract(collectives=1, name="dist.matmul_all_to_all",
          note="sharded-B product: one packed all_to_all of partial "
               "products, B never replicated")
@functools.lru_cache(maxsize=256)
def _matmul_a2a_prog(mesh: Mesh, sr, expand: int, bucket_cap: int,
                     out_cap: int, n_shards: int):
    """Sharded-B all-to-all product program.

    A's triples arrive replicated (``[n_shards, cap]``, flattened in the
    body); each shard expand-joins them against its OWN contraction block
    of B, buckets the partial products by destination row shard
    (:func:`bucket_coo_by_range` over the result's ``row_bounds``), and
    exactly one ``all_to_all`` of the packed ``[P, bucket_cap, 3]`` buffer
    delivers every product to the shard owning its output row, where one
    canonical merge ⊕-dedups them.  ``true_nnz`` rides along for the
    overflow warning, as in ``_matmul_prog``.
    """
    b_spec = {k: P("data", None) for k in _COO_SPEC}
    out_spec = {"rows": P("data", None), "cols": P("data", None),
                "vals": P("data", None), "nnz": P("data"),
                "true_nnz": P("data")}

    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(P(), P(), P(), b_spec, P(), P()),
             out_specs=out_spec, check_rep=False)
    def go(ar, ac, av, b, bm, bounds):
        # rerank the resident B block's rows onto the merged contraction
        # space in-program (bm is monotone, so the block stays sorted);
        # staged B passes the identity map
        rb0 = b["rows"][0]
        okb = rb0 != SENT
        rb = jnp.where(okb, bm[jnp.clip(rb0, 0, bm.shape[0] - 1)], SENT)
        pr, pc, pv, _ = expand_join_coo(
            ar.reshape(-1), ac.reshape(-1), av.reshape(-1),
            rb, b["cols"][0], b["vals"][0],
            sr.mul, zero=sr.zero, expand=expand)
        br, bc, bv = bucket_coo_by_range(pr, pc, pv, bounds, n_shards,
                                         bucket_cap, zero=sr.zero)
        got = jax.lax.all_to_all(_pack_coo(br, bc, bv), "data",
                                 split_axis=0, concat_axis=0, tiled=True)
        rows, cols, vals = _unpack_coo(got)
        r, c, v, nnz = dedup_sorted_coo(rows.reshape(-1), cols.reshape(-1),
                                        vals.reshape(-1), sr.add,
                                        zero=sr.zero)
        r, c, v = pad_to_cap(r, c, v, out_cap, sr.zero)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": jnp.minimum(nnz, out_cap)[None],
                "true_nnz": nnz[None]}

    return go


@contract(collectives=3, name="dist.matmul_2d",
          note="SUMMA-style grid: pc−1 packed ring ppermutes "
               "(probe grid 2×4 → 3); A never moves")
@functools.lru_cache(maxsize=256)
def _matmul_ring_prog(mesh: Mesh, sr, pr: int, pc: int, round_expand: int,
                      out_cap: int):
    """2D-grid ring product program.

    Shard ``s = (g, p)`` (``g = s // pc``) keeps its own A rows and starts
    with B contraction block ``p``; each of the ``pc`` rounds contracts
    the resident block locally, then one ``ppermute`` ring-shifts the
    packed block within the group (``pc−1`` shifts total — the last round
    skips it).  Output rows never leave their owner shard, so the round
    buffers concat + one canonical merge finish the product with no
    further communication.
    """
    a_spec = {k: P("data", None) for k in _COO_SPEC}
    out_spec = {"rows": P("data", None), "cols": P("data", None),
                "vals": P("data", None), "nnz": P("data"),
                "true_nnz": P("data")}
    n_shards = pr * pc
    perm = [(s, (s // pc) * pc + ((s % pc) - 1) % pc)
            for s in range(n_shards)]

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(a_spec, a_spec),
             out_specs=out_spec, check_rep=False)
    def go(a, b):
        ar, ac, av = a["rows"][0], a["cols"][0], a["vals"][0]
        bpk = _pack_coo(b["rows"][0], b["cols"][0], b["vals"][0])
        parts = []
        for rnd in range(pc):
            br, bc, bv = _unpack_coo(bpk)
            parts.append(expand_join_coo(ar, ac, av, br, bc, bv, sr.mul,
                                         zero=sr.zero,
                                         expand=round_expand)[:3])
            if rnd + 1 < pc:
                bpk = jax.lax.ppermute(bpk, "data", perm)
        rows = jnp.concatenate([p[0] for p in parts])
        cols = jnp.concatenate([p[1] for p in parts])
        vals = jnp.concatenate([p[2] for p in parts])
        r, c, v, nnz = dedup_sorted_coo(rows, cols, vals, sr.add,
                                        zero=sr.zero)
        r, c, v = pad_to_cap(r, c, v, out_cap, sr.zero)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": jnp.minimum(nnz, out_cap)[None],
                "true_nnz": nnz[None]}

    return go


@contract(collectives=1, name="dist.matmul_reduce_all_to_all",
          note="sharded-B fused epilogue: one mesh_combine, no exchange "
               "of partial products needed")
@functools.lru_cache(maxsize=256)
def _matmul_reduce_a2a_prog(mesh: Mesh, sr, expand: int, n_out: int,
                            axis: int):
    """Sharded-B twin of ``_matmul_reduce_prog``: each shard folds the
    products of ITS contraction block straight into the dense output
    vector, and the one psum-family collective both merges the partials
    and replaces the partial-product exchange — the all-to-all variant of
    the fused epilogue is no chattier than the replicate one."""
    b_spec = {k: P("data", None) for k in _COO_SPEC}

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(P(), P(), P(), b_spec, P()),
             out_specs=P(), check_rep=False)
    def go(ar, ac, av, b, bm):
        rb0 = b["rows"][0]
        okb = rb0 != SENT
        rb = jnp.where(okb, bm[jnp.clip(rb0, 0, bm.shape[0] - 1)], SENT)
        pr, pc, pv, _ = expand_join_coo(
            ar.reshape(-1), ac.reshape(-1), av.reshape(-1),
            rb, b["cols"][0], b["vals"][0],
            sr.mul, zero=sr.zero, expand=expand)
        keys = pr if axis == 1 else pc
        vec = jnp.full((n_out,), sr.zero, jnp.float32)
        vec = scatter_combine(vec, keys, pv, sr)  # SENT keys drop
        return mesh_combine(vec, "data", sr)

    return go


@contract(collectives=0, name="dist.matmul_bsr",
          note="one shard_map for the whole tiled product: per-shard "
               "pair lists ride in as traced operands")
@functools.lru_cache(maxsize=256)
def _matmul_bsr_prog(mesh: Mesh, sr, n_a: int, n_c: int, m: int, n: int,
                     out_cap: int, kernel_impl: str):
    """Single-program tiled (BSR pair-list) replicate-strategy product.

    Replaces the eager per-shard host loop: every shard packs its own A
    tiles from traced scatter targets, contracts its planned tile-pair
    list against the once-packed replicated B tiles
    (:func:`repro.kernels.bsr_spgemm.ops.bsr_pairlist` — the
    scalar-prefetch Pallas kernel on TPU, the jnp oracle elsewhere), and
    extracts canonical COO from its C tiles — one dispatch for the whole
    mesh instead of ``n_shards`` planner+kernel round-trips.  Per-shard
    plans are padded to uniform static sizes on host: dummy pairs target
    the extra C slot ``n_c`` (discarded), padded entries/blocks scatter
    out of bounds (dropped) or land past ``(m, n)`` (filtered).
    """
    shard1 = P("data", None)
    out_spec = {"rows": shard1, "cols": shard1, "vals": shard1,
                "nnz": P("data"), "true_nnz": P("data")}

    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(shard1, shard1, shard1, shard1, P(),
                       shard1, shard1, shard1, P("data", None, None)),
             out_specs=out_spec, check_rep=False)
    def go(av, tof, lr, lc, b_tiles, pa, pb, pcc, cblk):
        from repro.kernels.bsr_spgemm.ops import bsr_pairlist
        a_tiles = jnp.full((n_a, TILE, TILE), sr.zero, jnp.float32)
        a_tiles = a_tiles.at[tof[0], lr[0], lc[0]].set(
            av[0].astype(jnp.float32), mode="drop")
        c_tiles = bsr_pairlist(a_tiles, b_tiles, pa[0], pb[0], pcc[0],
                               n_c=n_c + 1, semiring=sr, impl=kernel_impl)
        c_use = c_tiles[:n_c]                      # drop the dummy slot
        iota = jnp.arange(TILE, dtype=jnp.int32)
        rows_g = (cblk[0][:, 0, None, None] * TILE
                  + iota[None, :, None])
        cols_g = (cblk[0][:, 1, None, None] * TILE
                  + iota[None, None, :])
        rows_g = jnp.broadcast_to(rows_g, c_use.shape).reshape(-1)
        cols_g = jnp.broadcast_to(cols_g, c_use.shape).reshape(-1)
        vals_g = c_use.reshape(-1)
        keep = (vals_g != sr.zero) & (rows_g < m) & (cols_g < n)
        r, c, v, nnz = coo_compact(rows_g, cols_g, vals_g, keep)
        r, c, v = pad_to_cap(r, c, v, out_cap, sr.zero)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": jnp.minimum(nnz, out_cap)[None],
                "true_nnz": nnz[None]}

    return go


@dataclasses.dataclass
class _MatmulSetup:
    """Host-side product prologue state shared by every strategy.

    ``a_*_h`` / ``counts`` / ``b_rows_h`` feed the distribution cost model
    (:func:`repro.core.spgemm.plan_dist_matmul`); the ``b_*_h`` triples are
    already in the merged contraction rank space, sorted by row, and back
    both the staging paths and the lazily built replicated-B tensor.
    """

    a_loc: AssocTensor             # sharded stacked triples, logical-coerced
    a_cols: jnp.ndarray            # device [P, cap] contraction-space cols
    a_rows_h: np.ndarray
    a_cols_h: np.ndarray
    counts: np.ndarray             # [P, cap] exact per-entry product counts
    ks: KeySpace                   # merged contraction keyspace
    b_col_space: KeySpace
    b_resident: bool               # B is a DistAssoc on this mesh
    b_repl: Optional[AssocTensor]  # replicated reranked B (lazy if resident)
    b_other: Optional["DistAssoc"]
    b_map: np.ndarray              # B row rank → merged rank (monotone)
    b_rows_h: np.ndarray           # sorted valid merged contraction ranks
    b_cols_h: np.ndarray
    b_vals_h: np.ndarray
    a2a_bounds: Optional[np.ndarray]   # resident B's mapped partition


class DistAssoc:
    """Row-partitioned AssocTensor over a mesh's ``data`` axis."""

    # eager metadata default (mirrors AssocTensor.overflow): matmul sets an
    # instance attribute when a shard truncated its result
    overflow = False

    def __init__(self, local: AssocTensor, mesh: Mesh, *,
                 row_bounds: np.ndarray):
        """``local``: stacked per-shard COO [n_shards, cap_local] arrays
        (leading axis sharded over `data`).  ``row_bounds``: shard row-rank
        boundaries, len n_shards+1."""
        self.local = local
        self.mesh = mesh
        self.row_bounds = row_bounds

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_triples(rows, cols, vals, mesh: Mesh, *, aggregate="min",
                     capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        n_shards = mesh.shape["data"]
        row_space = KeySpace(np.asarray(rows))
        col_space = KeySpace(np.asarray(cols))
        r, _ = row_space.rank(np.asarray(rows))
        # contiguous rank ranges (tablet splits)
        bounds = np.linspace(0, len(row_space), n_shards + 1).astype(np.int64)
        shard_of = np.searchsorted(bounds[1:], r, side="right")
        cap = capacity_per_shard or int(
            max(8, np.ceil(max(np.bincount(shard_of, minlength=n_shards).max(), 1) / 8) * 8))

        locs = []
        rows_np, cols_np, vals_np = (np.asarray(rows), np.asarray(cols),
                                     np.asarray(vals))
        for s in range(n_shards):
            m = shard_of == s
            locs.append(AssocTensor.from_triples(
                rows_np[m] if m.any() else rows_np[:0],
                cols_np[m] if m.any() else cols_np[:0],
                vals_np[m] if m.any() else vals_np[:0],
                aggregate=aggregate, capacity=cap,
                row_space=row_space, col_space=col_space))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *locs)
        sharded = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, P(*( ("data",) + (None,) * (x.ndim - 1))))),
            stacked)
        return DistAssoc(sharded, mesh, row_bounds=bounds)

    @staticmethod
    def from_assoc(a, mesh: Mesh, *, aggregate="min",
                   capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Shard a host Assoc over the mesh (host ⇄ device ⇄ dist pipeline)."""
        r, c, v = a.triples()
        return DistAssoc.from_triples(
            r, c, v, mesh, aggregate=aggregate,
            capacity_per_shard=capacity_per_shard)

    # -- conversions -----------------------------------------------------------
    def to_assoc(self):
        """Gather all shards to a host Assoc (small-data paths/tests)."""
        from .assoc import Assoc
        n_shards = self.mesh.shape["data"]
        merged = None
        for s in range(n_shards):
            local = jax.tree.map(lambda x: x[s], self.local)
            a = local.to_assoc()
            merged = a if merged is None else merged + a if a.nnz() else merged
        return merged

    def gather_replicated(self) -> AssocTensor:
        """All shards' triples as ONE replicated device AssocTensor.

        The broadcast-B step of the distributed product: shard row supports
        are disjoint and individually canonical, so the gather is a pure
        re-sort + compaction (:func:`coo_compact`) of the concatenated
        arrays — no ⊕-merge, and crucially no zero-drop: a stored ``0.0``
        (legitimate under min/max-family semirings whose ⊕-identity is
        ±inf) must survive chained products.
        """
        rows = self.local.rows.reshape(-1)
        cols = self.local.cols.reshape(-1)
        vals = self.local.vals.reshape(-1)
        r, c, v, nnz = coo_compact(rows, cols, vals, rows != SENT)
        return AssocTensor(r, c, v, nnz, self.local.row_space,
                           self.local.col_space, self.local.val_space)

    def _local_spec(self):
        """Per-shard COO dict + its shard_map PartitionSpec tree."""
        a_dict = {"rows": self.local.rows, "cols": self.local.cols,
                  "vals": self.local.vals, "nnz": self.local.nnz}
        spec = {k: P(*(("data",) + (None,) * (v.ndim - 1)))
                for k, v in a_dict.items()}
        return a_dict, spec

    # -- element-wise (alignment-free: row ranges are disjoint) -----------------
    def _ewise(self, other: "DistAssoc", op: str, semiring) -> "DistAssoc":
        sr = get_semiring(semiring)
        a_dict, _ = self._local_spec()
        b_dict = {"rows": other.local.rows, "cols": other.local.cols,
                  "vals": other.local.vals, "nnz": other.local.nnz}
        go = _ewise_prog(self.mesh, sr, op)
        out = go(a_dict, b_dict)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    @contract(collectives=0, note="shard-local ⊕: disjoint aligned rows")
    def add(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "add", semiring)

    @contract(collectives=0, note="shard-local ⊗: disjoint aligned rows")
    def mul(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "mul", semiring)

    def __add__(self, other):
        # thin wrapper over the one-node graph (lazy/eager share one path);
        # expression operands defer to the Node's reflected operator
        if not isinstance(other, DistAssoc):
            return NotImplemented
        return EwiseAdd(Source(self), Source(other)).collect()

    def __mul__(self, other):
        if not isinstance(other, DistAssoc):
            return NotImplemented
        return EwiseMul(Source(self), Source(other)).collect()

    # -- lazy expressions (the deferred pipeline API, repro.core.expr) ----------
    def lazy(self) -> Source:
        """Wrap as a lazy expression Source (see ``Assoc.lazy``)."""
        return Source(self)

    # -- selection (the D4M query surface, sharded) ------------------------------
    def _compiled_selection(self, ij):
        """Compile (row_sel, col_sel) once on host → shard-broadcast forms.

        Shared prologue of ``__getitem__`` and ``__setitem__``: returns
        ``(row_gather, col_gather, bounds, rmask, cmask)`` — the ``[k, 4]``
        rank-box list for the Pallas range kernel (``select.plan_boxes``:
        one box for a contiguous selection, ≤4 OR-composed boxes for a
        multi-interval one) plus membership masks for any scattered axis.
        Dispatch mirrors ``AssocTensor._selection_keep``.
        """
        from .select import compile_selector, plan_boxes

        rc = compile_selector(ij[0], self.local.row_space)
        cc = compile_selector(ij[1], self.local.col_space)
        nr = max(len(self.local.row_space), 1)
        nc = max(len(self.local.col_space), 1)
        boxes, row_gather, col_gather = plan_boxes(rc, cc, nr, nc)
        bounds = jnp.asarray(boxes, jnp.int32)
        rmask = (jnp.asarray(np.pad(rc.mask(), (0, nr - rc.n)))
                 if row_gather else jnp.zeros((1,), bool))
        cmask = (jnp.asarray(np.pad(cc.mask(), (0, nc - cc.n)))
                 if col_gather else jnp.zeros((1,), bool))
        if row_gather and col_gather:
            _bump_dispatch("gather")
        elif len(boxes) > 1:
            _bump_dispatch("multirange")
        elif row_gather or col_gather:
            _bump_dispatch("hybrid")
        else:
            _bump_dispatch("range")
        return row_gather, col_gather, bounds, rmask, cmask

    @contract(collectives=0,
              note="selection is shard-local: compiled boxes/masks broadcast")
    def __getitem__(self, ij) -> "DistAssoc":
        # thin wrapper over the one-node graph (lazy/eager one path)
        i, j = ij
        return Select(Source(self), i, j).collect()

    def _select_eager(self, ij) -> "DistAssoc":
        """D4M selection ``A[row_sel, col_sel]`` on a sharded array.

        The selector compiles **once on host** against the (replicated)
        keyspaces — every selector form the host ``Assoc`` takes works
        here — then executes shard-locally with zero collectives: row
        partitions are disjoint, so each shard masks and compacts its own
        COO triples.  Dispatch mirrors ``AssocTensor._selection_keep``:
        both axes contiguous → the shared Pallas range-mask kernel
        (``repro.kernels.range_extract``); ONE contiguous axis (e.g. a
        single-interval ``Match``/``StartsWith``) → the range kernel for
        that axis plus one membership gather for the other; both scattered
        → two gathers.  Nothing densifies.
        """
        row_gather, col_gather, bounds, rmask, cmask = \
            self._compiled_selection(ij)
        a_dict, _ = self._local_spec()
        go = _select_prog(self.mesh, row_gather, col_gather)
        out = go(a_dict, bounds, rmask, cmask)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    @contract(collectives=0,
              note="scalar assignment is shard-local over stored entries")
    def __setitem__(self, ij, value) -> None:
        """Selector-targeted scalar assignment, sharded (in place).

        The ROADMAP ``DistAssoc.__setitem__`` pushdown, mirroring the
        ``__getitem__`` structure exactly: the selector compiles once on
        host, then each shard overwrites the values of its own *stored*
        entries inside the selection — zero collectives, nothing
        densifies.  Semantics match ``AssocTensor.__setitem__``: numeric
        scalar, support unchanged (inserting new entries is a host-side
        ``from_triples``).
        """
        if (not isinstance(value, (int, float, np.integer, np.floating))
                or isinstance(value, (bool, np.bool_))):
            raise TypeError("DistAssoc __setitem__ takes a numeric scalar")
        if not self.local.numeric:
            raise TypeError("DistAssoc __setitem__ requires numeric values")
        row_gather, col_gather, bounds, rmask, cmask = \
            self._compiled_selection(ij)
        a_dict, _ = self._local_spec()
        go = _setvals_prog(self.mesh, row_gather, col_gather)
        new_vals = go(a_dict, bounds, rmask, cmask, jnp.float32(value))
        self.local = AssocTensor(self.local.rows, self.local.cols, new_vals,
                                 self.local.nnz, self.local.row_space,
                                 self.local.col_space,
                                 self.local.val_space)

    # -- global reductions --------------------------------------------------------
    @contract(collectives=1, note="local segment scatter + one mesh_combine")
    def col_reduce(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕ over rows per column → dense [n_cols] (one collective)."""
        sr = get_semiring(semiring)
        go = _col_reduce_prog(self.mesh, sr, len(self.local.col_space),
                              self.local.vals.dtype)
        return go(self.local.cols, self.local.vals, self.local.rows)

    @contract(collectives=1, note="disjoint-support concat as one collective")
    def row_reduce(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕ over cols per row → dense [n_rows] (one collective).

        Row supports are disjoint, so the psum-family combine is a pure
        concatenation of shard partials; reuses the col-reduce program
        with the row ranks as the scatter keys.
        """
        sr = get_semiring(semiring)
        go = _col_reduce_prog(self.mesh, sr, len(self.local.row_space),
                              self.local.vals.dtype)
        return go(self.local.rows, self.local.vals, self.local.rows)

    @contract(collectives=1, note="one psum of per-shard counts")
    def col_degree(self) -> jnp.ndarray:
        """Stored-entry count per column → dense int32 [n_cols] (one psum).

        The Graphulo degree-table idiom: the logical() + column-⊕ fusion
        runs shard-locally (one segment scatter over the shard's triples)
        and the per-shard partial counts merge with a single ``psum``.
        """
        go = _col_degree_prog(self.mesh, len(self.local.col_space))
        return go(self.local.cols, self.local.rows)

    @contract(collectives=1, note="per-shard y rows + one mesh_combine")
    def matmul_dense_vec(self, x: jnp.ndarray, semiring=PLUS_TIMES) -> jnp.ndarray:
        """y = A ⊗.⊕ x for a dense vector over the column keyspace.

        Row partitions are disjoint: every shard produces its own y rows;
        combining is a concatenation expressed as one psum-family
        collective of disjoint supports (the Graphulo pushdown pattern).
        Accumulates in the promoted values/operand dtype rather than
        hardcoded float32.
        """
        sr = get_semiring(semiring)
        dt = jnp.result_type(self.local.vals.dtype, x.dtype)
        go = _matvec_prog(self.mesh, sr, len(self.local.row_space), dt)
        return go(self.local.rows, self.local.cols, self.local.vals, x)

    # -- array multiplication (Graphulo pushdown, sharded) -----------------------
    def _as_replicated_operand(self, other) -> AssocTensor:
        """Coerce the B operand to a replicated device AssocTensor."""
        from .assoc import Assoc
        if isinstance(other, DistAssoc):
            return other.gather_replicated()
        if isinstance(other, AssocTensor):
            return other
        if isinstance(other, Assoc):
            return other.to_tensor()
        raise TypeError(f"cannot multiply DistAssoc by {type(other)!r}")

    def _matmul_setup(self, other) -> "_MatmulSetup":
        """Shared product prologue: logical() strings, align the contraction
        keyspace, and collect the host metadata the distribution cost model
        runs on (exact per-entry product counts, B's sorted contraction
        ranks, B's own partition bounds when it is mesh-resident).

        Semiring-independent — this is the sharded twin of
        ``spgemm._contraction_aligned``: alignment is pure key/rank work.
        """
        a_loc = self.local.logical() if not self.local.numeric else self.local
        b_resident = isinstance(other, DistAssoc) and other.mesh == self.mesh
        b_repl = None
        if b_resident:
            b_loc = (other.local.logical() if not other.local.numeric
                     else other.local)
            b_row_space, b_col_space = b_loc.row_space, b_loc.col_space
        else:
            b_t = self._as_replicated_operand(other)
            b_t = b_t.logical() if not b_t.numeric else b_t
            b_row_space, b_col_space = b_t.row_space, b_t.col_space
        ks, a_map, b_map = a_loc.col_space.union(b_row_space)
        b_map = np.asarray(b_map, np.int32)

        # device: rerank the sharded A cols onto the contraction space
        ok = a_loc.rows != SENT
        cm = jnp.asarray(a_map) if len(a_map) else jnp.zeros(1, jnp.int32)
        a_cols = jnp.where(ok, cm[jnp.clip(a_loc.cols, 0, cm.shape[0] - 1)],
                           SENT)
        a_rows_h = np.asarray(a_loc.rows)
        a_cols_h = np.asarray(a_cols)

        # host B triples in the merged contraction space, sorted by row:
        # shard supports are disjoint and ranges ordered, and the union
        # rank maps are monotone, so ravel order IS sorted order
        a2a_bounds = None
        if b_resident:
            rws = np.asarray(b_loc.rows).ravel()
            keep = rws != int(SENT)
            rh = rws[keep]
            b_rows_h = b_map[rh] if len(b_map) else rh
            b_cols_h = np.asarray(b_loc.cols).ravel()[keep]
            b_vals_h = np.asarray(b_loc.vals).ravel()[keep]
            rb = np.asarray(other.row_bounds, np.int64)
            if len(b_map):
                a2a_bounds = np.where(
                    rb < len(b_map),
                    b_map.astype(np.int64)[np.clip(rb, 0, len(b_map) - 1)],
                    len(ks))
            else:
                a2a_bounds = np.zeros_like(rb)
        else:
            b_repl = b_t.reranked(ks, b_col_space, b_map,
                                  np.arange(len(b_col_space), dtype=np.int32))
            rws = np.asarray(b_repl.rows)
            keep = rws != int(SENT)
            b_rows_h = rws[keep]
            b_cols_h = np.asarray(b_repl.cols)[keep]
            b_vals_h = np.asarray(b_repl.vals)[keep]

        # exact per-entry product counts (host): two searchsorteds over
        # B's contraction ranks — the cost model's only data dependence
        lo = np.searchsorted(b_rows_h, a_cols_h.ravel(), side="left")
        hi = np.searchsorted(b_rows_h, a_cols_h.ravel(), side="right")
        counts = np.where(a_rows_h.ravel() != int(SENT),
                          hi - lo, 0).reshape(a_rows_h.shape)
        return _MatmulSetup(a_loc=a_loc, a_cols=a_cols, a_rows_h=a_rows_h,
                            a_cols_h=a_cols_h, counts=counts, ks=ks,
                            b_col_space=b_col_space, b_resident=b_resident,
                            b_repl=b_repl,
                            b_other=other if b_resident else None,
                            b_map=b_map, b_rows_h=b_rows_h,
                            b_cols_h=b_cols_h, b_vals_h=b_vals_h,
                            a2a_bounds=a2a_bounds)

    def _b_replicated(self, st: "_MatmulSetup") -> AssocTensor:
        """Replicated reranked B for the replicate strategy (built lazily:
        the sharded strategies never pay for it)."""
        if st.b_repl is None:
            st.b_repl = AssocTensor(
                jnp.asarray(st.b_rows_h, jnp.int32),
                jnp.asarray(st.b_cols_h, jnp.int32),
                jnp.asarray(st.b_vals_h, jnp.float32),
                jnp.int32(len(st.b_rows_h)), st.ks, st.b_col_space, None)
        return st.b_repl

    def _put_sharded(self, tree):
        return jax.tree.map(
            lambda x: jax.device_put(
                jnp.asarray(x),
                NamedSharding(self.mesh,
                              P(*(("data",) + (None,) * (x.ndim - 1))))),
            tree)

    def _a2a_b_operand(self, st: "_MatmulSetup", sr):
        """The sharded-B operand + row rank map for the all_to_all programs.

        A mesh-resident B is reused IN PLACE (its row partition is already
        a contraction partition; the program reranks through ``bm``); any
        other B stages once, split by equal contraction ranges — the same
        bounds the cost model's product table used.
        """
        n_shards = self.mesh.shape["data"]
        if st.b_resident:
            loc = st.b_other.local
            b_dict = {"rows": loc.rows, "cols": loc.cols,
                      "vals": loc.vals.astype(jnp.float32)}
            bm = (jnp.asarray(st.b_map) if len(st.b_map)
                  else jnp.zeros(1, jnp.int32))
            return b_dict, bm
        k = len(st.ks)
        bnds = np.linspace(0, k, n_shards + 1).astype(np.int64)
        idx = np.searchsorted(st.b_rows_h, bnds)
        cap = int(max(8, _round_up(int(np.diff(idx).max(initial=0)) or 1, 8)))
        rows = np.full((n_shards, cap), int(SENT), np.int32)
        cols = np.full((n_shards, cap), int(SENT), np.int32)
        vals = np.full((n_shards, cap), sr.zero, np.float32)
        for s in range(n_shards):
            seg = slice(int(idx[s]), int(idx[s + 1]))
            length = seg.stop - seg.start
            rows[s, :length] = st.b_rows_h[seg]
            cols[s, :length] = st.b_cols_h[seg]
            vals[s, :length] = st.b_vals_h[seg]
        b_dict = self._put_sharded({"rows": rows, "cols": cols,
                                    "vals": vals})
        bm = jnp.arange(max(k, 1), dtype=jnp.int32)  # already merged-space
        return b_dict, bm

    def _stage_b_blocks(self, st: "_MatmulSetup", sr, pr: int, pc: int,
                        block_cap: int):
        """Stage B's contraction blocks for the 2D grid: block ``p`` lands
        on every shard ``(g, p)`` (``pr``-fold staging replication — the
        cost model's ``pr·nnz(B)`` term), SENT/zero-padded to the uniform
        ``block_cap`` so whole blocks ring-shift as one packed array."""
        k = len(st.ks)
        n_shards = pr * pc
        bnds = np.linspace(0, k, pc + 1).astype(np.int64)
        idx = np.searchsorted(st.b_rows_h, bnds)
        rows = np.full((n_shards, block_cap), int(SENT), np.int32)
        cols = np.full((n_shards, block_cap), int(SENT), np.int32)
        vals = np.full((n_shards, block_cap), sr.zero, np.float32)
        for s in range(n_shards):
            blk = s % pc
            seg = slice(int(idx[blk]), int(idx[blk + 1]))
            length = seg.stop - seg.start
            rows[s, :length] = st.b_rows_h[seg]
            cols[s, :length] = st.b_cols_h[seg]
            vals[s, :length] = st.b_vals_h[seg]
        return self._put_sharded({"rows": rows, "cols": cols, "vals": vals})

    def _estimated_out_cap(self, st: "_MatmulSetup", plan) -> int:
        """Per-shard output capacity from shard-local structure.

        The replicate expand size (total products of the worst shard) is a
        correct but hub-pessimal bound; past a threshold it is worth a host
        pass of :func:`repro.core.spgemm.estimate_out_nnz` over each
        shard's own blocks — the sketch can in principle under-estimate,
        so the saturation ``RuntimeWarning`` downstream stays the safety
        net.
        """
        from .spgemm import estimate_out_nnz, plan_matmul
        expand = plan.expands["replicate"]
        if expand <= (1 << 12):
            return expand
        m = len(self.local.row_space)
        k, n = len(st.ks), len(st.b_col_space)
        best = 0
        for s in range(st.a_rows_h.shape[0]):
            mask = st.a_rows_h[s] != int(SENT)
            if not mask.any():
                continue
            p = plan_matmul(st.a_rows_h[s][mask], st.a_cols_h[s][mask],
                            st.b_rows_h, st.b_cols_h, m, k, n, impl="bsr")
            best = max(best, estimate_out_nnz(p))
        return int(min(expand, max(8, _round_up(best or 1, 8))))

    def _matmul_finish(self, out, st: "_MatmulSetup", out_cap: int
                       ) -> "DistAssoc":
        """Shared epilogue: overflow surfacing + result assembly (row
        partition unchanged — every strategy emits row-sharded output)."""
        true_nnz = np.asarray(out.pop("true_nnz"))
        overflowed = bool((true_nnz > out_cap).any())
        if overflowed:
            import warnings
            worst = int(true_nnz.max())
            warnings.warn(
                f"DistAssoc.matmul: a shard produced {worst} entries but "
                f"out_capacity_per_shard is {out_cap}; excess entries were "
                f"dropped — pass a larger out_capacity_per_shard",
                RuntimeWarning, stacklevel=3)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                st.b_col_space, None)
        result = DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)
        result.overflow = overflowed
        return result

    @contract(collectives=0,
              note="replicate strategy: shard-local expand-join, zero "
                   "collectives; sharded-B strategies carry their own "
                   "contracts (dist.matmul_all_to_all / dist.matmul_2d)")
    def matmul(self, other, semiring=PLUS_TIMES, *, impl: str = "auto_dist",
               kernel_impl: str = "auto",
               grid: Optional[Tuple[int, int]] = None,
               out_capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Array multiplication ``A ⊗.⊕ B``, communication-strategy-tuned.

        ``other`` may be an ``AssocTensor``, host ``Assoc``, or another
        ``DistAssoc`` (mesh-resident B is reused in place on the sharded
        paths).  ``impl`` picks the communication strategy:

        ``"auto_dist"`` (default)
            host cost model (:func:`repro.core.spgemm.plan_dist_matmul`)
            chooses per multiply from exact product counts; the choice
            lands in ``PLAN_STATS["dist_replicate"/"dist_all_to_all"/
            "dist_2d"]``.
        ``"replicate"``
            broadcast-B, shard-local product, zero collectives (the
            Graphulo tablet-server pattern).
        ``"all_to_all"``
            B sharded by contraction range; one packed ``all_to_all`` of
            partial products.
        ``"2d"``
            SUMMA-style ``(pr, pc)`` grid (``grid=`` forces it), ``pc−1``
            ring ``ppermute`` shifts of B blocks; A never moves.
        ``"auto"`` / ``"coo"`` / ``"bsr"`` (legacy spelling)
            replicate strategy with that shard-local compute: ``coo`` the
            expand-join program, ``bsr`` the tiled pair-list program
            (``kernel_impl`` forwards to the kernel dispatch), ``auto``
            the ``_BSR_AUTO_EXPAND`` crossover.
        """
        if impl not in ("auto_dist", "replicate", "all_to_all", "2d",
                        "auto", "coo", "bsr"):
            raise ValueError(
                f"unknown DistAssoc matmul impl {impl!r}; expected "
                f"auto_dist/replicate/all_to_all/2d or legacy auto/coo/bsr")
        sr = get_semiring(semiring)
        st = self._matmul_setup(other)
        n_shards = self.mesh.shape["data"]
        plan = plan_dist_matmul(st.a_rows_h, st.a_cols_h, st.counts,
                                st.b_rows_h, len(st.ks), n_shards,
                                b_resident=st.b_resident, grid=grid,
                                a2a_bounds=st.a2a_bounds)
        if impl == "auto_dist":
            strategy, local = plan.strategy, "auto"
        elif impl in ("replicate", "all_to_all", "2d"):
            strategy, local = impl, "auto"
        else:  # legacy spellings pin the replicate strategy's local compute
            strategy, local = "replicate", impl
        from .plan import _bump  # lazy: plan.py imports this module
        _bump(f"dist_{strategy}")
        out_cap = out_capacity_per_shard or self._estimated_out_cap(st, plan)

        if strategy == "all_to_all":
            b_dict, bm = self._a2a_b_operand(st, sr)
            go = _matmul_a2a_prog(self.mesh, sr, plan.expands["all_to_all"],
                                  plan.bucket_cap, out_cap, n_shards)
            out = go(st.a_rows_h, st.a_cols_h, np.asarray(st.a_loc.vals),
                     b_dict, bm, jnp.asarray(self.row_bounds, jnp.int32))
            return self._matmul_finish(out, st, out_cap)
        if strategy == "2d":
            pr, pc = plan.grid
            b_dict = self._stage_b_blocks(st, sr, pr, pc, plan.block_cap)
            a_dict = {"rows": st.a_loc.rows, "cols": st.a_cols,
                      "vals": st.a_loc.vals}
            go = _matmul_ring_prog(self.mesh, sr, pr, pc,
                                   plan.expands["2d"], out_cap)
            out = go(a_dict, b_dict)
            return self._matmul_finish(out, st, out_cap)

        # replicate strategy: coo program vs tiled pair-list program
        expand = plan.expands["replicate"]
        if local == "bsr" or (local == "auto" and expand >= _BSR_AUTO_EXPAND):
            return self._matmul_bsr(st, sr, kernel_impl=kernel_impl,
                                    out_cap=out_cap)
        b = self._b_replicated(st)
        a_dict = {"rows": st.a_loc.rows, "cols": st.a_cols,
                  "vals": st.a_loc.vals}
        go = _matmul_prog(self.mesh, sr, expand, out_cap)
        out = go(a_dict, b.rows, b.cols, b.vals)
        return self._matmul_finish(out, st, out_cap)

    def _matmul_bsr(self, st: "_MatmulSetup", sr, *,
                    kernel_impl: str = "auto", out_cap: int) -> "DistAssoc":
        """Replicate-strategy tiled product as ONE cached shard_map program.

        The per-shard host planning survives (tile-pair lists are cheap
        numpy over rank triples), but execution is a single dispatch of
        :func:`_matmul_bsr_prog` for the whole mesh instead of the old
        eager per-shard planner+kernel loop.  Per-shard plans pad to
        uniform static sizes: invalid A entries scatter out of bounds
        (dropped), dummy pairs accumulate into an extra C slot (discarded),
        padded C blocks land past ``(m, n)`` (filtered).  B's entry→tile
        lists depend only on B's triples, so its packed tiles build once
        and broadcast.
        """
        from .spgemm import pack_tiles, plan_matmul
        n_shards = self.mesh.shape["data"]
        m = len(self.local.row_space)
        k, n = len(st.ks), len(st.b_col_space)
        plans = []
        for s in range(n_shards):
            mask = st.a_rows_h[s] != int(SENT)
            plans.append(plan_matmul(st.a_rows_h[s][mask],
                                     st.a_cols_h[s][mask],
                                     st.b_rows_h, st.b_cols_h,
                                     m, k, n, impl="bsr"))
        n_a = max(max(len(p.a_blocks) for p in plans), 1)
        n_c = max(max(len(p.c_blocks) for p in plans), 1)
        n_pairs = max(max(len(p.pair_a) for p in plans), 1)
        cap_a = st.a_rows_h.shape[1]

        tof = np.full((n_shards, cap_a), n_a, np.int32)   # OOB → dropped
        lr = np.zeros((n_shards, cap_a), np.int32)
        lc = np.zeros((n_shards, cap_a), np.int32)
        pa = np.zeros((n_shards, n_pairs), np.int32)
        pb = np.zeros((n_shards, n_pairs), np.int32)
        pcc = np.full((n_shards, n_pairs), n_c, np.int32)  # dummy C slot
        cblk = np.full((n_shards, n_c, 2), 1 << 20, np.int32)
        for s, p in enumerate(plans):
            ne, np_, nc_ = len(p.a_tile_of), len(p.pair_a), len(p.c_blocks)
            tof[s, :ne] = p.a_tile_of
            lr[s, :ne] = p.a_lr
            lc[s, :ne] = p.a_lc
            pa[s, :np_] = p.pair_a
            pb[s, :np_] = p.pair_b
            pcc[s, :np_] = p.pair_c
            cblk[s, :nc_] = p.c_blocks
        b_tiles = pack_tiles(jnp.asarray(st.b_vals_h, jnp.float32),
                             plans[0].b_tile_of, plans[0].b_lr,
                             plans[0].b_lc, len(plans[0].b_blocks),
                             TILE, TILE, sr.zero)
        sharded = self._put_sharded({"av": np.asarray(st.a_loc.vals),
                                     "tof": tof, "lr": lr, "lc": lc,
                                     "pa": pa, "pb": pb, "pcc": pcc,
                                     "cblk": cblk})
        go = _matmul_bsr_prog(self.mesh, sr, n_a, n_c, m, n, out_cap,
                              kernel_impl)
        out = go(sharded["av"], sharded["tof"], sharded["lr"],
                 sharded["lc"], b_tiles, sharded["pa"], sharded["pb"],
                 sharded["pcc"], sharded["cblk"])
        return self._matmul_finish(out, st, out_cap)

    def __matmul__(self, other):
        # thin wrapper over the one-node graph (see __add__)
        if isinstance(other, (DistAssoc, AssocTensor)) or hasattr(other, "adj"):
            return MatMul(Source(self), Source(other)).collect()
        return NotImplemented

    @contract(collectives=1, note="fused epilogue: exactly one psum-family op")
    def matmul_reduce(self, other, axis: int = 1, semiring=PLUS_TIMES, *,
                      impl: str = "auto_dist") -> jnp.ndarray:
        """Fused ``⊕-reduce(A ⊗.⊕ B, axis)`` — one collective, no C.

        Shards ⊕-fold products straight into a dense vector (no merge, no
        sort — ⊕ over every product per row/col IS the answer) and the
        partials combine with exactly one psum-family collective.
        ``axis=1`` → vector over the row keyspace; ``axis=0`` → vector
        over B's col keyspace.

        ``impl`` follows :meth:`matmul`: ``"replicate"`` broadcasts B and
        each shard folds its own rows' products; ``"all_to_all"`` keeps B
        sharded by contraction range — each shard folds the products of
        ITS block, and the same single collective that merges the partials
        replaces the partial-product exchange, so the sharded variant is
        no chattier.  ``"auto_dist"`` compares the two staging costs (the
        2D path has nothing to add here — there is no C to ring-shift
        for).
        """
        assert axis in (0, 1), axis
        if impl not in ("auto_dist", "replicate", "all_to_all"):
            raise ValueError(
                f"unknown matmul_reduce impl {impl!r}; expected "
                f"auto_dist/replicate/all_to_all")
        sr = get_semiring(semiring)
        st = self._matmul_setup(other)
        n_shards = self.mesh.shape["data"]
        plan = plan_dist_matmul(st.a_rows_h, st.a_cols_h, st.counts,
                                st.b_rows_h, len(st.ks), n_shards,
                                b_resident=st.b_resident,
                                a2a_bounds=st.a2a_bounds)
        if impl == "auto_dist":
            strategy = ("all_to_all"
                        if n_shards > 1 and (plan.costs["all_to_all"]
                                             < plan.costs["replicate"])
                        else "replicate")
        else:
            strategy = impl
        from .plan import _bump  # lazy: plan.py imports this module
        _bump(f"dist_{strategy}")
        n_out = (len(self.local.row_space) if axis == 1
                 else len(st.b_col_space))

        if strategy == "all_to_all":
            b_dict, bm = self._a2a_b_operand(st, sr)
            go = _matmul_reduce_a2a_prog(self.mesh, sr,
                                         plan.expands["all_to_all"],
                                         n_out, axis)
            return go(st.a_rows_h, st.a_cols_h, np.asarray(st.a_loc.vals),
                      b_dict, bm)
        b = self._b_replicated(st)
        a_dict = {"rows": st.a_loc.rows, "cols": st.a_cols,
                  "vals": st.a_loc.vals}
        go = _matmul_reduce_prog(self.mesh, sr, plan.expands["replicate"],
                                 n_out, axis)
        return go(a_dict, b.rows, b.cols, b.vals)

    @contract(collectives=1, note="fused reduce= epilogue (AA^T)")
    def sqout(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AAᵀ — the row-key graph, sharded; ``reduce=0/1`` runs the fused
        epilogue instead (dense vector over the row keyspace, one
        collective)."""
        t = self.gather_replicated().transpose()
        if reduce is None:
            return self.matmul(t, semiring)
        return self.matmul_reduce(t, reduce, semiring)

    @contract(collectives=1, note="fused reduce= epilogue (A^T A)")
    def sqin(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AᵀA — the correlation idiom.  The transpose breaks the row
        partition, so this runs as gathered-Aᵀ × broadcast-A from the
        transposed side: exact, but re-sharding the result is the caller's
        choice; ``reduce=0/1`` for the fused vector."""
        me = self.gather_replicated()
        t = me.transpose()
        if reduce is None:
            return t.matmul(me, semiring)
        return t.matmul_reduce(me, reduce, semiring)

"""Distributed associative arrays: the "Distributed" D of D4M on a mesh.

Historically D4M distributes via Accumulo tablet servers: tables are
row-range-partitioned and algebra pushes down to the servers (Graphulo).
The mesh-native mapping: a ``DistAssoc`` is an ``AssocTensor`` whose COO
triples are **row-rank-range partitioned over the `data` axis** (tablet ↔
shard), and the paper's operations decompose as:

  * element-wise ⊕ / ⊗ — row partitions are disjoint and aligned, so both
    are embarrassingly parallel ``shard_map`` calls (zero collectives);
  * array product ``A ⊗.⊕ B`` — contraction keys live on the row axis of B,
    so with B **broadcast** (replicated triples) each shard computes a
    LOCAL sparse product against its own rows: an expand-join on rank
    triples (:func:`repro.core.coo.expand_join_coo`) plus one canonical
    merge, never densifying.  Row supports are disjoint ⇒ the result is
    row-sharded on the same boundaries with **zero collectives** — the
    Graphulo server-side pattern with the combine elided entirely;
  * fused reductions (``matmul_reduce`` / ``sqout(reduce=)`` / degree) —
    each shard ⊕-folds its products straight into a dense vector and the
    partials merge with exactly **one** psum-family collective
    (:func:`repro.core.semiring.mesh_combine`);
  * global reductions (row/col ⊕-sums) — local segment scatter + the same
    one collective.

Shards keep the full keyspaces (host-side, cheap) and static capacity
``cap / n_shards``; re-sharding for elasticity is a host-side split by
row-rank ranges (same code path the checkpoint restore uses).  Sparse-B
*distribution* strategies (sharding B instead of broadcasting it) are a
ROADMAP follow-on; ``DistAssoc`` operands are transparently gathered to a
replicated ``AssocTensor`` today.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.contracts import contract

from .assoc_tensor import (AssocTensor, DISPATCH_STATS, _bump_dispatch,
                           coo_axis_mask_keep, coo_compact, coo_mask_keep,
                           coo_range_keep)
from .coo import SENT, dedup_sorted_coo, expand_join_coo
from .expr import EwiseAdd, EwiseMul, MatMul, Select, Source
from .keyspace import KeySpace
from .semiring import (PLUS_TIMES, get_semiring, mesh_combine,
                       scatter_combine)
from .spgemm import _round_up, pad_to_cap

__all__ = ["DistAssoc"]


# ---------------------------------------------------------------------------
# Cached shard_map programs.  A bare shard_map call re-traces and re-lowers
# on EVERY invocation (there is no dispatch cache outside jit) — on an
# 8-shard CPU mesh that is seconds per call.  The matmul-family programs are
# pure functions of (mesh, semiring, static sizes), so one lru_cache'd
# jit(shard_map(...)) per signature makes repeated products dispatch-cheap.
# Semiring is a frozen dataclass and Mesh is hashable: both key cleanly.
# ---------------------------------------------------------------------------

_COO_SPEC = ("rows", "cols", "vals")

def _local_coo_spec():
    """PartitionSpec tree of the per-shard COO dict (``_local_spec``'s
    static twin, so cached program builders need no instance)."""
    return {"rows": P("data", None), "cols": P("data", None),
            "vals": P("data", None), "nnz": P("data")}

# auto-strategy crossover for DistAssoc.matmul: below this per-shard
# expand-join size the jit-safe coo shard_map program wins (one fused
# dispatch, no host loop); above it the tiled pair-list strategy's
# O(products-touched) work beats the full expansion buffer
_BSR_AUTO_EXPAND = 1 << 14


@functools.lru_cache(maxsize=256)
def _matmul_prog(mesh: Mesh, sr, expand: int, out_cap: int):
    spec = {k: P("data", None) for k in _COO_SPEC}
    out_spec = {"rows": P("data", None), "cols": P("data", None),
                "vals": P("data", None), "nnz": P("data"),
                "true_nnz": P("data")}

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=out_spec, check_rep=False)
    def go(a, br, bc, bv):
        pr, pc, pv, _ = expand_join_coo(
            a["rows"][0], a["cols"][0], a["vals"][0], br, bc, bv,
            sr.mul, zero=sr.zero, expand=expand)
        r, c, v, nnz = dedup_sorted_coo(pr, pc, pv, sr.add, zero=sr.zero)
        r, c, v = pad_to_cap(r, c, v, out_cap, sr.zero)
        # true (pre-clamp) nnz rides along so the eager caller can surface
        # per-shard capacity overflow instead of truncating silently
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": jnp.minimum(nnz, out_cap)[None],
                "true_nnz": nnz[None]}

    return go


@functools.lru_cache(maxsize=256)
def _matmul_reduce_prog(mesh: Mesh, sr, expand: int, n_out: int, axis: int):
    spec = {k: P("data", None) for k in _COO_SPEC}

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=P(), check_rep=False)
    def go(a, br, bc, bv):
        pr, pc, pv, _ = expand_join_coo(
            a["rows"][0], a["cols"][0], a["vals"][0], br, bc, bv,
            sr.mul, zero=sr.zero, expand=expand)
        keys = pr if axis == 1 else pc
        vec = jnp.full((n_out,), sr.zero, jnp.float32)
        vec = scatter_combine(vec, keys, pv, sr)  # SENT keys drop
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _col_reduce_prog(mesh: Mesh, sr, nc: int, dt):
    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), P("data"), P("data")),
             out_specs=P(), check_rep=False)
    def go(cols, vals, rows):
        ok = rows[0] != SENT
        vec = jnp.full((nc,), sr.zero, dt)
        vec = scatter_combine(vec, jnp.where(ok, cols[0], nc),
                              jnp.where(ok, vals[0], sr.zero), sr)
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _col_degree_prog(mesh: Mesh, nc: int):
    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
             out_specs=P(), check_rep=False)
    def go(cols, rows):
        ok = rows[0] != SENT
        vec = jnp.zeros((nc,), jnp.int32)
        vec = vec.at[jnp.where(ok, cols[0], nc)].add(
            jnp.where(ok, 1, 0).astype(jnp.int32), mode="drop")
        return jax.lax.psum(vec, "data")

    return go


@functools.lru_cache(maxsize=256)
def _matvec_prog(mesh: Mesh, sr, nr: int, dt):
    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(P("data"), P("data"), P("data"), P()),
             out_specs=P(), check_rep=False)
    def go(rows, cols, vals, xv):
        ok = rows[0] != SENT
        contrib = sr.mul(jnp.where(ok, vals[0], sr.zero).astype(dt),
                         xv[jnp.clip(cols[0], 0, xv.shape[0] - 1)]
                         .astype(dt))
        y = jnp.full((nr,), sr.zero, dt)
        y = scatter_combine(y, jnp.where(ok, rows[0], nr),
                            jnp.where(ok, contrib, sr.zero), sr)
        return mesh_combine(y, "data", sr)

    return go


def _shard_selection_keep(a0, row_gather: bool, col_gather: bool,
                          bnds, rm, cm):
    """Shard-local keep mask for a compiled selection — the one dispatch
    body shared by ``__getitem__`` and ``__setitem__`` (range kernel /
    multirange OR / hybrid / double-gather, exactly as
    ``AssocTensor._selection_keep``).  ``bnds`` is the ``[k, 4]`` box list
    from ``select.plan_boxes`` (k static inside the shard_map trace)."""
    if row_gather and col_gather:
        return coo_mask_keep(a0["rows"], a0["cols"], rm, cm)
    keep = coo_range_keep(a0["rows"], a0["cols"], bnds[0])
    for i in range(1, bnds.shape[0]):
        keep = keep | coo_range_keep(a0["rows"], a0["cols"], bnds[i])
    if row_gather:
        keep = keep & coo_axis_mask_keep(a0["rows"], rm)
    if col_gather:
        keep = keep & coo_axis_mask_keep(a0["cols"], cm)
    return keep


@functools.lru_cache(maxsize=256)
def _reduce_add_n_prog(mesh: Mesh, sr, axis: int, n_out: int, n_terms: int):
    """Fused ``⊕-reduce(t₁ ⊕ t₂ ⊕ …, axis)`` over aligned sharded terms.

    The planner's Reduce-through-EwiseAdd rewrite lands here: instead of
    materializing the ⊕-merged array (a concat + sort per shard) and then
    reducing it, every term's triples scatter straight into one dense
    partial vector and the partials merge with exactly **one** psum-family
    collective — same contract as ``_matmul_reduce_prog``.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec,) * n_terms,
             out_specs=P(), check_rep=False)
    def go(*parts):
        vec = jnp.full((n_out,), sr.zero, jnp.float32)
        for p in parts:
            ok = p["rows"][0] != SENT
            keys = p["rows"][0] if axis == 1 else p["cols"][0]
            vec = scatter_combine(vec, jnp.where(ok, keys, n_out),
                                  jnp.where(ok, p["vals"][0], sr.zero), sr)
        return mesh_combine(vec, "data", sr)

    return go


@functools.lru_cache(maxsize=256)
def _select_prog(mesh: Mesh, row_gather: bool, col_gather: bool):
    """Shard-local selection program (``__getitem__``'s executor).

    Cached by dispatch kind only: the box list / masks ride in as traced
    arguments, so every selection with the same (mesh, dispatch) shape
    reuses one compiled program instead of re-tracing a bare shard_map
    per call.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P()),
             out_specs=spec, check_rep=False)
    def go(a, bnds, rm, cm):
        a0 = jax.tree.map(lambda x: x[0], a)
        # same raw-array primitives as AssocTensor — layers cannot drift
        keep = _shard_selection_keep(a0, row_gather, col_gather,
                                     bnds, rm, cm)
        r, c, v, nnz = coo_compact(a0["rows"], a0["cols"], a0["vals"], keep)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": nnz[None]}

    return go


@functools.lru_cache(maxsize=256)
def _setvals_prog(mesh: Mesh, row_gather: bool, col_gather: bool):
    """Selector-targeted value overwrite (``__setitem__``'s executor).

    The scalar rides in as a traced argument — assigning a different
    value hits the same compiled program.
    """
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, P(), P(), P(), P()),
             out_specs=P("data", None), check_rep=False)
    def go(a, bnds, rm, cm, val):
        a0 = jax.tree.map(lambda x: x[0], a)
        keep = _shard_selection_keep(a0, row_gather, col_gather,
                                     bnds, rm, cm)
        return jnp.where(keep, val.astype(a0["vals"].dtype),
                         a0["vals"])[None]

    return go


@functools.lru_cache(maxsize=256)
def _ewise_prog(mesh: Mesh, sr, op: str):
    """Element-wise ⊕ / ⊗ program: disjoint aligned row partitions, so the
    whole operation is one shard-local canonical merge, zero collectives."""
    spec = _local_coo_spec()

    @jax.jit
    @partial(shard_map, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
             check_rep=False)
    def go(a, b):
        # keyspaces are host metadata; inside shard_map the algebra runs
        # on raw rank arrays via the same canonicalization primitive the
        # single-device AssocTensor uses.
        a0 = jax.tree.map(lambda x: x[0], a)
        b0 = jax.tree.map(lambda x: x[0], b)
        rows = jnp.concatenate([a0["rows"], b0["rows"]])
        cols = jnp.concatenate([a0["cols"], b0["cols"]])
        vals = jnp.concatenate([a0["vals"], b0["vals"]])
        if op == "add":
            r, c, v, n = dedup_sorted_coo(rows, cols, vals, sr.add,
                                          zero=sr.zero)
            out = {"rows": r, "cols": c, "vals": v, "nnz": n}
        else:
            src = jnp.concatenate([
                jnp.zeros(a0["rows"].shape[0], jnp.int32),
                jnp.ones(b0["rows"].shape[0], jnp.int32)])
            r, c, v, n = dedup_sorted_coo(
                rows, cols, vals, sr.add, zero=sr.zero,
                require_pair=True, pair_op=sr.mul, src=src)
            cap = min(a0["rows"].shape[0], b0["rows"].shape[0])
            out = {"rows": r[:cap], "cols": c[:cap], "vals": v[:cap],
                   "nnz": jnp.minimum(n, cap)}
        return {"rows": out["rows"][None], "cols": out["cols"][None],
                "vals": out["vals"][None], "nnz": out["nnz"][None]}

    return go


class DistAssoc:
    """Row-partitioned AssocTensor over a mesh's ``data`` axis."""

    # eager metadata default (mirrors AssocTensor.overflow): matmul sets an
    # instance attribute when a shard truncated its result
    overflow = False

    def __init__(self, local: AssocTensor, mesh: Mesh, *,
                 row_bounds: np.ndarray):
        """``local``: stacked per-shard COO [n_shards, cap_local] arrays
        (leading axis sharded over `data`).  ``row_bounds``: shard row-rank
        boundaries, len n_shards+1."""
        self.local = local
        self.mesh = mesh
        self.row_bounds = row_bounds

    # -- construction --------------------------------------------------------
    @staticmethod
    def from_triples(rows, cols, vals, mesh: Mesh, *, aggregate="min",
                     capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        n_shards = mesh.shape["data"]
        row_space = KeySpace(np.asarray(rows))
        col_space = KeySpace(np.asarray(cols))
        r, _ = row_space.rank(np.asarray(rows))
        # contiguous rank ranges (tablet splits)
        bounds = np.linspace(0, len(row_space), n_shards + 1).astype(np.int64)
        shard_of = np.searchsorted(bounds[1:], r, side="right")
        cap = capacity_per_shard or int(
            max(8, np.ceil(max(np.bincount(shard_of, minlength=n_shards).max(), 1) / 8) * 8))

        locs = []
        rows_np, cols_np, vals_np = (np.asarray(rows), np.asarray(cols),
                                     np.asarray(vals))
        for s in range(n_shards):
            m = shard_of == s
            locs.append(AssocTensor.from_triples(
                rows_np[m] if m.any() else rows_np[:0],
                cols_np[m] if m.any() else cols_np[:0],
                vals_np[m] if m.any() else vals_np[:0],
                aggregate=aggregate, capacity=cap,
                row_space=row_space, col_space=col_space))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *locs)
        sharded = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, P(*( ("data",) + (None,) * (x.ndim - 1))))),
            stacked)
        return DistAssoc(sharded, mesh, row_bounds=bounds)

    @staticmethod
    def from_assoc(a, mesh: Mesh, *, aggregate="min",
                   capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Shard a host Assoc over the mesh (host ⇄ device ⇄ dist pipeline)."""
        r, c, v = a.triples()
        return DistAssoc.from_triples(
            r, c, v, mesh, aggregate=aggregate,
            capacity_per_shard=capacity_per_shard)

    # -- conversions -----------------------------------------------------------
    def to_assoc(self):
        """Gather all shards to a host Assoc (small-data paths/tests)."""
        from .assoc import Assoc
        n_shards = self.mesh.shape["data"]
        merged = None
        for s in range(n_shards):
            local = jax.tree.map(lambda x: x[s], self.local)
            a = local.to_assoc()
            merged = a if merged is None else merged + a if a.nnz() else merged
        return merged

    def gather_replicated(self) -> AssocTensor:
        """All shards' triples as ONE replicated device AssocTensor.

        The broadcast-B step of the distributed product: shard row supports
        are disjoint and individually canonical, so the gather is a pure
        re-sort + compaction (:func:`coo_compact`) of the concatenated
        arrays — no ⊕-merge, and crucially no zero-drop: a stored ``0.0``
        (legitimate under min/max-family semirings whose ⊕-identity is
        ±inf) must survive chained products.
        """
        rows = self.local.rows.reshape(-1)
        cols = self.local.cols.reshape(-1)
        vals = self.local.vals.reshape(-1)
        r, c, v, nnz = coo_compact(rows, cols, vals, rows != SENT)
        return AssocTensor(r, c, v, nnz, self.local.row_space,
                           self.local.col_space, self.local.val_space)

    def _local_spec(self):
        """Per-shard COO dict + its shard_map PartitionSpec tree."""
        a_dict = {"rows": self.local.rows, "cols": self.local.cols,
                  "vals": self.local.vals, "nnz": self.local.nnz}
        spec = {k: P(*(("data",) + (None,) * (v.ndim - 1)))
                for k, v in a_dict.items()}
        return a_dict, spec

    # -- element-wise (alignment-free: row ranges are disjoint) -----------------
    def _ewise(self, other: "DistAssoc", op: str, semiring) -> "DistAssoc":
        sr = get_semiring(semiring)
        a_dict, _ = self._local_spec()
        b_dict = {"rows": other.local.rows, "cols": other.local.cols,
                  "vals": other.local.vals, "nnz": other.local.nnz}
        go = _ewise_prog(self.mesh, sr, op)
        out = go(a_dict, b_dict)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    @contract(collectives=0, note="shard-local ⊕: disjoint aligned rows")
    def add(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "add", semiring)

    @contract(collectives=0, note="shard-local ⊗: disjoint aligned rows")
    def mul(self, other, semiring=PLUS_TIMES):
        return self._ewise(other, "mul", semiring)

    def __add__(self, other):
        # thin wrapper over the one-node graph (lazy/eager share one path);
        # expression operands defer to the Node's reflected operator
        if not isinstance(other, DistAssoc):
            return NotImplemented
        return EwiseAdd(Source(self), Source(other)).collect()

    def __mul__(self, other):
        if not isinstance(other, DistAssoc):
            return NotImplemented
        return EwiseMul(Source(self), Source(other)).collect()

    # -- lazy expressions (the deferred pipeline API, repro.core.expr) ----------
    def lazy(self) -> Source:
        """Wrap as a lazy expression Source (see ``Assoc.lazy``)."""
        return Source(self)

    # -- selection (the D4M query surface, sharded) ------------------------------
    def _compiled_selection(self, ij):
        """Compile (row_sel, col_sel) once on host → shard-broadcast forms.

        Shared prologue of ``__getitem__`` and ``__setitem__``: returns
        ``(row_gather, col_gather, bounds, rmask, cmask)`` — the ``[k, 4]``
        rank-box list for the Pallas range kernel (``select.plan_boxes``:
        one box for a contiguous selection, ≤4 OR-composed boxes for a
        multi-interval one) plus membership masks for any scattered axis.
        Dispatch mirrors ``AssocTensor._selection_keep``.
        """
        from .select import compile_selector, plan_boxes

        rc = compile_selector(ij[0], self.local.row_space)
        cc = compile_selector(ij[1], self.local.col_space)
        nr = max(len(self.local.row_space), 1)
        nc = max(len(self.local.col_space), 1)
        boxes, row_gather, col_gather = plan_boxes(rc, cc, nr, nc)
        bounds = jnp.asarray(boxes, jnp.int32)
        rmask = (jnp.asarray(np.pad(rc.mask(), (0, nr - rc.n)))
                 if row_gather else jnp.zeros((1,), bool))
        cmask = (jnp.asarray(np.pad(cc.mask(), (0, nc - cc.n)))
                 if col_gather else jnp.zeros((1,), bool))
        if row_gather and col_gather:
            _bump_dispatch("gather")
        elif len(boxes) > 1:
            _bump_dispatch("multirange")
        elif row_gather or col_gather:
            _bump_dispatch("hybrid")
        else:
            _bump_dispatch("range")
        return row_gather, col_gather, bounds, rmask, cmask

    @contract(collectives=0,
              note="selection is shard-local: compiled boxes/masks broadcast")
    def __getitem__(self, ij) -> "DistAssoc":
        # thin wrapper over the one-node graph (lazy/eager one path)
        i, j = ij
        return Select(Source(self), i, j).collect()

    def _select_eager(self, ij) -> "DistAssoc":
        """D4M selection ``A[row_sel, col_sel]`` on a sharded array.

        The selector compiles **once on host** against the (replicated)
        keyspaces — every selector form the host ``Assoc`` takes works
        here — then executes shard-locally with zero collectives: row
        partitions are disjoint, so each shard masks and compacts its own
        COO triples.  Dispatch mirrors ``AssocTensor._selection_keep``:
        both axes contiguous → the shared Pallas range-mask kernel
        (``repro.kernels.range_extract``); ONE contiguous axis (e.g. a
        single-interval ``Match``/``StartsWith``) → the range kernel for
        that axis plus one membership gather for the other; both scattered
        → two gathers.  Nothing densifies.
        """
        row_gather, col_gather, bounds, rmask, cmask = \
            self._compiled_selection(ij)
        a_dict, _ = self._local_spec()
        go = _select_prog(self.mesh, row_gather, col_gather)
        out = go(a_dict, bounds, rmask, cmask)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                self.local.col_space, self.local.val_space)
        return DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)

    @contract(collectives=0,
              note="scalar assignment is shard-local over stored entries")
    def __setitem__(self, ij, value) -> None:
        """Selector-targeted scalar assignment, sharded (in place).

        The ROADMAP ``DistAssoc.__setitem__`` pushdown, mirroring the
        ``__getitem__`` structure exactly: the selector compiles once on
        host, then each shard overwrites the values of its own *stored*
        entries inside the selection — zero collectives, nothing
        densifies.  Semantics match ``AssocTensor.__setitem__``: numeric
        scalar, support unchanged (inserting new entries is a host-side
        ``from_triples``).
        """
        if (not isinstance(value, (int, float, np.integer, np.floating))
                or isinstance(value, (bool, np.bool_))):
            raise TypeError("DistAssoc __setitem__ takes a numeric scalar")
        if not self.local.numeric:
            raise TypeError("DistAssoc __setitem__ requires numeric values")
        row_gather, col_gather, bounds, rmask, cmask = \
            self._compiled_selection(ij)
        a_dict, _ = self._local_spec()
        go = _setvals_prog(self.mesh, row_gather, col_gather)
        new_vals = go(a_dict, bounds, rmask, cmask, jnp.float32(value))
        self.local = AssocTensor(self.local.rows, self.local.cols, new_vals,
                                 self.local.nnz, self.local.row_space,
                                 self.local.col_space,
                                 self.local.val_space)

    # -- global reductions --------------------------------------------------------
    @contract(collectives=1, note="local segment scatter + one mesh_combine")
    def col_reduce(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕ over rows per column → dense [n_cols] (one collective)."""
        sr = get_semiring(semiring)
        go = _col_reduce_prog(self.mesh, sr, len(self.local.col_space),
                              self.local.vals.dtype)
        return go(self.local.cols, self.local.vals, self.local.rows)

    @contract(collectives=1, note="disjoint-support concat as one collective")
    def row_reduce(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕ over cols per row → dense [n_rows] (one collective).

        Row supports are disjoint, so the psum-family combine is a pure
        concatenation of shard partials; reuses the col-reduce program
        with the row ranks as the scatter keys.
        """
        sr = get_semiring(semiring)
        go = _col_reduce_prog(self.mesh, sr, len(self.local.row_space),
                              self.local.vals.dtype)
        return go(self.local.rows, self.local.vals, self.local.rows)

    @contract(collectives=1, note="one psum of per-shard counts")
    def col_degree(self) -> jnp.ndarray:
        """Stored-entry count per column → dense int32 [n_cols] (one psum).

        The Graphulo degree-table idiom: the logical() + column-⊕ fusion
        runs shard-locally (one segment scatter over the shard's triples)
        and the per-shard partial counts merge with a single ``psum``.
        """
        go = _col_degree_prog(self.mesh, len(self.local.col_space))
        return go(self.local.cols, self.local.rows)

    @contract(collectives=1, note="per-shard y rows + one mesh_combine")
    def matmul_dense_vec(self, x: jnp.ndarray, semiring=PLUS_TIMES) -> jnp.ndarray:
        """y = A ⊗.⊕ x for a dense vector over the column keyspace.

        Row partitions are disjoint: every shard produces its own y rows;
        combining is a concatenation expressed as one psum-family
        collective of disjoint supports (the Graphulo pushdown pattern).
        Accumulates in the promoted values/operand dtype rather than
        hardcoded float32.
        """
        sr = get_semiring(semiring)
        dt = jnp.result_type(self.local.vals.dtype, x.dtype)
        go = _matvec_prog(self.mesh, sr, len(self.local.row_space), dt)
        return go(self.local.rows, self.local.cols, self.local.vals, x)

    # -- array multiplication (Graphulo pushdown, sharded) -----------------------
    def _as_replicated_operand(self, other) -> AssocTensor:
        """Coerce the B operand to a replicated device AssocTensor."""
        from .assoc import Assoc
        if isinstance(other, DistAssoc):
            return other.gather_replicated()
        if isinstance(other, AssocTensor):
            return other
        if isinstance(other, Assoc):
            return other.to_tensor()
        raise TypeError(f"cannot multiply DistAssoc by {type(other)!r}")

    def _matmul_prologue(self, other):
        """Shared setup: logical() strings, align the contraction keyspace,
        and size the per-shard expand-join buffer from exact host counts.

        (Semiring-independent: this is the sharded-A twin of
        ``spgemm._contraction_aligned`` — alignment is pure key/rank work.)
        Returns ``(a_rows, a_cols, a_vals, b, expand)`` where the A arrays
        are the [n_shards, cap] sharded triples with cols reranked onto the
        contraction space and ``b`` is the replicated, reranked B tensor.
        """
        a_loc = self.local.logical() if not self.local.numeric else self.local
        b = self._as_replicated_operand(other)
        b = b.logical() if not b.numeric else b
        ks, a_map, b_map = a_loc.col_space.union(b.row_space)
        b = b.reranked(ks, b.col_space, b_map,
                       np.arange(len(b.col_space), dtype=np.int32))
        ok = a_loc.rows != SENT
        cm = jnp.asarray(a_map) if len(a_map) else jnp.zeros(1, jnp.int32)
        a_cols = jnp.where(ok, cm[jnp.clip(a_loc.cols, 0, cm.shape[0] - 1)],
                           SENT)
        # exact per-shard product counts (host): worst shard sizes the
        # static expansion buffer, so the main path can never overflow
        b_rows_h = np.asarray(b.rows)
        a_cols_h = np.asarray(a_cols)
        a_rows_h = np.asarray(a_loc.rows)
        lo = np.searchsorted(b_rows_h, a_cols_h.ravel(), side="left")
        hi = np.searchsorted(b_rows_h, a_cols_h.ravel(), side="right")
        counts = np.where(a_rows_h.ravel() != int(SENT), hi - lo, 0)
        per_shard = counts.reshape(a_rows_h.shape).sum(axis=1)
        expand = int(max(8, _round_up(int(per_shard.max(initial=0)) or 1, 8)))
        return a_loc.rows, a_cols, a_loc.vals, b, expand

    @contract(collectives=0,
              note="row-sharded A x broadcast B: shard-local expand-join")
    def matmul(self, other, semiring=PLUS_TIMES, *, impl: str = "auto",
               kernel_impl: str = "auto",
               out_capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Array multiplication ``A ⊗.⊕ B`` — row-sharded × broadcast-B.

        Each shard runs a LOCAL sparse product of its rows against the
        replicated B triples; because row supports are disjoint the shard
        outputs ARE the row-sharded result: **zero collectives**, the
        Graphulo tablet-server product.  ``other`` may be an
        ``AssocTensor``, host ``Assoc``, or another ``DistAssoc`` (gathered
        to replicated — sharded-B strategies are a ROADMAP item).

        ``impl`` picks the shard-local strategy: ``"coo"`` is the jit-safe
        expand-join + canonical-merge shard_map program; ``"bsr"`` runs
        each shard through the tiled pair-list strategy of
        :func:`repro.core.spgemm.matmul` (eager host loop over shards,
        results re-stacked onto the same row partition — ``kernel_impl``
        forwards to the pair-list kernel dispatch).  ``"auto"`` stays on
        coo until the per-shard expansion buffer crosses
        ``_BSR_AUTO_EXPAND`` products, where tiling starts to win.
        """
        if impl not in ("auto", "coo", "bsr"):
            raise ValueError(f"unknown DistAssoc matmul impl {impl!r}; "
                             f"expected auto/coo/bsr")
        sr = get_semiring(semiring)
        if impl == "bsr":
            return self._matmul_bsr(other, sr, kernel_impl=kernel_impl,
                                    out_capacity_per_shard=out_capacity_per_shard)
        a_rows, a_cols, a_vals, b, expand = self._matmul_prologue(other)
        if impl == "auto" and expand >= _BSR_AUTO_EXPAND:
            return self._matmul_bsr(other, sr, kernel_impl=kernel_impl,
                                    out_capacity_per_shard=out_capacity_per_shard)
        out_cap = out_capacity_per_shard or expand

        a_dict = {"rows": a_rows, "cols": a_cols, "vals": a_vals}
        go = _matmul_prog(self.mesh, sr, expand, out_cap)
        out = go(a_dict, b.rows, b.cols, b.vals)
        true_nnz = np.asarray(out.pop("true_nnz"))
        overflowed = bool((true_nnz > out_cap).any())
        if overflowed:
            import warnings
            worst = int(true_nnz.max())
            warnings.warn(
                f"DistAssoc.matmul: a shard produced {worst} entries but "
                f"out_capacity_per_shard is {out_cap}; excess entries were "
                f"dropped — pass a larger out_capacity_per_shard",
                RuntimeWarning, stacklevel=2)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], self.local.row_space,
                                b.col_space, None)
        result = DistAssoc(new_local, self.mesh, row_bounds=self.row_bounds)
        result.overflow = overflowed
        return result

    def _matmul_bsr(self, other, sr, *, kernel_impl: str = "auto",
                    out_capacity_per_shard: Optional[int] = None) -> "DistAssoc":
        """Shard-local tiled products through the pair-list BSR strategy.

        Eager host loop: each shard's triples become a standalone
        ``AssocTensor`` and run the full :func:`repro.core.spgemm.matmul`
        planner (tile-pair lists → scalar-prefetch pair-list kernel, or
        its ref/interpret twins per ``kernel_impl``).  Shard row supports
        are disjoint, so the per-shard outputs re-stack onto the SAME row
        partition with zero collectives; capacities are re-padded to the
        max shard before stacking (static shapes stay uniform).
        """
        from .spgemm import matmul as spgemm_matmul
        b = self._as_replicated_operand(other)
        n_shards = self.mesh.shape["data"]
        outs = []
        for s in range(n_shards):
            local = jax.tree.map(lambda x: x[s], self.local)
            outs.append(spgemm_matmul(local, b, sr, impl="bsr",
                                      kernel_impl=kernel_impl,
                                      out_capacity=out_capacity_per_shard))
        cap = max(o.rows.shape[0] for o in outs)
        rows, cols, vals, nnz = [], [], [], []
        for o in outs:
            r, c, v = pad_to_cap(o.rows, o.cols, o.vals, cap, sr.zero)
            rows.append(r); cols.append(c); vals.append(v); nnz.append(o.nnz)
        stacked = AssocTensor(jnp.stack(rows), jnp.stack(cols),
                              jnp.stack(vals), jnp.stack(nnz),
                              self.local.row_space, outs[0].col_space, None)
        sharded = jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(self.mesh,
                                 P(*(("data",) + (None,) * (x.ndim - 1))))),
            stacked)
        result = DistAssoc(sharded, self.mesh, row_bounds=self.row_bounds)
        result.overflow = any(getattr(o, "overflow", False) for o in outs)
        return result

    def __matmul__(self, other):
        # thin wrapper over the one-node graph (see __add__)
        if isinstance(other, (DistAssoc, AssocTensor)) or hasattr(other, "adj"):
            return MatMul(Source(self), Source(other)).collect()
        return NotImplemented

    @contract(collectives=1, note="fused epilogue: exactly one psum-family op")
    def matmul_reduce(self, other, axis: int = 1,
                      semiring=PLUS_TIMES) -> jnp.ndarray:
        """Fused ``⊕-reduce(A ⊗.⊕ B, axis)`` — one collective, no C.

        Shards ⊕-fold their local products straight into a dense vector
        (no merge, no sort — ⊕ over every product per row/col IS the
        answer) and the partials combine with exactly one psum-family
        collective.  ``axis=1`` → vector over the row keyspace (disjoint
        supports: the collective is a concatenation); ``axis=0`` → vector
        over B's col keyspace (true cross-shard ⊕).
        """
        assert axis in (0, 1), axis
        sr = get_semiring(semiring)
        a_rows, a_cols, a_vals, b, expand = self._matmul_prologue(other)
        n_out = (len(self.local.row_space) if axis == 1
                 else len(b.col_space))

        a_dict = {"rows": a_rows, "cols": a_cols, "vals": a_vals}
        go = _matmul_reduce_prog(self.mesh, sr, expand, n_out, axis)
        return go(a_dict, b.rows, b.cols, b.vals)

    @contract(collectives=1, note="fused reduce= epilogue (AA^T)")
    def sqout(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AAᵀ — the row-key graph, sharded; ``reduce=0/1`` runs the fused
        epilogue instead (dense vector over the row keyspace, one
        collective)."""
        t = self.gather_replicated().transpose()
        if reduce is None:
            return self.matmul(t, semiring)
        return self.matmul_reduce(t, reduce, semiring)

    @contract(collectives=1, note="fused reduce= epilogue (A^T A)")
    def sqin(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AᵀA — the correlation idiom.  The transpose breaks the row
        partition, so this runs as gathered-Aᵀ × broadcast-A from the
        transposed side: exact, but re-sharding the result is the caller's
        choice; ``reduce=0/1`` for the fused vector."""
        me = self.gather_replicated()
        t = me.transpose()
        if reduce is None:
            return t.matmul(me, semiring)
        return t.matmul_reduce(me, reduce, semiring)

"""Paper-faithful host implementation of D4M associative arrays.

This module reproduces §II of the paper exactly: an associative array ``A``
is stored via four attributes,

* ``A.row`` — sorted unique row keys with nonempty entries (1-D numpy array),
* ``A.col`` — sorted unique column keys (1-D numpy array),
* ``A.val`` — the float ``1.0`` (numeric case) **or** the sorted unique
  nonempty values (string case),
* ``A.adj`` — a ``scipy.sparse`` matrix of shape ``(len(row), len(col))``;
  in the string case entries are **1-based** pointers into ``A.val``
  (``A[A.row[i], A.col[j]] == A.val[k]  ⟺  A.adj[i, j] == k + 1``).

Algebra follows the paper's approach: element-wise addition re-indexes both
operands onto the *sorted union* of key sets and defers to
``scipy.sparse`` addition; element-wise multiplication restricts to the
*sorted intersection*; array multiplication contracts over
``A.col ∩ B.row`` with native CSR matmul; ``condense()`` drops empty
rows/cols via CSR/CSC ``indptr`` diffs; ``logical()`` replaces nonempty
entries with 1.

All triple canonicalization (constructor aggregation, ``combine``,
assignment) routes through the shared canonical COO core in
``repro.core.coo`` — the same primitive the device ``AssocTensor`` uses —
and the algebra is semiring-generic: :meth:`Assoc.add`, :meth:`Assoc.mul`
and :meth:`Assoc.matmul` accept any registered
:class:`~repro.core.semiring.Semiring` (default ``(+,×)``), so graph idioms
like ``sqin`` run under ``min_plus``/``max_min`` on host exactly as on
device.

This host class is the **reproduction baseline** benchmarked against the
paper's Figs 3–7; the TPU-native ``AssocTensor`` lives in
``assoc_tensor.py``.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence, Tuple, Union

import numpy as np
import scipy.sparse as sp

from .coo import (apply_pair, canonicalize_np, intersect_pairs_np,
                  linearize_pairs_np)
from .expr import EwiseAdd, EwiseMul, MatMul, Select, Source
from .keyspace import KeySpace
from .select import (Selector, compile_selector, sanitize_keys,
                     split_string_list)
from .semiring import PLUS_TIMES, get_semiring
from .sorted_ops import sorted_intersect, sorted_union

__all__ = ["Assoc", "is_string_array"]

KeyLike = Union[str, float, int, Sequence, np.ndarray, slice, Selector]


def _is_str_kind(arr: np.ndarray) -> bool:
    return arr.dtype.kind in ("U", "S", "O")


def is_string_array(arr: np.ndarray) -> bool:
    return _is_str_kind(np.asarray(arr))


# the key-coercion rule is shared with selector parsing (select.Keys)
_sanitize_keys = sanitize_keys


def _broadcast(row, col, val):
    """Broadcast row/col/val to a common length (paper constructor rule)."""
    n = max(len(row), len(col), len(val))
    out = []
    for a in (row, col, val):
        if len(a) == n:
            out.append(a)
        elif len(a) == 1:
            out.append(np.broadcast_to(a, (n,)).copy())
        else:
            raise ValueError(
                f"cannot broadcast lengths {(len(row), len(col), len(val))}")
    return out


class Assoc:
    """D4M associative array (paper-faithful host implementation)."""

    __array_priority__ = 100  # win against numpy binary ops

    # ------------------------------------------------------------------ #
    # construction                                                       #
    # ------------------------------------------------------------------ #
    def __init__(self, row=(), col=(), val=(), aggregate=min, adj=None):
        if adj is not None:
            self._init_from_adj(row, col, val, adj)
            return
        row = _sanitize_keys(row)
        col = _sanitize_keys(col)
        if isinstance(val, (int, float)) and not isinstance(val, bool):
            val = np.full(1, float(val))
        val = _sanitize_keys(val) if not isinstance(val, np.ndarray) else val
        if val.ndim == 0:
            val = val.reshape(1)
        if len(row) == 0 or len(col) == 0 or len(val) == 0:
            self._init_empty()
            return
        row, col, val = _broadcast(row, col, val)

        numeric = not _is_str_kind(val)
        if numeric:
            val = val.astype(np.float64)
            keep = val != 0.0
        else:
            val = val.astype(str)
            keep = val != ""
        row, col, val = row[keep], col[keep], val[keep]
        if len(row) == 0:
            self._init_empty()
            return

        # unique key spaces + integer codes
        self.row, row_codes = np.unique(row, return_inverse=True)
        self.col, col_codes = np.unique(col, return_inverse=True)

        # canonical COO core: lexsort + duplicate-run ⊕-merge + compaction
        r, c, v = canonicalize_np(row_codes, col_codes, val, combine=aggregate)

        if numeric:
            self.val = 1.0
            data = v.astype(np.float64)
        else:
            self.val, v_codes = np.unique(v.astype(str), return_inverse=True)
            data = v_codes.astype(np.float64) + 1.0  # 1-based pointers
        self.adj = sp.coo_matrix(
            (data, (r, c)), shape=(len(self.row), len(self.col)))
        self._drop_zeros_and_condense()

    def _init_from_adj(self, row, col, val, adj):
        """Paper's second constructor: keys + explicit sparse matrix."""
        row = np.unique(_sanitize_keys(row))
        col = np.unique(_sanitize_keys(col))
        adj = sp.coo_matrix(adj)
        if adj.shape[0] > len(row) or adj.shape[1] > len(col):
            raise ValueError("adj larger than provided key sets")
        self.row = row[: adj.shape[0]]
        self.col = col[: adj.shape[1]]
        if isinstance(val, float):
            self.val = 1.0
        else:
            self.val = np.unique(_sanitize_keys(val))
        self.adj = adj
        self._drop_zeros_and_condense()

    def _init_empty(self):
        self.row = np.empty(0, dtype=np.float64)
        self.col = np.empty(0, dtype=np.float64)
        self.val = 1.0
        self.adj = sp.coo_matrix((0, 0))

    @classmethod
    def _from_parts(cls, row, col, val, adj) -> "Assoc":
        a = cls.__new__(cls)
        a.row = np.asarray(row)
        a.col = np.asarray(col)
        a.val = val
        a.adj = adj if sp.issparse(adj) else sp.coo_matrix(adj)
        return a

    @classmethod
    def _assemble(cls, row_keys, col_keys, r, c, v) -> "Assoc":
        """Build from canonical code triples over given key arrays.

        Values are stored exactly — an explicit ``0.0`` that is *not* the
        ambient semiring's zero survives — and empty rows/cols are condensed
        away.  This is the assembly step of the semiring-generic algebra.
        """
        adj = sp.coo_matrix((np.asarray(v, dtype=np.float64), (r, c)),
                            shape=(len(row_keys), len(col_keys)))
        out = cls._from_parts(np.asarray(row_keys), np.asarray(col_keys),
                              1.0, adj)
        out.condense()
        return out

    # ------------------------------------------------------------------ #
    # basic properties                                                   #
    # ------------------------------------------------------------------ #
    @property
    def numeric(self) -> bool:
        return isinstance(self.val, float)

    def nnz(self) -> int:
        return int(self.adj.nnz)

    @property
    def shape(self) -> Tuple[int, int]:
        return (len(self.row), len(self.col))

    def triples(self):
        """Return (row_keys, col_keys, values) of the nonempty entries."""
        coo = self.adj.tocoo()
        rows = self.row[coo.row] if len(self.row) else self.row
        cols = self.col[coo.col] if len(self.col) else self.col
        if self.numeric:
            vals = coo.data.copy()
        else:
            vals = self.val[(coo.data - 1).astype(np.int64)]
        return rows, cols, vals

    def to_dict(self) -> dict:
        r, c, v = self.triples()
        return {(ri, ci): vi for ri, ci, vi in zip(r.tolist(), c.tolist(), v.tolist())}

    def get(self, i, j, default=None):
        d = self.to_dict()
        return d.get((i, j), default)

    # ------------------------------------------------------------------ #
    # cleanup: paper's condense() + explicit-zero elimination            #
    # ------------------------------------------------------------------ #
    def _drop_zeros_and_condense(self):
        adj = self.adj.tocoo()
        if adj.nnz:
            keep = adj.data != 0.0
            if not keep.all():
                adj = sp.coo_matrix(
                    (adj.data[keep], (adj.row[keep], adj.col[keep])),
                    shape=adj.shape)
        self.adj = adj
        self.condense()

    def condense(self) -> "Assoc":
        """Remove empty rows/cols (paper's .condense(), CSR/CSC indptr diff)."""
        csr = self.adj.tocsr()
        csc = self.adj.tocsc()
        csr_rows = csr.indptr
        csc_cols = csc.indptr
        good_rows = csr_rows[:-1] < csr_rows[1:]
        good_cols = csc_cols[:-1] < csc_cols[1:]
        if good_rows.all() and good_cols.all():
            self.adj = csr.tocoo()
            self._remap_vals()
            return self
        self.row = self.row[good_rows]
        self.col = self.col[good_cols]
        self.adj = csr[good_rows, :][:, good_cols].tocoo()
        self._remap_vals()
        return self

    def _remap_vals(self):
        """Shrink .val to the values actually referenced (string case)."""
        if self.numeric or self.adj.nnz == 0:
            if not self.numeric and self.adj.nnz == 0:
                self.val = 1.0  # empty arrays are stored as-if numeric
            return
        codes = (self.adj.data - 1).astype(np.int64)
        used = np.unique(codes)
        if len(used) == len(self.val):
            return
        remap = np.zeros(len(self.val), dtype=np.int64)
        remap[used] = np.arange(len(used))
        self.val = self.val[used]
        self.adj = sp.coo_matrix(
            (remap[codes] + 1.0, (self.adj.row, self.adj.col)),
            shape=self.adj.shape)

    def logical(self) -> "Assoc":
        """Replace every nonempty entry with 1 (paper's .logical())."""
        adj = self.adj.tocoo(copy=True)
        adj.data = np.ones(len(adj.data))
        return Assoc._from_parts(self.row.copy(), self.col.copy(), 1.0, adj)

    # ------------------------------------------------------------------ #
    # lazy expressions (the deferred pipeline API, repro.core.expr)      #
    # ------------------------------------------------------------------ #
    def lazy(self) -> Source:
        """Wrap this array as a lazy expression Source: operators then
        build a graph instead of executing, and ``.collect()`` runs the
        planned pipeline (selector pushdown, matmul→reduce fusion, …)."""
        return Source(self)

    # ------------------------------------------------------------------ #
    # element-wise addition (paper §II.C.1)                              #
    # ------------------------------------------------------------------ #
    def __add__(self, other: "Assoc") -> "Assoc":
        # thin wrapper: build a one-node graph and collect it (the lazy
        # and eager APIs share one execution path; Node operands defer to
        # the expression's reflected operator instead)
        if not isinstance(other, Assoc):
            return NotImplemented
        return EwiseAdd(Source(self), Source(other)).collect()

    def _add_eager(self, other: "Assoc") -> "Assoc":
        """Physical ⊕ under ``(+,×)`` (the executor's host backend)."""
        if self.nnz() == 0:
            return other.copy()
        if other.nnz() == 0:
            return self.copy()
        if self.numeric and other.numeric:
            return self._add_numeric(other)
        if not self.numeric and not other.numeric:
            return self.combine(other, "concat")
        raise TypeError("mixed numeric/string element-wise addition")

    def add(self, other: "Assoc", semiring=PLUS_TIMES) -> "Assoc":
        """Element-wise ⊕ over the union of key sets, semiring-generic.

        With the default ``(+,×)`` this is exactly ``self + other`` (scipy
        fast path, string concatenation).  Any other registered semiring
        runs through the canonical COO core: rank both operands into union
        keyspaces, concatenate triples, ⊕-merge duplicate pairs.
        """
        sr = get_semiring(semiring)
        if not isinstance(other, Assoc):
            raise TypeError("Assoc.add expects an Assoc")
        if sr.name == "plus_times":
            return self._add_eager(other)
        if not (self.numeric and other.numeric):
            raise TypeError("semiring algebra requires numeric arrays")
        if self.nnz() == 0:
            return other.copy()
        if other.nnz() == 0:
            return self.copy()
        rec = self._union_recode(other)
        if rec is None:
            raise TypeError("cannot mix string and numeric key sets")
        row_u, col_u, (ar, ac, acoo), (br, bc, bcoo) = rec
        r, c, v = canonicalize_np(
            np.concatenate([ar, br]), np.concatenate([ac, bc]),
            np.concatenate([acoo.data, bcoo.data]), combine=sr.add_np)
        keep = v != sr.zero
        return Assoc._assemble(row_u, col_u, r[keep], c[keep], v[keep])

    def _add_numeric(self, other: "Assoc") -> "Assoc":
        row_union, ia, ib = sorted_union(self.row, other.row)
        col_union, ja, jb = sorted_union(self.col, other.col)
        a = self._reindexed(ia, ja, (len(row_union), len(col_union)))
        b = other._reindexed(ib, jb, (len(row_union), len(col_union)))
        c_adj_pre = a.tocsr() + b.tocsr()
        out = Assoc._from_parts(row_union, col_union, 1.0, c_adj_pre.tocoo())
        out._drop_zeros_and_condense()
        return out

    def _reindexed(self, imap, jmap, shape) -> sp.coo_matrix:
        coo = self.adj.tocoo()
        return sp.coo_matrix(
            (coo.data, (imap[coo.row], jmap[coo.col])), shape=shape)

    def combine(self, other: "Assoc", binop) -> "Assoc":
        """Triple-append + one canonicalize pass (paper's ``Assoc.combine``).

        ``binop`` is an aggregator understood by the canonical COO core:
        a name (``"min"``/``"max"``/``"sum"``/``"concat"``/``"first"``/
        ``"last"``), a numpy ufunc, or a python callable (slow path).  Both
        operands are re-ranked onto union key spaces (their codes are
        already ranks — no key re-sorting), triples concatenated (self
        first, so order-sensitive ⊕ like concatenation sees self's value on
        the left) and merged in a single vectorized canonicalization — no
        per-element loops.
        """
        if self.nnz() and other.nnz() and self.numeric != other.numeric:
            raise TypeError("combine requires same value kind")
        if self.nnz() == 0:
            return other.copy()
        if other.nnz() == 0:
            return self.copy()
        rec = self._union_recode(other)
        if rec is None:
            raise TypeError("cannot mix string and numeric key sets")
        row_u, col_u, (ar, ac, acoo), (br, bc, bcoo) = rec
        # both operands are canonical ⇒ duplicate runs have length exactly 2
        # and are the support intersection: fold ONLY those pairs, pass the
        # disjoint remainder through untouched.
        ia, ib = intersect_pairs_np(linearize_pairs_np(ar, ac, len(col_u)),
                                    linearize_pairs_np(br, bc, len(col_u)))
        only_a = np.ones(len(ar), dtype=bool)
        only_a[ia] = False
        only_b = np.ones(len(br), dtype=bool)
        only_b[ib] = False

        if self.numeric:
            merged = apply_pair(binop, acoo.data[ia], bcoo.data[ib])
            # drop zeros only among NEWLY merged values: an explicit 0.0
            # already stored by an operand (legit under non-(+,×)
            # semirings) passes through untouched per _assemble's contract
            mkeep = merged != 0.0
            rows = np.concatenate([ar[only_a], br[only_b], ar[ia][mkeep]])
            cols = np.concatenate([ac[only_a], bc[only_b], ac[ia][mkeep]])
            vals = np.concatenate([acoo.data[only_a], bcoo.data[only_b],
                                   merged[mkeep]])
            return Assoc._assemble(row_u, col_u, rows, cols, vals)

        # string case: stay in rank space — non-overlapping entries keep
        # their pointer into the merged value dictionary; only the folded
        # pair values are materialized as strings.
        val_u, vam, vbm = sorted_union(self.val, other.val)
        merged = apply_pair(binop, self.val[(acoo.data[ia] - 1).astype(np.int64)],
                            other.val[(bcoo.data[ib] - 1).astype(np.int64)])
        merged = np.asarray(merged, dtype=str)
        mkeep = merged != ""  # empty string ⇒ unstored (paper rule)
        merged = merged[mkeep]
        # grow the value dictionary with genuinely new folded strings —
        # a small sorted insert, never a re-sort of the full value set
        mu = np.unique(merged)
        pos = np.searchsorted(val_u, mu)
        pos_c = np.clip(pos, 0, max(len(val_u) - 1, 0))
        new_vals = mu[val_u[pos_c] != mu] if len(val_u) else mu
        # concatenate (promotes the string width) + stable timsort merge of
        # the two sorted runs; disjoint by construction ⇒ sorted unique
        val_all = np.concatenate([val_u, new_vals])
        val_all.sort(kind="stable")
        shift = np.searchsorted(new_vals, val_u)  # old rank → new rank offset
        a_ranks = vam[(acoo.data - 1).astype(np.int64)]
        b_ranks = vbm[(bcoo.data - 1).astype(np.int64)]
        a_ranks = a_ranks + shift[a_ranks]
        b_ranks = b_ranks + shift[b_ranks]
        m_ranks = np.searchsorted(val_all, merged)
        rows = np.concatenate([ar[only_a], br[only_b], ar[ia][mkeep]])
        cols = np.concatenate([ac[only_a], bc[only_b], ac[ia][mkeep]])
        data = np.concatenate([a_ranks[only_a], b_ranks[only_b],
                               m_ranks]).astype(np.float64) + 1.0
        adj = sp.coo_matrix((data, (rows, cols)),
                            shape=(len(row_u), len(col_u)))
        out = Assoc._from_parts(row_u, col_u, val_all, adj)
        out.condense()
        return out

    def min(self, other: "Assoc") -> "Assoc":
        return self.combine(other, "min")

    def max(self, other: "Assoc") -> "Assoc":
        return self.combine(other, "max")

    def __sub__(self, other: "Assoc") -> "Assoc":
        if not (self.numeric and other.numeric):
            raise TypeError("subtraction requires numeric associative arrays")
        neg = other.copy()
        adj = neg.adj.tocoo(copy=True)
        adj.data = -adj.data
        neg.adj = adj
        return self + neg

    # ------------------------------------------------------------------ #
    # element-wise multiplication (paper §II.C.2)                        #
    # ------------------------------------------------------------------ #
    def __mul__(self, other: "Assoc") -> "Assoc":
        # thin wrapper over the one-node graph (see __add__)
        if not isinstance(other, Assoc):
            return NotImplemented
        return EwiseMul(Source(self), Source(other)).collect()

    def _mul_eager(self, other: "Assoc") -> "Assoc":
        """Physical ⊗ under ``(+,×)`` (the executor's host backend)."""
        if self.numeric and other.numeric:
            return self._mul_numeric(other)
        if not self.numeric and other.numeric:
            # numeric acts as a mask on the string array (paper)
            return self._mask_by(other)
        if self.numeric and not other.numeric:
            # reduced to the numeric case via .logical() (paper)
            return self._mul_numeric(other.logical())
        # string * string: intersection with ⊗ = min (default aggregator)
        return self._mul_string(other)

    def mul(self, other: "Assoc", semiring=PLUS_TIMES) -> "Assoc":
        """Element-wise ⊗ over the intersection of key sets, semiring-generic.

        Default ``(+,×)`` is exactly ``self * other``.  Other semirings run
        as a rank-based sorted intersection of (row, col) pair codes with ⊗
        applied across each matched pair.
        """
        sr = get_semiring(semiring)
        if not isinstance(other, Assoc):
            raise TypeError("Assoc.mul expects an Assoc")
        if sr.name == "plus_times":
            return self._mul_eager(other)
        if not (self.numeric and other.numeric):
            raise TypeError("semiring algebra requires numeric arrays")
        if self.nnz() == 0 or other.nnz() == 0:
            return Assoc()
        rec = self._union_recode(other)
        if rec is None:
            return Assoc()
        row_u, col_u, (ar, ac, acoo), (br, bc, bcoo) = rec
        ia, ib = intersect_pairs_np(linearize_pairs_np(ar, ac, len(col_u)),
                                    linearize_pairs_np(br, bc, len(col_u)))
        if len(ia) == 0:
            return Assoc()
        v = sr.mul_np(acoo.data[ia], bcoo.data[ib])
        keep = v != sr.zero
        return Assoc._assemble(row_u, col_u, ar[ia][keep], ac[ia][keep],
                               v[keep])

    def _mul_numeric(self, other: "Assoc") -> "Assoc":
        row_int, ia, ib = sorted_intersect(self.row, other.row)
        col_int, ja, jb = sorted_intersect(self.col, other.col)
        if len(row_int) == 0 or len(col_int) == 0:
            return Assoc()
        a = self.adj.tocsr()[ia, :][:, ja]
        b = other.adj.tocsr()[ib, :][:, jb]
        out = Assoc._from_parts(row_int, col_int, 1.0, a.multiply(b).tocoo())
        out._drop_zeros_and_condense()
        return out

    def _union_recode(self, other: "Assoc"):
        """Re-rank both operands' COO codes onto union key spaces.

        Both arrays are canonical, so ``adj`` codes are already ranks into
        their sorted key arrays; one ``sorted_union`` per axis plus a gather
        re-ranks every triple without touching the (possibly string) keys
        again.  Returns ``(row_u, col_u, (ar, ac, acoo), (br, bc, bcoo))``
        or None when the key kinds cannot intersect.
        """
        if (_is_str_kind(self.row) != _is_str_kind(other.row)
                or _is_str_kind(self.col) != _is_str_kind(other.col)):
            return None
        row_u, ram, rbm = sorted_union(self.row, other.row)
        col_u, cam, cbm = sorted_union(self.col, other.col)
        acoo = self.adj.tocoo()
        bcoo = other.adj.tocoo()
        return (row_u, col_u,
                (ram[acoo.row], cam[acoo.col], acoo),
                (rbm[bcoo.row], cbm[bcoo.col], bcoo))

    def _pair_intersect(self, other: "Assoc"):
        """Rank-based sorted intersection of both arrays' (row, col) sets.

        Returns ``(ia, ib)`` positions into the two COO entry lists (or
        None when empty/kind-mismatched) — the vectorized replacement for
        per-element dictionary probing in mask/string multiplication.
        """
        if self.nnz() == 0 or other.nnz() == 0:
            return None
        rec = self._union_recode(other)
        if rec is None:
            return None
        _, col_u, (ar, ac, _), (br, bc, _) = rec
        return intersect_pairs_np(linearize_pairs_np(ar, ac, len(col_u)),
                                  linearize_pairs_np(br, bc, len(col_u)))

    def _mask_by(self, mask: "Assoc") -> "Assoc":
        """Restrict a string array to the support of a numeric mask."""
        hit = self._pair_intersect(mask)
        if hit is None:
            return Assoc()
        ia, _ = hit
        # the result is a sub-array of self: same key/value spaces, subset
        # of adj entries — no re-canonicalization needed
        coo = self.adj.tocoo()
        sub = sp.coo_matrix((coo.data[ia], (coo.row[ia], coo.col[ia])),
                            shape=self.adj.shape)
        out = Assoc._from_parts(
            self.row.copy(), self.col.copy(),
            self.val if self.numeric else self.val.copy(), sub)
        out.condense()
        return out

    def _mul_string(self, other: "Assoc") -> "Assoc":
        """String ⊗ string: pair intersection with ⊗ = min (dict order)."""
        hit = self._pair_intersect(other)
        if hit is None:
            return Assoc()
        ia, ib = hit
        coo_a = self.adj.tocoo()
        coo_b = other.adj.tocoo()
        # decode values only for the matched pairs (the intersection is
        # typically far smaller than either operand), ⊗ = dictionary min
        va = self.val[(coo_a.data[ia] - 1).astype(np.int64)]
        vb = other.val[(coo_b.data[ib] - 1).astype(np.int64)]
        return Assoc(self.row[coo_a.row[ia]], self.col[coo_a.col[ia]],
                     np.where(va <= vb, va, vb))

    # ------------------------------------------------------------------ #
    # array multiplication (paper §II.C.3)                               #
    # ------------------------------------------------------------------ #
    def __matmul__(self, other: "Assoc") -> "Assoc":
        # thin wrapper over the one-node graph (see __add__)
        if not isinstance(other, Assoc):
            return NotImplemented
        return MatMul(Source(self), Source(other)).collect()

    def _matmul_eager(self, other: "Assoc") -> "Assoc":
        """Physical ``⊗.⊕`` under ``(+,×)``: native CSR matmul."""
        a = self.logical() if not self.numeric else self
        b = other.logical() if not other.numeric else other
        inner, ia, ib = sorted_intersect(a.col, b.row)
        if len(inner) == 0:
            return Assoc()
        a_m = a.adj.tocsr()[:, ia]
        b_m = b.adj.tocsr()[ib, :]
        prod = (a_m @ b_m).tocoo()
        out = Assoc._from_parts(a.row, b.col, 1.0, prod)
        out._drop_zeros_and_condense()
        return out

    def matmul(self, other: "Assoc", semiring=PLUS_TIMES) -> "Assoc":
        """Array multiplication ``⊗.⊕`` contracting over ``A.col ∩ B.row``.

        Default ``(+,×)`` is exactly ``self @ other`` (native CSR matmul).
        Other semirings contract through the canonical COO core's
        vectorized sort-merge join (``spgemm_np``) with ⊗ on matched pairs
        and a single ⊕-canonicalize of the expanded products.
        """
        sr = get_semiring(semiring)
        if not isinstance(other, Assoc):
            raise TypeError("Assoc.matmul expects an Assoc")
        if sr.name == "plus_times":
            return self._matmul_eager(other)
        # the one host sort-merge join (shared with the planner's fused
        # select+matmul — this is the keeps=None case)
        from .plan import host_matmul
        return host_matmul(self, None, other, None, sr, None)

    def matmul_reduce(self, other: "Assoc", axis: int = 1,
                      semiring=PLUS_TIMES) -> np.ndarray:
        """Fused ``⊕-reduce(self ⊗.⊕ other, axis)`` — C never materializes.

        The host half of the Graphulo pushdown: since ⊕ is associative and
        commutative, ``⊕_j C[i,j]`` folds directly over the expanded
        semiring products — one CSR-style segment scatter
        (:func:`repro.core.coo.spgemm_reduce_np`) instead of the full
        canonicalize that builds C's triples.  ``(+,×)`` collapses further
        to two sparse matvecs (``A @ (B @ 1)``).  Returns a dense vector
        aligned with ``self.row`` (``axis=1``) or ``other.col``
        (``axis=0``).
        """
        sr = get_semiring(semiring)
        if not isinstance(other, Assoc):
            raise TypeError("Assoc.matmul_reduce expects an Assoc")
        if axis not in (0, 1):
            raise ValueError(f"axis must be 0 or 1, got {axis!r}")
        # the one host sort-merge join + segment scatter (shared with the
        # planner's fused select+matmul_reduce — the keeps=None case)
        from .plan import host_matmul
        return host_matmul(self, None, other, None, sr, axis)

    def sqin(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AᵀA — the paper's correlation idiom (column-key graph).

        ``reduce=0/1`` returns the fused ⊕-reduction of the square
        (a dense vector over ``self.col``) instead of the square itself.
        """
        t = self.transpose()
        if reduce is None:
            return t.matmul(self, semiring)
        return t.matmul_reduce(self, reduce, semiring)

    def sqout(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AAᵀ — row-key graph; ``reduce=0/1`` for the fused reduction
        (a dense vector over ``self.row``)."""
        t = self.transpose()
        if reduce is None:
            return self.matmul(t, semiring)
        return self.matmul_reduce(t, reduce, semiring)

    # ------------------------------------------------------------------ #
    # structural ops                                                     #
    # ------------------------------------------------------------------ #
    def transpose(self) -> "Assoc":
        return Assoc._from_parts(
            self.col.copy(), self.row.copy(),
            self.val if self.numeric else self.val.copy(),
            self.adj.transpose().tocoo())

    @property
    def T(self) -> "Assoc":
        return self.transpose()

    def copy(self) -> "Assoc":
        return Assoc._from_parts(
            self.row.copy(), self.col.copy(),
            self.val if self.numeric else self.val.copy(),
            self.adj.copy())

    def to_tensor(self, *, capacity: Optional[int] = None,
                  row_space=None, col_space=None):
        """Upload to the device :class:`~repro.core.assoc_tensor.AssocTensor`.

        Inverse of ``AssocTensor.to_assoc()``: the round trip is lossless
        for string values (rank pointer scheme) and for numeric values
        representable in float32 — EXCEPT explicit ``0.0`` entries (as
        produced by non-(+,×) semiring algebra, e.g. a zero-cost
        ``min_plus`` path), which the device's 0-is-empty storage drops.
        Pass explicit keyspaces to align the result with other device
        arrays without a re-rank.
        """
        from .assoc_tensor import AssocTensor
        return AssocTensor.from_assoc(self, capacity,
                                      row_space=row_space,
                                      col_space=col_space)

    def sum(self, axis: Optional[int] = None, semiring=PLUS_TIMES):
        """⊕-reduce (default sum) via the shared reduce path in
        :mod:`repro.core.plan` — one host implementation for the Reduce
        node, ``AssocTensor`` and this wrapper, so reduction dtype/zero
        rules live in one place.  Note the Assoc wrapper drops entries
        equal to 0.0 (the paper's unstored value); non-(+,×) reductions
        whose ⊕-identity is not 0 are better consumed through the lazy
        ``.sum()`` vector form."""
        from .plan import host_axis_reduce
        if axis is None:
            return host_axis_reduce(self, None, semiring)
        m = host_axis_reduce(self, axis, semiring)
        if axis == 0:   # column sums → row vector keyed by col
            return Assoc(["sum"], self.col, m)
        return Assoc(self.row, ["sum"], m)  # row sums → column vector

    # ------------------------------------------------------------------ #
    # extraction & assignment (paper §II.B) — via the selector algebra   #
    # ------------------------------------------------------------------ #
    def _axis_space(self, keys: np.ndarray) -> KeySpace:
        """Lazy per-axis KeySpace (row/col arrays are already sorted-unique).

        Cached by array identity: mutation replaces ``self.row``/``self.col``
        wholesale, so an ``is`` check detects staleness.  The KeySpace
        content hash is what makes selector compilation cacheable across
        repeated queries on the same key dictionary.
        """
        cache = getattr(self, "_space_cache", None)
        if cache is None:
            cache = self._space_cache = {}
        slot = "row" if keys is self.row else "col"
        hit = cache.get(slot)
        if hit is not None and hit.keys is keys:
            return hit
        ks = KeySpace.from_sorted_unique(keys)
        cache[slot] = ks
        return ks

    def _resolve_keys(self, sel, keys: np.ndarray) -> np.ndarray:
        """Resolve any selector to sorted integer positions into ``keys``.

        Accepts every D4M index form — explicit lists, positional
        slices/ints, ``'a,:,b,'`` ranges — plus first-class
        :class:`~repro.core.select.Selector` objects
        (``StartsWith``/``Match``/``Where``/``Mask`` and ``&``/``|``/``~``
        compositions), all through one cached compilation path.
        """
        return compile_selector(sel, self._axis_space(keys)).positions()

    def __getitem__(self, ij) -> "Assoc":
        # thin wrapper over the one-node graph (see __add__)
        i, j = ij
        return Select(Source(self), i, j).collect()

    def _select_eager(self, ij) -> "Assoc":
        """Physical selection (the executor's host backend)."""
        i, j = ij
        ri = self._resolve_keys(i, self.row)
        ci = self._resolve_keys(j, self.col)
        if len(ri) == 0 or len(ci) == 0:
            return Assoc()
        sub = self.adj.tocsr()[ri, :][:, ci].tocoo()
        out = Assoc._from_parts(
            self.row[ri], self.col[ci],
            self.val if self.numeric else self.val.copy(), sub)
        out.condense()
        return out

    @staticmethod
    def _is_selector_arg(sel) -> bool:
        """Index forms that *select existing keys* (vs. name new ones).

        Must agree with ``__getitem__``'s reading of the same argument:
        2-tuples are inclusive ranges and bool arrays are masks on both
        sides, so get/set never diverge.  Plain key lists (including
        ``'a,b,'`` strings and numeric arrays) stay on the legacy
        assignment path, which may introduce new keys.
        """
        if isinstance(sel, (Selector, slice)):
            return True
        if isinstance(sel, tuple) and len(sel) == 2:
            return True
        if isinstance(sel, str):
            parts = split_string_list(sel)
            return sel == ":" or (len(parts) == 3 and parts[1] == ":")
        arr = np.asarray(sel)
        return arr.ndim > 0 and arr.dtype.kind == "b"  # mask (list or array)

    def _commit(self, merged: "Assoc") -> None:
        """Adopt another Assoc's state (the single assignment commit step)."""
        self.row, self.col = merged.row, merged.col
        self.val, self.adj = merged.val, merged.adj

    def __setitem__(self, ij, value):
        i, j = ij
        if isinstance(value, Assoc):
            # "last" wins: one canonicalize pass with the assigned triples
            # appended after self's (stable sort keeps them last in each run)
            self._commit(self.combine(value, "last") if self.nnz()
                         else value.copy())
            return
        if self._is_selector_arg(i) or self._is_selector_arg(j):
            # selector-targeted fill: resolve each axis against the existing
            # keys and assign the scalar over the selection's cross product
            rk = (self.row[self._resolve_keys(i, self.row)]
                  if self._is_selector_arg(i) else _sanitize_keys(i))
            ck = (self.col[self._resolve_keys(j, self.col)]
                  if self._is_selector_arg(j) else _sanitize_keys(j))
            if len(rk) == 0 or len(ck) == 0:
                return
            rr, cc = np.meshgrid(rk, ck, indexing="ij")
            patch = Assoc(rr.ravel(), cc.ravel(), np.full(rr.size, value))
            self._commit(self.combine(patch, "last") if self.nnz()
                         else patch.copy())
            return
        r, c, v = self.triples()
        rows = np.concatenate([r.astype(str) if _is_str_kind(r) else r,
                               _sanitize_keys(i)]) if len(r) else _sanitize_keys(i)
        cols = np.concatenate([c.astype(str) if _is_str_kind(c) else c,
                               _sanitize_keys(j)]) if len(c) else _sanitize_keys(j)
        vals = np.concatenate([v, np.asarray([value])]) if len(r) else np.asarray([value])
        self._commit(Assoc(rows, cols, vals, aggregate="last"))

    # ------------------------------------------------------------------ #
    # comparison / display                                               #
    # ------------------------------------------------------------------ #
    def __eq__(self, other) -> bool:  # structural equality of nonempty maps
        if not isinstance(other, Assoc):
            return NotImplemented
        return self.to_dict() == other.to_dict()

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __hash__(self):  # pragma: no cover - dict-keyed usage is unusual
        return id(self)

    def __repr__(self) -> str:
        r, c, v = self.triples()
        lines = [f"Assoc({len(self.row)}x{len(self.col)}, nnz={self.nnz()})"]
        for t, (ri, ci, vi) in enumerate(zip(r, c, v)):
            if t >= 8:
                lines.append(f"  ... ({self.nnz() - 8} more)")
                break
            lines.append(f"  ({ri!r}, {ci!r}) : {vi!r}")
        return "\n".join(lines)

    @staticmethod
    def _labels(arr) -> list:
        """Render keys/values for display: MATLAB ``num2str`` semantics for
        numerics (``1`` not ``1.0``), plain ``str`` for strings."""
        if is_string_array(arr):
            return [str(x) for x in arr.tolist()]
        return ["%.11g" % x for x in arr.tolist()]

    def printfull(self) -> str:
        """Tabular rendering like the paper's Fig. 1.

        Per-column widths come from a single scatter-max pass over the
        nonempty triples (linear in nnz + columns, robust to single-row and
        empty arrays).  Numeric arrays render exactly like string arrays —
        left-justified cells, MATLAB ``num2str`` number formatting — so the
        output matches the MATLAB D4M rendering for both value kinds.
        """
        rows = self._labels(self.row)
        cols = self._labels(self.col)
        coo = self.adj.tocoo()
        _, _, vals = self.triples()
        cells = np.asarray(self._labels(vals), dtype=object)
        widths = np.asarray([len(c) for c in cols], dtype=np.int64)
        if len(cells) and len(widths):
            np.maximum.at(widths, coo.col,
                          np.asarray([len(s) for s in cells], dtype=np.int64))
        grid = np.full((len(rows), len(cols)), "", dtype=object)
        if len(cells):
            grid[coo.row, coo.col] = cells
        rw = max((len(r) for r in rows), default=0)
        out = [" " * rw + "  "
               + "  ".join(c.ljust(int(w)) for c, w in zip(cols, widths))]
        for i, rl in enumerate(rows):
            body = "  ".join(str(grid[i, j]).ljust(int(widths[j]))
                             for j in range(len(cols)))
            out.append(rl.ljust(rw) + "  " + body)
        s = "\n".join(out)
        print(s)
        return s

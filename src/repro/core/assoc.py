"""Paper-faithful host implementation of D4M associative arrays.

This module reproduces §II of the paper exactly: an associative array ``A``
is stored via four attributes,

* ``A.row`` — sorted unique row keys with nonempty entries (1-D numpy array),
* ``A.col`` — sorted unique column keys (1-D numpy array),
* ``A.val`` — the float ``1.0`` (numeric case) **or** the sorted unique
  nonempty values (string case),
* ``A.adj`` — a ``scipy.sparse`` matrix of shape ``(len(row), len(col))``;
  in the string case entries are **1-based** pointers into ``A.val``
  (``A[A.row[i], A.col[j]] == A.val[k]  ⟺  A.adj[i, j] == k + 1``).

Algebra follows the paper's approach: element-wise addition re-indexes both
operands onto the *sorted union* of key sets and defers to
``scipy.sparse`` addition; element-wise multiplication restricts to the
*sorted intersection*; array multiplication contracts over
``A.col ∩ B.row`` with native CSR matmul; ``condense()`` drops empty
rows/cols via CSR/CSC ``indptr`` diffs; ``logical()`` replaces nonempty
entries with 1.

This host class is the **reproduction baseline** benchmarked against the
paper's Figs 3–7; the TPU-native ``AssocTensor`` lives in
``assoc_tensor.py``.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence, Tuple, Union

import numpy as np
import scipy.sparse as sp

from .sorted_ops import sorted_intersect, sorted_union

__all__ = ["Assoc", "is_string_array"]

KeyLike = Union[str, float, int, Sequence, np.ndarray, slice]

# D4M string-list convention: a string whose final character is a separator
# encodes a list, e.g. "a,b,c," == ["a","b","c"];  "a,:,b," is a range.
_SEPARATORS = (",", ";", "\t", "|")


def _is_str_kind(arr: np.ndarray) -> bool:
    return arr.dtype.kind in ("U", "S", "O")


def is_string_array(arr: np.ndarray) -> bool:
    return _is_str_kind(np.asarray(arr))


def _sanitize_keys(keys) -> np.ndarray:
    """Coerce a key argument to a 1-D numpy array of str or float."""
    if isinstance(keys, str):
        keys = _split_string_list(keys)
    arr = np.asarray(keys)
    if arr.ndim == 0:
        arr = arr.reshape(1)
    if _is_str_kind(arr):
        return arr.astype(str)
    return arr.astype(np.float64)


def _split_string_list(s: str):
    if len(s) > 0 and s[-1] in _SEPARATORS:
        sep = s[-1]
        return [p for p in s.split(sep) if p != ""]
    return [s]


def _broadcast(row, col, val):
    """Broadcast row/col/val to a common length (paper constructor rule)."""
    n = max(len(row), len(col), len(val))
    out = []
    for a in (row, col, val):
        if len(a) == n:
            out.append(a)
        elif len(a) == 1:
            out.append(np.broadcast_to(a, (n,)).copy())
        else:
            raise ValueError(
                f"cannot broadcast lengths {(len(row), len(col), len(val))}")
    return out


_AGG_UFUNC = {
    min: np.minimum, max: np.maximum, sum: np.add,
    "min": np.minimum, "max": np.maximum, "sum": np.add, "add": np.add,
    "prod": np.multiply,
}


def _aggregate_sorted_runs(sort_idx, run_starts, vals, aggregate):
    """Aggregate values of duplicate (row,col) runs; vals already sorted."""
    if aggregate in ("first",):
        return vals[run_starts]
    if aggregate in ("last",):
        ends = np.r_[run_starts[1:], len(vals)] - 1
        return vals[ends]
    ufunc = _AGG_UFUNC.get(aggregate)
    if ufunc is not None and vals.dtype.kind in "fiu":
        return ufunc.reduceat(vals, run_starts)
    # generic python-callable aggregator (e.g. string concat)
    fn: Callable = aggregate if callable(aggregate) else {
        "min": min, "max": max, "sum": lambda a, b: a + b,
        "concat": lambda a, b: a + b,
    }[aggregate]
    ends = np.r_[run_starts[1:], len(vals)]
    out = []
    for s, e in zip(run_starts, ends):
        acc = vals[s]
        for t in range(s + 1, e):
            acc = fn(acc, vals[t])
        out.append(acc)
    return np.asarray(out, dtype=vals.dtype if vals.dtype.kind != "U" else object)


class Assoc:
    """D4M associative array (paper-faithful host implementation)."""

    __array_priority__ = 100  # win against numpy binary ops

    # ------------------------------------------------------------------ #
    # construction                                                       #
    # ------------------------------------------------------------------ #
    def __init__(self, row=(), col=(), val=(), aggregate=min, adj=None):
        if adj is not None:
            self._init_from_adj(row, col, val, adj)
            return
        row = _sanitize_keys(row)
        col = _sanitize_keys(col)
        if isinstance(val, (int, float)) and not isinstance(val, bool):
            val = np.full(1, float(val))
        val = _sanitize_keys(val) if not isinstance(val, np.ndarray) else val
        if val.ndim == 0:
            val = val.reshape(1)
        if len(row) == 0 or len(col) == 0 or len(val) == 0:
            self._init_empty()
            return
        row, col, val = _broadcast(row, col, val)

        numeric = not _is_str_kind(val)
        if numeric:
            val = val.astype(np.float64)
            keep = val != 0.0
        else:
            val = val.astype(str)
            keep = val != ""
        row, col, val = row[keep], col[keep], val[keep]
        if len(row) == 0:
            self._init_empty()
            return

        # unique key spaces + integer codes
        self.row, row_codes = np.unique(row, return_inverse=True)
        self.col, col_codes = np.unique(col, return_inverse=True)

        # sort by (row_code, col_code) and aggregate duplicate runs
        order = np.lexsort((col_codes, row_codes))
        r, c, v = row_codes[order], col_codes[order], val[order]
        new_run = np.r_[True, (r[1:] != r[:-1]) | (c[1:] != c[:-1])]
        starts = np.flatnonzero(new_run)
        r, c = r[starts], c[starts]
        v = _aggregate_sorted_runs(order, starts, v, aggregate)

        if numeric:
            self.val = 1.0
            data = v.astype(np.float64)
        else:
            self.val, v_codes = np.unique(v.astype(str), return_inverse=True)
            data = v_codes.astype(np.float64) + 1.0  # 1-based pointers
        self.adj = sp.coo_matrix(
            (data, (r, c)), shape=(len(self.row), len(self.col)))
        self._drop_zeros_and_condense()

    def _init_from_adj(self, row, col, val, adj):
        """Paper's second constructor: keys + explicit sparse matrix."""
        row = np.unique(_sanitize_keys(row))
        col = np.unique(_sanitize_keys(col))
        adj = sp.coo_matrix(adj)
        if adj.shape[0] > len(row) or adj.shape[1] > len(col):
            raise ValueError("adj larger than provided key sets")
        self.row = row[: adj.shape[0]]
        self.col = col[: adj.shape[1]]
        if isinstance(val, float):
            self.val = 1.0
        else:
            self.val = np.unique(_sanitize_keys(val))
        self.adj = adj
        self._drop_zeros_and_condense()

    def _init_empty(self):
        self.row = np.empty(0, dtype=np.float64)
        self.col = np.empty(0, dtype=np.float64)
        self.val = 1.0
        self.adj = sp.coo_matrix((0, 0))

    @classmethod
    def _from_parts(cls, row, col, val, adj) -> "Assoc":
        a = cls.__new__(cls)
        a.row, a.col, a.val, a.adj = row, col, sp.coo_matrix(adj) if not sp.issparse(adj) else adj, None
        a.row = np.asarray(row)
        a.col = np.asarray(col)
        a.val = val
        a.adj = adj if sp.issparse(adj) else sp.coo_matrix(adj)
        return a

    # ------------------------------------------------------------------ #
    # basic properties                                                   #
    # ------------------------------------------------------------------ #
    @property
    def numeric(self) -> bool:
        return isinstance(self.val, float)

    def nnz(self) -> int:
        return int(self.adj.nnz)

    @property
    def shape(self) -> Tuple[int, int]:
        return (len(self.row), len(self.col))

    def triples(self):
        """Return (row_keys, col_keys, values) of the nonempty entries."""
        coo = self.adj.tocoo()
        rows = self.row[coo.row] if len(self.row) else self.row
        cols = self.col[coo.col] if len(self.col) else self.col
        if self.numeric:
            vals = coo.data.copy()
        else:
            vals = self.val[(coo.data - 1).astype(np.int64)]
        return rows, cols, vals

    def to_dict(self) -> dict:
        r, c, v = self.triples()
        return {(ri, ci): vi for ri, ci, vi in zip(r.tolist(), c.tolist(), v.tolist())}

    def get(self, i, j, default=None):
        d = self.to_dict()
        return d.get((i, j), default)

    # ------------------------------------------------------------------ #
    # cleanup: paper's condense() + explicit-zero elimination            #
    # ------------------------------------------------------------------ #
    def _drop_zeros_and_condense(self):
        adj = self.adj.tocoo()
        if adj.nnz:
            keep = adj.data != 0.0
            if not keep.all():
                adj = sp.coo_matrix(
                    (adj.data[keep], (adj.row[keep], adj.col[keep])),
                    shape=adj.shape)
        self.adj = adj
        self.condense()

    def condense(self) -> "Assoc":
        """Remove empty rows/cols (paper's .condense(), CSR/CSC indptr diff)."""
        csr = self.adj.tocsr()
        csc = self.adj.tocsc()
        csr_rows = csr.indptr
        csc_cols = csc.indptr
        good_rows = csr_rows[:-1] < csr_rows[1:]
        good_cols = csc_cols[:-1] < csc_cols[1:]
        if good_rows.all() and good_cols.all():
            self.adj = csr.tocoo()
            self._remap_vals()
            return self
        self.row = self.row[good_rows]
        self.col = self.col[good_cols]
        self.adj = csr[good_rows, :][:, good_cols].tocoo()
        self._remap_vals()
        return self

    def _remap_vals(self):
        """Shrink .val to the values actually referenced (string case)."""
        if self.numeric or self.adj.nnz == 0:
            if not self.numeric and self.adj.nnz == 0:
                self.val = 1.0  # empty arrays are stored as-if numeric
            return
        codes = (self.adj.data - 1).astype(np.int64)
        used = np.unique(codes)
        if len(used) == len(self.val):
            return
        remap = np.zeros(len(self.val), dtype=np.int64)
        remap[used] = np.arange(len(used))
        self.val = self.val[used]
        self.adj = sp.coo_matrix(
            (remap[codes] + 1.0, (self.adj.row, self.adj.col)),
            shape=self.adj.shape)

    def logical(self) -> "Assoc":
        """Replace every nonempty entry with 1 (paper's .logical())."""
        adj = self.adj.tocoo(copy=True)
        adj.data = np.ones(len(adj.data))
        return Assoc._from_parts(self.row.copy(), self.col.copy(), 1.0, adj)

    # ------------------------------------------------------------------ #
    # element-wise addition (paper §II.C.1)                              #
    # ------------------------------------------------------------------ #
    def __add__(self, other: "Assoc") -> "Assoc":
        if not isinstance(other, Assoc):
            return NotImplemented
        if self.nnz() == 0:
            return other.copy()
        if other.nnz() == 0:
            return self.copy()
        if self.numeric and other.numeric:
            return self._add_numeric(other)
        if not self.numeric and not other.numeric:
            return self.combine(other, lambda a, b: a + b)
        raise TypeError("mixed numeric/string element-wise addition")

    def _add_numeric(self, other: "Assoc") -> "Assoc":
        row_union, ia, ib = sorted_union(self.row, other.row)
        col_union, ja, jb = sorted_union(self.col, other.col)
        a = self._reindexed(ia, ja, (len(row_union), len(col_union)))
        b = other._reindexed(ib, jb, (len(row_union), len(col_union)))
        c_adj_pre = a.tocsr() + b.tocsr()
        out = Assoc._from_parts(row_union, col_union, 1.0, c_adj_pre.tocoo())
        out._drop_zeros_and_condense()
        return out

    def _reindexed(self, imap, jmap, shape) -> sp.coo_matrix:
        coo = self.adj.tocoo()
        return sp.coo_matrix(
            (coo.data, (imap[coo.row], jmap[coo.col])), shape=shape)

    def combine(self, other: "Assoc", binop: Callable) -> "Assoc":
        """Triple-append + aggregate (paper's Assoc.combine; string ⊕ etc.)."""
        ra, ca, va = self.triples()
        rb, cb, vb = other.triples()
        if _is_str_kind(va) != _is_str_kind(vb):
            raise TypeError("combine requires same value kind")
        row = np.concatenate([ra.astype(str) if _is_str_kind(ra) else ra,
                              rb.astype(str) if _is_str_kind(rb) else rb])
        col = np.concatenate([ca.astype(str) if _is_str_kind(ca) else ca,
                              cb.astype(str) if _is_str_kind(cb) else cb])
        val = np.concatenate([va, vb])
        return Assoc(row, col, val, aggregate=binop)

    def min(self, other: "Assoc") -> "Assoc":
        return self.combine(other, min)

    def max(self, other: "Assoc") -> "Assoc":
        return self.combine(other, max)

    def __sub__(self, other: "Assoc") -> "Assoc":
        if not (self.numeric and other.numeric):
            raise TypeError("subtraction requires numeric associative arrays")
        neg = other.copy()
        adj = neg.adj.tocoo(copy=True)
        adj.data = -adj.data
        neg.adj = adj
        return self + neg

    # ------------------------------------------------------------------ #
    # element-wise multiplication (paper §II.C.2)                        #
    # ------------------------------------------------------------------ #
    def __mul__(self, other: "Assoc") -> "Assoc":
        if not isinstance(other, Assoc):
            return NotImplemented
        if self.numeric and other.numeric:
            return self._mul_numeric(other)
        if not self.numeric and other.numeric:
            # numeric acts as a mask on the string array (paper)
            return self._mask_by(other)
        if self.numeric and not other.numeric:
            # reduced to the numeric case via .logical() (paper)
            return self._mul_numeric(other.logical())
        # string * string: intersection with ⊗ = min (default aggregator)
        return self._mul_string(other)

    def _mul_numeric(self, other: "Assoc") -> "Assoc":
        row_int, ia, ib = sorted_intersect(self.row, other.row)
        col_int, ja, jb = sorted_intersect(self.col, other.col)
        if len(row_int) == 0 or len(col_int) == 0:
            return Assoc()
        a = self.adj.tocsr()[ia, :][:, ja]
        b = other.adj.tocsr()[ib, :][:, jb]
        out = Assoc._from_parts(row_int, col_int, 1.0, a.multiply(b).tocoo())
        out._drop_zeros_and_condense()
        return out

    def _mask_by(self, mask: "Assoc") -> "Assoc":
        """Restrict a string array to the support of a numeric mask."""
        rm, cm, _ = mask.triples()
        keys_mask = set(zip(rm.tolist(), cm.tolist()))
        r, c, v = self.triples()
        keep = np.fromiter(
            ((ri, ci) in keys_mask for ri, ci in zip(r.tolist(), c.tolist())),
            dtype=bool, count=len(r))
        return Assoc(r[keep], c[keep], v[keep])

    def _mul_string(self, other: "Assoc") -> "Assoc":
        r1, c1, v1 = self.triples()
        r2, c2, v2 = other.triples()
        d2 = {(ri, ci): vi for ri, ci, vi in zip(r2.tolist(), c2.tolist(), v2.tolist())}
        rows, cols, vals = [], [], []
        for ri, ci, vi in zip(r1.tolist(), c1.tolist(), v1.tolist()):
            if (ri, ci) in d2:
                rows.append(ri)
                cols.append(ci)
                vals.append(min(vi, d2[(ri, ci)]))
        return Assoc(rows, cols, vals)

    # ------------------------------------------------------------------ #
    # array multiplication (paper §II.C.3)                               #
    # ------------------------------------------------------------------ #
    def __matmul__(self, other: "Assoc") -> "Assoc":
        if not isinstance(other, Assoc):
            return NotImplemented
        a = self.logical() if not self.numeric else self
        b = other.logical() if not other.numeric else other
        inner, ia, ib = sorted_intersect(a.col, b.row)
        if len(inner) == 0:
            return Assoc()
        a_m = a.adj.tocsr()[:, ia]
        b_m = b.adj.tocsr()[ib, :]
        prod = (a_m @ b_m).tocoo()
        out = Assoc._from_parts(a.row, b.col, 1.0, prod)
        out._drop_zeros_and_condense()
        return out

    def sqin(self) -> "Assoc":
        """AᵀA — the paper's correlation idiom (column-key graph)."""
        return self.transpose() @ self

    def sqout(self) -> "Assoc":
        """AAᵀ — row-key graph."""
        return self @ self.transpose()

    # ------------------------------------------------------------------ #
    # structural ops                                                     #
    # ------------------------------------------------------------------ #
    def transpose(self) -> "Assoc":
        return Assoc._from_parts(
            self.col.copy(), self.row.copy(),
            self.val if self.numeric else self.val.copy(),
            self.adj.transpose().tocoo())

    @property
    def T(self) -> "Assoc":
        return self.transpose()

    def copy(self) -> "Assoc":
        return Assoc._from_parts(
            self.row.copy(), self.col.copy(),
            self.val if self.numeric else self.val.copy(),
            self.adj.copy())

    def sum(self, axis: Optional[int] = None):
        a = self if self.numeric else self.logical()
        if axis is None:
            return float(a.adj.sum())
        m = np.asarray(a.adj.sum(axis=axis)).ravel()
        if axis == 0:   # column sums → row vector keyed by col
            return Assoc(["sum"], a.col, m)
        return Assoc(a.row, ["sum"], m)  # row sums → column vector

    # ------------------------------------------------------------------ #
    # extraction & assignment (paper §II.B)                              #
    # ------------------------------------------------------------------ #
    def _resolve_keys(self, sel, keys: np.ndarray) -> np.ndarray:
        """Resolve a selector to integer positions into ``keys``."""
        n = len(keys)
        if isinstance(sel, slice):          # positional (paper rule 2)
            return np.arange(n)[sel]
        if isinstance(sel, (int, np.integer)) and not isinstance(sel, bool):
            return np.asarray([int(sel)])
        if isinstance(sel, str):
            if sel == ":":
                return np.arange(n)
            parts = _split_string_list(sel)
            if len(parts) == 3 and parts[1] == ":":
                lo, hi = parts[0], parts[2]
                # right-INCLUSIVE string slice (paper rule 1)
                lo_i = np.searchsorted(keys.astype(str), lo, side="left")
                hi_i = np.searchsorted(keys.astype(str), hi, side="right")
                return np.arange(lo_i, hi_i)
            sel = parts
        arr = np.asarray(sel)
        if arr.dtype.kind in "iu" and not isinstance(sel, np.ndarray):
            arr = arr  # lists of ints are positional too (paper rule 2)
            return arr.ravel()
        if _is_str_kind(arr):
            pos = np.searchsorted(keys.astype(str), arr.astype(str))
            pos = np.clip(pos, 0, max(n - 1, 0))
            hit = keys.astype(str)[pos] == arr.astype(str) if n else np.zeros(arr.shape, bool)
            return pos[hit]
        # numeric key membership
        pos = np.searchsorted(keys, arr)
        pos = np.clip(pos, 0, max(n - 1, 0))
        hit = keys[pos] == arr if n else np.zeros(arr.shape, bool)
        return pos[hit]

    def __getitem__(self, ij) -> "Assoc":
        i, j = ij
        ri = self._resolve_keys(i, self.row)
        ci = self._resolve_keys(j, self.col)
        if len(ri) == 0 or len(ci) == 0:
            return Assoc()
        sub = self.adj.tocsr()[ri, :][:, ci].tocoo()
        out = Assoc._from_parts(
            self.row[ri], self.col[ci],
            self.val if self.numeric else self.val.copy(), sub)
        out.condense()
        return out

    def __setitem__(self, ij, value):
        i, j = ij
        if isinstance(value, Assoc):
            merged = self.combine(value, lambda a, b: b) if self.nnz() else value.copy()
            self.row, self.col = merged.row, merged.col
            self.val, self.adj = merged.val, merged.adj
            return
        r, c, v = self.triples()
        rows = np.concatenate([r.astype(str) if _is_str_kind(r) else r,
                               _sanitize_keys(i)]) if len(r) else _sanitize_keys(i)
        cols = np.concatenate([c.astype(str) if _is_str_kind(c) else c,
                               _sanitize_keys(j)]) if len(c) else _sanitize_keys(j)
        vals = np.concatenate([v, np.asarray([value])]) if len(r) else np.asarray([value])
        merged = Assoc(rows, cols, vals, aggregate="last")
        self.row, self.col = merged.row, merged.col
        self.val, self.adj = merged.val, merged.adj

    # ------------------------------------------------------------------ #
    # comparison / display                                               #
    # ------------------------------------------------------------------ #
    def __eq__(self, other) -> bool:  # structural equality of nonempty maps
        if not isinstance(other, Assoc):
            return NotImplemented
        return self.to_dict() == other.to_dict()

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __hash__(self):  # pragma: no cover - dict-keyed usage is unusual
        return id(self)

    def __repr__(self) -> str:
        r, c, v = self.triples()
        lines = [f"Assoc({len(self.row)}x{len(self.col)}, nnz={self.nnz()})"]
        for t, (ri, ci, vi) in enumerate(zip(r, c, v)):
            if t >= 8:
                lines.append(f"  ... ({self.nnz() - 8} more)")
                break
            lines.append(f"  ({ri!r}, {ci!r}) : {vi!r}")
        return "\n".join(lines)

    def printfull(self) -> str:
        """Tabular rendering like the paper's Fig. 1."""
        d = self.to_dict()
        cols = [str(x) for x in self.col.tolist()]
        widths = {c: max(len(c), *(len(str(d.get((r, rc), ""))) for r, rc in
                  ((rr, cc) for rr in self.row.tolist() for cc in [c2 for c2 in self.col.tolist() if str(c2) == c])))
                  for c in cols} if len(self.row) else {c: len(c) for c in cols}
        rw = max((len(str(r)) for r in self.row.tolist()), default=0)
        out = [" " * rw + "  " + "  ".join(c.ljust(widths[c]) for c in cols)]
        for r in self.row.tolist():
            cells = [str(d.get((r, c), "")).ljust(widths[str(c)]) for c in self.col.tolist()]
            out.append(str(r).ljust(rw) + "  " + "  ".join(cells))
        s = "\n".join(out)
        print(s)
        return s

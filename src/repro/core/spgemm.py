"""Graphulo-style sparse matmul planner: the one engine behind ``⊗.⊕``.

"D4M: Bringing Associative Arrays to Database Engines" (Graphulo) showed
that associative-array multiplication scales by pushing the semiring
contraction — and the reduction that usually follows it — down to the
sparse storage layer instead of materializing dense intermediates.  This
module is that pushdown for the device layer: it plans every
``A ⊗.⊕ B`` on the **host** (block structure, strategy choice, product
counts — all cheap numpy over the operands' rank triples) and executes it
on device under one of three strategies:

``dense``
    Densify both operands onto MXU-aligned adjacency tiles and contract
    with the Pallas semiring matmul.  Peak memory O(M·K + K·N + M·N) —
    unbeatable for small or genuinely dense operands, hopeless at scale.
``bsr``
    Block-tiled sparse path: pack only the **present** 128×128 tiles of
    each operand (COO → block mask + packed tiles), contract the planned
    tile-pair list with the scalar-prefetch Pallas kernel
    (:mod:`repro.kernels.bsr_spgemm.pairlist`: the pair list rides in SMEM
    and drives the DMA schedule — no gathered tile copies — with the
    ⊕-scatter fused into VMEM-resident output tiles; jitted chunked-einsum
    oracle off-TPU), and emit the result COO **directly from the tiles** —
    no |rowspace|×|colspace| dense product and no full-space argsort ever
    exist.  Peak memory is bounded by the present tiles plus the output
    COO, which is sized by :func:`estimate_out_nnz` rather than the raw
    product count.
``coo``
    Expand-join on raw rank triples (:func:`repro.core.coo.expand_join_coo`
    + one canonical merge).  Fully jit/shard_map-safe — this is the
    strategy ``DistAssoc`` shards run — and the right choice when operands
    are tiny or the caller is inside a trace.

Strategy choice (``impl="auto"``) compares modeled footprints::

    dense_cost = Mp·Kp + Kp·Np + Mp·Np          (padded dense operands + C)
    bsr_cost   = (nA + nB + nPairs + 2·nC) · T  (packed tiles, T = 128²)

and picks ``bsr`` iff it is strictly cheaper — i.e. exactly when the tile
occupancy is low enough that skipping empty tiles beats the dense MXU
sweep.  ``impl=`` overrides the choice per call.  ``auto`` never picks
``coo``: its sequential-expansion layout loses to tiles on device except
under jit, where the caller knows to ask for it.

The fused epilogues (:func:`matmul_reduce`) compute row/column
⊕-reductions of ``A ⊗.⊕ B`` — the ``sqin``/``sqout``/degree family —
without materializing C on **any** path: the dense strategy runs the fused
``bsr_spgemm_reduce`` Pallas kernel (reduction accumulated in VMEM), the
bsr strategy folds tile products straight into a vector of length M (or
N).  Planning is host-side and eager by design: keyspace unions already
happen on host, so the plan adds one numpy pass over the triples.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract

from .coo import SENT, dedup_sorted_coo, expand_join_coo
from .semiring import PLUS_TIMES, Semiring, get_semiring, scatter_combine

__all__ = ["MatmulPlan", "plan_matmul", "matmul", "matmul_reduce",
           "bsr_matmul_coo", "pack_tiles", "estimate_out_nnz", "TILE",
           "DistPlan", "plan_dist_matmul", "suggest_grid"]

TILE = 128  # MXU-aligned block edge: bm = bk = bn = 128

# tile-pairs contracted per device dispatch; the MXU einsum touches
# chunk·(bm·bk + bk·bn + bm·bn) floats, the VPU path adds a [chunk, bm, 32,
# bn] broadcast slab — both bounded to a few tens of MiB
_CHUNK_MXU = 64
_CHUNK_VPU = 8


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class MatmulPlan:
    """Host-side execution plan for one ``A ⊗.⊕ B``.

    Block structure is expressed per *valid entry* (tile id + intra-tile
    coords, the scatter targets for tile packing) and per *tile pair*
    (which A tile meets which B tile, accumulating into which C tile).
    The pair lists are **grouped by ``pair_c``** (sorted ascending) — the
    scalar-prefetch kernel's VMEM-resident output accumulation depends on
    each C tile's pairs being one contiguous run.  ``products`` is the
    exact scalar product count — an upper bound on nnz(C); the default
    output sizing tightens it via :func:`estimate_out_nnz`.
    """

    impl: str                    # chosen strategy: "dense" | "bsr"
    m: int
    k: int
    n: int
    # A entries → packed tiles
    a_tile_of: np.ndarray
    a_lr: np.ndarray
    a_lc: np.ndarray
    a_blocks: np.ndarray         # [nA, 2] (block-row, block-k)
    # B entries → packed tiles
    b_tile_of: np.ndarray
    b_lr: np.ndarray
    b_lc: np.ndarray
    b_blocks: np.ndarray         # [nB, 2] (block-k, block-col)
    # tile-pair contraction list
    pair_a: np.ndarray
    pair_b: np.ndarray
    pair_c: np.ndarray
    c_blocks: np.ndarray         # [nC, 2] (block-row, block-col)
    products: int
    dense_cost: int
    bsr_cost: int


def pad_to_cap(r: jnp.ndarray, c: jnp.ndarray, v: jnp.ndarray,
               cap: int, zero: float):
    """Slice canonical triples to ``cap`` and sentinel-pad the tail."""
    r, c, v = r[:cap], c[:cap], v[:cap]
    pad = cap - r.shape[0]
    if pad > 0:
        r = jnp.concatenate([r, jnp.full(pad, SENT, jnp.int32)])
        c = jnp.concatenate([c, jnp.full(pad, SENT, jnp.int32)])
        v = jnp.concatenate([v, jnp.full(pad, zero, v.dtype)])
    return r, c, v


def _densify_aligned(a, b, sr: Semiring):
    """Dense-strategy prologue: both adjs on MXU tiles, K widths matched."""
    da = a.to_dense_adj(zero=sr.zero)
    db = b.to_dense_adj(zero=sr.zero)
    kk = max(da.shape[1], db.shape[0])
    da = jnp.pad(da, ((0, 0), (0, kk - da.shape[1])),
                 constant_values=sr.zero)
    db = jnp.pad(db, ((0, kk - db.shape[0]), (0, 0)),
                 constant_values=sr.zero)
    return da, db


def _exact_products(a_k: np.ndarray, b_k: np.ndarray, k: int) -> int:
    """Exact scalar product count: ⟨per-k nnz of A, per-k nnz of B⟩."""
    if k == 0 or len(a_k) == 0 or len(b_k) == 0:
        return 0
    return int(np.bincount(a_k, minlength=k).astype(np.int64)
               @ np.bincount(b_k, minlength=k).astype(np.int64))


def _entry_blocks(rows: np.ndarray, cols: np.ndarray, bm: int, bk: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-entry tile assignment: (tile_of, local_r, local_c, blocks[nT, 2])."""
    bi = rows // bm
    bj = cols // bk
    codes = bi.astype(np.int64) * (2 ** 31) + bj
    uniq, tile_of = np.unique(codes, return_inverse=True)
    blocks = np.stack([(uniq // (2 ** 31)).astype(np.int32),
                       (uniq % (2 ** 31)).astype(np.int32)], axis=1)
    return tile_of.astype(np.int32), (rows % bm).astype(np.int32), \
        (cols % bk).astype(np.int32), blocks


def plan_matmul(a_rows: np.ndarray, a_cols: np.ndarray,
                b_rows: np.ndarray, b_cols: np.ndarray,
                m: int, k: int, n: int, *, impl: str = "auto",
                bm: int = TILE, bk: int = TILE, bn: int = TILE) -> MatmulPlan:
    """Plan ``C[i,j] = ⊕_k A[i,k] ⊗ B[k,j]`` over *valid* host rank triples.

    ``a_rows/a_cols`` are A's (row, contraction) codes, ``b_rows/b_cols``
    B's (contraction, col) codes — valid entries only, no sentinels.  See
    the module docstring for the strategy heuristic.
    """
    a_tile_of, a_lr, a_lc, a_blocks = _entry_blocks(a_rows, a_cols, bm, bk)
    b_tile_of, b_lr, b_lc, b_blocks = _entry_blocks(b_rows, b_cols, bk, bn)

    # tile-pair join on the contraction block: B blocks are sorted by
    # (block-k, block-col) already (np.unique), A blocks by (block-row,
    # block-k) — sort A's k column for the merge
    a_k = a_blocks[:, 1]
    b_k = b_blocks[:, 0]
    a_ord = np.argsort(a_k, kind="stable")
    lo = np.searchsorted(b_k, a_k[a_ord], side="left")
    hi = np.searchsorted(b_k, a_k[a_ord], side="right")
    counts = hi - lo
    total = int(counts.sum())
    pair_a = np.repeat(a_ord, counts).astype(np.int32)
    run_base = np.repeat(np.cumsum(counts) - counts, counts)
    pair_b = (np.repeat(lo, counts)
              + (np.arange(total) - run_base)).astype(np.int32)
    c_codes = (a_blocks[pair_a, 0].astype(np.int64) * (2 ** 31)
               + b_blocks[pair_b, 1])
    c_uniq, pair_c = np.unique(c_codes, return_inverse=True)
    c_blocks = np.stack([(c_uniq // (2 ** 31)).astype(np.int32),
                         (c_uniq % (2 ** 31)).astype(np.int32)], axis=1)
    # group pairs by output tile (sorted pair_c): the scalar-prefetch
    # kernel keeps each C tile VMEM-resident across its contiguous run of
    # pairs and flushes it exactly once — see kernels/bsr_spgemm/pairlist
    order = np.argsort(pair_c, kind="stable")
    pair_a, pair_b, pair_c = pair_a[order], pair_b[order], pair_c[order]

    products = _exact_products(a_cols, b_rows, k)

    t = bm * bk
    dense_cost = (_round_up(max(m, 1), bm) * _round_up(max(k, 1), bk)
                  + _round_up(max(k, 1), bk) * _round_up(max(n, 1), bn)
                  + _round_up(max(m, 1), bm) * _round_up(max(n, 1), bn))
    bsr_cost = (len(a_blocks) + len(b_blocks) + total + 2 * len(c_blocks)) * t
    if impl == "auto":
        impl = "bsr" if bsr_cost < dense_cost else "dense"
    return MatmulPlan(impl=impl, m=m, k=k, n=n,
                      a_tile_of=a_tile_of, a_lr=a_lr, a_lc=a_lc,
                      a_blocks=a_blocks,
                      b_tile_of=b_tile_of, b_lr=b_lr, b_lc=b_lc,
                      b_blocks=b_blocks,
                      pair_a=pair_a, pair_b=pair_b,
                      pair_c=pair_c.astype(np.int32), c_blocks=c_blocks,
                      products=products,
                      dense_cost=dense_cost, bsr_cost=bsr_cost)


# distinct-(i,j) sketch sizing: a 1<<20-bin bitmap costs 1 MiB host memory;
# candidate enumeration is skipped past the budget (the cheap bounds win)
_SKETCH_BINS = 1 << 20
_SKETCH_BUDGET = 1 << 22
_EXACT_BITSET_MAX = 1 << 22


def estimate_out_nnz(plan: MatmulPlan, *, budget: int = _SKETCH_BUDGET,
                     bins: int = _SKETCH_BINS) -> int:
    """Upper-bound estimate of ``nnz(C)`` — what ``out_capacity`` defaults to.

    The exact product count over-sizes hub-heavy outputs by orders of
    magnitude (every product through a hub row lands on the same few
    cells).  This estimator tightens it with three *provable* bounds plus
    one sketch:

    1. ``m·n`` and ``products`` (the old default);
    2. present C tiles × tile area;
    3. ``Σ_pairs |distinct rows(A tile)| · |distinct cols(B tile)|`` — every
       nonzero of C lies in some pair's candidate rectangle;
    4. when the candidate enumeration fits ``budget``: the exact distinct
       candidate count via a bitset (small keyspaces — still a provable
       bound), else a linear-counting hash sketch over the candidate
       ``(i, j)`` codes, inflated 1.25× for collision slack.

    Only (4)'s hashed variant can in principle under-estimate; a saturated
    sketch (≥98% bins set) warns and falls back to the provable bounds —
    and the downstream overflow warning in :func:`bsr_matmul_coo` remains
    the safety net.
    """
    if len(plan.pair_a) == 0:
        return 0
    m, n = max(plan.m, 1), max(plan.n, 1)
    bound = min(plan.products, m * n,
                len(plan.c_blocks) * TILE * TILE)
    # per-tile distinct local rows (A) / local cols (B)
    a_codes = np.unique(plan.a_tile_of.astype(np.int64) * TILE + plan.a_lr)
    b_codes = np.unique(plan.b_tile_of.astype(np.int64) * TILE + plan.b_lc)
    a_starts = np.searchsorted(a_codes // TILE,
                               np.arange(len(plan.a_blocks) + 1))
    b_starts = np.searchsorted(b_codes // TILE,
                               np.arange(len(plan.b_blocks) + 1))
    pa, pb = plan.pair_a, plan.pair_b
    n_rows = a_starts[pa + 1] - a_starts[pa]
    n_cols = b_starts[pb + 1] - b_starts[pb]
    cross = int((n_rows.astype(np.int64) * n_cols).sum())
    bound = min(bound, cross)
    if cross > budget or bound <= 4096:
        return bound

    # enumerate candidate (i, j) codes pair by pair into a bitmap
    hashed = m * n > _EXACT_BITSET_MAX
    bits = np.zeros(bins if hashed else m * n, dtype=bool)
    a_loc = (a_codes % TILE).astype(np.int64)
    b_loc = (b_codes % TILE).astype(np.int64)
    for p in range(len(pa)):
        rows = (a_loc[a_starts[pa[p]]:a_starts[pa[p] + 1]]
                + int(plan.a_blocks[pa[p], 0]) * TILE)
        cols = (b_loc[b_starts[pb[p]]:b_starts[pb[p] + 1]]
                + int(plan.b_blocks[pb[p], 1]) * TILE)
        codes = rows[:, None] * n + cols[None, :]
        if hashed:
            codes = (codes.astype(np.uint64)
                     * np.uint64(0x9E3779B97F4A7C15)) % np.uint64(bins)
        bits[codes.ravel()] = True
    hit = int(bits.sum())
    if not hashed:
        return min(bound, hit)  # exact distinct candidates: provable bound
    empty = bits.size - hit
    if empty < bits.size * 0.02:
        warnings.warn(
            f"estimate_out_nnz: distinct-pair sketch saturated "
            f"({hit}/{bits.size} bins); falling back to the exact product "
            f"count bound", RuntimeWarning, stacklevel=2)
        return bound
    est = bits.size * np.log(bits.size / empty)  # linear counting
    return min(bound, int(est * 1.25) + 64)


def pack_tiles(vals: jnp.ndarray, tile_of: np.ndarray, lr: np.ndarray,
               lc: np.ndarray, n_tiles: int, br: int, bc: int,
               zero: float) -> jnp.ndarray:
    """Scatter valid COO values into packed dense tiles [n_tiles, br, bc]."""
    tiles = jnp.full((max(n_tiles, 1), br, bc), zero, jnp.float32)
    if len(tile_of) == 0:
        return tiles
    return tiles.at[jnp.asarray(tile_of), jnp.asarray(lr),
                    jnp.asarray(lc)].set(vals)


def _warn_overflow(true_nnz: int, capacity: int, what: str) -> None:
    warnings.warn(
        f"{what}: result has {true_nnz} entries but capacity {capacity}; "
        f"{true_nnz - capacity} entries were dropped — pass a larger "
        f"out_capacity", RuntimeWarning, stacklevel=3)


def bsr_matmul_coo(plan: MatmulPlan, a_vals: jnp.ndarray, b_vals: jnp.ndarray,
                   sr: Semiring, out_capacity: int, *,
                   kernel_impl: str = "auto",
                   bm: int = TILE, bk: int = TILE, bn: int = TILE):
    """Execute the BSR strategy: packed tiles in, canonical COO out.

    The pair-list contraction dispatches through
    :func:`repro.kernels.bsr_spgemm.ops.bsr_pairlist` — the scalar-prefetch
    Pallas kernel on TPU (tile pairs DMA'd straight from their packed slots,
    ⊕-scatter fused into VMEM-resident C tiles), the jitted chunked-einsum
    oracle elsewhere.  ``kernel_impl`` forwards to that dispatch
    (``"interpret"`` exercises the kernel body on CPU); ``"chunked"`` keeps
    the legacy eager host-chunked loop (perf baseline).

    Returns ``(rows, cols, vals, nnz, overflowed)``; the extraction lexsort
    runs over the **present C tiles only** — never over |rowspace|×
    |colspace| — so peak memory is tiles + the output COO.
    """
    if len(plan.pair_a) == 0:
        rows = jnp.full(out_capacity, SENT, jnp.int32)
        return rows, rows, jnp.full(out_capacity, sr.zero, jnp.float32), \
            jnp.int32(0), False

    a_tiles = pack_tiles(a_vals, plan.a_tile_of, plan.a_lr, plan.a_lc,
                         len(plan.a_blocks), bm, bk, sr.zero)
    b_tiles = pack_tiles(b_vals, plan.b_tile_of, plan.b_lr, plan.b_lc,
                         len(plan.b_blocks), bk, bn, sr.zero)
    n_c = len(plan.c_blocks)
    if kernel_impl == "chunked":
        from repro.kernels.bsr_spgemm.ref import chunk_products
        c_tiles = jnp.full((n_c, bm, bn), sr.zero, jnp.float32)
        chunk = _CHUNK_MXU if sr.mxu else _CHUNK_VPU
        for p0 in range(0, len(plan.pair_a), chunk):
            pa = plan.pair_a[p0:p0 + chunk]
            pb = plan.pair_b[p0:p0 + chunk]
            pc = plan.pair_c[p0:p0 + chunk]
            parts = chunk_products(a_tiles[jnp.asarray(pa)],
                                   b_tiles[jnp.asarray(pb)], sr)
            c_tiles = scatter_combine(c_tiles, jnp.asarray(pc), parts, sr)
    else:
        from repro.kernels.bsr_spgemm.ops import bsr_pairlist
        c_tiles = bsr_pairlist(
            a_tiles, b_tiles, jnp.asarray(plan.pair_a),
            jnp.asarray(plan.pair_b), jnp.asarray(plan.pair_c),
            n_c=n_c, semiring=sr, impl=kernel_impl)

    # tiles → canonical COO: global coords per tile cell, zero-drop,
    # lexsort over the nC·bm·bn tile cells (bounded by present tiles)
    ci = jnp.asarray(plan.c_blocks[:, 0], jnp.int32)
    cj = jnp.asarray(plan.c_blocks[:, 1], jnp.int32)
    rows_g = (ci[:, None, None] * bm
              + jnp.arange(bm, dtype=jnp.int32)[None, :, None])
    cols_g = (cj[:, None, None] * bn
              + jnp.arange(bn, dtype=jnp.int32)[None, None, :])
    rows_g = jnp.broadcast_to(rows_g, (n_c, bm, bn)).reshape(-1)
    cols_g = jnp.broadcast_to(cols_g, (n_c, bm, bn)).reshape(-1)
    vals_g = c_tiles.reshape(-1)
    valid = ((vals_g != sr.zero) & (rows_g < plan.m) & (cols_g < plan.n))
    r = jnp.where(valid, rows_g, SENT)
    c = jnp.where(valid, cols_g, SENT)
    v = jnp.where(valid, vals_g, sr.zero)
    order = jnp.lexsort((c, r))[:out_capacity]
    r, c, v = r[order], c[order], v[order]
    true_nnz = int(valid.sum())
    overflowed = true_nnz > out_capacity
    if overflowed:
        _warn_overflow(true_nnz, out_capacity, "bsr_matmul_coo")
    r, c, v = pad_to_cap(r, c, v, out_capacity, sr.zero)
    nnz = jnp.int32(min(true_nnz, out_capacity))
    return r, c, v, nnz, overflowed


def _contraction_aligned(a, b, sr: Semiring):
    """Shared prologue: logical() strings, align the contraction keyspace."""
    a = a.logical() if not a.numeric else a
    b = b.logical() if not b.numeric else b
    ks, a_map, b_map = a.col_space.union(b.row_space)
    a = a.reranked(a.row_space, ks,
                   np.arange(len(a.row_space), dtype=np.int32), a_map)
    b = b.reranked(ks, b.col_space, b_map,
                   np.arange(len(b.col_space), dtype=np.int32))
    return a, b, ks


def _valid_host(t) -> Tuple[np.ndarray, np.ndarray, int]:
    """Host copies of the valid (row, col) rank codes of an AssocTensor."""
    nnz = int(t.nnz)
    return (np.asarray(t.rows)[:nnz].astype(np.int64),
            np.asarray(t.cols)[:nnz].astype(np.int64), nnz)


def _apply_keep(t, rows: np.ndarray, cols: np.ndarray, nnz: int,
                keep: Optional[np.ndarray]):
    """Slice an operand's entry lists by a host keep mask (selector fusion).

    ``keep`` is a bool array over the ``nnz`` valid entries (None ⇒ all).
    Returns ``(rows, cols, vals)`` with the host code arrays subset and the
    device values gathered at the kept positions — a *list slice*, never a
    canonicalized sliced array: the subset of a sorted canonical COO is
    itself sorted canonical, so no compact/lexsort ever runs.
    """
    if keep is None:
        return rows, cols, t.vals[:nnz]
    if len(keep) != nnz:
        raise ValueError(f"keep mask of length {len(keep)} for operand "
                         f"with {nnz} valid entries")
    idx = np.flatnonzero(np.asarray(keep, bool))
    return rows[idx], cols[idx], t.vals[jnp.asarray(idx, jnp.int32)]


def _pad_triples(rows: np.ndarray, cols: np.ndarray, vals: jnp.ndarray,
                 cap: int, zero: float):
    """Kept host codes + device vals → sentinel-padded device COO triples
    (sorted by construction) for the jit-safe expand-join path.  Pure
    upload + the module's one padding primitive (:func:`pad_to_cap`)."""
    return pad_to_cap(jnp.asarray(rows, jnp.int32),
                      jnp.asarray(cols, jnp.int32),
                      vals.astype(jnp.float32), cap, zero)


def _scatter_dense(rows: np.ndarray, cols: np.ndarray, vals: jnp.ndarray,
                   nr: int, nc: int, zero: float,
                   pad_to: int = TILE) -> jnp.ndarray:
    """Densify kept triples onto an MXU-aligned adj (keep-aware twin of
    ``AssocTensor.to_dense_adj``)."""
    nrp = _round_up(max(nr, 1), pad_to)
    ncp = _round_up(max(nc, 1), pad_to)
    dense = jnp.full((nrp, ncp), zero, jnp.float32)
    if len(rows) == 0:
        return dense
    return dense.at[jnp.asarray(rows), jnp.asarray(cols)].set(
        vals.astype(jnp.float32), mode="drop")


@contract(collectives=0, name="spgemm.matmul",
          note="single-device planned product: BSR pair-list kernel path")
def matmul(a, b, semiring=PLUS_TIMES, *, impl: str = "auto",
           out_capacity: Optional[int] = None, use_kernel: bool = True,
           kernel_impl: str = "auto",
           a_keep: Optional[np.ndarray] = None,
           b_keep: Optional[np.ndarray] = None):
    """Array multiplication ``A ⊗.⊕ B`` for device AssocTensors, planned.

    ``impl``: ``"auto"`` (heuristic), ``"dense"``, ``"bsr"`` or ``"coo"``
    (see module docstring).  ``use_kernel=False`` keeps the dense strategy
    on the jnp reference contraction (test oracle).  ``kernel_impl``
    forwards to the BSR pair-list kernel dispatch (``"interpret"`` runs
    the Pallas body on CPU, ``"chunked"`` the legacy eager loop).  When no
    ``out_capacity`` is given, the BSR strategy sizes the output COO with
    :func:`estimate_out_nnz` instead of the exact product count — on
    hub-heavy inputs (many products folding into few distinct cells) that
    shrinks the buffer by orders of magnitude.  Eager/host-driven — inside
    a jit trace use ``impl="coo"`` building blocks directly.

    ``a_keep``/``b_keep`` are host bool masks over the operands' valid
    entries (the compiled form of a deferred selection, see
    :mod:`repro.core.plan`): the plan's entry/tile lists are sliced and
    the values gathered once, so ``A[sel] @ B[sel]`` runs without ever
    building either slice as an array.
    """
    from .assoc_tensor import AssocTensor

    if impl not in ("auto", "dense", "bsr", "coo"):
        raise ValueError(f"unknown matmul impl {impl!r}; "
                         f"expected auto/dense/bsr/coo")
    sr = get_semiring(semiring)
    a, b, ks = _contraction_aligned(a, b, sr)
    m, k, n = len(a.row_space), len(ks), len(b.col_space)
    ra, ca, na = _valid_host(a)
    rb, cb, nb = _valid_host(b)
    ra, ca, a_vals = _apply_keep(a, ra, ca, na, a_keep)
    rb, cb, b_vals = _apply_keep(b, rb, cb, nb, b_keep)
    filtered = a_keep is not None or b_keep is not None

    def _cap(products: int) -> int:
        return out_capacity or max(8, _round_up(
            min(products, max(m, 1) * max(n, 1)) or 8, 8))

    if impl == "coo":
        # no tile planning needed: the expansion size is the exact product
        # count, one bincount dot over the contraction codes
        products = _exact_products(ca, rb, k)
        cap = _cap(products)
        expand = max(8, _round_up(max(products, 1), 8))
        ar, ac, av = ((a.rows, a.cols, a.vals) if a_keep is None
                      else _pad_triples(ra, ca, a_vals, a.capacity, sr.zero))
        br, bc, bv = ((b.rows, b.cols, b.vals) if b_keep is None
                      else _pad_triples(rb, cb, b_vals, b.capacity, sr.zero))
        pr, pc, pv, _ = expand_join_coo(ar, ac, av, br, bc, bv,
                                        sr.mul, zero=sr.zero, expand=expand)
        r, c, v, nnz = dedup_sorted_coo(pr, pc, pv, sr.add, zero=sr.zero)
        true_nnz = int(nnz)
        overflowed = true_nnz > cap
        if overflowed:
            _warn_overflow(true_nnz, cap, "matmul[coo]")
        r, c, v = pad_to_cap(r, c, v, cap, sr.zero)
        out = AssocTensor(r, c, v, jnp.minimum(nnz, cap),
                          a.row_space, b.col_space, None)
        out.overflow = overflowed
        return out

    def _dense(cap: int) -> "AssocTensor":
        if filtered:
            da = _scatter_dense(ra, ca, a_vals, m, k, sr.zero)
            db = _scatter_dense(rb, cb, b_vals, k, n, sr.zero)
        else:
            da, db = _densify_aligned(a, b, sr)
        if use_kernel:
            from repro.kernels.semiring_matmul.ops import semiring_matmul
            dc = semiring_matmul(da, db, semiring=sr)
        else:
            dc = sr.matmul_dense(da, db)
        return AssocTensor.from_dense_adj(dc, a.row_space, b.col_space, cap,
                                          zero=sr.zero)

    if impl == "dense":
        # explicit dense: no tile-pair planning needed, only the product
        # count for the default capacity
        return _dense(_cap(_exact_products(ca, rb, k)))

    plan = plan_matmul(ra, ca, rb, cb, m, k, n, impl=impl)
    if plan.impl == "dense":
        return _dense(_cap(plan.products))

    cap = out_capacity or max(8, _round_up(
        max(estimate_out_nnz(plan), 1), 8))
    r, c, v, nnz, overflowed = bsr_matmul_coo(plan, a_vals, b_vals, sr, cap,
                                              kernel_impl=kernel_impl)
    out = AssocTensor(r, c, v, nnz, a.row_space, b.col_space, None)
    out.overflow = overflowed
    return out


@contract(collectives=0, name="spgemm.matmul_reduce",
          note="fused epilogue: C tiles never materialized")
def matmul_reduce(a, b, axis: int, semiring=PLUS_TIMES, *,
                  impl: str = "auto", kernel_impl: str = "auto",
                  a_keep: Optional[np.ndarray] = None,
                  b_keep: Optional[np.ndarray] = None) -> jnp.ndarray:
    """Fused ``⊕-reduce(A ⊗.⊕ B, axis)`` — C is never materialized.

    ``axis=1`` ⊕-folds over columns → vector over ``a.row_space``;
    ``axis=0`` ⊕-folds over rows → vector over ``b.col_space``.  The
    reduction monoid is the semiring's own ⊕ (the only choice for which
    the fusion ``⊕_j ⊕_k A[i,k] ⊗ B[k,j]`` is exact).  Strategy mirrors
    :func:`matmul`; the dense strategy runs the fused
    ``bsr_spgemm_reduce`` Pallas kernel and the bsr strategy the fused
    pair-list reduce kernel (``kernel_impl`` forwards to both dispatches —
    ``"interpret"`` exercises the kernel bodies on CPU, ``"chunked"``
    keeps the legacy eager loop on the bsr path).
    """
    from repro.kernels.bsr_spgemm.ops import bsr_spgemm_reduce, make_block_mask

    assert axis in (0, 1), axis
    if impl not in ("auto", "dense", "bsr", "coo"):
        raise ValueError(f"unknown matmul impl {impl!r}; "
                         f"expected auto/dense/bsr/coo")
    sr = get_semiring(semiring)
    a, b, ks = _contraction_aligned(a, b, sr)
    m, k, n = len(a.row_space), len(ks), len(b.col_space)
    out_len = m if axis == 1 else n
    ra, ca, na = _valid_host(a)
    rb, cb, nb = _valid_host(b)
    ra, ca, a_vals = _apply_keep(a, ra, ca, na, a_keep)
    rb, cb, b_vals = _apply_keep(b, rb, cb, nb, b_keep)
    filtered = a_keep is not None or b_keep is not None
    if len(ra) == 0 or len(rb) == 0 or out_len == 0:
        return jnp.full(max(out_len, 0), sr.zero, jnp.float32)

    if impl == "coo":
        # expand-join + one segment scatter: the jit-safe fused epilogue
        # (the same shape DistAssoc shards run, minus the collective)
        products = _exact_products(ca, rb, k)
        expand = max(8, _round_up(max(products, 1), 8))
        ar, ac, av = ((a.rows, a.cols, a.vals) if a_keep is None
                      else _pad_triples(ra, ca, a_vals, a.capacity, sr.zero))
        br, bc, bv = ((b.rows, b.cols, b.vals) if b_keep is None
                      else _pad_triples(rb, cb, b_vals, b.capacity, sr.zero))
        pr, pc, pv, _ = expand_join_coo(ar, ac, av, br, bc, bv,
                                        sr.mul, zero=sr.zero, expand=expand)
        keys = pr if axis == 1 else pc
        vec = jnp.full(out_len, sr.zero, jnp.float32)
        return scatter_combine(vec, keys, pv, sr)  # SENT keys drop

    def _dense() -> jnp.ndarray:
        if filtered:
            da = _scatter_dense(ra, ca, a_vals, m, k, sr.zero)
            db = _scatter_dense(rb, cb, b_vals, k, n, sr.zero)
            mask = make_block_mask(
                jnp.asarray(ra, jnp.int32), jnp.asarray(ca, jnp.int32),
                jnp.ones(len(ra), bool),
                da.shape[0] // TILE, da.shape[1] // TILE)
        else:
            da, db = _densify_aligned(a, b, sr)
            mask = make_block_mask(a.rows, a.cols, a.valid_mask(),
                                   da.shape[0] // TILE, da.shape[1] // TILE)
        vec = bsr_spgemm_reduce(da, mask, db, axis=axis, semiring=sr,
                                impl=kernel_impl)
        return vec[:out_len]

    if impl == "dense":
        return _dense()  # uses no plan fields: skip the tile-pair join

    plan = plan_matmul(ra, ca, rb, cb, m, k, n, impl=impl)
    if plan.impl == "dense":
        return _dense()

    # bsr strategy: fold tile products straight into per-output-block
    # vectors — no C tiles, no dedup (⊕ over all products per row/col IS
    # the answer).  Pairs regroup by output block (block-row for axis=1,
    # block-col for axis=0) so the pair-list reduce kernel can keep each
    # block's partial vector VMEM-resident across its run of pairs.
    if len(plan.pair_a) == 0:
        return jnp.full(max(out_len, 0), sr.zero, jnp.float32)
    a_tiles = pack_tiles(a_vals, plan.a_tile_of, plan.a_lr, plan.a_lc,
                         len(plan.a_blocks), TILE, TILE, sr.zero)
    b_tiles = pack_tiles(b_vals, plan.b_tile_of, plan.b_lr, plan.b_lc,
                         len(plan.b_blocks), TILE, TILE, sr.zero)
    blk = (plan.a_blocks[plan.pair_a, 0] if axis == 1
           else plan.b_blocks[plan.pair_b, 1])
    order = np.argsort(blk, kind="stable")
    o_uniq, pair_o = np.unique(blk[order], return_inverse=True)
    pa, pb = plan.pair_a[order], plan.pair_b[order]

    if kernel_impl == "chunked":
        from repro.kernels.bsr_spgemm.ref import chunk_products
        blocks = jnp.full((len(o_uniq), TILE), sr.zero, jnp.float32)
        chunk = _CHUNK_MXU if sr.mxu else _CHUNK_VPU
        for p0 in range(0, len(pa), chunk):
            parts = chunk_products(a_tiles[jnp.asarray(pa[p0:p0 + chunk])],
                                   b_tiles[jnp.asarray(pb[p0:p0 + chunk])],
                                   sr)
            pvec = sr.add_reduce(parts, axis=2 if axis == 1 else 1)
            blocks = scatter_combine(
                blocks, jnp.asarray(pair_o[p0:p0 + chunk], jnp.int32),
                pvec, sr)
    else:
        from repro.kernels.bsr_spgemm.ops import bsr_pairlist_reduce
        blocks = bsr_pairlist_reduce(
            a_tiles, b_tiles, jnp.asarray(pa), jnp.asarray(pb),
            jnp.asarray(pair_o, jnp.int32), n_o=len(o_uniq), axis=axis,
            semiring=sr, impl=kernel_impl)            # [n_o, TILE]

    padded = _round_up(max(out_len, 1), TILE)
    vec = jnp.full(padded, sr.zero, jnp.float32)
    offs = jnp.arange(TILE, dtype=jnp.int32)
    idx = jnp.asarray(o_uniq[:, None] * TILE, jnp.int32) + offs[None, :]
    vec = scatter_combine(vec, idx, blocks, sr)
    return vec[:out_len]


# ---------------------------------------------------------------------------
# Distribution cost model: which communication pattern should a sharded
# product use?  The planner already computes exact per-entry product counts
# on host (two searchsorteds over B's contraction ranks); this section turns
# them into triples-moved estimates for the three DistAssoc strategies and
# picks the cheapest — the D4M.jl / Graphulo observation that the win at
# scale comes from moving the *smaller* data (B slices or partial products),
# not from one hard-coded pattern.
# ---------------------------------------------------------------------------

def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


# Weight of per-shard sort work (expand-join argsorts + the canonical
# dedup merge) relative to one moved triple.  The critical-path sort
# sizes are the SAME padded capacities the movement terms use, so skew
# prices both: a hub row inflates a bucket, the bucket inflates the
# exchange AND the merge that consumes it.  Sorting a resident triple
# costs more than copying one on every backend we run (XLA's CPU sort
# badly so, TPU less), so the weight leans the chooser toward the
# strategy with the smallest per-shard merge when movement is close.
_SORT_WEIGHT = 8.0

# Per-shard expand size above which DistAssoc's replicate path swaps its
# local compute from the coo expand-join to the tiled pair-list (BSR)
# program.  That swap re-plans the pair lists on host — a scan of ALL of
# B per shard — so the distribution cost model charges replicate for it
# (see plan_dist_matmul); the sharded strategies never pay it because
# each shard only ever contracts one B block.
BSR_AUTO_EXPAND = 1 << 14


@dataclasses.dataclass
class DistPlan:
    """Host-side communication plan for one sharded ``A ⊗.⊕ B``.

    ``costs`` holds the modeled data movement per strategy in **triples**
    (COO entries: 12 bytes each — the unit every term shares, so bytes
    cancel).  Replicated/staged movement and collective movement are
    counted at the same weight, but the collective terms use the *padded*
    capacities (``bucket_cap`` / ``block_cap``) — the model is honest
    about skew: a hub row that concentrates partial products into one
    bucket inflates the all-to-all estimate exactly as it inflates the
    real exchange.
    """

    strategy: str                  # "replicate" | "all_to_all" | "2d"
    grid: Tuple[int, int]          # (pr, pc); (n_shards, 1) off the 2d path
    bucket_cap: int                # all_to_all per-(src, dest) bucket slots
    block_cap: int                 # 2d staged B-block capacity (triples)
    expands: dict                  # strategy → per-shard expand-join slots
    costs: dict                    # strategy → modeled triples moved

    @property
    def expand(self) -> int:
        return self.expands[self.strategy]


def suggest_grid(n_shards: int, k: int, a_cols: np.ndarray,
                 counts: np.ndarray, b_rows: np.ndarray):
    """Pick the 2D process grid ``(pr, pc)`` from nnz structure.

    Models each divisor split ``pr·pc = n_shards`` (``pc`` = contraction
    blocks ring-shifted through the shards, ``pr`` = replication factor of
    each block at staging) and returns the grid minimizing::

        pr·nnz(B)  +  n_shards·(pc−1)·block_cap  +  w·pc·round_expand

    — staged B replication vs ring traffic vs per-shard merge work
    (``w`` = :data:`_SORT_WEIGHT`; the final dedup consumes all ``pc``
    round buffers), all in triples.  Also returns
    the per-round expand size and staged block capacity for the winner, so
    the caller sizes the program's static buffers from the same exact
    counts the model used.  ``a_cols``/``counts`` are the ``[P, cap]``
    host contraction ranks and per-entry product counts (SENT entries
    carry count 0); ``b_rows`` the sorted valid contraction ranks of B.
    """
    nnz_b = len(b_rows)
    dest = np.broadcast_to(np.arange(counts.shape[0])[:, None],
                           counts.shape)
    best = None
    for pc in _divisors(n_shards):
        pr = n_shards // pc
        bnds = np.linspace(0, k, pc + 1).astype(np.int64)
        kb = np.searchsorted(bnds[1:], a_cols, side="right").clip(0, pc - 1)
        table = np.zeros((counts.shape[0], pc), np.int64)
        np.add.at(table, (dest, kb), counts)
        round_expand = int(max(8, _round_up(int(table.max(initial=0)) or 1, 8)))
        blk_nnz = np.diff(np.searchsorted(b_rows, bnds))
        block_cap = int(max(8, _round_up(int(blk_nnz.max(initial=0)) or 1, 8)))
        cost = (pr * nnz_b + n_shards * (pc - 1) * block_cap
                + _SORT_WEIGHT * pc * round_expand)
        if best is None or cost < best[0]:
            best = (cost, (pr, pc), round_expand, block_cap)
    return best[1], best[2], best[3], best[0]


def plan_dist_matmul(a_rows: np.ndarray, a_cols: np.ndarray,
                     counts: np.ndarray, b_rows: np.ndarray, k: int,
                     n_shards: int, *, b_resident: bool = False,
                     grid: Optional[Tuple[int, int]] = None,
                     a2a_bounds: Optional[np.ndarray] = None) -> DistPlan:
    """Choose replicate / all-to-all / 2D for one sharded product.

    Inputs are pure host metadata (the sharded twin of
    :func:`plan_matmul`'s entry lists): ``a_rows``/``a_cols`` the
    ``[n_shards, cap]`` SENT-padded rank arrays with cols on the
    contraction space, ``counts`` the exact per-entry B-run lengths, and
    ``b_rows`` B's sorted valid contraction ranks.  Modeled cost =
    movement + ``w``·(per-shard sort work), ``w`` = :data:`_SORT_WEIGHT`::

        replicate:   P·nnz(B)                        + w·expand
        all_to_all:  P·nnz(A) + stage(B) + P²·bucket_cap
                                         + w·(expand + P·bucket_cap)
        2d(pr, pc):  pr·nnz(B) + P·(pc−1)·block_cap + w·pc·round_expand

    The sort terms are what makes the chooser load-balance-aware: A's
    row skew concentrates ``replicate``'s and ``2d``'s expand on the hub
    shard (A never moves), while ``all_to_all`` re-buckets products by
    contraction block — its expand is the *column* max of the product
    table, not the row max.

    ``stage(B)`` drops to 0 when B is a resident ``DistAssoc`` on the same
    mesh (its row partition IS a contraction-range partition — the rank
    maps of :meth:`KeySpace.union` are monotone, so reranking preserves
    it and the all-to-all path reuses B's shards in place); in that case
    ``a2a_bounds`` carries B's actual partition boundaries in the merged
    rank space so the product table matches the blocks the program will
    really contract.  ``grid`` forces the 2D grid instead of
    :func:`suggest_grid`.
    """
    P = n_shards
    nnz_a = int((a_rows != int(SENT)).sum())
    nnz_b = len(b_rows)
    per_shard = counts.sum(axis=1)
    expand_rep = int(max(8, _round_up(int(per_shard.max(initial=0)) or 1, 8)))

    # all_to_all: shard t computes every product whose contraction rank
    # falls in k-block t; the [dest, src] product table sizes both the
    # compute expansion (column sums) and the exchange buckets (max cell)
    bnds = (np.asarray(a2a_bounds, np.int64) if a2a_bounds is not None
            else np.linspace(0, k, P + 1).astype(np.int64))
    kb = np.searchsorted(bnds[1:], a_cols, side="right").clip(0, max(P - 1, 0))
    dest = np.broadcast_to(np.arange(counts.shape[0])[:, None], counts.shape)
    table = np.zeros((P, P), np.int64)
    np.add.at(table, (dest, kb), counts)
    bucket_cap = int(max(8, _round_up(int(table.max(initial=0)) or 1, 8)))
    expand_a2a = int(max(8, _round_up(
        int(table.sum(axis=0).max(initial=0)) or 1, 8)))

    if grid is not None:
        pr, pc = grid
        if pr * pc != P:
            raise ValueError(f"grid {grid} does not tile {P} shards")
        # forced grid: size its buffers directly
        bnds2 = np.linspace(0, k, pc + 1).astype(np.int64)
        kb2 = np.searchsorted(bnds2[1:], a_cols,
                              side="right").clip(0, pc - 1)
        t2 = np.zeros((P, pc), np.int64)
        np.add.at(t2, (dest, kb2), counts)
        round_expand = int(max(8, _round_up(int(t2.max(initial=0)) or 1, 8)))
        blk_nnz = np.diff(np.searchsorted(b_rows, bnds2))
        block_cap = int(max(8, _round_up(
            int(blk_nnz.max(initial=0)) or 1, 8)))
        cost_2d = (pr * nnz_b + P * (pc - 1) * block_cap
                   + _SORT_WEIGHT * pc * round_expand)
        grid_2d = (pr, pc)
    else:
        grid_2d, round_expand, block_cap, cost_2d = suggest_grid(
            P, k, a_cols, counts, b_rows)

    cost_rep = float(P * nnz_b + _SORT_WEIGHT * expand_rep)
    if expand_rep >= BSR_AUTO_EXPAND:
        # replicate's local compute will switch to the pair-list program,
        # whose host planning rescans B once per shard
        cost_rep += float(_SORT_WEIGHT * P * nnz_b)
    costs = {
        "replicate": cost_rep,
        "all_to_all": float(P * nnz_a + (0 if b_resident else nnz_b)
                            + P * P * bucket_cap
                            + _SORT_WEIGHT * (expand_a2a
                                              + P * bucket_cap)),
        "2d": float(cost_2d),
    }
    if P == 1:
        strategy = "replicate"     # nothing to distribute
    else:
        strategy = min(costs, key=costs.get)
    expands = {"replicate": expand_rep, "all_to_all": expand_a2a,
               "2d": round_expand}
    return DistPlan(strategy=strategy, grid=grid_2d, bucket_cap=bucket_cap,
                    block_cap=block_cap, expands=expands, costs=costs)

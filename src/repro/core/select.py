"""D4M selector algebra: one query language for every associative array.

The defining surface of D4M is associative-array indexing — explicit key
lists ``A['alice,bob,', :]``, right-inclusive ranges ``'a,:,b,'``, prefix
queries ``StartsWith('ab,')`` — which the paper presents as the composable
query language that turns associative arrays into a database interface.
This module makes that language first-class and layer-independent:

* a small set of :class:`Selector` objects — :class:`Keys`, :class:`Range`,
  :class:`StartsWith`, :class:`Match`, :class:`Where`, :class:`Mask`,
  :class:`Positions`, :class:`All` — closed under ``&`` / ``|`` / ``~``;
* each selector **compiles against a** :class:`~repro.core.keyspace.KeySpace`
  into a :class:`Compiled` form that is either a *contiguous rank range*
  ``[lo, hi)`` (the device fast path) or a *sorted index set* (the gather
  path); composition happens on compiled forms with the sorted-set
  primitives from :mod:`repro.core.sorted_ops`;
* compilation is **cached per (KeySpace, selector)** — keyspaces are
  immutable and content-hashed, so repeated queries on the same key
  dictionary skip the searchsorted/regex work entirely.

``Assoc`` (host), ``AssocTensor`` (device) and ``DistAssoc`` (mesh) all
resolve their ``__getitem__`` selectors through :func:`compile_selector`,
so ``A[sel, :]`` means the same thing — and returns the same entries — on
every layer.
"""
from __future__ import annotations

import hashlib
import re
import threading
from collections import OrderedDict
from typing import Callable, Optional, Sequence, Tuple, Union

import numpy as np

from .keyspace import KeySpace
from .sorted_ops import sorted_intersect, sorted_union

__all__ = [
    "Selector", "Keys", "Range", "StartsWith", "Match", "Where", "Mask",
    "Positions", "All", "And", "Or", "Not", "Compiled",
    "as_selector", "compile_selector", "plan_boxes", "sanitize_keys",
    "split_string_list",
    "CACHE_STATS", "clear_compile_cache", "reset_cache_stats",
]

# D4M string-list convention: a string whose final character is a separator
# encodes a list, e.g. "a,b,c," == ["a","b","c"];  "a,:,b," is a range.
SEPARATORS = (",", ";", "\t", "|")


def split_string_list(s: str):
    """Split a D4M string-list (trailing separator chooses the delimiter)."""
    if len(s) > 0 and s[-1] in SEPARATORS:
        sep = s[-1]
        return [p for p in s.split(sep) if p != ""]
    return [s]


def sanitize_keys(keys) -> np.ndarray:
    """Coerce a key argument to a 1-D numpy array of str or float.

    The one key-coercion rule shared by selector parsing (:class:`Keys`)
    and ``Assoc`` construction/assignment.
    """
    if isinstance(keys, str):
        keys = split_string_list(keys)
    arr = np.asarray(keys)
    if arr.ndim == 0:
        arr = arr.reshape(1)
    if arr.dtype.kind in ("U", "S", "O"):
        return arr.astype(str)
    return arr.astype(np.float64)


def _payload_digest(b: bytes) -> bytes:
    """Fixed-size stand-in for large byte payloads in cache keys: without
    it, Mask/Keys entries over big keyspaces pin their full payload in the
    cache key and every lookup re-hashes megabytes."""
    return hashlib.sha1(b).digest()


# ---------------------------------------------------------------------------
# Compiled form
# ---------------------------------------------------------------------------

class Compiled:
    """A selector compiled against one KeySpace.

    Either a contiguous half-open rank range ``[lo, hi)`` (``is_range``) or
    a sorted unique int64 index set.  ``n`` is the keyspace size; a set
    whose indices happen to be contiguous normalizes to a range, so the
    device fast path triggers whenever it can.
    """

    __slots__ = ("lo", "hi", "_idx", "n")

    def __init__(self, lo: int, hi: int, idx: Optional[np.ndarray], n: int):
        self.lo = lo
        self.hi = hi
        self._idx = idx
        self.n = n

    @staticmethod
    def from_range(lo: int, hi: int, n: int) -> "Compiled":
        lo = int(max(0, min(lo, n)))
        hi = int(max(lo, min(hi, n)))
        return Compiled(lo, hi, None, n)

    @staticmethod
    def from_indices(idx, n: int, *, validate: bool = True) -> "Compiled":
        idx = np.unique(np.asarray(idx, dtype=np.int64))
        if validate and len(idx) and (idx[0] < 0 or idx[-1] >= n):
            raise IndexError(
                f"positions {idx[[0, -1]].tolist()} out of range for "
                f"keyspace of size {n}")
        if len(idx) == 0:
            return Compiled.from_range(0, 0, n)
        if int(idx[-1]) - int(idx[0]) + 1 == len(idx):  # contiguous ⇒ range
            return Compiled.from_range(int(idx[0]), int(idx[-1]) + 1, n)
        # Compiled objects are cached process-wide: freeze the index set so
        # a caller mutating positions() cannot poison later identical queries
        idx.setflags(write=False)
        return Compiled(int(idx[0]), int(idx[-1]) + 1, idx, n)

    @property
    def is_range(self) -> bool:
        return self._idx is None

    @property
    def count(self) -> int:
        return (self.hi - self.lo) if self.is_range else len(self._idx)

    def positions(self) -> np.ndarray:
        """Sorted int64 positions into the keyspace."""
        if self.is_range:
            return np.arange(self.lo, self.hi, dtype=np.int64)
        return self._idx

    def mask(self) -> np.ndarray:
        """Boolean membership mask over the whole keyspace (len == n)."""
        m = np.zeros(self.n, dtype=bool)
        if self.is_range:
            m[self.lo:self.hi] = True
        else:
            m[self._idx] = True
        return m

    def runs(self, max_runs: int = 4) -> Optional[list]:
        """Decompose into ≤``max_runs`` contiguous ``[lo, hi)`` intervals.

        A range is its own single run; a scattered index set splits at the
        gaps.  Returns ``None`` when more than ``max_runs`` intervals would
        be needed — the caller falls back to a membership gather.  This is
        the multi-interval extension of the ``from_indices`` contiguous⇒
        range normalization: a ``Match``/``Where`` whose hits form a few
        rank intervals runs as a few range-kernel calls instead of a
        gather (see ``plan_boxes``).
        """
        if self.is_range:
            return [(self.lo, self.hi)]
        idx = self._idx
        breaks = np.flatnonzero(np.diff(idx) > 1)
        if len(breaks) + 1 > max_runs:
            return None
        starts = np.concatenate(([0], breaks + 1))
        ends = np.concatenate((breaks, [len(idx) - 1]))
        return [(int(idx[s]), int(idx[e]) + 1)
                for s, e in zip(starts, ends)]

    def __repr__(self) -> str:
        if self.is_range:
            return f"Compiled(range=[{self.lo},{self.hi}), n={self.n})"
        return f"Compiled(set={self.count} of {self.n})"


def plan_boxes(rc: Compiled, cc: Compiled, nr: int, nc: int,
               max_boxes: int = 4):
    """Device selection dispatch plan: rank boxes + residual gather flags.

    Returns ``(boxes, row_gather, col_gather)`` where ``boxes`` is an
    int32 ``[k, 4]`` array of ``(rlo, rhi, clo, chi)`` range-kernel
    bounds, ``k ≤ max_boxes``, and each ``*_gather`` flag marks an axis
    that still needs a membership gather.  The keep mask is the OR of the
    per-box range-kernel masks ANDed with any gathers — the boxes are
    disjoint by construction (interval runs of sorted unique indices), so
    OR-composition is exact and no merge of extracted lists is needed.

    Preference order: both axes interval-decomposable and the box product
    fits → pure multi-range (no gathers); one axis decomposable → its
    runs as boxes (other bound open) + one gather; neither → one full box
    + two gathers (the caller's plain gather path).
    """
    r_runs = rc.runs(max_boxes)
    c_runs = cc.runs(max_boxes)
    if (r_runs is not None and c_runs is not None
            and len(r_runs) * len(c_runs) <= max_boxes):
        boxes = [(rl, rh, cl, ch) for rl, rh in r_runs for cl, ch in c_runs]
        return np.asarray(boxes, np.int32).reshape(-1, 4), False, False
    if r_runs is not None and len(r_runs) <= max_boxes:
        boxes = [(rl, rh, 0, nc) for rl, rh in r_runs]
        return np.asarray(boxes, np.int32), False, True
    if c_runs is not None and len(c_runs) <= max_boxes:
        boxes = [(0, nr, cl, ch) for cl, ch in c_runs]
        return np.asarray(boxes, np.int32), True, False
    return (np.asarray([(0, nr, 0, nc)], np.int32), True, True)


def _and_compiled(a: Compiled, b: Compiled) -> Compiled:
    if a.is_range and b.is_range:
        return Compiled.from_range(max(a.lo, b.lo), min(a.hi, b.hi), a.n)
    # timsort-merge sorted intersection (see sorted_ops.sorted_intersect)
    k, _, _ = sorted_intersect(a.positions(), b.positions())
    return Compiled.from_indices(k, a.n, validate=False)


def _or_compiled(a: Compiled, b: Compiled) -> Compiled:
    # empty is the identity: keeps single-range operands (e.g. one-prefix
    # StartsWith folds) on the range fast path instead of materializing
    if a.count == 0:
        return b
    if b.count == 0:
        return a
    if a.is_range and b.is_range and a.lo <= b.hi and b.lo <= a.hi:
        return Compiled.from_range(min(a.lo, b.lo), max(a.hi, b.hi), a.n)
    k, _, _ = sorted_union(a.positions(), b.positions())
    return Compiled.from_indices(k, a.n, validate=False)


def _not_compiled(a: Compiled) -> Compiled:
    return Compiled.from_indices(np.flatnonzero(~a.mask()), a.n,
                                 validate=False)


# ---------------------------------------------------------------------------
# Selector objects
# ---------------------------------------------------------------------------

class Selector:
    """Base class: composable, hashable-keyed, compiles per KeySpace."""

    def __and__(self, other) -> "Selector":
        return And(self, as_selector(other))

    def __rand__(self, other) -> "Selector":
        return And(as_selector(other), self)

    def __or__(self, other) -> "Selector":
        return Or(self, as_selector(other))

    def __ror__(self, other) -> "Selector":
        return Or(as_selector(other), self)

    def __invert__(self) -> "Selector":
        return Not(self)

    # hashable identity used by the per-KeySpace compilation cache
    def cache_key(self) -> tuple:
        raise NotImplementedError

    def _compile(self, space: KeySpace) -> Compiled:
        raise NotImplementedError


class All(Selector):
    """Every key (the ``:`` selector)."""

    def cache_key(self) -> tuple:
        return ("all",)

    def _compile(self, space: KeySpace) -> Compiled:
        return Compiled.from_range(0, len(space), len(space))

    def __repr__(self):
        return "All()"


class Keys(Selector):
    """Explicit key list (D4M ``'a,b,c,'``); unknown keys are ignored."""

    def __init__(self, keys):
        self.keys = sanitize_keys(keys)

    def cache_key(self) -> tuple:
        # dtype.str encodes the itemsize: without it, UTF-32 payloads of
        # different key lists (e.g. ['ab'] vs ['a','b']) collide
        return ("keys", self.keys.dtype.str, len(self.keys),
                _payload_digest(self.keys.tobytes()))

    def _compile(self, space: KeySpace) -> Compiled:
        arr = self.keys
        if space.is_string:
            arr = arr.astype(str)
        elif arr.dtype.kind in ("U", "S", "O"):
            try:
                arr = arr.astype(np.float64)
            except ValueError:
                return Compiled.from_range(0, 0, len(space))
        ranks, found = space.rank(arr, strict=False)
        del found
        return Compiled.from_indices(ranks, len(space), validate=False)

    def __repr__(self):
        return f"Keys({self.keys.tolist()!r})"


class Positions(Selector):
    """Integer *positions* into the sorted key array (paper rule 2)."""

    def __init__(self, pos: Union[slice, int, Sequence, np.ndarray]):
        if isinstance(pos, (int, np.integer)):
            pos = np.asarray([int(pos)], dtype=np.int64)
        if not isinstance(pos, slice):
            pos = np.asarray(pos, dtype=np.int64).ravel()
        self.pos = pos

    def cache_key(self) -> tuple:
        if isinstance(self.pos, slice):
            return ("pos_slice", self.pos.start, self.pos.stop, self.pos.step)
        return ("pos", len(self.pos), _payload_digest(self.pos.tobytes()))

    def _compile(self, space: KeySpace) -> Compiled:
        n = len(space)
        if isinstance(self.pos, slice):
            return Compiled.from_indices(np.arange(n, dtype=np.int64)[self.pos],
                                         n, validate=False)
        pos = self.pos
        neg = pos < 0
        if neg.any():
            pos = np.where(neg, pos + n, pos)
        return Compiled.from_indices(pos, n)

    def __repr__(self):
        return f"Positions({self.pos!r})"


class Range(Selector):
    """D4M key range ``'lo,:,hi,'`` — inclusive on both ends by default.

    Open ends are ``None``.  Exclusive bounds use the prev/next-string
    trick the paper's string slices rely on: an exclusive lower bound
    starts *after* the last key equal to ``lo`` (``searchsorted right``),
    an exclusive upper bound stops *before* the first key equal to ``hi``
    (``searchsorted left``) — no literal successor strings are ever built.
    """

    def __init__(self, lo=None, hi=None, *, inclusive: Tuple[bool, bool] = (True, True)):
        self.lo = lo
        self.hi = hi
        self.inclusive = (bool(inclusive[0]), bool(inclusive[1]))

    def cache_key(self) -> tuple:
        # open bounds get a distinct tag: str(None) would collide with the
        # literal key "None" (a common stringified null in ingested data)
        lo = ("open",) if self.lo is None else ("key", str(self.lo))
        hi = ("open",) if self.hi is None else ("key", str(self.hi))
        return ("range", lo, hi, self.inclusive)

    def _compile(self, space: KeySpace) -> Compiled:
        n = len(space)
        keys = space.keys

        def cast(x):
            return str(x) if space.is_string else float(x)

        lo_i = 0
        hi_i = n
        try:
            if self.lo is not None:
                side = "left" if self.inclusive[0] else "right"
                lo_i = int(np.searchsorted(keys, cast(self.lo), side=side))
            if self.hi is not None:
                side = "right" if self.inclusive[1] else "left"
                hi_i = int(np.searchsorted(keys, cast(self.hi), side=side))
        except ValueError:   # string bounds against a numeric keyspace
            return Compiled.from_range(0, 0, n)
        return Compiled.from_range(lo_i, hi_i, n)

    def __repr__(self):
        return f"Range({self.lo!r}, {self.hi!r}, inclusive={self.inclusive})"


class StartsWith(Selector):
    """Prefix query (D4M ``StartsWith('ab,')``); accepts a prefix list.

    Each prefix compiles to the rank range ``[prefix, next(prefix))``
    where ``next`` increments the final character — the classic
    next-string boundary, computed on the *prefix*, never on the keys.
    """

    def __init__(self, prefixes):
        if isinstance(prefixes, str):
            prefixes = split_string_list(prefixes)
        self.prefixes = tuple(str(p) for p in prefixes)

    def cache_key(self) -> tuple:
        return ("startswith", self.prefixes)

    @staticmethod
    def _next_string(p: str) -> Optional[str]:
        """Smallest string that is greater than every string prefixed by p."""
        chars = list(p)
        while chars:
            o = ord(chars[-1])
            if o < 0x10FFFF:
                chars[-1] = chr(o + 1)
                return "".join(chars)
            chars.pop()  # carry past a maximal code point
        return None      # every string starts with p ⇒ open upper end

    def _compile(self, space: KeySpace) -> Compiled:
        if not space.is_string:
            raise TypeError("StartsWith requires a string keyspace")
        n = len(space)
        out = Compiled.from_range(0, 0, n)
        for p in self.prefixes:
            if p == "":
                return Compiled.from_range(0, n, n)
            lo = int(np.searchsorted(space.keys, p, side="left"))
            nxt = self._next_string(p)
            hi = n if nxt is None else int(
                np.searchsorted(space.keys, nxt, side="left"))
            out = _or_compiled(out, Compiled.from_range(lo, hi, n))
        return out

    def __repr__(self):
        return f"StartsWith({list(self.prefixes)!r})"


class Match(Selector):
    """Regex query over the (stringified) keys — ``re.search`` semantics."""

    def __init__(self, pattern: str, flags: int = 0):
        self.pattern = pattern
        self.flags = flags
        self._rx = re.compile(pattern, flags)

    def cache_key(self) -> tuple:
        return ("match", self.pattern, self.flags)

    def _compile(self, space: KeySpace) -> Compiled:
        keys = space.keys if space.is_string else space.keys.astype(str)
        hits = np.fromiter((self._rx.search(k) is not None for k in keys),
                           dtype=bool, count=len(keys))
        return Compiled.from_indices(np.flatnonzero(hits), len(space),
                                     validate=False)

    def __repr__(self):
        return f"Match({self.pattern!r})"


class Where(Selector):
    """Arbitrary per-key predicate.  Never cached: per-query lambdas would
    fill the cache with dead entries (and pin their closures) without ever
    hitting — and compilation is the predicate loop itself anyway."""

    def __init__(self, fn: Callable):
        self.fn = fn

    def cache_key(self) -> tuple:
        raise TypeError("Where selectors compile uncached")

    def _compile(self, space: KeySpace) -> Compiled:
        fn = self.fn
        hits = np.fromiter((bool(fn(k)) for k in space.keys.tolist()),
                           dtype=bool, count=len(space))
        return Compiled.from_indices(np.flatnonzero(hits), len(space),
                                     validate=False)

    def __repr__(self):
        return f"Where({self.fn!r})"


class Mask(Selector):
    """Boolean membership mask over the keyspace (len == len(space))."""

    def __init__(self, mask):
        self.bits = np.asarray(mask, dtype=bool).ravel()

    def cache_key(self) -> tuple:
        return ("mask", len(self.bits), _payload_digest(self.bits.tobytes()))

    def _compile(self, space: KeySpace) -> Compiled:
        if len(self.bits) != len(space):
            raise ValueError(
                f"Mask of length {len(self.bits)} against keyspace of "
                f"size {len(space)}")
        return Compiled.from_indices(np.flatnonzero(self.bits), len(space),
                                     validate=False)

    def __repr__(self):
        return f"Mask(n={len(self.bits)}, count={int(self.bits.sum())})"


class And(Selector):
    def __init__(self, a: Selector, b: Selector):
        self.a, self.b = a, b

    def cache_key(self) -> tuple:
        return ("and", self.a.cache_key(), self.b.cache_key())

    def _compile(self, space: KeySpace) -> Compiled:
        return _and_compiled(compile_selector(self.a, space),
                             compile_selector(self.b, space))

    def __repr__(self):
        return f"({self.a!r} & {self.b!r})"


class Or(Selector):
    def __init__(self, a: Selector, b: Selector):
        self.a, self.b = a, b

    def cache_key(self) -> tuple:
        return ("or", self.a.cache_key(), self.b.cache_key())

    def _compile(self, space: KeySpace) -> Compiled:
        return _or_compiled(compile_selector(self.a, space),
                            compile_selector(self.b, space))

    def __repr__(self):
        return f"({self.a!r} | {self.b!r})"


class Not(Selector):
    def __init__(self, a: Selector):
        self.a = a

    def cache_key(self) -> tuple:
        return ("not", self.a.cache_key())

    def _compile(self, space: KeySpace) -> Compiled:
        return _not_compiled(compile_selector(self.a, space))

    def __repr__(self):
        return f"~{self.a!r}"


# ---------------------------------------------------------------------------
# Parsing raw __getitem__ arguments → Selector
# ---------------------------------------------------------------------------

def as_selector(sel) -> Selector:
    """Coerce any D4M index argument into a Selector.

    Paper rules, uniform across layers:
      * ``:`` / ``slice`` / ints / int arrays / int 2-tuples — *positions*
        into the sorted key array (rule 2);
      * strings — key lists (``'a,b,'``), ranges (``'a,:,b,'``), or a
        single key;
      * key-payload 2-tuples — inclusive key ranges;
      * bool arrays — membership masks;
      * float / string arrays — explicit key lookups;
      * Selector instances pass through.

    Selections are *order-free sets*: every selector compiles to a sorted
    unique position set (or range), so reversed slices and duplicate
    positions normalize — results are always in canonical key order.
    """
    if isinstance(sel, Selector):
        return sel
    if isinstance(sel, slice):
        if sel == slice(None):
            return All()
        return Positions(sel)
    if isinstance(sel, (bool, np.bool_)):
        raise TypeError("a bare bool is not a selector")
    if isinstance(sel, (int, np.integer)):
        return Positions(int(sel))
    if isinstance(sel, str):
        if sel == ":":
            return All()
        parts = split_string_list(sel)
        if len(parts) == 3 and parts[1] == ":":
            return Range(parts[0], parts[2])
        return Keys(parts)
    if isinstance(sel, tuple) and len(sel) == 2:
        # int payloads keep the paper's uniform ints-are-POSITIONS rule
        # (matching list/array forms); key payloads are an inclusive Range
        if all(isinstance(x, (int, np.integer)) and not isinstance(x, bool)
               for x in sel):
            return Positions(np.asarray(sel, dtype=np.int64))
        return Range(sel[0], sel[1])
    arr = np.asarray(sel)
    if arr.dtype.kind == "b":
        return Mask(arr)
    if arr.dtype.kind in "iu":
        # integer selectors are POSITIONS (paper rule 2) — uniformly,
        # whether given as a python list or a numpy array
        return Positions(arr)
    return Keys(arr)


# ---------------------------------------------------------------------------
# Compilation cache (per KeySpace): keyspaces are immutable and content-
# hashed, so (digest, selector-key) fully determines the compiled form.
# ---------------------------------------------------------------------------

_COMPILE_CACHE: "OrderedDict" = OrderedDict()
_CACHE_CAP = 4096

CACHE_STATS = {"hits": 0, "misses": 0}

# Guards the LRU mutation + counter bumps: the serve engine compiles
# selectors from many worker threads, and concurrent move_to_end/popitem
# corrupts the OrderedDict.
_COMPILE_LOCK = threading.RLock()


def clear_compile_cache() -> None:
    """Drop all cached compilations and zero the counters (mirrors
    ``keyspace.clear_union_cache``)."""
    with _COMPILE_LOCK:
        _COMPILE_CACHE.clear()
        reset_cache_stats()


def reset_cache_stats() -> None:
    with _COMPILE_LOCK:
        CACHE_STATS["hits"] = 0
        CACHE_STATS["misses"] = 0


def invalidate_compiled_for(digests) -> int:
    """Drop compiled selectors keyed on any of ``digests`` (KeySpace content
    hashes).  Ingest compaction retires a table's old keyspaces; their
    compiled selectors can never be *wrong* (content-keyed), but they pin
    rank tables for spaces no live table uses, so compaction sheds them."""
    stale = set(digests)
    if not stale:
        return 0
    with _COMPILE_LOCK:
        drop = [k for k in _COMPILE_CACHE if k[0] in stale]
        for k in drop:
            del _COMPILE_CACHE[k]
    return len(drop)


def compile_selector(sel, space: KeySpace) -> Compiled:
    """Compile a selector (or raw index argument) against a KeySpace."""
    sel = as_selector(sel)
    try:
        key = (space.digest, sel.cache_key())
    except TypeError:        # unhashable component: compile uncached
        return sel._compile(space)
    with _COMPILE_LOCK:
        hit = _COMPILE_CACHE.get(key)
        if hit is not None:
            CACHE_STATS["hits"] += 1
            _COMPILE_CACHE.move_to_end(key)  # LRU: refresh on hit
            return hit
    # _compile outside the lock: it is pure, so racing threads at worst
    # compile the same key twice and the second insert is a no-op.
    comp = sel._compile(space)
    with _COMPILE_LOCK:
        CACHE_STATS["misses"] += 1
        if key not in _COMPILE_CACHE:
            while len(_COMPILE_CACHE) >= _CACHE_CAP:
                _COMPILE_CACHE.popitem(last=False)   # evict LRU, no cliff
            _COMPILE_CACHE[key] = comp
    return comp

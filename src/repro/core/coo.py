"""Canonical COO triple-store: the one primitive behind every Assoc op.

The paper's associative-array model is "sorted key sets + a sparse
adjacency"; every operation on it — constructor aggregation, element-wise
⊕ over the union of key sets, element-wise ⊗ over the intersection, array
multiplication, assignment — reduces to **canonicalizing a bag of COO
triples**: lexsort by (row, col), ⊕-merge duplicate runs, compact the
result.  D4M.jl routes all algebra through exactly this primitive; this
module is our single shared implementation of it with two backends:

* :func:`canonicalize_np` — host (numpy) backend over integer code arrays
  and numeric **or string** values.  Numeric merges use ``ufunc.reduceat``;
  string/generic merges use a run-offset doubling loop that is vectorized
  over runs (O(max-run-length) bulk steps, never a per-element Python loop).
* :func:`dedup_sorted_coo` — device (jnp) backend over fixed-capacity
  sentinel-padded rank arrays, jit-safe, used by ``AssocTensor`` and the
  ``DistAssoc`` shard kernels.

Both backends share one contract: triples in, canonical sorted/merged
triples out.  ``Assoc`` (host) and ``AssocTensor`` (device) are thin views
over this layer; see also :func:`intersect_pairs_np` (rank-based sorted
intersection of key-pair sets) and :func:`spgemm_np` (host semiring
contraction via a vectorized sort-merge join).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .sorted_ops import INT_SENTINEL

__all__ = [
    "aggregate_runs",
    "apply_pair",
    "canonicalize_np",
    "intersect_pairs_np",
    "linearize_pairs_np",
    "spgemm_np",
    "spgemm_reduce_np",
    "expand_join_coo",
    "dedup_sorted_coo",
    "SENT",
]

SENT = jnp.int32(INT_SENTINEL)

AggLike = Union[str, Callable]

# named/builtin aggregators → numpy ufuncs (numeric fast path: reduceat)
_UFUNCS = {
    "min": np.minimum, "max": np.maximum, "sum": np.add, "add": np.add,
    "prod": np.multiply, min: np.minimum, max: np.maximum, sum: np.add,
}

# named aggregators → object-array pair ops (string / generic fallback path)
_PAIR_OPS = {
    "min": lambda a, b: np.where(a <= b, a, b),
    "max": lambda a, b: np.where(a >= b, a, b),
    "sum": lambda a, b: a + b,
    "add": lambda a, b: a + b,
    "concat": lambda a, b: a + b,
    "prod": lambda a, b: a * b,
    min: lambda a, b: np.where(a <= b, a, b),
    max: lambda a, b: np.where(a >= b, a, b),
    sum: lambda a, b: a + b,
}


def _pair_fn(combine) -> Callable:
    fn = _PAIR_OPS.get(combine)
    if fn is not None:
        return fn
    if isinstance(combine, np.ufunc):
        return combine
    if callable(combine):
        ufn = np.frompyfunc(combine, 2, 1)
        return ufn
    raise ValueError(f"unknown aggregator {combine!r}")


def apply_pair(combine: AggLike, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Apply a two-operand aggregator elementwise — the run-length-≤-2 case.

    Merging two individually-canonical triple sets produces duplicate runs
    of length exactly 2, so the whole ⊕-merge is one vectorized pairwise
    application; ``a`` holds the left (first) operand's values.
    """
    if combine == "first":
        return a
    if combine == "last":
        return b
    if np.asarray(a).dtype.kind in "fiub":
        ufunc = _UFUNCS.get(combine)
        if ufunc is None and isinstance(combine, np.ufunc):
            ufunc = combine
        if ufunc is not None:
            return ufunc(a, b)
        return np.asarray(_pair_fn(combine)(a, b), dtype=np.asarray(a).dtype)
    out = _pair_fn(combine)(np.asarray(a).astype(object), b)
    return np.asarray(out.tolist() if isinstance(out, np.ndarray) else out,
                      dtype=str)


def aggregate_runs(vals: np.ndarray, starts: np.ndarray,
                   combine: AggLike) -> np.ndarray:
    """⊕-merge duplicate runs of a (row, col)-sorted value array.

    ``starts`` are the run-head positions (first index of each duplicate
    group).  Returns one merged value per run, combining left-to-right in
    the sorted (stable) order — so order-sensitive ⊕ like string
    concatenation sees values in input order.
    """
    vals = np.asarray(vals)
    n = len(vals)
    if len(starts) == n:          # no duplicates at all
        return vals
    ends = np.r_[starts[1:], n]
    if combine == "first":
        return vals[starts]
    if combine == "last":
        return vals[ends - 1]

    ufunc = _UFUNCS.get(combine)
    if ufunc is None and isinstance(combine, np.ufunc):
        ufunc = combine
    if ufunc is not None and vals.dtype.kind in "fiub":
        return ufunc.reduceat(vals, starts)

    # generic/string path: vectorized over runs, one bulk step per extra
    # run element (duplicate runs are short in practice: 2-operand merges
    # produce runs of length ≤ 2 ⇒ exactly one step).
    pair = _pair_fn(combine)
    lengths = ends - starts
    numeric = vals.dtype.kind in "fiub"
    # object accumulator: string results may outgrow the input itemsize
    acc = vals[starts].astype(object)
    for k in range(1, int(lengths.max())):
        sel = np.flatnonzero(lengths > k)
        acc[sel] = pair(acc[sel], vals[starts[sel] + k])
    return acc.astype(vals.dtype) if numeric else acc.astype(str)


def canonicalize_np(rows, cols, vals, combine: AggLike = "min"
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host backend: lexsort + duplicate-run ⊕-merge + compaction.

    ``rows``/``cols`` are integer code (or rank) arrays, ``vals`` numeric or
    string values of the same length.  Returns ``(rows, cols, vals)`` sorted
    by ``(row, col)`` with every pair unique — the canonical triple form
    that both the paper's constructor and all element-wise algebra share.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    if len(rows) == 0:
        return rows, cols, vals
    order = np.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    new_run = np.r_[True, (r[1:] != r[:-1]) | (c[1:] != c[:-1])]
    starts = np.flatnonzero(new_run)
    return r[starts], c[starts], aggregate_runs(v, starts, combine)


def linearize_pairs_np(rows, cols, ncols: int) -> np.ndarray:
    """(row, col) code pairs → one int64 linear code per pair.

    ``code = row * ncols + col`` — a total order on key pairs that lets
    element-wise intersection/masking run as a sorted-set operation on
    integers (:func:`intersect_pairs_np`) instead of per-element probing.
    """
    return (np.asarray(rows).astype(np.int64) * np.int64(max(int(ncols), 1))
            + np.asarray(cols))


def intersect_pairs_np(lin_a: np.ndarray, lin_b: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Rank-based sorted intersection of two unique (row, col) pair-code sets.

    ``lin_a``/``lin_b`` are int64 linearized pair codes (``row * ncols +
    col`` over a shared keyspace).  Returns positions ``(ia, ib)`` into each
    input such that ``lin_a[ia] == lin_b[ib]`` — the paper's element-wise
    intersection without any per-element dictionary probing.
    """
    _, ia, ib = np.intersect1d(lin_a, lin_b, assume_unique=True,
                               return_indices=True)
    return ia, ib


def spgemm_np(a_row, a_k, a_val, b_k, b_col, b_val,
              mul: Callable, add: AggLike
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host semiring contraction ``C[i,j] = ⊕_k A[i,k] ⊗ B[k,j]`` on codes.

    ``(a_row, a_k, a_val)`` are A's triples with contraction codes ``a_k``;
    ``(b_k, b_col, b_val)`` are B's triples **sorted by** ``b_k``.  The join
    is a vectorized sort-merge: each A entry expands against its B run via
    ``searchsorted`` + ``repeat``, products are formed in bulk with ⊗, and
    one :func:`canonicalize_np` pass ⊕-merges them.  No Python loops.
    """
    empty = (np.empty(0, a_row.dtype if len(a_row) else np.int64),
             np.empty(0, b_col.dtype if len(b_col) else np.int64),
             np.empty(0, np.float64))
    if len(a_row) == 0 or len(b_k) == 0:
        return empty
    lo = np.searchsorted(b_k, a_k, side="left")
    hi = np.searchsorted(b_k, a_k, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        return empty
    a_idx = np.repeat(np.arange(len(a_row)), counts)
    run_base = np.repeat(np.cumsum(counts) - counts, counts)
    b_idx = np.repeat(lo, counts) + (np.arange(total) - run_base)
    rows = a_row[a_idx]
    cols = b_col[b_idx]
    vals = mul(a_val[a_idx], b_val[b_idx])
    return canonicalize_np(rows, cols, vals, combine=add)


def spgemm_reduce_np(a_row, a_k, a_val, b_k, b_col, b_val,
                     mul: Callable, add_np: np.ufunc, zero: float,
                     axis: int, n_out: int) -> np.ndarray:
    """Fused host contraction + ⊕-reduction: never materializes C.

    Computes ``⊕_j C[i, j]`` (``axis=1``, vector over A's row codes) or
    ``⊕_i C[i, j]`` (``axis=0``, vector over B's col codes) for
    ``C = A ⊗.⊕ B`` — since ⊕ is associative and commutative the reduction
    folds directly over the expanded products, so the canonicalize pass (and
    C's triples) are skipped entirely.  Same operand layout as
    :func:`spgemm_np`; ``add_np`` must be a true ufunc (``.at`` scatter).
    Graphulo's server-side combine, on host: one segment scatter per product.
    """
    out = np.full(n_out, zero, dtype=np.float64)
    if len(a_row) == 0 or len(b_k) == 0:
        return out
    lo = np.searchsorted(b_k, a_k, side="left")
    hi = np.searchsorted(b_k, a_k, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        return out
    a_idx = np.repeat(np.arange(len(a_row)), counts)
    run_base = np.repeat(np.cumsum(counts) - counts, counts)
    b_idx = np.repeat(lo, counts) + (np.arange(total) - run_base)
    keys = a_row[a_idx] if axis == 1 else b_col[b_idx]
    vals = np.asarray(mul(a_val[a_idx], b_val[b_idx]), dtype=np.float64)
    add_np.at(out, keys, vals)
    return out


# ---------------------------------------------------------------------------
# Device backend: sort + duplicate-run aggregation on fixed-capacity,
# sentinel-padded rank triples.
#
# Given COO triples (possibly with duplicates and sentinel padding), produce
# the canonical form: lexicographically sorted by (row, col), duplicates
# merged with ⊕, valid entries compacted to the front, tail sentinel-padded.
# This one primitive implements the paper's constructor aggregation AND both
# element-wise ops (union-with-⊕ and run-length-2 intersection-with-⊗).
# ---------------------------------------------------------------------------

def dedup_sorted_coo(rows, cols, vals, combine, *, zero: float = 0.0,
                     require_pair: bool = False, pair_op=None,
                     src: Optional[jnp.ndarray] = None):
    """Canonicalize COO triples on device (jit-safe, shape-static).

    Parameters
    ----------
    rows, cols: int32[cap] rank arrays; sentinel-padded entries are dropped.
    vals:       float[cap] values.
    combine:    ⊕ used to merge duplicate (row, col) runs (semiring add or an
                aggregation op).  Must be associative & commutative.
    require_pair: if True, keep ONLY entries forming a cross-source duplicate
                pair (element-wise intersection); ``src`` flags the source
                array (0/1) and ``pair_op`` is the ⊗ applied across the pair.
    Returns (rows, cols, vals, nnz) in canonical sorted/padded form.
    """
    cap = rows.shape[0]
    valid = rows != SENT
    # lexsort by (row, col); sentinels sort last because SENT is max int32
    order = jnp.lexsort((cols, rows))
    r, c, v = rows[order], cols[order], vals[order]
    ok = valid[order]
    if src is not None:
        s = src[order]

    same_as_prev = jnp.concatenate([
        jnp.array([False]),
        (r[1:] == r[:-1]) & (c[1:] == c[:-1]) & ok[1:],
    ])

    if require_pair:
        # intersection: inputs are individually dedup'd, so runs have length
        # ≤ 2 and a pair always spans both sources.
        same_as_next = jnp.concatenate([same_as_prev[1:], jnp.array([False])])
        is_pair_head = same_as_next
        nxt = jnp.clip(jnp.arange(cap) + 1, 0, cap - 1)
        a_val = jnp.where(s == 0, v, v[nxt])   # value from source 0
        b_val = jnp.where(s == 0, v[nxt], v)   # value from source 1
        out_v = pair_op(a_val, b_val)
        keep = is_pair_head & ok
        r = jnp.where(keep, r, SENT)
        c = jnp.where(keep, c, SENT)
        v = jnp.where(keep, out_v, zero)
    else:
        # union/aggregate: segment-combine runs onto the run head.
        # Runs are short in practice (2 sources ⇒ ≤2; constructor ⇒ small),
        # but we handle arbitrary lengths with a log-step doubling scan.
        seg_id = jnp.cumsum((~same_as_prev).astype(jnp.int32)) - 1
        # segment-reduce via sort-order associativity: combine progressively
        step = 1
        acc = v
        alive = ok
        while step < cap:
            shifted = jnp.roll(acc, step)
            shifted_seg = jnp.roll(seg_id, step)
            shifted_alive = jnp.roll(alive, step)
            same_seg = (shifted_seg == seg_id) & (jnp.arange(cap) >= step)
            contrib = same_seg & shifted_alive & alive
            acc = jnp.where(contrib, combine(acc, shifted), acc)
            step *= 2
        # run tail now holds the full combine; move it to the head via the
        # trick of flipping: easier — recompute head as combine over run by
        # taking the value at the run's LAST element.
        is_head = ~same_as_prev & ok
        run_last = jnp.concatenate([(~same_as_prev[1:]), jnp.array([True])])
        # index of last element of the run each head starts
        head_pos = jnp.flatnonzero(is_head, size=cap, fill_value=cap - 1)
        last_pos = jnp.flatnonzero(run_last & ok, size=cap, fill_value=cap - 1)
        v_heads = acc[last_pos]
        r = jnp.where(is_head, r, SENT)
        c = jnp.where(is_head, c, SENT)
        v = jnp.zeros_like(v).at[head_pos].set(v_heads)
        v = jnp.where(is_head, v, zero)

    # drop zeros ("empty" values are unstored, matching the paper)
    nonzero = v != zero
    keepmask = (r != SENT) & nonzero
    r = jnp.where(keepmask, r, SENT)
    c = jnp.where(keepmask, c, SENT)
    v = jnp.where(keepmask, v, zero)
    # compact to front: stable sort on validity
    order2 = jnp.lexsort((c, r))  # sentinels (SENT) go last; order preserved
    r, c, v = r[order2], c[order2], v[order2]
    nnz = (r != SENT).sum().astype(jnp.int32)
    return r, c, v, nnz


def expand_join_coo(a_rows, a_cols, a_vals, b_rows, b_cols, b_vals,
                    mul, *, zero: float, expand: int):
    """Device sort-merge join of two COO operands — jit/shard_map-safe.

    The device mirror of :func:`spgemm_np`'s expansion step: contraction
    codes are A's cols and B's rows; B must be in canonical (row, col) order
    (every canonical COO already is, and rank translation onto merged
    keyspaces is monotone, so reranked operands stay sorted).  Each A entry
    expands against its B run via two ``searchsorted`` calls; the expansion
    is laid out into a **static** ``expand``-sized buffer (products beyond it
    are dropped — callers size ``expand`` from host-side exact counts, see
    ``DistAssoc.matmul``).  Returns pre-⊕ product triples
    ``(rows, cols, vals, total)`` with sentinel padding; ⊕-merging them is
    one :func:`dedup_sorted_coo` pass (or a direct segment scatter for the
    fused reduce epilogues, where no merge is needed at all).

    Never densifies: peak memory is the two operands plus ``expand``
    product slots.
    """
    cap_a = a_rows.shape[0]
    cap_b = b_rows.shape[0]
    lo = jnp.searchsorted(b_rows, a_cols, side="left")
    hi = jnp.searchsorted(b_rows, a_cols, side="right")
    ok = a_rows != SENT
    counts = jnp.where(ok, hi - lo, 0)
    cum = jnp.cumsum(counts)
    total = cum[cap_a - 1] if cap_a else jnp.int32(0)
    e = jnp.arange(expand, dtype=jnp.int32)
    # which A entry produced product slot e: first index with cum > e
    a_of = jnp.clip(jnp.searchsorted(cum, e, side="right"), 0, cap_a - 1)
    start = cum[a_of] - counts[a_of]
    b_idx = jnp.clip(lo[a_of] + (e - start), 0, cap_b - 1)
    valid = e < total
    rows = jnp.where(valid, a_rows[a_of], SENT)
    cols = jnp.where(valid, b_cols[b_idx], SENT)
    vals = jnp.where(valid, mul(a_vals[a_of], b_vals[b_idx]), zero)
    return rows, cols, vals, total


def bucket_coo_by_range(rows, cols, vals, bounds, n_buckets: int,
                        bucket_cap: int, *, zero: float):
    """Scatter COO triples into ``[n_buckets, bucket_cap]`` buckets keyed by
    the range of ``rows`` — jit/shard_map-safe.

    The routing step of the sharded-B all-to-all product: partial products
    land on the shard that owns their output row, so each producer buckets
    its triples by ``searchsorted(bounds[1:], rows)`` before the exchange.
    ``bounds`` is the ``[n_buckets+1]`` rank-boundary array (the same
    ``row_bounds`` the DistAssoc partition uses); sentinel rows and bucket
    overflow beyond ``bucket_cap`` are dropped via out-of-bounds scatter —
    callers size ``bucket_cap`` from host-side exact counts so the main
    path never overflows.  Returns ``(rows, cols, vals)`` each shaped
    ``[n_buckets, bucket_cap]``, sentinel/zero padded.
    """
    ok = rows != SENT
    dest = jnp.searchsorted(bounds[1:], rows, side="right").astype(jnp.int32)
    dest = jnp.where(ok, dest, n_buckets)          # invalid → OOB → dropped
    order = jnp.argsort(dest, stable=True)
    d = dest[order]
    # rank within bucket: position minus the bucket's run start
    slot = jnp.arange(rows.shape[0]) - jnp.searchsorted(d, d, side="left")
    out_r = jnp.full((n_buckets, bucket_cap), SENT, jnp.int32)
    out_c = jnp.full((n_buckets, bucket_cap), SENT, jnp.int32)
    out_v = jnp.full((n_buckets, bucket_cap), zero, vals.dtype)
    out_r = out_r.at[d, slot].set(rows[order], mode="drop")
    out_c = out_c.at[d, slot].set(cols[order], mode="drop")
    out_v = out_v.at[d, slot].set(vals[order], mode="drop")
    return out_r, out_c, out_v

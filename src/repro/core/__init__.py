"""repro.core — the paper's contribution: D4M associative arrays in JAX.

* ``coo``          — the canonical COO/semiring triple-store core every
                     associative-array implementation builds on
                     (host ``canonicalize_np`` / device ``dedup_sorted_coo``).
* ``Assoc``        — paper-faithful host implementation (numpy/scipy).
* ``AssocTensor``  — TPU-native device implementation (padded COO, semirings).
* ``KeySpace``     — host key dictionaries backing device rank arrays.
* ``Semiring``     — the value algebras (⊕, ⊗, 0, 1).
* ``DistAssoc``    — mesh-sharded associative arrays (the Distributed D).
* ``expr``/``plan`` — lazy expression graphs + the planner/executor behind
                     them (``A.lazy()[sel] @ B.lazy()[sel] … .collect()``);
                     the eager operators are thin wrappers over one-node
                     graphs, so lazy and eager share a single code path.

Telemetry counters (and their reset helpers) are exported together so
benchmarks and tests can assert a fast path actually fired:
``CACHE_STATS`` (selector compilation), ``UNION_STATS`` (keyspace-union
memoization), ``DISPATCH_STATS`` (selection execution paths) and
``PLAN_STATS`` (expression hash-consing + planner rewrites).
"""
from .assoc import Assoc
from .assoc_tensor import AssocTensor, DISPATCH_STATS
from .coo import (aggregate_runs, canonicalize_np, dedup_sorted_coo,
                  intersect_pairs_np, linearize_pairs_np, spgemm_np)
from .dist_assoc import DistAssoc
from .expr import (EwiseAdd, EwiseMul, LazyExpr, MatMul, Reduce, Select,
                   Source, Transpose, lazy)
from .keyspace import KeySpace, UNION_STATS, clear_union_cache
from .plan import PLAN_STATS, clear_plan_cache, reset_plan_stats
from .select import (All, CACHE_STATS, Keys, Mask, Match, Positions, Range,
                     Selector, StartsWith, Where, as_selector,
                     clear_compile_cache, compile_selector, reset_cache_stats)
from .semiring import (AND_OR, MAX_MIN, MAX_PLUS, MAX_TIMES, MIN_PLUS,
                       PLUS_TIMES, REGISTRY, STRING, Semiring, get_semiring,
                       mesh_combine, scatter_combine)
from .spgemm import matmul_reduce, plan_matmul
from .sorted_ops import (INT_SENTINEL, sorted_intersect,
                         sorted_intersect_padded, sorted_union,
                         sorted_union_padded)


def reset_all_stats():
    """Zero every telemetry counter in one call.

    Covers ``UNION_STATS`` (and drops the keyspace-union cache),
    ``CACHE_STATS`` (selector compilation — counters only; compiled
    selectors stay warm), ``DISPATCH_STATS`` (selection execution paths)
    and ``PLAN_STATS`` (and drops the plan cache).  Tests get this
    between cases from the autouse fixture in ``tests/conftest.py``;
    benchmarks call it before a measured region.
    """
    clear_union_cache()
    reset_cache_stats()
    for k in DISPATCH_STATS:
        DISPATCH_STATS[k] = 0
    reset_plan_stats()


__all__ = [
    "Assoc", "AssocTensor", "DistAssoc", "KeySpace", "Semiring",
    "get_semiring",
    "REGISTRY", "PLUS_TIMES", "MAX_PLUS", "MIN_PLUS", "MAX_MIN", "MAX_TIMES",
    "AND_OR", "STRING", "INT_SENTINEL", "sorted_union", "sorted_intersect",
    "sorted_union_padded", "sorted_intersect_padded",
    "aggregate_runs", "canonicalize_np", "dedup_sorted_coo",
    "intersect_pairs_np", "linearize_pairs_np", "spgemm_np",
    "matmul_reduce", "plan_matmul", "mesh_combine", "scatter_combine",
    "Selector", "Keys", "Range", "StartsWith", "Match", "Where", "Mask",
    "Positions", "All", "as_selector", "compile_selector",
    # lazy expressions + planner
    "LazyExpr", "Source", "Select", "EwiseAdd", "EwiseMul", "MatMul",
    "Reduce", "Transpose", "lazy",
    # telemetry counters + reset helpers
    "reset_all_stats",
    "PLAN_STATS", "reset_plan_stats", "clear_plan_cache",
    "CACHE_STATS", "clear_compile_cache", "reset_cache_stats",
    "UNION_STATS", "clear_union_cache",
    "DISPATCH_STATS",
]

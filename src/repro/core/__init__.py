"""repro.core — the paper's contribution: D4M associative arrays in JAX.

* ``coo``          — the canonical COO/semiring triple-store core every
                     associative-array implementation builds on
                     (host ``canonicalize_np`` / device ``dedup_sorted_coo``).
* ``Assoc``        — paper-faithful host implementation (numpy/scipy).
* ``AssocTensor``  — TPU-native device implementation (padded COO, semirings).
* ``KeySpace``     — host key dictionaries backing device rank arrays.
* ``Semiring``     — the value algebras (⊕, ⊗, 0, 1).
* ``DistAssoc``    — mesh-sharded associative arrays (the Distributed D).
"""
from .assoc import Assoc
from .assoc_tensor import AssocTensor
from .coo import (aggregate_runs, canonicalize_np, dedup_sorted_coo,
                  intersect_pairs_np, linearize_pairs_np, spgemm_np)
from .dist_assoc import DistAssoc
from .keyspace import KeySpace
from .select import (All, Keys, Mask, Match, Positions, Range, Selector,
                     StartsWith, Where, as_selector, compile_selector)
from .semiring import (AND_OR, MAX_MIN, MAX_PLUS, MAX_TIMES, MIN_PLUS,
                       PLUS_TIMES, REGISTRY, STRING, Semiring, get_semiring,
                       mesh_combine, scatter_combine)
from .spgemm import matmul_reduce, plan_matmul
from .sorted_ops import (INT_SENTINEL, sorted_intersect,
                         sorted_intersect_padded, sorted_union,
                         sorted_union_padded)

__all__ = [
    "Assoc", "AssocTensor", "DistAssoc", "KeySpace", "Semiring",
    "get_semiring",
    "REGISTRY", "PLUS_TIMES", "MAX_PLUS", "MIN_PLUS", "MAX_MIN", "MAX_TIMES",
    "AND_OR", "STRING", "INT_SENTINEL", "sorted_union", "sorted_intersect",
    "sorted_union_padded", "sorted_intersect_padded",
    "aggregate_runs", "canonicalize_np", "dedup_sorted_coo",
    "intersect_pairs_np", "linearize_pairs_np", "spgemm_np",
    "matmul_reduce", "plan_matmul", "mesh_combine", "scatter_combine",
    "Selector", "Keys", "Range", "StartsWith", "Match", "Where", "Mask",
    "Positions", "All", "as_selector", "compile_selector",
]

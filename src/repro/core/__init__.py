"""repro.core — the paper's contribution: D4M associative arrays in JAX.

* ``Assoc``        — paper-faithful host implementation (numpy/scipy).
* ``AssocTensor``  — TPU-native device implementation (padded COO, semirings).
* ``KeySpace``     — host key dictionaries backing device rank arrays.
* ``Semiring``     — the value algebras (⊕, ⊗, 0, 1).
* ``DistAssoc``    — mesh-sharded associative arrays (the Distributed D).
"""
from .assoc import Assoc
from .assoc_tensor import AssocTensor
from .keyspace import KeySpace
from .semiring import (AND_OR, MAX_MIN, MAX_PLUS, MAX_TIMES, MIN_PLUS,
                       PLUS_TIMES, STRING, Semiring, get_semiring)
from .sorted_ops import (INT_SENTINEL, sorted_intersect,
                         sorted_intersect_padded, sorted_union,
                         sorted_union_padded)

__all__ = [
    "Assoc", "AssocTensor", "KeySpace", "Semiring", "get_semiring",
    "PLUS_TIMES", "MAX_PLUS", "MIN_PLUS", "MAX_MIN", "MAX_TIMES", "AND_OR",
    "STRING", "INT_SENTINEL", "sorted_union", "sorted_intersect",
    "sorted_union_padded", "sorted_intersect_padded",
]

"""Sorted-set primitives: union / intersection with index maps.

These are the paper's §II.C building blocks.  The paper constructs the sorted
union/intersection of two repetition-free sorted key arrays with a scalar
merge loop, recording *index maps* describing how each input embeds into the
result.  Those index maps are what lets ``A.adj`` / ``B.adj`` be re-indexed
onto the combined key space so a single bulk sparse-linear-algebra call
finishes the job.

Two implementations:

* ``sorted_union`` / ``sorted_intersect`` — host (numpy) reference with the
  exact semantics of the paper's merge loop, but vectorized via two-sided
  ``searchsorted`` (no Python-level loop; this is already the first
  TPU-minded rewrite and is what the host ``Assoc`` uses).
* ``sorted_union_padded`` / ``sorted_intersect_padded`` — shape-static jnp
  versions for fixed-capacity device arrays (sentinel-padded), jit-safe;
  the Pallas ``sorted_merge`` kernel accelerates the same contract.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "sorted_union",
    "sorted_intersect",
    "sorted_union_padded",
    "sorted_intersect_padded",
    "INT_SENTINEL",
]

# Padding sentinel for int32 rank arrays: sorts after every valid rank.
INT_SENTINEL = np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# Host (numpy) — used by the paper-faithful Assoc
# ---------------------------------------------------------------------------

def sorted_union(i: np.ndarray, j: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted union of two repetition-free sorted arrays with index maps.

    Returns ``(k, i_map, j_map)`` where ``k`` is the sorted union and
    ``k[i_map] == i`` and ``k[j_map] == j`` elementwise (the paper's "how I
    and J sit within K").

    The concatenation of two sorted runs is merged with a *stable* sort
    (timsort gallops through presorted runs in ~O(n)) rather than
    ``np.union1d``'s full introsort — noticeably cheaper for the string key
    arrays the host ``Assoc`` unions on every element-wise op.
    """
    i = np.asarray(i)
    j = np.asarray(j)
    k = np.concatenate([i, j])
    k.sort(kind="stable")  # two presorted runs: timsort merge, ~O(n)
    if len(k):
        k = k[np.r_[True, k[1:] != k[:-1]]]
    i_map = np.searchsorted(k, i)
    j_map = np.searchsorted(k, j)
    return k, i_map, j_map


def sorted_intersect(i: np.ndarray, j: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted intersection with index maps *into the inputs*.

    Returns ``(k, i_map, j_map)`` with ``i[i_map] == k`` and ``j[j_map] == k``
    (the paper records how K sits within I and J).

    Same timsort trick as :func:`sorted_union`: the concatenation of two
    sorted repetition-free runs is merged with a *stable* sort (timsort
    gallops through presorted runs in ~O(n)); an element appears twice in
    the merge iff it lies in both inputs, so adjacent duplicates ARE the
    intersection — no ``np.intersect1d`` re-sort.
    """
    i = np.asarray(i)
    j = np.asarray(j)
    k = np.concatenate([i, j])
    k.sort(kind="stable")  # two presorted runs: timsort merge, ~O(n)
    k = k[:-1][k[1:] == k[:-1]] if len(k) else k
    i_map = np.searchsorted(i, k)
    j_map = np.searchsorted(j, k)
    return k, i_map, j_map


# ---------------------------------------------------------------------------
# Device (jnp, shape-static) — used by AssocTensor
#
# Inputs are int32 rank arrays of static length, sorted ascending, padded at
# the tail with INT_SENTINEL.  Outputs have static capacity len(i)+len(j)
# (union) / min(len(i), len(j)) (intersection), padded the same way, plus the
# actual count.
# ---------------------------------------------------------------------------

def sorted_union_padded(i: jnp.ndarray, j: jnp.ndarray):
    """Shape-static sorted union of sentinel-padded sorted int32 arrays.

    Returns ``(k, nk, i_map, j_map)``:
      * ``k``:  int32[len(i)+len(j)] sorted union, sentinel-padded,
      * ``nk``: int32 scalar count of valid entries,
      * ``i_map``/``j_map``: positions of each input element within ``k``
        (sentinel positions map to the tail and are masked by callers).

    Strategy: positions in the merged order are computable analytically —
    element ``i[m]`` lands at ``m + (# j strictly below it)`` and ``j[n]`` at
    ``n + (# i at-or-below it)``; duplicates collapse because the j-copy maps
    onto the i-copy's slot.  A scatter-min compacts the union.  This is the
    merge-path formulation the Pallas kernel tiles.
    """
    ni_cap, nj_cap = i.shape[0], j.shape[0]
    cap = ni_cap + nj_cap
    sent = jnp.int32(INT_SENTINEL)

    # rank of each element in the merged multiset
    i_in_j = jnp.searchsorted(j, i, side="left")   # # of j strictly less
    j_in_i = jnp.searchsorted(i, j, side="right")  # # of i less-or-equal
    i_pos = jnp.arange(ni_cap, dtype=jnp.int32) + i_in_j.astype(jnp.int32)
    j_pos = jnp.arange(nj_cap, dtype=jnp.int32) + j_in_i.astype(jnp.int32)

    # duplicates: j element equal to some i element occupies the same slot
    j_dup = (j_in_i > 0) & (i[jnp.clip(j_in_i - 1, 0, ni_cap - 1)] == j)
    j_pos = jnp.where(j_dup, j_pos - 1, j_pos)

    # merged array with duplicates collapsed; sentinel-valid mask
    merged = jnp.full((cap,), sent, dtype=jnp.int32)
    merged = merged.at[i_pos].set(i, mode="drop")
    merged = merged.at[j_pos].set(j, mode="drop")

    # compact: valid slots are those < sentinel; stable-partition via argsort
    # of (is_sentinel, position) — equivalently sort merged (sentinels sort
    # to the tail and order among valid entries is already ascending).
    slot_valid = merged != sent
    order = jnp.argsort(~slot_valid, stable=True)  # valid slots first, in order
    k = merged[order]
    nk = slot_valid.sum().astype(jnp.int32)

    # index maps: position of the slot each element landed in after compaction
    inv = jnp.zeros((cap,), dtype=jnp.int32).at[order].set(
        jnp.arange(cap, dtype=jnp.int32))
    i_map = inv[i_pos]
    j_map = inv[j_pos]
    # sentinel inputs map to tail
    i_map = jnp.where(i == sent, cap - 1, i_map)
    j_map = jnp.where(j == sent, cap - 1, j_map)
    return k, nk, i_map, j_map


def sorted_intersect_padded(i: jnp.ndarray, j: jnp.ndarray):
    """Shape-static sorted intersection of sentinel-padded sorted arrays.

    Returns ``(k, nk, i_map, j_map)`` with capacity ``min(len(i), len(j))``;
    ``i_map``/``j_map`` give, for each valid ``k[t]``, its position in ``i``
    / ``j`` (tail positions are clamped and masked by ``t < nk``).
    """
    cap = min(i.shape[0], j.shape[0])
    sent = jnp.int32(INT_SENTINEL)

    pos_in_j = jnp.searchsorted(j, i, side="left")
    hit = (pos_in_j < j.shape[0]) & (j[jnp.clip(pos_in_j, 0, j.shape[0] - 1)] == i)
    hit = hit & (i != sent)

    # compact the hits into the first nk slots, preserving order
    order = jnp.argsort(~hit, stable=True)[:cap]
    nk = hit.sum().astype(jnp.int32)
    valid = jnp.arange(cap) < nk
    k = jnp.where(valid, i[order], sent)
    i_map = jnp.where(valid, order.astype(jnp.int32), jnp.int32(0))
    j_map = jnp.where(valid, pos_in_j[order].astype(jnp.int32), jnp.int32(0))
    return k, nk, i_map, j_map

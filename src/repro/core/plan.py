"""Planner + executor for lazy D4M expressions (the other half of expr.py).

``collect()`` hands an expression graph here.  The planner rewrites it
before anything executes:

* **selector pushdown** — ``Select`` nodes move through ``Transpose``
  (axes swap), element-wise ⊕/⊗ (applied to both operands) and ``MatMul``
  (row selection to A, column selection to B), and adjacent selections
  compose with the selector algebra's ``&``.  Only *key-based* selectors
  are pushed (``Keys``/``Range``/``StartsWith``/``Match``/``Where`` and
  their ``&``/``|``/``~`` compositions): their membership is a pure
  predicate of the key, so it commutes with any keyspace change the
  operation makes.  ``Positions``/``Mask`` address ranks of the *result*
  keyspace and stay put.
* **select→matmul fusion** — a selection sitting on a matmul operand is
  compiled (``select.py`` compiled forms) and folded into the spgemm
  plan: the packed-tile lists and rank ranges are sliced on host and the
  values gathered once, so the sliced operand is **never built as an
  array** (no compact, no lexsort, no canonicalize).  ``DistAssoc``
  executes the same fusion shard-locally (rows of deselected entries are
  sentinel-masked in place; broadcast-B entries outside the selection are
  ⊗-annihilated by setting their value to the semiring zero) with zero
  collectives.
* **MatMul→Reduce fusion** — ``Reduce(MatMul(a, b, sr), axis, sr)``
  collapses onto the fused ``matmul_reduce`` epilogues (the
  ``sqin``/``sqout`` family): C is never materialized on any layer.
* **ewise-chain fusion** — ``A ⊕ B ⊕ C ⊕ …`` under one semiring runs as a
  single canonicalize pass over all operands' triples instead of one pass
  per ``⊕``.
* **hash-consing** — repeated subtrees (same sources, same structure)
  execute once per ``collect()``; ``PLAN_STATS`` counts hits/misses and
  the rewrites, mirroring ``UNION_STATS``/``DISPATCH_STATS``.

The executor then evaluates the optimized graph on whichever layer the
sources live on — host ``Assoc``, device ``AssocTensor``, or sharded
``DistAssoc`` — by dispatching to the layers' *physical* methods.  Eager
operators are thin wrappers that build a one-node graph and collect it, so
lazy and eager share this single execution path.

This module also hosts the **shared axis-reduction path**
(:func:`host_axis_reduce` / :func:`device_axis_reduce`): ``Assoc.sum``,
``AssocTensor.reduce_rows``/``reduce_cols`` and the ``Reduce`` node all
route through it, so reduction dtype/zero rules come from the PR 3 combine
helpers (``scatter_combine`` / ``add_np``) in one place.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .coo import SENT, canonicalize_np, dedup_sorted_coo
from .expr import (EwiseAdd, EwiseMul, LazyExpr, MatMul, Reduce, Select,
                   Source, Transpose)
from .select import (All, And, Compiled, Keys, Match, Not, Or, Range,
                     StartsWith, Where, as_selector, compile_selector)
from .semiring import PLUS_TIMES, get_semiring, scatter_combine
from .sorted_ops import sorted_intersect, sorted_union

__all__ = ["execute", "optimize", "PLAN_STATS", "reset_plan_stats",
           "clear_plan_cache", "host_axis_reduce", "device_axis_reduce",
           "host_matmul"]


# Planner/executor telemetry, matching UNION_STATS / DISPATCH_STATS /
# CACHE_STATS: hash-consing hit/miss counts plus one counter per rewrite
# family, so tests and benchmarks can assert a fusion actually fired.
# ``plan_hits``/``plan_misses`` count the *cross-collect* plan cache: a
# repeated pipeline (same structural key over the same source arrays)
# skips the optimize() walk entirely on its second and later collects.
PLAN_STATS = {
    "hits": 0, "misses": 0,
    "plan_hits": 0, "plan_misses": 0,
    "pushdown": 0, "fused_matmul_reduce": 0,
    "fused_select_matmul": 0, "ewise_fused": 0,
    "reduce_through_add": 0, "fused_select_ewise": 0,
    # distributed matmul strategy choices (DistAssoc.matmul/_reduce):
    # which communication pattern the cost model — or an explicit impl=
    # override — actually ran
    "dist_replicate": 0, "dist_all_to_all": 0, "dist_2d": 0,
    # plan-cache entries dropped because a compaction (repro.ingest)
    # retired the Source arrays they were keyed on
    "plan_invalidations": 0,
}


# One lock guards the plan cache's LRU mutation AND the PLAN_STATS bumps:
# a concurrent server collects from many worker threads, and OrderedDict
# move_to_end/popitem under concurrent mutation corrupts the dict.  RLock
# (not Lock) because reset_plan_stats() -> clear_plan_cache() re-enters.
_PLAN_LOCK = threading.RLock()


def _bump(key: str, n: int = 1) -> None:
    """Locked PLAN_STATS increment (dict ``+=`` is a read-modify-write —
    concurrent collects would silently lose counts)."""
    with _PLAN_LOCK:
        PLAN_STATS[key] += n


def reset_plan_stats() -> None:
    """Zero the counters AND cold-start the planner (plan cache cleared):
    a fresh measurement window should see its own misses and rewrites, not
    inherit plans memoized by earlier pipelines."""
    with _PLAN_LOCK:
        for k in PLAN_STATS:
            PLAN_STATS[k] = 0
        clear_plan_cache()


# Cross-collect plan cache: optimized graph memoized by the hash-consed
# structural key (expr.key(): node structure + id() of source arrays and
# opaque selectors).  Identity keys cannot go stale while an entry lives —
# the cached graph itself pins its Source arrays and selector objects, so
# their ids are not reusable — and in-place value mutation is safe because
# the cache stores the *rewrite*, never results.  LRU-bounded so pinned
# arrays cannot accumulate without bound.
_PLAN_CACHE: "OrderedDict[tuple, LazyExpr]" = OrderedDict()
_PLAN_CACHE_CAP = 256


def clear_plan_cache() -> None:
    """Invalidation hook: drop every memoized optimized plan (and with it
    the pinned references to their source arrays/selectors)."""
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()


def _key_touches(key, ids: set) -> bool:
    """Does a structural plan key reference any ``("src", id)`` leaf with
    an id in ``ids``?  Keys are nested tuples (expr.key())."""
    if isinstance(key, tuple):
        if len(key) == 2 and key[0] == "src" and key[1] in ids:
            return True
        return any(_key_touches(k, ids) for k in key)
    return False


def invalidate_plan_for(array_ids) -> int:
    """Targeted invalidation: drop every cached plan whose key references
    one of ``array_ids`` (``id()`` of retired Source arrays).

    Used by ingest compaction (:mod:`repro.ingest`): the compacted table's
    old base and merged snapshots are retired, and any plan keyed on them
    would pin the dead arrays until LRU eviction.  Identity keys cannot
    serve stale *results* (the new base is a new object ⇒ new key); this
    hook reclaims the memory and keeps the LRU hot for live tables.
    """
    ids = set(array_ids)
    if not ids:
        return 0
    with _PLAN_LOCK:
        drop = [k for k in _PLAN_CACHE if _key_touches(k, ids)]
        for k in drop:
            del _PLAN_CACHE[k]
        PLAN_STATS["plan_invalidations"] += len(drop)
    return len(drop)


def _layer(x) -> str:
    from .assoc import Assoc
    from .assoc_tensor import AssocTensor
    from .dist_assoc import DistAssoc
    if isinstance(x, Assoc):
        return "host"
    if isinstance(x, AssocTensor):
        return "device"
    if isinstance(x, DistAssoc):
        return "dist"
    raise TypeError(f"not an associative array: {type(x)!r}")


# ---------------------------------------------------------------------------
# Rewrite pass 1: selector pushdown
# ---------------------------------------------------------------------------

def _pushable(sel) -> bool:
    """True iff the selector's membership is a pure predicate of the key.

    Such selectors commute with transpose/ewise/matmul and compose with
    ``&`` across nested selections.  ``Positions``/``Mask``/non-trivial
    slices address ranks of a *specific* keyspace and must not move.
    """
    try:
        s = as_selector(sel)
    except TypeError:
        return False
    if isinstance(s, (Keys, Range, StartsWith, Match, Where, All)):
        return True
    if isinstance(s, (And, Or)):
        return _pushable(s.a) and _pushable(s.b)
    if isinstance(s, Not):
        return _pushable(s.a)
    return False


def _push(node: LazyExpr) -> LazyExpr:
    if isinstance(node, Source):
        return node
    if isinstance(node, Select):
        child = node.child
        rs, cs = node.row_sel, node.col_sel
        if isinstance(child, Select) and all(
                _pushable(s) for s in (rs, cs, child.row_sel, child.col_sel)):
            _bump("pushdown")
            return _push(Select(child.child,
                                as_selector(child.row_sel) & as_selector(rs),
                                as_selector(child.col_sel) & as_selector(cs)))
        if _pushable(rs) and _pushable(cs):
            if isinstance(child, Transpose):
                _bump("pushdown")
                return Transpose(_push(Select(child.child, cs, rs)))
            if isinstance(child, (EwiseAdd, EwiseMul)):
                _bump("pushdown")
                return type(child)(_push(Select(child.a, rs, cs)),
                                   _push(Select(child.b, rs, cs)),
                                   semiring=child.semiring)
            if isinstance(child, MatMul):
                _bump("pushdown")
                return MatMul(_push(Select(child.a, rs, All())),
                              _push(Select(child.b, All(), cs)),
                              semiring=child.semiring)
        return Select(_push(child), rs, cs)
    if isinstance(node, Transpose):
        return Transpose(_push(node.child))
    if isinstance(node, Reduce):
        return Reduce(_push(node.child), node.axis, node.semiring)
    if isinstance(node, (EwiseAdd, EwiseMul, MatMul)):
        return type(node)(_push(node.a), _push(node.b),
                          semiring=node.semiring)
    return node


# ---------------------------------------------------------------------------
# Rewrite pass 2: fusion (internal physical nodes)
# ---------------------------------------------------------------------------

class _MatMulReduce(LazyExpr):
    """Fused ``⊕-reduce(a ⊗.⊕ b, axis)`` — executes via matmul_reduce."""

    def __init__(self, a, b, axis, semiring):
        self.a, self.b, self.axis = a, b, axis
        self.semiring = semiring

    def key(self):
        return ("mmr", self.a.key(), self.b.key(), self.axis,
                self.semiring.name)


class _EwiseAddN(LazyExpr):
    """n-ary fused ⊕ chain — one canonicalize pass over all operands."""

    def __init__(self, terms, semiring):
        self.terms = list(terms)
        self.semiring = semiring

    def key(self):
        return ("ewise_add_n", tuple(t.key() for t in self.terms),
                self.semiring.name)


class _ReduceAddN(LazyExpr):
    """Fused ``⊕-reduce(t₁ ⊕ t₂ ⊕ …, axis)`` — the Reduce-through-EwiseAdd
    rewrite.  Valid when the ⊕ of the chain IS the reduction combine (same
    ``add_kind`` monoid): then ⊕-folding every term's entries straight into
    the output vector equals reducing the materialized merge, and the
    concat + canonicalize sort of the merge never happens.  Keeps the ewise
    semiring too: the executor's non-numeric fallback must materialize with
    the chain's own ⊕."""

    def __init__(self, terms, axis, semiring, ewise_semiring):
        self.terms = list(terms)
        self.axis = axis
        self.semiring = semiring
        self.ewise_semiring = ewise_semiring

    def key(self):
        return ("reduce_add_n", tuple(t.key() for t in self.terms),
                self.axis, self.semiring.name, self.ewise_semiring.name)


def _flatten_add(node, sr) -> List[LazyExpr]:
    if isinstance(node, EwiseAdd) and node.semiring.name == sr.name:
        return _flatten_add(node.a, sr) + _flatten_add(node.b, sr)
    return [node]


def _fuse(node: LazyExpr) -> LazyExpr:
    if isinstance(node, Source):
        return node
    if isinstance(node, Reduce):
        child = _fuse(node.child)
        if (isinstance(child, MatMul) and node.axis is not None
                and child.semiring.name == node.semiring.name):
            _bump("fused_matmul_reduce")
            return _MatMulReduce(child.a, child.b, node.axis, child.semiring)
        if (isinstance(child, (EwiseAdd, _EwiseAddN))
                and node.axis is not None
                and child.semiring.add_kind == node.semiring.add_kind):
            # reduce(A ⊕ B) → scatter both operands' entries into the
            # reduce vector directly.  add_kind equality is the exact
            # condition: it names the ⊕ monoid (sum/max/min) for every
            # registered semiring, so the chain's ⊕ and the reduction
            # combine are the same associative-commutative op and the
            # per-entry fold order cannot matter.
            _bump("reduce_through_add")
            terms = (child.terms if isinstance(child, _EwiseAddN)
                     else [child.a, child.b])
            return _ReduceAddN(terms, node.axis, node.semiring,
                               child.semiring)
        return Reduce(child, node.axis, node.semiring)
    if isinstance(node, EwiseAdd):
        terms = _flatten_add(node, node.semiring)
        if len(terms) >= 3:
            _bump("ewise_fused")
            return _EwiseAddN([_fuse(t) for t in terms], node.semiring)
        return EwiseAdd(_fuse(node.a), _fuse(node.b), semiring=node.semiring)
    if isinstance(node, (EwiseMul, MatMul)):
        return type(node)(_fuse(node.a), _fuse(node.b),
                          semiring=node.semiring)
    if isinstance(node, Select):
        return Select(_fuse(node.child), node.row_sel, node.col_sel)
    if isinstance(node, Transpose):
        return Transpose(_fuse(node.child))
    return node


def optimize(node: LazyExpr) -> LazyExpr:
    """Rewrite an expression graph: pushdown first, then fusion."""
    return _fuse(_push(node))


# ---------------------------------------------------------------------------
# Execution (hash-consed)
# ---------------------------------------------------------------------------

_MISS = object()


def _single_node_fast(node: LazyExpr):
    """Dispatch a one-node graph (what every eager wrapper builds)
    straight to the physical backend — no rewrite walk, no memo, no
    structural keys.  Returns ``_MISS`` for anything deeper."""
    if isinstance(node, Select) and isinstance(node.child, Source):
        return node.child.array._select_eager((node.row_sel, node.col_sel))
    if isinstance(node, (EwiseAdd, EwiseMul, MatMul)) \
            and isinstance(node.a, Source) and isinstance(node.b, Source):
        a, b = node.a.array, node.b.array
        if isinstance(node, MatMul):
            if _layer(a) != "dist" and _layer(b) == "dist":
                b = b.gather_replicated()  # same rule as _eval_matmul
            return a.matmul(b, node.semiring)
        _require_same_layer(a, b, "⊕" if isinstance(node, EwiseAdd) else "⊗")
        if isinstance(node, EwiseAdd):
            return a.add(b, node.semiring)
        return a.mul(b, node.semiring)
    return _MISS


def execute(node: LazyExpr):
    """Optimize + evaluate; repeated subtrees run once and repeated
    *collects* of the same graph reuse the optimized plan (PLAN_STATS)."""
    fast = _single_node_fast(node)
    if fast is not _MISS:
        return fast
    key = node.key()
    with _PLAN_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            PLAN_STATS["plan_hits"] += 1
            _PLAN_CACHE.move_to_end(key)
    if plan is None:
        # optimize() outside the lock: rewrites are pure and idempotent, so
        # two threads racing the same cold key just do the walk twice and
        # one insert wins — cheaper than serializing every cold plan.
        plan = optimize(node)
        with _PLAN_LOCK:
            PLAN_STATS["plan_misses"] += 1
            if key not in _PLAN_CACHE:
                _PLAN_CACHE[key] = plan
                if len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
                    _PLAN_CACHE.popitem(last=False)
    return _eval(plan, {})


def _eval(node: LazyExpr, memo: dict):
    if isinstance(node, Source):
        return node.array
    k = node.key()
    if k in memo:
        _bump("hits")
        return memo[k]
    _bump("misses")
    out = _eval_inner(node, memo)
    memo[k] = out
    return out


def _strip_select(node) -> Tuple[LazyExpr, Optional[tuple]]:
    """Peel one Select off a matmul operand for select→matmul fusion.

    ``Transpose(Select(x, r, c))`` is ``Select(Transpose(x), c, r)`` for
    *every* selector form — transpose swaps the keyspaces without changing
    either — so a selection under a transpose fuses too (the ``sqin`` /
    ``sqout`` shapes)."""
    if isinstance(node, Select):
        return node.child, (node.row_sel, node.col_sel)
    if isinstance(node, Transpose) and isinstance(node.child, Select):
        s = node.child
        return Transpose(s.child), (s.col_sel, s.row_sel)
    return node, None


def _eval_inner(node: LazyExpr, memo: dict):
    if isinstance(node, Select):
        arr = _eval(node.child, memo)
        _layer(arr)  # clean TypeError when the child is not an array
        return arr._select_eager((node.row_sel, node.col_sel))
    if isinstance(node, Transpose):
        arr = _eval(node.child, memo)
        if _layer(arr) == "dist":
            # the transpose breaks the row partition: gather to a
            # replicated device tensor (same rule DistAssoc.sqin uses)
            return arr.gather_replicated().transpose()
        return arr.transpose()
    if isinstance(node, EwiseAdd):
        a_node, asels = _strip_select(node.a)
        b_node, bsels = _strip_select(node.b)
        if asels is not None or bsels is not None:
            # the pushdown's (A ⊕ B)[sel] → A[sel] ⊕ B[sel] shape: fold
            # the selections into the one canonical merge instead of
            # materializing each slice (compact + lexsort per operand)
            a, b = _eval(a_node, memo), _eval(b_node, memo)
            _require_same_layer(a, b, "⊕")
            return _fused_select_add(a, asels, b, bsels, node.semiring)
        a, b = _eval(node.a, memo), _eval(node.b, memo)
        _require_same_layer(a, b, "⊕")
        return a.add(b, node.semiring)
    if isinstance(node, EwiseMul):
        a, b = _eval(node.a, memo), _eval(node.b, memo)
        _require_same_layer(a, b, "⊗")
        return a.mul(b, node.semiring)
    if isinstance(node, MatMul):
        return _eval_matmul(node.a, node.b, node.semiring, None, memo)
    if isinstance(node, _MatMulReduce):
        return _eval_matmul(node.a, node.b, node.semiring, node.axis, memo)
    if isinstance(node, Reduce):
        arr = _eval(node.child, memo)
        if isinstance(arr, (float, np.floating, np.ndarray, jnp.ndarray)):
            # reducing an already-reduced result: only the full ⊕ is left
            if node.axis is not None:
                raise ValueError(
                    "axis reduction of an already-reduced result; "
                    "use .sum() for the remaining full ⊕")
            if isinstance(arr, (float, np.floating)):
                return arr                  # ⊕ over a single scalar
            sr = get_semiring(node.semiring)
            if isinstance(arr, np.ndarray):
                return float(sr.add_np.reduce(arr)) if arr.size \
                    else float(sr.zero)
            return sr.add_reduce(arr) if arr.size else jnp.float32(sr.zero)
        return _axis_reduce(arr, node.axis, node.semiring)
    if isinstance(node, _EwiseAddN):
        terms = [_eval(t, memo) for t in node.terms]
        return _add_n(terms, node.semiring)
    if isinstance(node, _ReduceAddN):
        terms = [_eval(t, memo) for t in node.terms]
        return _reduce_add_n(terms, node.axis, node.semiring,
                             node.ewise_semiring)
    raise TypeError(f"cannot execute node {node!r}")


def _require_same_layer(a, b, what: str) -> None:
    la, lb = _layer(a), _layer(b)
    if la != lb:
        raise TypeError(f"element-wise {what} across layers "
                        f"({la} vs {lb}); convert one operand first")


def _eval_matmul(a_node, b_node, sr, axis, memo):
    a_node, asels = _strip_select(a_node)
    b_node, bsels = _strip_select(b_node)
    a = _eval(a_node, memo)
    b = _eval(b_node, memo)
    if _layer(a) != "dist" and _layer(b) == "dist":
        # a transposed (hence gathered) A against a still-sharded B: pull
        # B to a replicated device tensor — the rule eager sqin applies
        b = b.gather_replicated()
    if asels is None and bsels is None:
        if axis is None:
            return a.matmul(b, sr)
        return a.matmul_reduce(b, axis, sr)
    _bump("fused_select_matmul")
    layer = _layer(a)
    if layer == "host":
        return host_matmul(a, asels, b, bsels, sr, axis)
    if layer == "device":
        return _device_fused_matmul(a, asels, b, bsels, sr, axis)
    return _dist_fused_matmul(a, asels, b, bsels, sr, axis)


# ---------------------------------------------------------------------------
# Compiled-selection helpers (shared by the fused paths)
# ---------------------------------------------------------------------------

def _member(comp: Compiled, codes: np.ndarray) -> Optional[np.ndarray]:
    """Membership of rank codes in a compiled selection (None ⇒ selects
    everything — no filtering needed)."""
    if comp.count == comp.n:
        return None
    if comp.is_range:
        return (codes >= comp.lo) & (codes < comp.hi)
    # comp.n == 0 cannot reach here: count == n returned None above
    return comp.mask()[np.clip(codes, 0, comp.n - 1)] & (codes < comp.n)


def _entry_keep(rc: Compiled, cc: Compiled, rows: np.ndarray,
                cols: np.ndarray) -> Optional[np.ndarray]:
    """AND of row/col membership over entry code arrays (None ⇒ keep all)."""
    keep = None
    rm = _member(rc, rows)
    cm = _member(cc, cols)
    for m in (rm, cm):
        if m is not None:
            keep = m if keep is None else (keep & m)
    return keep


# ---------------------------------------------------------------------------
# Fused select→matmul, host layer
# ---------------------------------------------------------------------------

def _host_entry_keep(a, coo, sels) -> Optional[np.ndarray]:
    if sels is None:
        return None
    rc = compile_selector(sels[0], a._axis_space(a.row))
    cc = compile_selector(sels[1], a._axis_space(a.col))
    return _entry_keep(rc, cc, coo.row, coo.col)


def host_matmul(a, asels, b, bsels, sr, axis=None):
    """Host ``⊗.⊕`` contraction (+ optional fused selection/reduction).

    With ``asels``/``bsels`` = None this is THE host semiring
    contraction — ``Assoc.matmul`` and ``Assoc.matmul_reduce`` delegate
    here, so the sort-merge join prologue exists once.  With selections,
    it is select+matmul(+reduce) without materializing either slice:
    ``(+,×)`` keeps scipy's CSR engine — deselected entries have their
    *data* zeroed in place (a value mask, not a re-indexing), so the
    product — and the fused matvec reduction — run on the full-shape
    operands and zero contributions vanish on their own.  Other semirings
    run the filtered expand-join (``spgemm_np`` / ``spgemm_reduce_np``)
    over the kept entries only.

    Note on reduce alignment: the ``axis=1`` vector is indexed by the
    *unsliced* ``a.row`` (deselected rows hold the ⊕-identity), unlike an
    eager ``(A[sel] @ B).sum(axis=1)`` whose host result condensed its
    keyspace first — on device the two agree because device selection
    never shrinks keyspaces.
    """
    import scipy.sparse as sp

    from .assoc import Assoc
    from .coo import spgemm_np, spgemm_reduce_np

    sr = get_semiring(sr)
    a0 = a if a.numeric else a.logical()
    b0 = b if b.numeric else b.logical()
    n_out = len(a0.row) if axis == 1 else len(b0.col)
    inner, ia, ib = sorted_intersect(a0.col, b0.row)
    if len(inner) == 0 or a0.nnz() == 0 or b0.nnz() == 0:
        if axis is None:
            return Assoc()
        return np.full(n_out, sr.zero, dtype=np.float64)
    acoo = a0.adj.tocoo()
    bcoo = b0.adj.tocoo()
    a_keep = _host_entry_keep(a0, acoo, asels)
    b_keep = _host_entry_keep(b0, bcoo, bsels)

    if sr.name == "plus_times":
        da = acoo.data if a_keep is None else np.where(a_keep, acoo.data, 0.0)
        db = bcoo.data if b_keep is None else np.where(b_keep, bcoo.data, 0.0)
        am = sp.csr_matrix((da, (acoo.row, acoo.col)),
                           shape=a0.adj.shape)[:, ia]
        bm = sp.csr_matrix((db, (bcoo.row, bcoo.col)),
                           shape=b0.adj.shape)[ib, :]
        if axis is None:
            out = Assoc._from_parts(a0.row, b0.col, 1.0, (am @ bm).tocoo())
            out._drop_zeros_and_condense()
            return out
        if axis == 1:
            return np.asarray(am @ (bm @ np.ones(bm.shape[1]))).ravel()
        return np.asarray((np.ones(am.shape[0]) @ am) @ bm).ravel()

    amap = np.full(len(a0.col), -1, dtype=np.int64)
    amap[ia] = np.arange(len(inner))
    bmap = np.full(len(b0.row), -1, dtype=np.int64)
    bmap[ib] = np.arange(len(inner))
    ak, bk = amap[acoo.col], bmap[bcoo.row]
    am_, bm_ = ak >= 0, bk >= 0
    if a_keep is not None:
        am_ &= a_keep
    if b_keep is not None:
        bm_ &= b_keep
    a_row, a_k, a_val = acoo.row[am_], ak[am_], acoo.data[am_]
    b_k, b_col, b_val = bk[bm_], bcoo.col[bm_], bcoo.data[bm_]
    order = np.lexsort((b_col, b_k))
    if axis is None:
        r, c, v = spgemm_np(a_row, a_k, a_val,
                            b_k[order], b_col[order], b_val[order],
                            sr.mul_np, sr.add_np)
        keep = v != sr.zero
        return Assoc._assemble(a0.row, b0.col, r[keep], c[keep], v[keep])
    return spgemm_reduce_np(a_row, a_k, a_val,
                            b_k[order], b_col[order], b_val[order],
                            sr.mul_np, sr.add_np, sr.zero, axis, n_out)


# ---------------------------------------------------------------------------
# Fused select→matmul, device layer (keeps flow into the spgemm plan)
# ---------------------------------------------------------------------------

def _tensor_entry_keep(t, sels) -> Optional[np.ndarray]:
    if sels is None:
        return None
    rc = compile_selector(sels[0], t.row_space)
    cc = compile_selector(sels[1], t.col_space)
    na = int(t.nnz)
    rows = np.asarray(t.rows)[:na].astype(np.int64)
    cols = np.asarray(t.cols)[:na].astype(np.int64)
    return _entry_keep(rc, cc, rows, cols)


def _device_fused_matmul(a, asels, b, bsels, sr, axis=None):
    from . import spgemm
    a_keep = _tensor_entry_keep(a, asels)
    b_keep = _tensor_entry_keep(b, bsels)
    if axis is None:
        return spgemm.matmul(a, b, sr, a_keep=a_keep, b_keep=b_keep)
    return spgemm.matmul_reduce(a, b, axis, sr,
                                a_keep=a_keep, b_keep=b_keep)


# ---------------------------------------------------------------------------
# Fused select→matmul, dist layer (shard-local masking, zero collectives)
# ---------------------------------------------------------------------------

def _dist_fused_matmul(a, asels, b, bsels, sr, axis=None):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .assoc_tensor import AssocTensor
    from .dist_assoc import DistAssoc

    sr = get_semiring(sr)
    loc = a.local if a.local.numeric else a.local.logical()
    masked = loc
    if asels is not None:
        rc = compile_selector(asels[0], loc.row_space)
        cc = compile_selector(asels[1], loc.col_space)
        rows_h = np.asarray(loc.rows).astype(np.int64)
        cols_h = np.asarray(loc.cols).astype(np.int64)
        keep = _entry_keep(rc, cc, rows_h, cols_h)
        if keep is not None:
            keep &= rows_h != int(SENT)
            # sentinel-mask deselected rows IN PLACE: the expand-join skips
            # SENT entries, so the sliced A never exists as a compacted
            # array and each shard filters its own triples (no collectives)
            keep_dev = jax.device_put(
                jnp.asarray(keep),
                NamedSharding(a.mesh, P("data", None)))
            masked = AssocTensor(
                jnp.where(keep_dev, loc.rows, SENT), loc.cols, loc.vals,
                loc.nnz, loc.row_space, loc.col_space, None)

    bt = a._as_replicated_operand(b)
    bt = bt.logical() if not bt.numeric else bt
    if bsels is not None:
        rc = compile_selector(bsels[0], bt.row_space)
        cc = compile_selector(bsels[1], bt.col_space)
        rows_h = np.asarray(bt.rows).astype(np.int64)
        cols_h = np.asarray(bt.cols).astype(np.int64)
        keep = _entry_keep(rc, cc, rows_h, cols_h)
        if keep is not None:
            keep &= rows_h != int(SENT)
            # deselected B entries are ⊗-annihilated (value → semiring
            # zero) rather than removed: the rank arrays stay sorted for
            # the shard-local searchsorted join, and zero products are
            # dropped by the canonical merge.  Every registered semiring's
            # zero annihilates ⊗, which is what makes this a pure value
            # mask rather than a slice.
            bt = AssocTensor(
                bt.rows, bt.cols,
                jnp.where(jnp.asarray(keep), bt.vals,
                          jnp.float32(sr.zero)),
                bt.nnz, bt.row_space, bt.col_space, None)

    d = DistAssoc(masked, a.mesh, row_bounds=a.row_bounds)
    if axis is None:
        return d.matmul(bt, sr)
    return d.matmul_reduce(bt, axis, sr)


# ---------------------------------------------------------------------------
# Fused select→ewise-add (the pushdown's (A ⊕ B)[sel] → A[sel] ⊕ B[sel]
# shape): the slices never materialize — compiled keep masks filter each
# operand's entries inside the ONE canonical merge, exactly how matmul
# operands fuse.  Saves a compact + lexsort per sliced operand.
# ---------------------------------------------------------------------------

def _fused_select_add(a, asels, b, bsels, sr):
    sr = get_semiring(sr)
    layer = _layer(a)
    numeric = (a.local.numeric and b.local.numeric if layer == "dist"
               else a.numeric and b.numeric)
    if not numeric:
        # string ⊕ concatenates (order-sensitive, no zero to drop): keep
        # the materializing path rather than re-deriving its semantics
        aa = a._select_eager(asels) if asels is not None else a
        bb = b._select_eager(bsels) if bsels is not None else b
        return aa.add(bb, sr)
    _bump("fused_select_ewise")
    if layer == "host":
        return _host_fused_select_add(a, asels, b, bsels, sr)
    if layer == "device":
        return _device_fused_select_add(a, asels, b, bsels, sr)
    return _dist_fused_select_add(a, asels, b, bsels, sr)


def _host_fused_select_add(a, asels, b, bsels, sr):
    from .assoc import Assoc

    acoo = a.adj.tocoo()
    bcoo = b.adj.tocoo()
    a_keep = _host_entry_keep(a, acoo, asels)
    b_keep = _host_entry_keep(b, bcoo, bsels)
    row_u, _, _ = sorted_union(a.row, b.row)
    col_u, _, _ = sorted_union(a.col, b.col)
    rs, cs, vs = [], [], []
    for t, coo, keep in ((a, acoo, a_keep), (b, bcoo, b_keep)):
        rmap = np.searchsorted(row_u, t.row)
        cmap = np.searchsorted(col_u, t.col)
        er, ec, ev = coo.row, coo.col, coo.data
        if keep is not None:
            er, ec, ev = er[keep], ec[keep], ev[keep]
        rs.append(rmap[er])
        cs.append(cmap[ec])
        vs.append(ev)
    if not sum(len(x) for x in rs):
        return Assoc()
    r, c, v = canonicalize_np(np.concatenate(rs), np.concatenate(cs),
                              np.concatenate(vs), combine=sr.add_np)
    keep = v != sr.zero
    return Assoc._assemble(row_u, col_u, r[keep], c[keep], v[keep])


def _masked_rows(t, sels) -> jnp.ndarray:
    """Rows array with deselected entries sentinel-masked in place (the
    canonical merge skips SENT — no compact, no per-operand sort)."""
    keep = _tensor_entry_keep(t, sels)
    if keep is None:
        return t.rows
    full = np.zeros(t.rows.shape[0], bool)
    full[:len(keep)] = keep
    return jnp.where(jnp.asarray(full), t.rows, SENT)


def _device_fused_select_add(a, asels, b, bsels, sr):
    from .assoc_tensor import AssocTensor

    rs_space, ra_m, rb_m = a.row_space.union(b.row_space)
    cs_space, ca_m, cb_m = a.col_space.union(b.col_space)

    def remap(t, sels, rm, cm):
        rows = _masked_rows(t, sels)
        ok = rows != SENT
        rmj = jnp.asarray(rm) if len(rm) else jnp.zeros(1, jnp.int32)
        cmj = jnp.asarray(cm) if len(cm) else jnp.zeros(1, jnp.int32)
        rr = jnp.where(ok, rmj[jnp.clip(rows, 0, rmj.shape[0] - 1)], SENT)
        cc = jnp.where(ok, cmj[jnp.clip(t.cols, 0, cmj.shape[0] - 1)], SENT)
        return rr, cc, t.vals
    ar, ac, av = remap(a, asels, ra_m, ca_m)
    br, bc, bv = remap(b, bsels, rb_m, cb_m)
    rows = jnp.concatenate([ar, br])
    cols = jnp.concatenate([ac, bc])
    vals = jnp.concatenate([av, bv])
    r, c, v, nnz = dedup_sorted_coo(rows, cols, vals, sr.add, zero=sr.zero)
    return AssocTensor(r, c, v, nnz, rs_space, cs_space, a.val_space)


def _dist_masked_local(d, sels):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from .assoc_tensor import AssocTensor

    loc = d.local
    if sels is None:
        return loc
    rc = compile_selector(sels[0], loc.row_space)
    cc = compile_selector(sels[1], loc.col_space)
    rows_h = np.asarray(loc.rows).astype(np.int64)
    cols_h = np.asarray(loc.cols).astype(np.int64)
    keep = _entry_keep(rc, cc, rows_h, cols_h)
    if keep is None:
        return loc
    keep &= rows_h != int(SENT)
    keep_dev = jax.device_put(jnp.asarray(keep),
                              NamedSharding(d.mesh, P("data", None)))
    return AssocTensor(jnp.where(keep_dev, loc.rows, SENT), loc.cols,
                       loc.vals, loc.nnz, loc.row_space, loc.col_space,
                       loc.val_space)


def _dist_fused_select_add(a, asels, b, bsels, sr):
    from .assoc_tensor import AssocTensor
    from .dist_assoc import DistAssoc, _ewise_prog

    la = _dist_masked_local(a, asels)
    lb = _dist_masked_local(b, bsels)
    go = _ewise_prog(a.mesh, sr, "add")
    out = go({"rows": la.rows, "cols": la.cols, "vals": la.vals,
              "nnz": la.nnz},
             {"rows": lb.rows, "cols": lb.cols, "vals": lb.vals,
              "nnz": lb.nnz})
    new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                            out["nnz"], la.row_space, la.col_space,
                            la.val_space)
    return DistAssoc(new_local, a.mesh, row_bounds=a.row_bounds)


# ---------------------------------------------------------------------------
# Shared axis reductions (the one reduce path: eager sum/reduce_rows and
# the Reduce node all land here — dtype/zero rules from the combine helpers)
# ---------------------------------------------------------------------------

def host_axis_reduce(a, axis: Optional[int], semiring=PLUS_TIMES):
    """⊕-reduce a host Assoc: ``axis=1`` → float64 vector over ``a.row``,
    ``axis=0`` → vector over ``a.col``, ``None`` → scalar.  ``(+,×)``
    keeps the scipy fast path (bit-identical to the historical
    ``Assoc.sum``); other semirings run one ``add_np`` scatter — the host
    mirror of :func:`~repro.core.semiring.scatter_combine`."""
    sr = get_semiring(semiring)
    aa = a if a.numeric else a.logical()
    if axis is None:
        if aa.nnz() == 0:
            return float(sr.zero)
        if sr.name == "plus_times":
            return float(aa.adj.sum())
        return float(sr.add_np.reduce(aa.adj.tocoo().data))
    if axis not in (0, 1):
        raise ValueError(f"axis must be None, 0 or 1, got {axis!r}")
    if sr.name == "plus_times":
        return np.asarray(aa.adj.sum(axis=axis), dtype=np.float64).ravel()
    coo = aa.adj.tocoo()
    n_out = len(aa.row) if axis == 1 else len(aa.col)
    out = np.full(n_out, sr.zero, dtype=np.float64)
    sr.add_np.at(out, coo.row if axis == 1 else coo.col, coo.data)
    return out


def device_axis_reduce(t, axis: Optional[int], semiring=PLUS_TIMES):
    """⊕-reduce a device AssocTensor with one ``scatter_combine``:
    ``axis=1`` → vector over the row keyspace, ``axis=0`` → over the col
    keyspace, ``None`` → scalar ⊕ over every stored entry."""
    sr = get_semiring(semiring)
    ok = t.valid_mask()
    if axis is None:
        return sr.add_reduce(jnp.where(ok, t.vals, sr.zero))
    if axis not in (0, 1):
        raise ValueError(f"axis must be None, 0 or 1, got {axis!r}")
    n_out = len(t.row_space) if axis == 1 else len(t.col_space)
    keys = t.rows if axis == 1 else t.cols
    vec = jnp.full((n_out,), sr.zero, t.vals.dtype)
    return scatter_combine(vec, jnp.where(ok, keys, n_out),
                           jnp.where(ok, t.vals, sr.zero), sr)


def _axis_reduce(arr, axis: Optional[int], sr):
    layer = _layer(arr)
    if layer == "host":
        return host_axis_reduce(arr, axis, sr)
    if layer == "device":
        return device_axis_reduce(arr, axis, sr)
    if axis == 0:
        return arr.col_reduce(sr)
    if axis == 1:
        return arr.row_reduce(sr)
    srr = get_semiring(sr)
    vec = arr.col_reduce(sr)
    if vec.shape[0] == 0:
        return jnp.float32(srr.zero)
    return srr.add_reduce(vec)


# ---------------------------------------------------------------------------
# Fused ⊕-chain reductions (Reduce pushed through EwiseAdd: every term's
# entries scatter straight into the output vector — the ⊕-merged array is
# never materialized, so its concat + canonicalize sort never runs)
# ---------------------------------------------------------------------------

def _reduce_add_n(terms, axis, sr, ewise_sr):
    sr = get_semiring(sr)
    ewise_sr = get_semiring(ewise_sr)
    layers = {_layer(t) for t in terms}
    if len(layers) != 1:
        raise TypeError(f"⊕ chain mixes layers: {sorted(layers)}")
    layer = layers.pop()
    numeric = all((t.local.numeric if layer == "dist" else t.numeric)
                  for t in terms)
    if not numeric:
        # string ⊕ concatenates before logical() flattens — per-entry
        # scatter would count overlaps twice; materialize the chain
        return _axis_reduce(_add_n(terms, ewise_sr), axis, sr)
    if layer == "host":
        return _host_reduce_add_n(terms, axis, sr)
    if layer == "device":
        return _device_reduce_add_n(terms, axis, sr)
    return _dist_reduce_add_n(terms, axis, sr, ewise_sr)


def _host_reduce_add_n(terms, axis, sr):
    live = [t for t in terms if t.nnz()]
    if not live:
        return np.full(0, sr.zero, dtype=np.float64)
    key_u = live[0].row if axis == 1 else live[0].col
    for t in live[1:]:
        key_u, _, _ = sorted_union(key_u, t.row if axis == 1 else t.col)
    out = np.full(len(key_u), sr.zero, dtype=np.float64)
    for t in live:
        coo = t.adj.tocoo()
        keys = t.row if axis == 1 else t.col
        kmap = np.searchsorted(key_u, keys)
        sr.add_np.at(out, kmap[coo.row if axis == 1 else coo.col], coo.data)
    return out


def _device_reduce_add_n(terms, axis, sr):
    rs_space, cs_space = terms[0].row_space, terms[0].col_space
    for t in terms[1:]:
        rs_space, _, _ = rs_space.union(t.row_space)
        cs_space, _, _ = cs_space.union(t.col_space)
    out_space = rs_space if axis == 1 else cs_space
    n_out = max(len(out_space), 0)
    dt = jnp.result_type(*[t.vals.dtype for t in terms])
    vec = jnp.full((n_out,), sr.zero, dt)
    for t in terms:
        ok = t.valid_mask()
        space = t.row_space if axis == 1 else t.col_space
        keys = t.rows if axis == 1 else t.cols
        if space != out_space:
            kmap = jnp.asarray(np.searchsorted(
                out_space.keys, space.keys).astype(np.int32))
            if kmap.shape[0]:
                keys = kmap[jnp.clip(keys, 0, kmap.shape[0] - 1)]
        vec = scatter_combine(vec, jnp.where(ok, keys, n_out),
                              jnp.where(ok, t.vals, sr.zero), sr)
    return vec


def _dist_reduce_add_n(terms, axis, sr, ewise_sr):
    from .dist_assoc import _reduce_add_n_prog

    d0 = terms[0]
    if any(t.local.row_space != d0.local.row_space
           or t.local.col_space != d0.local.col_space for t in terms[1:]):
        # dist ⊕ requires aligned spaces anyway (_dist_add_n's contract);
        # an exotic graph that mixes them falls back to materializing
        return _axis_reduce(_add_n(terms, ewise_sr), axis, sr)
    n_out = len(d0.local.row_space if axis == 1 else d0.local.col_space)
    go = _reduce_add_n_prog(d0.mesh, sr, axis, n_out, len(terms))
    dicts = tuple({"rows": t.local.rows, "cols": t.local.cols,
                   "vals": t.local.vals, "nnz": t.local.nnz} for t in terms)
    return go(*dicts)


# ---------------------------------------------------------------------------
# Fused n-ary ⊕ chains (one canonicalize pass)
# ---------------------------------------------------------------------------

def _add_n(terms, sr):
    sr = get_semiring(sr)
    layers = {_layer(t) for t in terms}
    if len(layers) != 1:
        raise TypeError(f"⊕ chain mixes layers: {sorted(layers)}")
    layer = layers.pop()
    if layer == "host":
        return _host_add_n(terms, sr)
    if layer == "device":
        return _device_add_n(terms, sr)
    return _dist_add_n(terms, sr)


def _host_add_n(terms, sr):
    from .assoc import Assoc, is_string_array

    live = [t for t in terms if t.nnz()]
    if not live:
        return Assoc()
    if len(live) == 1:
        return live[0].copy()
    if any(not t.numeric for t in live):
        # string ⊕ is order-sensitive concatenation: left fold pairwise
        out = live[0]
        for t in live[1:]:
            out = out.add(t, sr)
        return out
    str_rows = is_string_array(live[0].row)
    str_cols = is_string_array(live[0].col)
    if any(is_string_array(t.row) != str_rows
           or is_string_array(t.col) != str_cols for t in live):
        raise TypeError("cannot mix string and numeric key sets")
    row_u, col_u = live[0].row, live[0].col
    for t in live[1:]:
        row_u, _, _ = sorted_union(row_u, t.row)
        col_u, _, _ = sorted_union(col_u, t.col)
    rs, cs, vs = [], [], []
    for t in live:
        coo = t.adj.tocoo()
        rmap = np.searchsorted(row_u, t.row)
        cmap = np.searchsorted(col_u, t.col)
        rs.append(rmap[coo.row])
        cs.append(cmap[coo.col])
        vs.append(coo.data)
    r, c, v = canonicalize_np(np.concatenate(rs), np.concatenate(cs),
                              np.concatenate(vs), combine=sr.add_np)
    keep = v != sr.zero
    return Assoc._assemble(row_u, col_u, r[keep], c[keep], v[keep])


def _device_add_n(terms, sr):
    from .assoc_tensor import AssocTensor

    rs_space, cs_space = terms[0].row_space, terms[0].col_space
    for t in terms[1:]:
        rs_space, _, _ = rs_space.union(t.row_space)
        cs_space, _, _ = cs_space.union(t.col_space)
    aligned = []
    for t in terms:
        if t.row_space == rs_space and t.col_space == cs_space:
            aligned.append(t)
            continue
        rm = np.searchsorted(rs_space.keys, t.row_space.keys).astype(np.int32)
        cm = np.searchsorted(cs_space.keys, t.col_space.keys).astype(np.int32)
        aligned.append(t.reranked(rs_space, cs_space, rm, cm))
    rows = jnp.concatenate([t.rows for t in aligned])
    cols = jnp.concatenate([t.cols for t in aligned])
    vals = jnp.concatenate([t.vals for t in aligned])
    r, c, v, nnz = dedup_sorted_coo(rows, cols, vals, sr.add, zero=sr.zero)
    return AssocTensor(r, c, v, nnz, rs_space, cs_space,
                       aligned[0].val_space)


def _dist_add_n(terms, sr):
    from functools import partial

    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .assoc_tensor import AssocTensor
    from .dist_assoc import DistAssoc

    d0 = terms[0]
    dicts = tuple({"rows": t.local.rows, "cols": t.local.cols,
                   "vals": t.local.vals, "nnz": t.local.nnz} for t in terms)
    spec = {"rows": P("data", None), "cols": P("data", None),
            "vals": P("data", None), "nnz": P("data")}

    @partial(shard_map, mesh=d0.mesh, in_specs=(spec,) * len(dicts),
             out_specs=spec, check_rep=False)
    def go(*parts):
        rows = jnp.concatenate([p["rows"][0] for p in parts])
        cols = jnp.concatenate([p["cols"][0] for p in parts])
        vals = jnp.concatenate([p["vals"][0] for p in parts])
        r, c, v, n = dedup_sorted_coo(rows, cols, vals, sr.add, zero=sr.zero)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": n[None]}

    out = go(*dicts)
    new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                            out["nnz"], d0.local.row_space,
                            d0.local.col_space, d0.local.val_space)
    return DistAssoc(new_local, d0.mesh, row_bounds=d0.row_bounds)

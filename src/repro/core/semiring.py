"""Semiring algebra for associative arrays.

A semiring ``(V, ⊕, ⊗, 0, 1)`` supplies the addition/multiplication pair under
which associative-array algebra (element-wise add, element-wise multiply, and
array multiplication ``⊗.⊕``) is defined.  This module provides a small
registry of the semirings used by D4M plus the machinery the device kernels
dispatch on.

Two families of implementations coexist:

* **scalar/python** callables (``add_py`` / ``mul_py``) used by the host
  ``Assoc`` reference implementation and by property tests of the axioms;
* **jnp** callables (``add`` / ``mul``) that operate on arrays and are safe
  inside jit/pallas (the Pallas semiring-matmul kernel selects an MXU path
  only for ``(+,×)``; every other semiring contracts on the VPU via
  broadcast-reduce).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Semiring",
    "PLUS_TIMES",
    "MAX_PLUS",
    "MIN_PLUS",
    "MAX_MIN",
    "MAX_TIMES",
    "AND_OR",
    "get_semiring",
    "scatter_combine",
    "mesh_combine",
    "REGISTRY",
]


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A (numerical) semiring usable on both host and device.

    Attributes
    ----------
    name:       registry key, e.g. ``"plus_times"``.
    add:        jnp elementwise ⊕ (associative & commutative).
    mul:        jnp elementwise ⊗ (associative; distributes over ⊕).
    zero:       identity of ⊕ / annihilator of ⊗ (python float).
    one:        identity of ⊗ (python float).
    add_reduce: jnp reduction implementing ⊕ along an axis (used by matmul
                contractions and aggregation).
    add_np:     numpy ufunc mirror of ⊕ — the host ``Assoc`` routes its
                semiring-generic algebra (and the canonical COO merge's
                ``reduceat`` fast path) through this, keeping host code off
                the device entirely.
    mul_np:     numpy ufunc mirror of ⊗.
    mxu:        True iff the contraction can be lowered to a plain matmul on
                the MXU (only the plus-times algebra qualifies).
    idempotent_add: True iff ``a ⊕ a == a`` (max/min-style algebras); such
                semirings make telemetry merges retry-idempotent.
    add_kind:   the ⊕ monoid family — ``"sum"``, ``"max"`` or ``"min"``.
                Every registered ⊕ belongs to one of the three, which is what
                lets segment accumulation run as a native scatter
                (:func:`scatter_combine`) and cross-shard reduction as the
                matching psum-family collective (:func:`mesh_combine`)
                instead of branching on semiring names at every call site.
    """

    name: str
    add: Callable[[Any, Any], Any]
    mul: Callable[[Any, Any], Any]
    zero: float
    one: float
    add_reduce: Callable[..., Any]
    add_np: Callable[[Any, Any], Any] = np.add
    mul_np: Callable[[Any, Any], Any] = np.multiply
    mxu: bool = False
    idempotent_add: bool = False
    add_kind: str = "sum"

    # ---- host/scalar views (numpy-friendly; used by host Assoc + tests) ----
    def add_py(self, a, b):
        return np.asarray(self.add_np(np.asarray(a), np.asarray(b)))[()]

    def mul_py(self, a, b):
        return np.asarray(self.mul_np(np.asarray(a), np.asarray(b)))[()]

    def matmul_dense(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Reference dense semiring contraction ``C[i,j] = ⊕_k a[i,k] ⊗ b[k,j]``.

        Used as the jnp oracle for the Pallas kernel and as the fallback path
        on backends where the kernel is unavailable.
        """
        if self.mxu:
            return jnp.matmul(a, b, preferred_element_type=jnp.float32)
        # broadcast-reduce: [i, k, 1] ⊗ [1, k, j] → reduce over k
        prod = self.mul(a[:, :, None], b[None, :, :])
        return self.add_reduce(prod, axis=1)

    def is_zero(self, x) -> Any:
        if math.isinf(self.zero):
            return jnp.isinf(x) & ((x < 0) == (self.zero < 0))
        return x == self.zero


def _mk(name, add, mul, zero, one, add_reduce, add_np, mul_np,
        mxu=False, idem=False, kind="sum") -> Semiring:
    return Semiring(
        name=name, add=add, mul=mul, zero=zero, one=one,
        add_reduce=add_reduce, add_np=add_np, mul_np=mul_np,
        mxu=mxu, idempotent_add=idem, add_kind=kind,
    )


PLUS_TIMES = _mk(
    "plus_times", jnp.add, jnp.multiply, 0.0, 1.0, jnp.sum,
    np.add, np.multiply, mxu=True, kind="sum")
MAX_PLUS = _mk(
    "max_plus", jnp.maximum, jnp.add, -jnp.inf, 0.0, jnp.max,
    np.maximum, np.add, idem=True, kind="max")
MIN_PLUS = _mk(
    "min_plus", jnp.minimum, jnp.add, jnp.inf, 0.0, jnp.min,
    np.minimum, np.add, idem=True, kind="min")
MAX_MIN = _mk(
    "max_min", jnp.maximum, jnp.minimum, -jnp.inf, jnp.inf, jnp.max,
    np.maximum, np.minimum, idem=True, kind="max")
MAX_TIMES = _mk(
    "max_times", jnp.maximum, jnp.multiply, 0.0, 1.0, jnp.max,
    np.maximum, np.multiply, idem=True, kind="max")
# Boolean algebra on {0., 1.}: on this domain ∨ ≡ max and ∧ ≡ min, and the
# max/min forms stay in floating point so one code path (and one canonical
# COO merge) serves every semiring on host and device alike.
AND_OR = _mk(
    "and_or", jnp.maximum, jnp.minimum, 0.0, 1.0, jnp.max,
    np.maximum, np.minimum, idem=True, kind="max")


def scatter_combine(vec: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                    sr: Semiring, *, mode: str = "drop") -> jnp.ndarray:
    """Segment-⊕ ``vals`` into ``vec`` at ``idx`` with the semiring's native
    scatter (``.add`` / ``.max`` / ``.min``) — the one segment-accumulation
    primitive behind reductions and the fused matmul epilogues.  ``vec`` must
    be pre-filled with ``sr.zero`` (the scatter is a pure ⊕-merge)."""
    at = vec.at[idx]
    if sr.add_kind == "sum":
        return at.add(vals, mode=mode)
    if sr.add_kind == "max":
        return at.max(vals, mode=mode)
    return at.min(vals, mode=mode)


def mesh_combine(x: jnp.ndarray, axis_name: str, sr: Semiring) -> jnp.ndarray:
    """Cross-shard ⊕ as the psum-family collective matching ``sr.add_kind``.

    Inside ``shard_map`` bodies this is the single combine step of the
    Graphulo pushdown pattern: shard-local partials (or disjoint-support
    rows, for which ⊕-with-zero is concatenation) merge in one collective.
    """
    import jax

    if sr.add_kind == "sum":
        return jax.lax.psum(x, axis_name)
    if sr.add_kind == "max":
        return jax.lax.pmax(x, axis_name)
    return jax.lax.pmin(x, axis_name)

REGISTRY: Dict[str, Semiring] = {
    s.name: s
    for s in (PLUS_TIMES, MAX_PLUS, MIN_PLUS, MAX_MIN, MAX_TIMES, AND_OR)
}


def get_semiring(name_or_sr) -> Semiring:
    if isinstance(name_or_sr, Semiring):
        return name_or_sr
    try:
        return REGISTRY[str(name_or_sr)]
    except KeyError as exc:
        raise KeyError(
            f"unknown semiring {name_or_sr!r}; known: {sorted(REGISTRY)}"
        ) from exc


# ---------------------------------------------------------------------------
# The (nonunital) string algebra (Σ*, ⌢, min, ε) — host only.
#
# String values cannot live on a TPU; the device stores int32 ranks into the
# sorted unique-value array (the paper's own pointer scheme).  min under the
# dictionary order is then rank-min (device-safe); concatenation creates new
# values and therefore runs on host where the dictionary can grow.
# ---------------------------------------------------------------------------

class StringAlgebra:
    """The paper's nonunital string semiring: ⊕ = concatenation, ⊗ = min."""

    name = "string"
    zero = ""  # ε — identity for concatenation, the "empty" value

    @staticmethod
    def add_py(a: str, b: str) -> str:
        return a + b

    @staticmethod
    def mul_py(a: str, b: str) -> str:
        return min(a, b)


STRING = StringAlgebra()

"""Lazy D4M expressions: the deferred composition API over every layer.

D4M's exemplar queries are one-liners that *chain* selection, element-wise
⊕/⊗ and array multiplication — and D4M 3.0 showed the big wins come from
deferring evaluation of such chains so the work can be pushed into the
multiply.  This module is the expression half of that design:

* a small algebra of graph nodes — :class:`Source`, :class:`Select`,
  :class:`EwiseAdd`/:class:`EwiseMul`, :class:`MatMul`, :class:`Reduce`,
  :class:`Transpose` — each carrying its own ``semiring``;
* ``A.lazy()`` on ``Assoc``/``AssocTensor``/``DistAssoc`` wraps the array
  in a :class:`Source`; from there the usual operators **build the graph
  instead of executing**:  ``A.lazy()[sel] @ B.lazy()[sel]`` is a three-node
  expression, not two slices and a product;
* ``.collect()`` hands the graph to the planner
  (:mod:`repro.core.plan`), which rewrites it — selector pushdown,
  ``MatMul→Reduce`` fusion onto the spgemm epilogues, ewise-chain
  fusion, hash-consed repeated subtrees — and then executes the optimized
  program on whichever layer the sources live on.

The eager APIs are thin wrappers over this module: ``A + B`` builds a
one-node :class:`EwiseAdd` graph and collects it immediately, so lazy and
eager are one code path with one semantics, not two parallel
implementations.
"""
from __future__ import annotations

from typing import Any, Optional

from .select import as_selector
from .semiring import PLUS_TIMES, get_semiring

__all__ = [
    "LazyExpr", "Source", "Select", "EwiseAdd", "EwiseMul", "MatMul",
    "Reduce", "Transpose", "lazy",
]


def lazy(x) -> "LazyExpr":
    """Wrap an associative array (any layer) as an expression Source;
    expression nodes pass through unchanged."""
    if isinstance(x, LazyExpr):
        return x
    return Source(x)


def _sel_key(sel) -> tuple:
    """Structural identity of a selector argument (for hash-consing).

    Falls back to object identity for uncacheable selectors (``Where``
    closures) — still stable within one ``collect()``.
    """
    try:
        return as_selector(sel).cache_key()
    except TypeError:
        return ("id", id(sel))


class LazyExpr:
    """Base expression node: deferred, composable, layer-agnostic.

    Nodes are immutable; building one never touches array data.  The
    operators mirror the eager associative-array API exactly — plus the
    explicit ``add``/``mul``/``matmul``/``sum`` forms that take a
    ``semiring=``.
    """

    __array_priority__ = 200  # beat numpy AND the eager Assoc in binary ops

    semiring = PLUS_TIMES

    # -- graph building -----------------------------------------------------
    def __getitem__(self, ij) -> "Select":
        i, j = ij
        return Select(self, i, j)

    def add(self, other, semiring=PLUS_TIMES) -> "EwiseAdd":
        return EwiseAdd(self, lazy(other), semiring=semiring)

    def mul(self, other, semiring=PLUS_TIMES) -> "EwiseMul":
        return EwiseMul(self, lazy(other), semiring=semiring)

    def matmul(self, other, semiring=PLUS_TIMES) -> "MatMul":
        return MatMul(self, lazy(other), semiring=semiring)

    def __add__(self, other) -> "EwiseAdd":
        return EwiseAdd(self, lazy(other))

    def __radd__(self, other) -> "EwiseAdd":
        return EwiseAdd(lazy(other), self)

    def __mul__(self, other) -> "EwiseMul":
        return EwiseMul(self, lazy(other))

    def __rmul__(self, other) -> "EwiseMul":
        return EwiseMul(lazy(other), self)

    def __matmul__(self, other) -> "MatMul":
        return MatMul(self, lazy(other))

    def __rmatmul__(self, other) -> "MatMul":
        return MatMul(lazy(other), self)

    def sum(self, axis: Optional[int] = None, semiring=PLUS_TIMES) -> "Reduce":
        """⊕-reduction: ``axis=1`` → vector over rows, ``axis=0`` → vector
        over cols, ``axis=None`` → scalar ⊕ over every entry."""
        return Reduce(self, axis, semiring=semiring)

    reduce = sum

    def transpose(self) -> "Transpose":
        return Transpose(self)

    @property
    def T(self) -> "Transpose":
        return self.transpose()

    def sqin(self, semiring=PLUS_TIMES,
             reduce: Optional[int] = None) -> "LazyExpr":
        """AᵀA as a graph — the planner collapses ``reduce=0/1`` onto the
        fused spgemm epilogue."""
        sq = MatMul(Transpose(self), self, semiring=semiring)
        return sq if reduce is None else Reduce(sq, reduce, semiring=semiring)

    def sqout(self, semiring=PLUS_TIMES,
              reduce: Optional[int] = None) -> "LazyExpr":
        """AAᵀ as a graph; ``reduce=0/1`` for the fused vector."""
        sq = MatMul(self, Transpose(self), semiring=semiring)
        return sq if reduce is None else Reduce(sq, reduce, semiring=semiring)

    # -- evaluation ---------------------------------------------------------
    def collect(self):
        """Optimize and execute the graph; returns the layer-native result
        (array for structural nodes, dense vector/scalar for reductions)."""
        from .plan import execute
        return execute(self)

    # -- structural identity (hash-consing key) -----------------------------
    def key(self) -> tuple:
        raise NotImplementedError


class Source(LazyExpr):
    """A leaf: one concrete ``Assoc`` / ``AssocTensor`` / ``DistAssoc``."""

    def __init__(self, array: Any):
        self.array = array

    def key(self) -> tuple:
        return ("src", id(self.array))

    def __repr__(self) -> str:
        return f"Source({type(self.array).__name__})"


class Select(LazyExpr):
    """Deferred D4M selection ``child[row_sel, col_sel]`` (any selector
    form the eager ``__getitem__`` takes)."""

    def __init__(self, child: LazyExpr, row_sel, col_sel):
        self.child = child
        self.row_sel = row_sel
        self.col_sel = col_sel

    def key(self) -> tuple:
        return ("select", self.child.key(),
                _sel_key(self.row_sel), _sel_key(self.col_sel))

    def __repr__(self) -> str:
        return f"Select({self.child!r}, {self.row_sel!r}, {self.col_sel!r})"


class _Binary(LazyExpr):
    tag = "?"

    def __init__(self, a: LazyExpr, b: LazyExpr, semiring=PLUS_TIMES):
        self.a = lazy(a)
        self.b = lazy(b)
        self.semiring = get_semiring(semiring)

    def key(self) -> tuple:
        return (self.tag, self.a.key(), self.b.key(), self.semiring.name)

    def __repr__(self) -> str:
        return (f"{type(self).__name__}({self.a!r}, {self.b!r}, "
                f"semiring={self.semiring.name})")


class EwiseAdd(_Binary):
    """Element-wise ⊕ over the union of key sets (paper §II.C.1)."""
    tag = "ewise_add"


class EwiseMul(_Binary):
    """Element-wise ⊗ over the intersection of key sets (paper §II.C.2)."""
    tag = "ewise_mul"


class MatMul(_Binary):
    """Array multiplication ``⊗.⊕`` contracting over col/row keys."""
    tag = "matmul"


class Reduce(LazyExpr):
    """⊕-reduction along an axis (``None`` → full scalar reduction).

    The result vector is indexed by the child result's row (``axis=1``)
    or col (``axis=0``) keyspace.  On device/dist that keyspace is always
    the source's full keyspace (selection never shrinks it); on host, a
    *fused* select+matmul reduce is likewise indexed by the unsliced
    ``a.row``/``b.col`` (deselected keys hold the ⊕-identity), whereas an
    eagerly materialized child would have condensed its keys first — zip
    the vector with the source keyspace, not the slice.
    """

    def __init__(self, child: LazyExpr, axis: Optional[int],
                 semiring=PLUS_TIMES):
        if axis not in (None, 0, 1):
            raise ValueError(f"axis must be None, 0 or 1, got {axis!r}")
        self.child = lazy(child)
        self.axis = axis
        self.semiring = get_semiring(semiring)

    def key(self) -> tuple:
        return ("reduce", self.child.key(), self.axis, self.semiring.name)

    def __repr__(self) -> str:
        return (f"Reduce({self.child!r}, axis={self.axis}, "
                f"semiring={self.semiring.name})")


class Transpose(LazyExpr):
    """Deferred transpose; the planner pushes selections through it."""

    def __init__(self, child: LazyExpr):
        self.child = lazy(child)

    def key(self) -> tuple:
        return ("transpose", self.child.key())

    def __repr__(self) -> str:
        return f"Transpose({self.child!r})"

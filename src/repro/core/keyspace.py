"""Host-side key dictionaries for device associative arrays.

TPU device code cannot hold strings or dynamically-growing key sets, so the
device representation (``AssocTensor``) stores **int32 ranks** into a
host-side sorted unique key array — the paper's string-value pointer scheme
(``adj[i,j] = k+1`` into sorted ``A.val``) promoted to a general mechanism
for rows, columns *and* values.

Because the key array is sorted, rank order ⇔ lexicographic order, so
order-theoretic semiring ops (min/max under dictionary order) act directly on
ranks on device.  Range queries (D4M's right-inclusive string slices) resolve
on host to a rank interval, executed on device as an integer mask.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Optional, Tuple, Union

import numpy as np

__all__ = ["KeySpace", "UNION_STATS", "clear_union_cache"]

# Memoized keyspace unions: keyspaces are immutable and content-hashed, so
# (digest_a, digest_b) fully determines (merged, self_map, other_map).
# Repeated ops on the same array pair — the common case in iterated algebra
# and selector queries — skip the merge entirely (ROADMAP "amortize
# keyspace unions").  LRU-evicted: entries pin full merged keyspaces, so
# the bound must shed cold pairs without a clear-all cliff.
_UNION_CACHE: "OrderedDict" = OrderedDict()
_UNION_CACHE_CAP = 256

UNION_STATS = {"hits": 0, "misses": 0, "evictions": 0}

# Guards LRU mutation + counter bumps under concurrent union() calls
# (serve workers union keyspaces from many threads).
_UNION_LOCK = threading.RLock()


def clear_union_cache() -> None:
    with _UNION_LOCK:
        _UNION_CACHE.clear()
        UNION_STATS["hits"] = 0
        UNION_STATS["misses"] = 0
        UNION_STATS["evictions"] = 0


class KeySpace:
    """An immutable sorted-unique key dictionary (host side)."""

    def __init__(self, keys):
        arr = np.asarray(keys)
        if arr.dtype.kind in ("U", "S", "O"):
            arr = arr.astype(str)
        else:
            arr = arr.astype(np.float64)
        self.keys = np.unique(arr)  # sorted unique
        self._digest = self._compute_digest(self.keys)

    @staticmethod
    def _compute_digest(keys: np.ndarray) -> str:
        # dtype.str + length disambiguate the fixed-width buffer: a plain
        # separator join would collide for keys containing the separator
        # (["a\x00b"] vs ["a", "b"]), and the digest is the sole identity
        # for the union/compile caches
        h = hashlib.sha1(f"{keys.dtype.str}:{len(keys)}:".encode())
        h.update(keys.tobytes())
        return h.hexdigest()

    @classmethod
    def from_sorted_unique(cls, keys: np.ndarray) -> "KeySpace":
        """Wrap an array that is already sorted-unique (skips ``np.unique``).

        The array object is kept by reference, so callers (e.g. the host
        ``Assoc``'s lazy per-axis keyspaces) can validate cache freshness
        with an identity check.
        """
        ks = cls.__new__(cls)
        ks.keys = keys
        ks._digest = cls._compute_digest(keys)
        return ks

    @property
    def digest(self) -> str:
        """Content hash — the compilation-cache key for this keyspace."""
        return self._digest

    # -- container protocol -------------------------------------------------
    def __len__(self) -> int:
        return len(self.keys)

    def __contains__(self, key) -> bool:
        return len(self.rank(np.asarray([key]), strict=False)[0]) == 1

    def __getitem__(self, rank):
        return self.keys[rank]

    # jit static-aux requirements: cheap, content-based hash/eq
    def __hash__(self) -> int:
        return hash(self._digest)

    def __eq__(self, other) -> bool:
        if self is other:
            return True
        return isinstance(other, KeySpace) and self._digest == other._digest

    def __repr__(self) -> str:
        return f"KeySpace(n={len(self)}, kind={self.keys.dtype.kind})"

    @property
    def is_string(self) -> bool:
        return self.keys.dtype.kind == "U"

    # -- rank mapping ---------------------------------------------------------
    def rank(self, keys, strict: bool = True) -> Tuple[np.ndarray, np.ndarray]:
        """Map keys → int32 ranks.  Returns ``(ranks, found_mask)``.

        With ``strict=True`` unknown keys raise; otherwise they are filtered
        (mask reports which inputs were found).
        """
        arr = np.asarray(keys)
        if self.is_string:
            arr = arr.astype(str)
        pos = np.searchsorted(self.keys, arr)
        pos_c = np.clip(pos, 0, max(len(self.keys) - 1, 0))
        found = (self.keys[pos_c] == arr) if len(self.keys) else np.zeros(arr.shape, bool)
        if strict and not found.all():
            missing = arr[~found][:5]
            raise KeyError(f"keys not in KeySpace: {missing!r}")
        return pos_c[found].astype(np.int32) if not strict else pos_c.astype(np.int32), found

    def rank_range(self, lo, hi) -> Tuple[int, int]:
        """Right-inclusive D4M range ``lo ≤ k ≤ hi`` → half-open rank range."""
        lo_i = int(np.searchsorted(self.keys, lo, side="left"))
        hi_i = int(np.searchsorted(self.keys, hi, side="right"))
        return lo_i, hi_i

    # -- merging --------------------------------------------------------------
    def union(self, other: "KeySpace") -> Tuple["KeySpace", np.ndarray, np.ndarray]:
        """Merged keyspace + rank-translation tables for both inputs.

        ``self_map[r]`` is the rank in the union of the key with rank ``r``
        in ``self`` (likewise ``other_map``).  The translation tables are the
        host analogue of the paper's union index maps; uploading them lets
        the device re-rank an AssocTensor onto the merged space with one
        gather.
        """
        if self == other:
            eye = np.arange(len(self), dtype=np.int32)
            return self, eye, eye
        if self.is_string != other.is_string:
            raise TypeError("cannot merge string and numeric keyspaces")
        cache_key = (self._digest, other._digest)
        with _UNION_LOCK:
            hit = _UNION_CACHE.get(cache_key)
            if hit is not None:
                UNION_STATS["hits"] += 1
                _UNION_CACHE.move_to_end(cache_key)
                return hit
        # merge outside the lock (pure; a cold-key race just merges twice)
        merged = KeySpace(np.concatenate([self.keys, other.keys]))
        self_map = np.searchsorted(merged.keys, self.keys).astype(np.int32)
        other_map = np.searchsorted(merged.keys, other.keys).astype(np.int32)
        # cached tuples are shared across callers: freeze the maps so an
        # in-place tweak cannot poison later unions of the same pair
        self_map.setflags(write=False)
        other_map.setflags(write=False)
        with _UNION_LOCK:
            UNION_STATS["misses"] += 1
            if cache_key not in _UNION_CACHE:
                while len(_UNION_CACHE) >= _UNION_CACHE_CAP:
                    # streaming ingest mints fresh keyspaces every append
                    # batch — count sheds so sustained-mutation workloads
                    # can see the memo churning instead of helping
                    _UNION_CACHE.popitem(last=False)
                    UNION_STATS["evictions"] += 1
                _UNION_CACHE[cache_key] = (merged, self_map, other_map)
        return merged, self_map, other_map

    @staticmethod
    def integers(n: int) -> "KeySpace":
        """The keyspace {0.0, 1.0, ..., n-1} — ranks coincide with keys."""
        return KeySpace(np.arange(n, dtype=np.float64))

"""TPU-native associative arrays: fixed-capacity, jit-safe, semiring-generic.

``AssocTensor`` is the device counterpart of the host ``Assoc``.  Where the
paper's Python implementation leans on ``scipy.sparse`` with dynamic shapes,
the TPU demands static shapes and bulk vector ops, so:

* keys are **int32 ranks** into host-side :class:`~repro.core.keyspace.KeySpace`
  dictionaries (see that module for why rank order ⇔ key order);
* the nonempty entries live in a **sorted, sentinel-padded COO triple**
  ``(rows, cols, vals)`` of static ``capacity`` plus an ``nnz`` scalar —
  growth is an explicit host-side ``grow()``, mirroring how Accumulo-backed
  D4M splits tablets rather than reallocating per insert;
* element-wise algebra is *concat → lexsort → segment-reduce* — one fused,
  shape-static pipeline that subsumes the paper's constructor aggregation,
  sorted-union addition and sorted-intersection multiplication;
* array multiplication densifies ``adj`` onto MXU-aligned tiles and calls the
  Pallas semiring matmul (``repro.kernels.semiring_matmul``), or its
  block-sparse variant for large sparse operands.

All methods are pure functions of array state (registered pytree) and safe
under ``jax.jit`` / ``pjit``; keyspaces ride in the static aux.  The one
exception is the eager-only in-place ``__setitem__`` (see its docstring).
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .assoc import Assoc
from repro.analysis.contracts import contract

from .coo import SENT, dedup_sorted_coo
from .expr import EwiseAdd, EwiseMul, MatMul, Select, Source
from .keyspace import KeySpace
from .semiring import PLUS_TIMES, Semiring, get_semiring
from .sorted_ops import INT_SENTINEL

# ``dedup_sorted_coo`` — the canonical COO merge shared with the host Assoc —
# lives in repro.core.coo; re-exported here for backward compatibility.
__all__ = ["AssocTensor", "dedup_sorted_coo"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# -- selection primitives on raw COO rank arrays ------------------------------
#
# Shared by AssocTensor's methods AND DistAssoc's shard_map bodies (which
# operate on raw per-shard arrays, not pytree objects): one implementation
# of the keep mask and the sentinel-blank + lexsort compaction, so the
# layers cannot drift apart.

def coo_range_keep(rows: jnp.ndarray, cols: jnp.ndarray,
                   bounds: jnp.ndarray) -> jnp.ndarray:
    """Keep mask for a rank box — the Pallas range-mask kernel."""
    from repro.kernels.range_extract import range_mask
    return range_mask(rows, cols, bounds) != 0


def coo_mask_keep(rows: jnp.ndarray, cols: jnp.ndarray,
                  row_mask: jnp.ndarray, col_mask: jnp.ndarray) -> jnp.ndarray:
    """Keep mask for keyspace membership masks (one gather each)."""
    ok = rows != SENT
    return (ok & row_mask[jnp.clip(rows, 0, row_mask.shape[0] - 1)]
            & col_mask[jnp.clip(cols, 0, col_mask.shape[0] - 1)])


def coo_axis_mask_keep(idx: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Single-axis membership gather (the set half of a hybrid selection)."""
    ok = idx != SENT
    return ok & mask[jnp.clip(idx, 0, mask.shape[0] - 1)]


# Selection-path dispatch counters (eager queries only): which execution
# path compiled selections take — ``range`` (Pallas range kernel, both axes
# contiguous), ``multirange`` (a multi-interval selection decomposed into
# ≤4 range-kernel boxes, OR-composed), ``hybrid`` (one contiguous axis
# through the range kernel + one membership gather), ``gather`` (both axes
# scattered).  Mirrors select.CACHE_STATS; tests and benchmarks read these
# to pin the fast path.
DISPATCH_STATS = {"range": 0, "multirange": 0, "hybrid": 0, "gather": 0}

# Dict += is a read-modify-write: serve workers bump these concurrently.
_DISPATCH_LOCK = threading.Lock()


def _bump_dispatch(key: str) -> None:
    with _DISPATCH_LOCK:
        DISPATCH_STATS[key] += 1


def coo_compact(rows: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray,
                keep: jnp.ndarray):
    """Keep-masked triples → canonical sorted/sentinel-padded form."""
    r = jnp.where(keep, rows, SENT)
    c = jnp.where(keep, cols, SENT)
    v = jnp.where(keep, vals, 0.0)
    order = jnp.lexsort((c, r))
    return r[order], c[order], v[order], keep.sum().astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AssocTensor:
    """Device associative array (padded COO + host keyspaces)."""

    rows: jnp.ndarray  # int32[capacity], sorted by (row, col), SENT-padded
    cols: jnp.ndarray  # int32[capacity]
    vals: jnp.ndarray  # float32[capacity] (or int32 value-ranks if val_space)
    nnz: jnp.ndarray   # int32 scalar
    row_space: KeySpace = dataclasses.field(metadata={"static": True})
    col_space: KeySpace = dataclasses.field(metadata={"static": True})
    val_space: Optional[KeySpace] = None  # None ⇒ numeric values

    # eager-only metadata, NOT part of the pytree: capacity-producing ops
    # (matmul, from_dense_adj) set an instance attribute when the result was
    # truncated; after any tree_map/jit round trip it falls back to this
    # class default rather than raising
    overflow = False

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return ((self.rows, self.cols, self.vals, self.nnz),
                (self.row_space, self.col_space, self.val_space))

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, cols, vals, nnz = children
        return cls(rows, cols, vals, nnz, *aux)

    # -- construction ---------------------------------------------------------
    @staticmethod
    def from_triples(row_keys, col_keys, values, *, aggregate="min",
                     capacity: Optional[int] = None,
                     row_space: Optional[KeySpace] = None,
                     col_space: Optional[KeySpace] = None) -> "AssocTensor":
        """Host-side constructor (the D4M ``Assoc(row, col, val)`` analogue).

        Builds keyspaces (or ranks into provided ones), uploads rank triples,
        and canonicalizes on device with the ``aggregate`` collision op.
        """
        row_keys = np.asarray(row_keys)
        col_keys = np.asarray(col_keys)
        values = np.asarray(values)
        if values.ndim == 0:
            values = np.broadcast_to(values, row_keys.shape).copy()

        val_space = None
        if values.dtype.kind in ("U", "S", "O"):
            val_space = KeySpace(values)
            vals_num, _ = val_space.rank(values)
            vals_num = vals_num.astype(np.float32)
        else:
            vals_num = values.astype(np.float32)

        row_space = row_space or KeySpace(row_keys)
        col_space = col_space or KeySpace(col_keys)
        r, _ = row_space.rank(row_keys)
        c, _ = col_space.rank(col_keys)

        cap = capacity or _round_up(max(len(r), 8), 8)
        if cap < len(r):
            raise ValueError(f"capacity {cap} < {len(r)} triples")
        pad = cap - len(r)
        rj = jnp.asarray(np.concatenate([r, np.full(pad, INT_SENTINEL, np.int32)]))
        cj = jnp.asarray(np.concatenate([c, np.full(pad, INT_SENTINEL, np.int32)]))
        vj = jnp.asarray(np.concatenate([vals_num, np.zeros(pad, np.float32)]))

        agg = {
            "min": jnp.minimum, "max": jnp.maximum, "sum": jnp.add,
            min: jnp.minimum, max: jnp.maximum, sum: jnp.add,
        }.get(aggregate, aggregate)
        # string values: aggregation acts on ranks; offset by +1 so that the
        # zero-drop below only removes true sentinels, not rank 0.
        if val_space is not None:
            vj = jnp.where(rj != SENT, vj + 1.0, 0.0)
        rows, cols, vals, nnz = dedup_sorted_coo(rj, cj, vj, agg)
        return AssocTensor(rows, cols, vals, nnz, row_space, col_space, val_space)

    @staticmethod
    def from_assoc(a: Assoc, capacity: Optional[int] = None, *,
                   row_space: Optional[KeySpace] = None,
                   col_space: Optional[KeySpace] = None) -> "AssocTensor":
        """Upload a host Assoc; inverse of :meth:`to_assoc` (lossless for
        string values and f32-representable numeric values; explicit 0.0
        entries are dropped — the device stores 0 as empty)."""
        r, c, v = a.triples()
        return AssocTensor.from_triples(r, c, v, capacity=capacity,
                                        row_space=row_space,
                                        col_space=col_space)

    def to_assoc(self) -> Assoc:
        """Download to the host paper-faithful representation."""
        n = int(self.nnz)
        r = np.asarray(self.rows)[:n]
        c = np.asarray(self.cols)[:n]
        v = np.asarray(self.vals)[:n]
        row_keys = self.row_space.keys[r]
        col_keys = self.col_space.keys[c]
        if self.val_space is not None:
            vals = self.val_space.keys[(v - 1.0).astype(np.int64)]
        else:
            vals = v.astype(np.float64)
        return Assoc(row_keys, col_keys, vals)

    # -- basic properties -----------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    @property
    def numeric(self) -> bool:
        return self.val_space is None

    def valid_mask(self) -> jnp.ndarray:
        return self.rows != SENT

    # -- re-ranking onto merged keyspaces --------------------------------------
    def reranked(self, row_space: KeySpace, col_space: KeySpace,
                 row_map: np.ndarray, col_map: np.ndarray) -> "AssocTensor":
        """Translate ranks onto merged keyspaces (one gather each)."""
        rm = jnp.asarray(row_map)
        cm = jnp.asarray(col_map)
        ok = self.valid_mask()
        rows = jnp.where(ok, rm[jnp.clip(self.rows, 0, len(rm) - 1)], SENT)
        cols = jnp.where(ok, cm[jnp.clip(self.cols, 0, len(cm) - 1)], SENT)
        return AssocTensor(rows, cols, self.vals, self.nnz,
                           row_space, col_space, self.val_space)

    def _aligned(self, other: "AssocTensor"):
        """Bring two arrays onto common keyspaces (host merge, amortized)."""
        rs, rm_a, rm_b = self.row_space.union(other.row_space)
        cs, cm_a, cm_b = self.col_space.union(other.col_space)
        a = self if (rs == self.row_space and cs == self.col_space) else \
            self.reranked(rs, cs, rm_a, cm_a)
        b = other if (rs == other.row_space and cs == other.col_space) else \
            other.reranked(rs, cs, rm_b, cm_b)
        return a, b

    # -- lazy expressions (the deferred pipeline API, repro.core.expr) ---------
    def lazy(self) -> Source:
        """Wrap as a lazy expression Source (see ``Assoc.lazy``)."""
        return Source(self)

    # -- element-wise algebra ---------------------------------------------------
    def add(self, other: "AssocTensor", semiring=PLUS_TIMES) -> "AssocTensor":
        """Element-wise ⊕ over the union of key sets (paper §II.C.1)."""
        sr = get_semiring(semiring)
        a, b = self._aligned(other)
        rows = jnp.concatenate([a.rows, b.rows])
        cols = jnp.concatenate([a.cols, b.cols])
        vals = jnp.concatenate([a.vals, b.vals])
        r, c, v, nnz = dedup_sorted_coo(rows, cols, vals, sr.add, zero=sr.zero)
        return AssocTensor(r, c, v, nnz, a.row_space, a.col_space, a.val_space)

    def __add__(self, other):
        # thin wrapper over the one-node graph (lazy/eager share one path);
        # expression operands defer to the Node's reflected operator
        if not isinstance(other, AssocTensor):
            return NotImplemented
        return EwiseAdd(Source(self), Source(other)).collect()

    def mul(self, other: "AssocTensor", semiring=PLUS_TIMES) -> "AssocTensor":
        """Element-wise ⊗ over the intersection of key sets (paper §II.C.2)."""
        sr = get_semiring(semiring)
        a, b = self._aligned(other)
        rows = jnp.concatenate([a.rows, b.rows])
        cols = jnp.concatenate([a.cols, b.cols])
        vals = jnp.concatenate([a.vals, b.vals])
        src = jnp.concatenate([
            jnp.zeros(a.capacity, jnp.int32), jnp.ones(b.capacity, jnp.int32)])
        r, c, v, nnz = dedup_sorted_coo(
            rows, cols, vals, sr.add, zero=sr.zero,
            require_pair=True, pair_op=sr.mul, src=src)
        cap = min(a.capacity, b.capacity)
        return AssocTensor(r[:cap], c[:cap], v[:cap], jnp.minimum(nnz, cap),
                           a.row_space, a.col_space, a.val_space)

    def __mul__(self, other):
        if not isinstance(other, AssocTensor):
            return NotImplemented
        return EwiseMul(Source(self), Source(other)).collect()

    def logical(self) -> "AssocTensor":
        """Replace nonempty entries with 1 (paper's ``.logical()``)."""
        ok = self.valid_mask()
        return AssocTensor(self.rows, self.cols,
                           jnp.where(ok, 1.0, 0.0).astype(self.vals.dtype),
                           self.nnz, self.row_space, self.col_space, None)

    # -- densification + array multiplication -----------------------------------
    def to_dense_adj(self, *, pad_to: int = 128,
                     zero: float = 0.0) -> jnp.ndarray:
        """Scatter onto a dense (|rowspace|, |colspace|) MXU-aligned array."""
        nr = _round_up(max(len(self.row_space), 1), pad_to)
        nc = _round_up(max(len(self.col_space), 1), pad_to)
        ok = self.valid_mask()
        # route padding entries out of bounds so mode="drop" discards them
        r = jnp.where(ok, self.rows, nr)
        c = jnp.where(ok, self.cols, nc)
        v = jnp.where(ok, self.vals, zero)
        dense = jnp.full((nr, nc), zero, dtype=self.vals.dtype)
        # duplicate-free by invariant: plain scatter
        return dense.at[r, c].set(v, mode="drop", unique_indices=False)

    @staticmethod
    def from_dense_adj(dense, row_space: KeySpace, col_space: KeySpace,
                       capacity: int, *, zero: float = 0.0,
                       warn_overflow: bool = True) -> "AssocTensor":
        """Top-|capacity| nonzeros of a dense adj back to padded COO.

        When the true nonzero count exceeds ``capacity`` the excess entries
        (latest in (row, col) order) are dropped; the result records that
        as an eager ``overflow`` attribute (bool device scalar) and, on
        host-driven (untraced) paths, emits a ``RuntimeWarning`` — a silent
        truncation here corrupts every downstream ⊕ without a trace.
        """
        nr, nc = dense.shape
        flat = dense.reshape(-1)
        ok = flat != zero
        # order: valid entries first, in row-major (row, col) order
        idx = jnp.arange(flat.shape[0], dtype=jnp.int32)
        order = jnp.argsort(jnp.where(ok, idx, jnp.int32(2**31 - 1)),
                            stable=True)[:capacity]
        taken_ok = ok[order]
        rows = jnp.where(taken_ok, order // nc, SENT).astype(jnp.int32)
        cols = jnp.where(taken_ok, order % nc, SENT).astype(jnp.int32)
        vals = jnp.where(taken_ok, flat[order], zero)
        true_nnz = ok.sum()
        nnz = jnp.minimum(true_nnz, capacity).astype(jnp.int32)
        out = AssocTensor(rows, cols, vals, nnz, row_space, col_space, None)
        overflow = true_nnz > capacity
        out.overflow = overflow
        if warn_overflow and not isinstance(dense, jax.core.Tracer) \
                and bool(overflow):
            import warnings
            warnings.warn(
                f"from_dense_adj: {int(true_nnz)} nonzeros exceed capacity "
                f"{capacity}; {int(true_nnz) - capacity} entries dropped",
                RuntimeWarning, stacklevel=2)
        return out

    def transpose(self) -> "AssocTensor":
        """Swap rows/cols and restore canonical (row, col) order."""
        ok = self.valid_mask()
        r = jnp.where(ok, self.cols, SENT)
        c = jnp.where(ok, self.rows, SENT)
        order = jnp.lexsort((c, r))
        return AssocTensor(r[order], c[order], self.vals[order], self.nnz,
                           self.col_space, self.row_space, self.val_space)

    @property
    def T(self) -> "AssocTensor":
        return self.transpose()

    def matmul(self, other: "AssocTensor", semiring=PLUS_TIMES,
               out_capacity: Optional[int] = None,
               use_kernel: bool = True, impl: str = "auto",
               kernel_impl: str = "auto") -> "AssocTensor":
        """Array multiplication ``⊗.⊕`` contracting over col/row keys.

        Strings are first reduced via ``logical()`` (paper rule).  Planned
        and executed by :mod:`repro.core.spgemm` — the dense strategy
        contracts MXU-aligned adj tiles through the Pallas semiring matmul;
        the BSR strategy packs only the present 128×128 tiles and streams
        them through the scalar-prefetch pair-list kernel, never
        materializing the dense product; ``impl`` overrides the auto
        heuristic (``"dense"`` / ``"bsr"`` / ``"coo"``) and ``kernel_impl``
        the pair-list kernel dispatch (``"pallas"`` / ``"interpret"`` /
        ``"ref"`` / ``"chunked"``).
        """
        from .spgemm import matmul as _planned_matmul
        return _planned_matmul(self, other, semiring, impl=impl,
                               out_capacity=out_capacity,
                               use_kernel=use_kernel,
                               kernel_impl=kernel_impl)

    def matmul_reduce(self, other: "AssocTensor", axis: int,
                      semiring=PLUS_TIMES, *, impl: str = "auto",
                      kernel_impl: str = "auto") -> jnp.ndarray:
        """Fused ``⊕-reduce(self ⊗.⊕ other, axis)`` — skips materializing
        the product entirely (Graphulo pushdown; see
        :func:`repro.core.spgemm.matmul_reduce`).  Returns a dense vector
        over ``self.row_space`` (``axis=1``) or ``other.col_space``
        (``axis=0``)."""
        from .spgemm import matmul_reduce as _planned_reduce
        return _planned_reduce(self, other, axis, semiring, impl=impl,
                               kernel_impl=kernel_impl)

    def sqin(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AᵀA — the correlation idiom.  ``reduce=0/1`` returns the fused
        ⊕-reduction of the square instead (vector over the col keyspace)."""
        t = self.transpose()
        if reduce is None:
            return t.matmul(self, semiring)
        return t.matmul_reduce(self, reduce, semiring)

    def sqout(self, semiring=PLUS_TIMES, reduce: Optional[int] = None):
        """AAᵀ — row-key graph; ``reduce=0/1`` for the fused reduction."""
        t = self.transpose()
        if reduce is None:
            return self.matmul(t, semiring)
        return self.matmul_reduce(t, reduce, semiring)

    def __matmul__(self, other):
        if not isinstance(other, AssocTensor):
            return NotImplemented
        return MatMul(Source(self), Source(other)).collect()

    # -- extraction -------------------------------------------------------------
    #
    # All __getitem__ selection routes through the selector algebra
    # (repro.core.select): the selector compiles once on host against the
    # keyspaces, then executes on device against the padded COO triples —
    # a contiguous rank box goes through the Pallas range-mask kernel, a
    # general index set through one membership gather.  Selection never
    # densifies.

    def _compact(self, keep: jnp.ndarray) -> "AssocTensor":
        """Keep-masked triples → canonical sorted/sentinel-padded form."""
        r, c, v, nnz = coo_compact(self.rows, self.cols, self.vals, keep)
        return AssocTensor(r, c, v, nnz,
                           self.row_space, self.col_space, self.val_space)

    def _range_keep(self, row_range: Tuple[int, int],
                    col_range: Tuple[int, int]) -> jnp.ndarray:
        """Keep mask for a rank box, via the shared Pallas range kernel."""
        bounds = jnp.asarray([row_range[0], row_range[1],
                              col_range[0], col_range[1]], dtype=jnp.int32)
        return coo_range_keep(self.rows, self.cols, bounds)

    def _mask_keep(self, row_mask: jnp.ndarray,
                   col_mask: jnp.ndarray) -> jnp.ndarray:
        """Keep mask for keyspace membership masks (one gather each)."""
        return coo_mask_keep(self.rows, self.cols, row_mask, col_mask)

    def extract_ranges(self, row_range: Tuple[int, int],
                       col_range: Tuple[int, int]) -> "AssocTensor":
        """Sub-array by rank ranges (host resolves key slices → ranks)."""
        return self._compact(self._range_keep(row_range, col_range))

    def extract_mask(self, row_mask: jnp.ndarray,
                     col_mask: jnp.ndarray) -> "AssocTensor":
        """Sub-array by keyspace membership masks (gather path, jit-safe).

        ``row_mask``/``col_mask`` are bool arrays over the row/col
        keyspaces — the compiled form of a non-contiguous selector.
        """
        return self._compact(self._mask_keep(row_mask, col_mask))

    def _compiled_pair(self, ij):
        from .select import compile_selector
        return (compile_selector(ij[0], self.row_space),
                compile_selector(ij[1], self.col_space))

    def _device_masks(self, rc, cc) -> Tuple[jnp.ndarray, jnp.ndarray]:
        rm = (np.ascontiguousarray(rc.mask()) if len(self.row_space)
              else np.zeros(1, bool))
        cm = (np.ascontiguousarray(cc.mask()) if len(self.col_space)
              else np.zeros(1, bool))
        return jnp.asarray(rm), jnp.asarray(cm)

    def _selection_keep(self, ij) -> jnp.ndarray:
        """Compile (row_sel, col_sel) and evaluate the device keep mask.

        The single dispatch point between four execution paths — both
        ``__getitem__`` and ``__setitem__`` go through here, planned by
        :func:`repro.core.select.plan_boxes`:

        * both axes contiguous → ONE Pallas range-mask kernel call;
        * a multi-interval ``Match``/``Where``/``Keys`` whose hits form ≤4
          rank boxes → one range-kernel call per box, OR-composed (the
          boxes are disjoint interval runs, so the OR is exact and the
          single downstream compaction is the only sort — no merge of
          extracted lists needed);
        * one axis boxable, the other scattered → the box calls AND one
          membership gather for the scattered axis;
        * both axes scattered → two membership gathers (no kernel).
        """
        from .select import plan_boxes

        rc, cc = self._compiled_pair(ij)
        nr = max(len(self.row_space), 1)
        nc = max(len(self.col_space), 1)
        boxes, row_gather, col_gather = plan_boxes(rc, cc, nr, nc)
        if row_gather and col_gather:
            _bump_dispatch("gather")
            return self._mask_keep(*self._device_masks(rc, cc))
        if len(boxes) > 1:
            _bump_dispatch("multirange")
        elif row_gather or col_gather:
            _bump_dispatch("hybrid")
        else:
            _bump_dispatch("range")
        keep = self._range_keep((int(boxes[0][0]), int(boxes[0][1])),
                                (int(boxes[0][2]), int(boxes[0][3])))
        for b in boxes[1:]:
            keep = keep | self._range_keep((int(b[0]), int(b[1])),
                                           (int(b[2]), int(b[3])))
        # membership mask built (and uploaded) ONLY for a scattered axis —
        # boxed axes are already handled by the kernel bounds
        if row_gather:
            keep = keep & coo_axis_mask_keep(
                self.rows, jnp.asarray(np.ascontiguousarray(rc.mask())))
        if col_gather:
            keep = keep & coo_axis_mask_keep(
                self.cols, jnp.asarray(np.ascontiguousarray(cc.mask())))
        return keep

    @contract(collectives=0,
              note="device selection: range kernel / masks, never dense")
    def __getitem__(self, ij) -> "AssocTensor":
        # thin wrapper over the one-node graph (see __add__)
        i, j = ij
        return Select(Source(self), i, j).collect()

    def _select_eager(self, ij) -> "AssocTensor":
        """Physical selection (the executor's device backend)."""
        return self._compact(self._selection_keep(ij))

    @contract(collectives=0,
              note="in-place value overwrite over stored entries")
    def __setitem__(self, ij, value) -> None:
        """Selector-targeted value update (in place, numeric scalar).

        Overwrites the values of *stored* entries inside the selection;
        the support is unchanged (inserting new entries is a host-side
        ``from_triples`` — the device layout is fixed-capacity).

        Eager/host-driven only: this mutates the Python object, which is
        the one exception to the module's pure-pytree contract — inside a
        ``jax.jit`` trace use ``extract_*``/functional updates instead.
        """
        if (not isinstance(value, (int, float, np.integer, np.floating))
                or isinstance(value, (bool, np.bool_))):
            raise TypeError("device __setitem__ takes a numeric scalar")
        if not self.numeric:
            raise TypeError("device __setitem__ requires numeric values")
        keep = self._selection_keep(ij)
        self.vals = jnp.where(keep, jnp.float32(value), self.vals)

    # -- reductions ---------------------------------------------------------------
    #
    # Both axis reductions route through the shared reduce path in
    # repro.core.plan (one scatter_combine implementation for the Reduce
    # node, eager calls, and the fused epilogue partials alike).

    def reduce_rows(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕-reduce over columns → dense vector over the row keyspace."""
        from .plan import device_axis_reduce
        return device_axis_reduce(self, 1, semiring)

    def reduce_cols(self, semiring=PLUS_TIMES) -> jnp.ndarray:
        """⊕-reduce over rows → dense vector over the col keyspace."""
        from .plan import device_axis_reduce
        return device_axis_reduce(self, 0, semiring)

    def nnz_host(self) -> int:
        return int(self.nnz)

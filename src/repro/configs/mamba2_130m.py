"""mamba2-130m [ssm] — 24L d768 (attn-free) ssm_state=128 vocab50280.

SSD (state-space duality) blocks: d_inner 1536, head_dim 64 (24 heads),
conv width 4, chunk 128.  Attention-free ⇒ decode state is O(1) in sequence
length, so all four shapes including long_500k run.  24 heads don't divide
the 16-way model axis ⇒ SSM internals replicate over `model`; only the
in/out projections are TP-sharded (see DESIGN.md §Arch-applicability).
[arXiv:2405.21060]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24, d_ff=0,
    vocab=50280, head_dim=64, norm="rmsnorm", act="swiglu",
    rope_theta=None, tie_embeddings=True,
    ssm={"d_inner": 1536, "d_state": 128, "head_dim": 64, "d_conv": 4,
         "n_groups": 1, "chunk": 128},
    shard_ssm_heads=False,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, loss_chunk=32, max_seq=512,
    ssm={"d_inner": 128, "d_state": 16, "head_dim": 32, "d_conv": 4,
         "n_groups": 1, "chunk": 32},
)

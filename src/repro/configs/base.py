"""Architecture/config schema shared by all assigned architectures.

Every ``src/repro/configs/<arch>.py`` exports ``CONFIG`` (the exact published
configuration) and ``SMOKE`` (a reduced same-family config for CPU tests).
``repro.launch`` consumes these via :func:`repro.configs.get_config`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    norm: str = "rmsnorm"
    act: str = "swiglu"
    pos_emb: str = "rope"            # rope | sinusoidal | learned
    rope_theta: Optional[float] = 10000.0
    rotary_dim: Optional[int] = None  # partial ("2d") RoPE if < head_dim
    qk_norm: bool = False
    attn_bias: bool = False
    window: Optional[int] = None     # sliding-window attention
    tie_embeddings: bool = False
    scale_emb: float = 1.0           # μP-style embedding scale (MiniCPM)
    scale_depth: Optional[float] = None  # residual scale s/√L (MiniCPM)
    logit_scale: Optional[float] = None
    max_seq: int = 544768            # learned-pos capacity / rope cache bound
    moe: Optional[Dict[str, Any]] = None
    ssm: Optional[Dict[str, Any]] = None
    hybrid: Optional[Dict[str, Any]] = None
    encdec: Optional[Dict[str, Any]] = None
    mla: Optional[Dict[str, Any]] = None
    mtp: bool = False                # DeepSeek multi-token prediction head
    mtp_weight: float = 0.1
    # numerics / implementation policy
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    attn_impl: str = "reference"     # reference (XLA) | pallas (TPU)
    attn_chunk: int = 512            # query-chunk for the reference path
    prefill_chunk: Optional[int] = None  # window-wise cache build (long ctx)
    loss_chunk: int = 512            # sequence chunk for chunked xent
    remat: str = "full"              # none | full  (per-layer checkpoint)
    # sharding hints (consumed by launch/sharding.py)
    shard_ssm_heads: bool = True     # False when H % |model| != 0
    moe_sharding: str = "ep"         # ep | tp  (expert vs hidden split)
    seq_parallel: bool = False       # residual stream S-sharded over model

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell: what to lower and at what size."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def step(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

"""whisper-medium [audio] — enc-dec, 24+24L d1024 16H ffn4096 vocab51865.

Conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings [B, 1500, d_model]; the transformer backbone
(bidirectional encoder + causal decoder with cross-attention) is real.
Decoder uses learned positions; encoder sinusoidal.  [arXiv:2212.04356]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, norm="layernorm", act="gelu",
    pos_emb="learned", rope_theta=None, attn_bias=True,
    encdec={"enc_layers": 24, "enc_frames": 1500},
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
    encdec={"enc_layers": 2, "enc_frames": 30},
)

"""mixtral-8x22b [moe] — 56L d6144 48H (GQA kv=8) ffn16384, 8 experts top-2.

Sliding-window attention (window 4096 per the assignment spec) makes the
long_500k decode cell sub-quadratic (ring-buffer KV cache of the window).
Experts < |model| ⇒ MoE hidden dims are TP-sharded (moe_sharding="tp").
[arXiv:2401.04088; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, head_dim=128, norm="rmsnorm", act="swiglu",
    rope_theta=1000000.0, window=4096,
    moe={"n_experts": 8, "top_k": 2, "d_ff": 16384, "first_dense": 0,
         "router_type": "softmax_topk", "capacity_factor": 1.25,
         "aux_weight": 0.01},
    moe_sharding="tp",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, window=64, attn_chunk=64, loss_chunk=32, max_seq=512,
    moe={"n_experts": 4, "top_k": 2, "d_ff": 64, "first_dense": 0,
         "router_type": "softmax_topk", "capacity_factor": 2.0,
         "aux_weight": 0.01},
)

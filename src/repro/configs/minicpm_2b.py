"""minicpm-2b [dense] — 40L d2304 36H (kv=36 ≡ MHA) ffn5760 vocab122753.

μP-style scaling (scale_emb=12, residual scale 1.4/√L, logits scaled by
256/d_model) and the WSD learning-rate schedule (see repro.optim.schedules).
Architecture is llama-like.  [arXiv:2404.06395; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64, norm="rmsnorm", act="swiglu",
    rope_theta=10000.0, tie_embeddings=True,
    scale_emb=12.0, scale_depth=1.4, logit_scale=256.0 / 2304.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=509,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
    logit_scale=256.0 / 64.0,
)

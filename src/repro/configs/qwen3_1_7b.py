"""qwen3-1.7b [dense] — 28L d2048 16H (GQA kv=8) ffn6144 vocab151936.

Per-head q/k RMS-norm, tied embeddings.  [hf:Qwen/Qwen3-8B; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, head_dim=128, qk_norm=True, tie_embeddings=True,
    norm="rmsnorm", act="swiglu", rope_theta=1000000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
)

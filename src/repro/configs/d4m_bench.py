"""The paper's own workload (Figs 3–7): associative-array benchmarks.

Six synthetic datasets exactly as §III.A describes: for each n in [5, 18],
8·2^n uniformly random integer keys in [0, 2^n] (cast to strings), numeric
values in [0, 100], and random length-8 strings.  ``make_dataset(n)``
regenerates them deterministically; ``benchmarks/run.py`` consumes this.
"""
from __future__ import annotations

import numpy as np

N_RANGE = range(5, 19)          # paper: 5 ≤ n ≤ 18
ENTRIES_PER_ROW = 8             # ≈ 8 nonempty entries per row
SEED = 20220926                 # HPEC'22 publication date


def make_dataset(n: int, seed: int = SEED):
    """Returns dict with rows/rows2/cols/cols2/num_vals/str_vals for size n."""
    rng = np.random.default_rng(seed + n)
    m = ENTRIES_PER_ROW * (2 ** n)
    def ints():
        return rng.integers(0, 2 ** n, size=m)
    letters = np.array(list("abcdefghijklmnopqrstuvwxyz"))
    def strs():
        idx = rng.integers(0, 26, size=(m, 8))
        return np.array(["".join(row) for row in letters[idx]])
    return {
        "rows": ints().astype(str),
        "rows2": ints().astype(str),
        "cols": ints().astype(str),
        "cols2": ints().astype(str),
        "num_vals": rng.integers(0, 100, size=m).astype(np.float64),
        "str_vals": strs(),
    }

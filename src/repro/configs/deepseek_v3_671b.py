"""deepseek-v3-671b [moe] — 61L d7168 128H ffn(expert)=2048 vocab129280.

MLA (kv_lora 512 + rope 64, q_lora 1536), 1 shared + 256 routed experts
top-8 with sigmoid gating and aux-loss-free bias balancing; first 3 layers
dense (d_ff 18432); multi-token-prediction head (one extra block predicting
t+2, λ=0.1) active in train_step.  [arXiv:2412.19437; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab=129280, head_dim=128, norm="rmsnorm", act="swiglu",
    rope_theta=10000.0,
    mla={"q_lora_rank": 1536, "kv_lora_rank": 512,
         "qk_nope_dim": 128, "qk_rope_dim": 64, "v_head_dim": 128},
    moe={"n_experts": 256, "top_k": 8, "d_ff": 2048, "first_dense": 3,
         "router_type": "sigmoid_topk", "router_bias": True,
         "shared_expert": 1, "routed_scale": 2.5, "capacity_factor": 1.25,
         "aux_weight": 0.0},
    moe_sharding="ep",
    mtp=True, mtp_weight=0.1,
    prefill_chunk=4096,  # window-wise 32k prefill: 27→12.2 GB/chip (§Perf)
)

SMOKE = CONFIG.replace(
    prefill_chunk=None,  # CPU smoke tests exercise one-shot prefill
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, vocab=512, d_ff=128,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
    mla={"q_lora_rank": 24, "kv_lora_rank": 16,
         "qk_nope_dim": 16, "qk_rope_dim": 8, "v_head_dim": 16},
    moe={"n_experts": 8, "top_k": 2, "d_ff": 32, "first_dense": 1,
         "router_type": "sigmoid_topk", "router_bias": True,
         "shared_expert": 1, "routed_scale": 2.5, "capacity_factor": 2.0,
         "aux_weight": 0.0},
)

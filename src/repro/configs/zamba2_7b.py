"""zamba2-7b [hybrid] — 81L d3584 (Mamba2 backbone) + shared attn blocks.

81 Mamba2 layers (d_inner 7168, state 64, head_dim 64 ⇒ 112 SSM heads,
16-way shardable); ONE shared attention+MLP block (32 heads, d_ff 14336)
invoked every 6 layers with a per-invocation LoRA delta on wq — the Zamba2
weight-sharing trick.  Hybrid state ⇒ long_500k runs (full attention in the
~14 shared invocations; the SSM carries the long-range state).
[arXiv:2411.15242]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112, norm="rmsnorm", act="swiglu",
    rope_theta=10000.0,
    ssm={"d_inner": 7168, "d_state": 64, "head_dim": 64, "d_conv": 4,
         "n_groups": 1, "chunk": 128},
    hybrid={"attn_every": 6, "lora_rank": 128},
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
    ssm={"d_inner": 128, "d_state": 16, "head_dim": 32, "d_conv": 4,
         "n_groups": 1, "chunk": 32},
    hybrid={"attn_every": 2, "lora_rank": 8},
)

"""starcoder2-7b [dense] — 32L d4608 36H (GQA kv=4) ffn18432 vocab49152.

GeLU MLP, LayerNorm with bias, RoPE.  [arXiv:2402.19173; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, d_ff=18432,
    vocab=49152, head_dim=128, norm="layernorm", act="gelu",
    attn_bias=True, rope_theta=100000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, d_ff=144, vocab=512,
    head_dim=12, attn_chunk=64, loss_chunk=32, max_seq=512,
)

"""chatglm3-6b [dense] — 28L d4096 32H (GQA kv=2) ffn13696 vocab65024.

RoPE applied to half the head dim ("2d" rotary), multi-query-style GQA with
2 KV groups.  [arXiv:2406.12793; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128, rotary_dim=64,  # 2d RoPE: half of head_dim
    norm="rmsnorm", act="swiglu", rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, rotary_dim=8, attn_chunk=64, loss_chunk=32, max_seq=512,
)

"""chameleon-34b [vlm] — 48L d8192 64H (GQA kv=8) ffn22016 vocab65536.

Early-fusion VLM: VQ image tokens share the 65536-entry vocabulary with
text, so the backbone sees one mixed token stream — the modality frontend
(VQ-GAN tokenizer) is a STUB per the assignment; ``input_specs()`` provides
token ids.  q/k-norm for training stability.  [arXiv:2405.09818]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128, qk_norm=True,
    norm="rmsnorm", act="swiglu", rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, attn_chunk=64, loss_chunk=32, max_seq=512,
)

"""Config registry: one module per assigned architecture (+ the paper's own
D4M benchmark workload in ``d4m_bench``).

``get_config(name)`` → full published config; ``get_smoke(name)`` → reduced
same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   ModelConfig, ShapeSpec)

ARCH_IDS: List[str] = [
    "chatglm3_6b",
    "qwen3_1_7b",
    "starcoder2_7b",
    "minicpm_2b",
    "whisper_medium",
    "deepseek_v3_671b",
    "mixtral_8x22b",
    "chameleon_34b",
    "mamba2_130m",
    "zamba2_7b",
]

def _normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def _mod(name: str):
    name = _normalize(name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).SMOKE


def shapes_for(name: str) -> List[ShapeSpec]:
    """The assigned shape cells for an architecture, with documented skips."""
    cfg = get_config(name)
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
    if not sub_quadratic_decode(cfg):
        out.remove(LONG_500K)  # pure full-attention arch — skip per brief
    return out


def sub_quadratic_decode(cfg: ModelConfig) -> bool:
    """long_500k eligibility: SSM/hybrid state or sliding-window cache."""
    return cfg.family in ("ssm", "hybrid") or cfg.window is not None


__all__ = ["ARCH_IDS", "ModelConfig", "ShapeSpec", "get_config", "get_smoke",
           "shapes_for", "sub_quadratic_decode", "ALL_SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K"]

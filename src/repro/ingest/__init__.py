"""repro.ingest — the Dynamic D of D4M: LSM-style streaming mutation.

Resident tables (PR 8's serve layer) could serve but never absorb data;
this package adds the Accumulo-flavored write path over all three array
layers:

* :class:`~repro.ingest.table.IngestTable` — per-table **delta buffer**
  absorbing raw triple batches (key-partitioned straight to the owning
  row shard for ``DistAssoc``; zero collectives on the ingest path),
  **merge-on-read** snapshots (base ⊕ delta through the compiled overlay
  merge, memoized between mutations), and **compaction** that folds delta
  into a new base, bumps the table version, and invalidates the planner/
  compile cache entries keyed on the retired arrays.
* :class:`~repro.ingest.table.Compactor` — background thread compacting
  on a depth threshold or idle timeout.
* :mod:`~repro.ingest.merge` — the compiled merge programs, contract-
  checked by ``tools/d4mcheck``: ``ingest.append`` and the merge-on-read
  programs are zero-collective and never densify.
"""
from .table import Compactor, IngestTable

__all__ = ["Compactor", "IngestTable"]

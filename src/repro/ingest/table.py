"""LSM-style mutable overlay over a resident associative array.

:class:`IngestTable` wraps a base array from any of the three layers
(host ``Assoc``, device ``AssocTensor``, sharded ``DistAssoc``) with the
Accumulo tablet-server write path:

* ``insert(rows, cols, vals)`` appends a raw triple batch to a host-side
  **delta buffer** — pure list appends, no canonicalization, no device
  work, and for the sharded layer the batch is key-partitioned straight
  to the owning row shard (no global re-canonicalize, zero collectives);
* ``snapshot()`` is the **merge-on-read** view: base ⊕ delta through the
  compiled overlay-merge programs (:mod:`repro.ingest.merge`), memoized
  per (version, delta-depth) so repeated reads between mutations reuse
  one merge;
* ``compact()`` re-canonicalizes delta into a new base, bumps the table
  ``version``, and invalidates the planner/compile cache entries keyed on
  the retired arrays (:func:`repro.core.plan.invalidate_plan_for` /
  :func:`repro.core.select.invalidate_compiled_for`) so nothing pins dead
  state; :class:`Compactor` runs this in the background on a depth
  threshold or an idle timeout.

Aggregation semantics match a one-shot constructor over the concatenated
triples: ⊕ collisions combine base-first (the host ``combine`` order),
and device/dist layers restrict ⊕ to the commutative monoids
(``sum``/``min``/``max``) the unstable device sort supports; host tables
accept any ``Assoc`` aggregator (including order-sensitive ``"concat"``).
One seeded difference is inherited from the layers themselves: the host
constructor drops explicit-zero *raw* values before aggregation while the
device constructor drops zero *results* after it — ingest preserves each
layer's own semantics.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["IngestTable", "Compactor"]


def _next_pow2(n: int) -> int:
    p = 8
    while p < n:
        p *= 2
    return p


def _boundary_keys(space, bounds) -> np.ndarray:
    """First-key of shards 1..S-1 — the key-interval routing table."""
    keys = space.keys
    if len(keys) == 0:
        return keys[:0]
    idx = np.minimum(np.asarray(bounds[1:-1], dtype=np.int64),
                     len(keys) - 1)
    return keys[idx]


class IngestTable:
    """Mutable LSM overlay (delta buffer + merge-on-read + compaction)."""

    def __init__(self, base, *, aggregate: str = "sum",
                 compact_threshold: int = 4096, name: str = ""):
        from repro.core import Assoc, AssocTensor, DistAssoc

        if isinstance(base, Assoc):
            self.layer = "host"
        elif isinstance(base, AssocTensor):
            self.layer = "device"
        elif isinstance(base, DistAssoc):
            self.layer = "dist"
        else:
            raise TypeError(
                f"IngestTable base must be Assoc/AssocTensor/DistAssoc, "
                f"got {type(base).__name__}")
        if self.layer == "device" and base.val_space is not None:
            raise TypeError("device ingest requires numeric values")
        if self.layer == "dist" and base.local.val_space is not None:
            raise TypeError("dist ingest requires numeric values")
        if self.layer in ("device", "dist"):
            from .merge import _agg_op
            _agg_op(aggregate)   # validate early, not at first read

        self.base = base
        self.aggregate = aggregate
        self.compact_threshold = int(compact_threshold)
        self.name = name
        self.version = 0

        self._lock = threading.RLock()
        # host/device: one flat batch list; dist: one list per shard
        self._batches: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._shard_batches: List[List[Tuple]] = []
        self._depth = 0
        self._last_insert_t = time.monotonic()
        self._snap: Optional[Tuple[int, int, Any]] = None  # (ver, depth, arr)
        self._retired: List[Any] = []   # superseded arrays, pending invalidation
        self.stats: Dict[str, int] = {
            "inserts": 0, "insert_triples": 0, "reads": 0, "merges": 0,
            "compactions": 0,
        }
        if self.layer == "dist":
            self._nshards = base.mesh.shape["data"]
            self._shard_batches = [[] for _ in range(self._nshards)]
            self._bkeys = _boundary_keys(base.local.row_space,
                                         base.row_bounds)

    # -- write path ----------------------------------------------------------
    def insert(self, rows, cols, vals) -> Dict[str, int]:
        """Append one raw triple batch (host work only: validates, and for
        the dist layer routes each triple to its owning row shard by key
        interval — the zero-collective ingest path)."""
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = np.asarray(vals)
        if not (len(rows) == len(cols) == len(vals)):
            raise ValueError(
                f"batch arrays must have equal length, got "
                f"{len(rows)}/{len(cols)}/{len(vals)}")
        if len(rows) == 0:
            return {"accepted": 0, "delta_depth": self._depth}
        if self.layer in ("device", "dist") and vals.dtype.kind not in "fiub":
            raise TypeError(
                f"{self.layer} ingest requires numeric values, got dtype "
                f"{vals.dtype}")
        if vals.dtype.kind in "fiub":
            vals = vals.astype(np.float64)
        with self._lock:
            if self.layer == "dist":
                if len(self._bkeys):
                    shard = np.searchsorted(self._bkeys, rows, side="right")
                else:
                    shard = np.zeros(len(rows), dtype=np.int64)
                for s in range(self._nshards):
                    m = shard == s
                    if m.any():
                        self._shard_batches[s].append(
                            (rows[m], cols[m], vals[m]))
            else:
                self._batches.append((rows, cols, vals))
            self._depth += len(rows)
            self._last_insert_t = time.monotonic()
            self.stats["inserts"] += 1
            self.stats["insert_triples"] += len(rows)
            return {"accepted": len(rows), "delta_depth": self._depth}

    @property
    def delta_depth(self) -> int:
        return self._depth

    # -- read path (merge-on-read) -------------------------------------------
    def snapshot(self):
        """The queryable view: base ⊕ buffered delta.

        Memoized per (version, delta-depth): repeated reads between
        mutations reuse one merged array — the merge-on-read *hit* the
        stats report.  With an empty delta the base itself is returned
        (no copy, stable ``id`` ⇒ stable plan-cache keys)."""
        with self._lock:
            self.stats["reads"] += 1
            if self._depth == 0:
                return self.base
            if self._snap is not None and \
                    self._snap[:2] == (self.version, self._depth):
                return self._snap[2]
            self.stats["merges"] += 1
            merged = getattr(self, f"_merge_{self.layer}")()
            if self._snap is not None:
                self._retired.append(self._snap[2])
            self._snap = (self.version, self._depth, merged)
            return merged

    def _delta_triples(self):
        rows = np.concatenate([b[0] for b in self._batches])
        cols = np.concatenate([b[1] for b in self._batches])
        vals = np.concatenate([b[2] for b in self._batches])
        return rows, cols, vals

    def _merge_host(self):
        from repro.core import Assoc
        r, c, v = self._delta_triples()
        delta = Assoc(r, c, v, aggregate=self.aggregate)
        return self.base.combine(delta, self.aggregate)

    def _union_spaces(self, d_rows, d_cols):
        """Union keyspaces + base rank maps (memoized in the keyspace
        layer); keeps the base space OBJECT when content is unchanged so
        digests and compile-cache keys stay put."""
        from repro.core import KeySpace
        base = self.base if self.layer == "device" else self.base.local
        rs, rmap, _ = base.row_space.union(KeySpace(d_rows))
        cs, cmap, _ = base.col_space.union(KeySpace(d_cols))
        if rs == base.row_space:
            rs = base.row_space
        if cs == base.col_space:
            cs = base.col_space
        rerank = rs is not base.row_space or cs is not base.col_space
        return rs, cs, rmap, cmap, rerank

    @staticmethod
    def _pad_ranks(r, c, v, cap: int):
        import jax.numpy as jnp
        from repro.core.sorted_ops import INT_SENTINEL
        pad = cap - len(r)
        sent = np.full(pad, INT_SENTINEL, np.int32)
        rj = jnp.asarray(np.concatenate([r.astype(np.int32), sent]))
        cj = jnp.asarray(np.concatenate([c.astype(np.int32), sent]))
        vj = jnp.asarray(np.concatenate(
            [v.astype(np.float32), np.zeros(pad, np.float32)]))
        return rj, cj, vj

    def _merge_device(self):
        from repro.core import AssocTensor
        from .merge import merge_read

        d_rows, d_cols, d_vals = self._delta_triples()
        rs, cs, rmap, cmap, rerank = self._union_spaces(d_rows, d_cols)
        base = self.base if not rerank else \
            self.base.reranked(rs, cs, rmap, cmap)
        rr, _ = rs.rank(d_rows)
        cr, _ = cs.rank(d_cols)
        capd = _next_pow2(len(rr))
        dr, dc, dv = self._pad_ranks(rr, cr, d_vals, capd)
        r, c, v, nnz = merge_read(base, dr, dc, dv, self.aggregate,
                                  nrows=len(rs), ncols=len(cs))
        return AssocTensor(r, c, v, nnz, rs, cs, None)

    def _merge_dist(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import AssocTensor, DistAssoc
        from .merge import dist_merge

        per_shard = [self._shard_triples(s) for s in range(self._nshards)]
        d_rows = np.concatenate([t[0] for t in per_shard])
        d_cols = np.concatenate([t[1] for t in per_shard])
        rs, cs, rmap, cmap, rerank = self._union_spaces(d_rows, d_cols)
        base = self.base
        loc = base.local
        # new shard bounds: ranks of the old boundary KEYS in the union
        # space — key-interval ownership is the invariant, so the insert
        # routing and the rank partition stay consistent
        nb = np.empty(self._nshards + 1, dtype=np.int64)
        nb[0], nb[-1] = 0, len(rs)
        if len(self._bkeys):
            nb[1:-1] = np.searchsorted(rs.keys, self._bkeys, side="left")
        else:
            nb[1:-1] = len(rs)

        capd = _next_pow2(max((len(t[0]) for t in per_shard), default=8))
        drs, dcs, dvs = [], [], []
        for (r_k, c_k, v) in per_shard:
            rr, _ = rs.rank(r_k)
            cr, _ = cs.rank(c_k)
            dr, dc, dv = self._pad_ranks(rr, cr, v, capd)
            drs.append(dr)
            dcs.append(dc)
            dvs.append(dv)
        shard1 = NamedSharding(base.mesh, P("data", None))
        dr = jax.device_put(jnp.stack(drs), shard1)
        dc = jax.device_put(jnp.stack(dcs), shard1)
        dv = jax.device_put(jnp.stack(dvs), shard1)
        rm = jnp.asarray(rmap if rerank else np.zeros(1, np.int32))
        cm = jnp.asarray(cmap if rerank else np.zeros(1, np.int32))
        a_dict = {"rows": loc.rows, "cols": loc.cols, "vals": loc.vals,
                  "nnz": loc.nnz}
        out = dist_merge(base.mesh, a_dict, dr, dc, dv, rm, cm,
                         self.aggregate, rerank)
        new_local = AssocTensor(out["rows"], out["cols"], out["vals"],
                                out["nnz"], rs, cs, None)
        return DistAssoc(new_local, base.mesh, row_bounds=nb)

    def _shard_triples(self, s: int):
        batches = self._shard_batches[s]
        if not batches:
            e = self.base.local.row_space.keys[:0]
            return e, e, np.empty(0, np.float64)
        return (np.concatenate([b[0] for b in batches]),
                np.concatenate([b[1] for b in batches]),
                np.concatenate([b[2] for b in batches]))

    # -- compaction ----------------------------------------------------------
    def compact(self) -> Dict[str, int]:
        """Fold delta into a new base (reusing the cached merge when the
        delta is unchanged), bump ``version``, and drop planner/compile
        cache entries keyed on the retired arrays."""
        from repro.core.plan import invalidate_plan_for
        from repro.core.select import invalidate_compiled_for

        with self._lock:
            if self._depth == 0:
                return {"compacted": 0, "version": self.version}
            folded = self._depth
            new_base = self.snapshot()
            retired = self._retired + [self.base]
            self._retired = []
            self._snap = None
            self.base = new_base
            self._batches = []
            if self.layer == "dist":
                self._shard_batches = [[] for _ in range(self._nshards)]
                self._bkeys = _boundary_keys(new_base.local.row_space,
                                             new_base.row_bounds)
            self._depth = 0
            self.version += 1
            self.stats["compactions"] += 1
        # invalidation outside the lock: pure cache maintenance.  Retired
        # object refs are held until here, so their ids cannot be reused
        # by unrelated arrays before the caches drop them.
        n_plans = invalidate_plan_for([id(a) for a in retired])
        stale = self._stale_digests(retired, new_base)
        invalidate_compiled_for(stale)
        return {"compacted": folded, "version": self.version,
                "plans_invalidated": n_plans}

    @staticmethod
    def _stale_digests(retired, new_base) -> set:
        def spaces(a):
            loc = getattr(a, "local", a)
            rs = getattr(loc, "row_space", None)
            cs = getattr(loc, "col_space", None)
            return [s for s in (rs, cs) if s is not None]

        live = {s.digest for s in spaces(new_base)}
        return {s.digest for a in retired for s in spaces(a)} - live

    def maybe_compact(self, idle_s: float = 0.25) -> bool:
        """Compact if the delta crossed the threshold or went idle."""
        with self._lock:
            depth = self._depth
            idle = time.monotonic() - self._last_insert_t
        if depth == 0:
            return False
        if depth >= self.compact_threshold or idle >= idle_s:
            self.compact()
            return True
        return False

    # -- telemetry -----------------------------------------------------------
    def info(self) -> Dict[str, Any]:
        with self._lock:
            reads = self.stats["reads"]
            merges = self.stats["merges"]
            return {
                "ingest": True, "layer": self.layer,
                "aggregate": self.aggregate, "version": self.version,
                "delta_depth": self._depth,
                "compact_threshold": self.compact_threshold,
                **{k: v for k, v in self.stats.items()},
                "merge_hit_rate": (
                    (reads - merges) / reads if reads else 0.0),
            }


class Compactor:
    """Background compaction: polls a registry's ingest tables and folds
    delta into base on a depth threshold (the table's own
    ``compact_threshold``) or an idle timeout."""

    def __init__(self, registry, *, interval_s: float = 0.05,
                 idle_s: float = 0.25):
        self.registry = registry
        self.interval_s = float(interval_s)
        self.idle_s = float(idle_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "Compactor":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="d4m-ingest-compactor",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            for name in self.registry.ingest_names():
                try:
                    self.registry.ingest_table(name).maybe_compact(
                        idle_s=self.idle_s)
                except Exception:      # table dropped mid-iteration etc.
                    continue

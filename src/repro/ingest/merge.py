"""Merge-on-read programs: base COO ⊕ delta overlay, on device.

The LSM read path has three compiled programs, all zero-collective and
never densifying (declared via ``@contract``, proven by ``d4mcheck``):

* :func:`_delta_canon_prog` — canonicalize a raw (unsorted, duplicated)
  delta buffer into sorted merged COO: the device work an append batch
  triggers at read time.  One :func:`~repro.core.coo.dedup_sorted_coo`
  pass, nothing else.
* :func:`_merge_read_prog` — single-device overlay merge.  Base is
  already canonical (sorted by (row, col) ⇔ sorted by linearized key),
  so after canonicalizing delta the union layout comes from the
  ``sorted_merge`` rank-count kernel (:func:`overlay_scatter` →
  ``merge_positions``): scatter base, then gather-⊕-scatter delta onto
  the shared slots, then one compaction.  O(capb + capd) work and
  memory — the base is never re-sorted and nothing is densified.
* :func:`_dist_merge_prog` — sharded overlay merge: delta triples are
  routed to their owning row shard on host (key-partitioned at insert),
  so the merge is one shard-local concat + canonicalize under
  ``shard_map`` with **zero collectives**; the optional rank-translation
  gathers rerank the resident base onto the union keyspaces in the same
  program.

All programs are cached builders (``functools.lru_cache``) keyed on the
aggregate name only; array shapes key jit's own trace cache, and ingest
pads delta buffers to power-of-two capacities so sustained streaming
reuses a handful of traces instead of recompiling per batch.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.analysis.contracts import contract
from repro.core.assoc_tensor import coo_compact
from repro.core.coo import SENT, dedup_sorted_coo
from repro.kernels.sorted_merge.ops import overlay_scatter

__all__ = ["AGG_OPS", "delta_canon", "merge_read", "dist_merge"]

# Device/dist ingest aggregates: restricted to the associative AND
# commutative monoids (jnp.lexsort gives no stability guarantee, so an
# order-sensitive ⊕ like "concat" is host-layer-only).
AGG_OPS = {"sum": jnp.add, "min": jnp.minimum, "max": jnp.maximum}


def _agg_op(aggregate: str):
    op = AGG_OPS.get(aggregate)
    if op is None:
        raise ValueError(
            f"device ingest aggregate must be one of {sorted(AGG_OPS)}, "
            f"got {aggregate!r} (host-layer tables accept any Assoc "
            f"aggregator)")
    return op


@contract(collectives=0, name="ingest.append",
          note="delta-buffer canonicalize: one dedup pass, no collectives, "
               "O(cap) memory")
@functools.lru_cache(maxsize=16)
def _delta_canon_prog(aggregate: str):
    op = _agg_op(aggregate)

    @jax.jit
    def go(rows, cols, vals):
        return dedup_sorted_coo(rows, cols, vals, op)

    return go


@contract(collectives=0, name="ingest.merge_read",
          note="overlay merge via the sorted_merge rank-count kernel: "
               "base is never re-sorted, output is O(capb + capd)")
@functools.lru_cache(maxsize=16)
def _merge_read_prog(aggregate: str):
    """base ⊕ delta overlay; ``ncols`` is a traced scalar so a growing
    column keyspace never retraces."""
    op = _agg_op(aggregate)

    @jax.jit
    def go(br, bc, bv, dr, dc, dv, ncols):
        dr, dc, dv, _ = dedup_sorted_coo(dr, dc, dv, op)
        cap = br.shape[0] + dr.shape[0]
        # linearized (row, col) keys: canonical COO order IS linear-key
        # order, so both sides are sorted & repetition-free as the
        # rank-count kernel requires (callers guard nr*ncols < 2**31)
        kb = jnp.where(br != SENT, br * ncols + bc, SENT)
        kd = jnp.where(dr != SENT, dr * ncols + dc, SENT)
        i_dst, j_dst, j_dup = overlay_scatter(kb, kd)
        out_r = jnp.full(cap, SENT, jnp.int32).at[i_dst].set(br, mode="drop")
        out_c = jnp.full(cap, SENT, jnp.int32).at[i_dst].set(bc, mode="drop")
        out_v = jnp.zeros(cap, bv.dtype).at[i_dst].set(bv, mode="drop")
        # delta lands second: a duplicate gathers the base value from the
        # shared slot and ⊕-combines base-on-the-left (host combine order)
        cur = out_v.at[j_dst].get(mode="fill", fill_value=0.0)
        merged = jnp.where(j_dup, op(cur, dv), dv)
        out_r = out_r.at[j_dst].set(dr, mode="drop")
        out_c = out_c.at[j_dst].set(dc, mode="drop")
        out_v = out_v.at[j_dst].set(merged, mode="drop")
        # zero-drop parity with from_triples: ⊕-cancelled entries unstore
        keep = (out_r != SENT) & (out_v != 0.0)
        return coo_compact(out_r, out_c, out_v, keep)

    return go


@functools.lru_cache(maxsize=16)
def _merge_concat_prog(aggregate: str):
    """Fallback overlay merge (concat + one canonicalize) for keyspaces
    too large to linearize into int32 — same result, O(cap log cap)."""
    op = _agg_op(aggregate)

    @jax.jit
    def go(br, bc, bv, dr, dc, dv):
        rows = jnp.concatenate([br, dr])
        cols = jnp.concatenate([bc, dc])
        vals = jnp.concatenate([bv, dv])
        return dedup_sorted_coo(rows, cols, vals, op)

    return go


@contract(collectives=0, name="ingest.dist_merge_read",
          note="shard-local overlay merge: delta is pre-routed to the "
               "owning row shard, so zero collectives")
@functools.lru_cache(maxsize=16)
def _dist_merge_prog(mesh, aggregate: str, rerank: bool):
    op = _agg_op(aggregate)
    spec = {"rows": P("data", None), "cols": P("data", None),
            "vals": P("data", None), "nnz": P("data")}
    dspec = P("data", None)

    @jax.jit
    @partial(shard_map, mesh=mesh,
             in_specs=(spec, dspec, dspec, dspec, P(), P()),
             out_specs=spec, check_rep=False)
    def go(a, dr, dc, dv, rmap, cmap):
        a0 = jax.tree.map(lambda x: x[0], a)
        br, bc, bv = a0["rows"], a0["cols"], a0["vals"]
        if rerank:
            ok = br != SENT
            br = jnp.where(ok, rmap[jnp.clip(br, 0, rmap.shape[0] - 1)],
                           SENT)
            bc = jnp.where(ok, cmap[jnp.clip(bc, 0, cmap.shape[0] - 1)],
                           SENT)
        rows = jnp.concatenate([br, dr[0]])
        cols = jnp.concatenate([bc, dc[0]])
        vals = jnp.concatenate([bv, dv[0]])
        r, c, v, n = dedup_sorted_coo(rows, cols, vals, op)
        return {"rows": r[None], "cols": c[None], "vals": v[None],
                "nnz": n[None]}

    return go


# -- eager wrappers (what IngestTable calls) --------------------------------

def delta_canon(rows, cols, vals, aggregate: str):
    """Canonicalize one padded raw delta buffer → (r, c, v, nnz)."""
    return _delta_canon_prog(aggregate)(rows, cols, vals)


def merge_read(base, dr, dc, dv, aggregate: str, *, nrows: int, ncols: int):
    """Overlay-merge a base AssocTensor's triples with a padded raw delta;
    returns canonical (r, c, v, nnz) of length ``capb + capd``."""
    if nrows * max(ncols, 1) < 2**31 - 1:
        prog = _merge_read_prog(aggregate)
        return prog(base.rows, base.cols, base.vals, dr, dc, dv,
                    jnp.int32(max(ncols, 1)))
    prog = _merge_concat_prog(aggregate)
    return prog(base.rows, base.cols, base.vals, dr, dc, dv)


def dist_merge(mesh, a_dict, dr, dc, dv, rmap, cmap, aggregate: str,
               rerank: bool):
    """Run the sharded overlay merge program; returns the output COO dict
    (per-shard arrays of length ``capb + capd``)."""
    prog = _dist_merge_prog(mesh, aggregate, rerank)
    return prog(a_dict, dr, dc, dv, rmap, cmap)

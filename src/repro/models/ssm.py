"""Mamba2 (SSD — state-space duality) blocks, chunked for TPU.

The SSD recurrence per head (state size N, head dim P):

    h_t = exp(Δ_t·A) · h_{t-1} + Δ_t · B_t xᵗ_t        h ∈ R^{N×P}
    y_t = C_tᵀ h_t + D · x_t

is evaluated chunk-parallel (chunk Q): within a chunk the dual "masked
attention" form ``Y = ((C Bᵀ) ∘ L) X`` runs as dense MXU einsums, and a
short ``lax.scan`` over chunks carries the inter-chunk state.  This is the
standard SSD decomposition — sequential work drops from S steps to S/Q.

Decode is the exact single-step recurrence on a carried ``(conv_tail,
ssm_state)`` cache: O(1) memory in sequence length, which is why the SSM and
hybrid architectures own the ``long_500k`` shape.

Layout notes (TPU): heads shard over the ``model`` mesh axis ("heads"
logical axis on every H-indexed dim); B/C are per-group (G=1 here) and
replicated; all chunk einsums contract locally so the block is
collective-free except the in/out projections' TP.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, _normal, init_linear, linear, rms_norm_simple


def init_mamba2(key, cfg) -> Tuple[Params, Params]:
    m = cfg.ssm
    d = cfg.d_model
    d_in = m["d_inner"]
    n, hdim, conv = m["d_state"], m["head_dim"], m["d_conv"]
    g = m.get("n_groups", 1)
    nh = d_in // hdim
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    # in_proj → [z, x, B, C, dt]
    p["in_z"], s["in_z"] = init_linear(ks[0], d, d_in, axes=("embed", "heads"), dtype=cfg.param_dtype)
    p["in_x"], s["in_x"] = init_linear(ks[1], d, d_in, axes=("embed", "heads"), dtype=cfg.param_dtype)
    p["in_b"], s["in_b"] = init_linear(ks[2], d, g * n, axes=("embed", None), dtype=cfg.param_dtype)
    p["in_c"], s["in_c"] = init_linear(ks[3], d, g * n, axes=("embed", None), dtype=cfg.param_dtype)
    p["in_dt"], s["in_dt"] = init_linear(ks[4], d, nh, axes=("embed", "heads"), dtype=cfg.param_dtype)
    p["dt_bias"] = jnp.zeros((nh,), jnp.float32); s["dt_bias"] = ("heads",)
    p["a_log"] = jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)); s["a_log"] = ("heads",)
    p["d_skip"] = jnp.ones((nh,), jnp.float32); s["d_skip"] = ("heads",)
    # depthwise causal convs (split: x-part sharded, BC-part replicated)
    p["conv_x"] = _normal(ks[5], (conv, d_in), 0.5, cfg.param_dtype)
    p["conv_bc"] = _normal(ks[6], (conv, 2 * g * n), 0.5, cfg.param_dtype)
    s["conv_x"] = (None, "heads"); s["conv_bc"] = (None, None)
    p["norm_g"] = jnp.ones((d_in,), cfg.param_dtype); s["norm_g"] = ("heads",)
    p["out"], s["out"] = init_linear(ks[7], d_in, d, axes=("heads", "embed"), dtype=cfg.param_dtype)
    return p, s


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv via K shifted adds. x [B,S,C], kernel [K,C]."""
    k = kernel.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail  # [B, K-1, C] — previous inputs (decode path)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_tail


def _ssd_chunked(xh, bt, ct, dt, a, chunk: int,
                 h0: Optional[jnp.ndarray] = None):
    """Chunk-parallel SSD scan.

    xh [B,S,H,P], bt/ct [B,S,G,N] (G broadcasts over H), dt [B,S,H] (>0),
    a [H] (<0).  Returns (y [B,S,H,P], h_last [B,H,N,P]).
    """
    b, s, h, p = xh.shape
    g, n = bt.shape[2], bt.shape[3]
    while s % chunk:  # halve until it divides (short prompts / odd lengths)
        chunk //= 2
    chunk = max(chunk, 1)
    nc = s // chunk
    r = h // g  # heads per B/C group — NEVER materialize B/C per head

    def resh(t, last):
        return t.reshape((b, nc, chunk) + last)

    xh_c = resh(xh, (g, r, p))                        # [B,NC,Q,G,R,P]
    bt_c = resh(bt, (g, n))                           # [B,NC,Q,G,N]
    ct_c = resh(ct, (g, n))
    dt_c = resh(dt, (g, r))                           # [B,NC,Q,G,R]
    la = dt_c * a.reshape(g, r)[None, None, None]     # log-decay per step, <0
    cum = jnp.cumsum(la, axis=2)                      # [B,NC,Q,G,R]

    # intra-chunk (dual attention form): M[i,j] = exp(cum_i − cum_j)·(i≥j),
    # applied per head; the C·Bᵀ Gram matrix is per *group* (tiny for G≪H).
    gram = jnp.einsum("bcqgn,bckgn->bcqkg", ct_c, bt_c)   # [B,NC,Q,K,G]
    li = cum[:, :, :, None]                                # [B,NC,Q,1,G,R]
    lj = cum[:, :, None, :, :, :]                          # [B,NC,1,K,G,R]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None, None]
    # mask BEFORE exp: the i<j region has li−lj > 0 and exp() there would
    # overflow to inf, poisoning gradients through the where.
    decay = jnp.exp(jnp.where(tri, li - lj, -jnp.inf))     # [B,NC,Q,K,G,R]
    y_intra = jnp.einsum("bcqkg,bcqkgr,bckgr,bckgrp->bcqgrp",
                         gram, decay, dt_c, xh_c)

    # per-chunk aggregated state:  S_c = Σ_t exp(cum_last − cum_t)·Δ_t·B_t xᵗ_t
    seg = jnp.exp(cum[:, :, -1:] - cum)                    # [B,NC,Q,G,R]
    state_c = jnp.einsum("bcqgr,bcqgr,bcqgn,bcqgrp->bcgrnp",
                         seg, dt_c, bt_c, xh_c)            # [B,NC,G,R,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1])                   # [B,NC,G,R]

    # inter-chunk: scan carried state across chunks
    def step(h_prev, inp):
        st, dec = inp                                  # [B,G,R,N,P], [B,G,R]
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev                           # emit state ENTERING chunk

    init = (h0.reshape(b, g, r, n, p) if h0 is not None
            else jnp.zeros((b, g, r, n, p), xh.dtype))
    h_last, h_in = jax.lax.scan(
        step, init,
        (state_c.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(1, 0, 2, 3)))
    h_in = h_in.transpose(1, 0, 2, 3, 4, 5)            # [B,NC,G,R,N,P]

    # contribution of carried state:  y⁺_t = exp(cum_t)·C_t · h_in
    y_inter = jnp.einsum("bcqgr,bcqgn,bcgrnp->bcqgrp",
                         jnp.exp(cum), ct_c, h_in)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_last.reshape(b, h, n, p)


def mamba2_block(p: Params, cfg, x: jnp.ndarray, *, mode: str,
                 cache: Optional[Params] = None):
    """Full Mamba2 block. cache = {"conv_x","conv_bc": tails, "h": state}."""
    m = cfg.ssm
    b, s, _ = x.shape
    d_in, n, hdim = m["d_inner"], m["d_state"], m["head_dim"]
    g = m.get("n_groups", 1)
    nh = d_in // hdim

    z = linear(p["in_z"], x)
    xr = linear(p["in_x"], x)
    bc = jnp.concatenate([linear(p["in_b"], x), linear(p["in_c"], x)], axis=-1)
    dt = jax.nn.softplus(
        linear(p["in_dt"], x).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    tail_x = cache["conv_x"] if cache is not None else None
    tail_bc = cache["conv_bc"] if cache is not None else None
    xr, new_tail_x = _causal_conv(xr, p["conv_x"], tail_x)
    bc, new_tail_bc = _causal_conv(bc, p["conv_bc"], tail_bc)
    bt = bc[..., :g * n].reshape(b, s, g, n).astype(jnp.float32)
    ct = bc[..., g * n:].reshape(b, s, g, n).astype(jnp.float32)
    xh = xr.reshape(b, s, nh, hdim).astype(jnp.float32)

    if mode in ("train", "prefill", "chunked_prefill"):
        h0 = (cache["h"].astype(xh.dtype) if (mode == "chunked_prefill"
                                              and cache is not None) else None)
        y, h_last = _ssd_chunked(xh, bt, ct, dt, a, m.get("chunk", 256),
                                 h0=h0)
    else:  # decode: exact single-step recurrence
        h_prev = cache["h"]                            # [B,H,N,P] fp32
        dec = jnp.exp(dt[:, 0] * a[None, :])           # [B,H]
        bt0 = jnp.repeat(bt[:, 0], nh // g, axis=1)    # [B,H,N]
        ct0 = jnp.repeat(ct[:, 0], nh // g, axis=1)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0], bt0, xh[:, 0])
        h_new = h_prev * dec[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ct0, h_new)[:, None]
        h_last = h_new

    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm_simple(y * jax.nn.silu(z), p["norm_g"])
    out = linear(p["out"], y)
    new_cache = None
    if mode in ("prefill", "chunked_prefill", "decode"):
        new_cache = {"conv_x": new_tail_x, "conv_bc": new_tail_bc,
                     "h": h_last.astype(jnp.float32)}
    return out, new_cache


def init_ssm_cache(cfg, batch: int):
    m = cfg.ssm
    d_in, n, hdim, conv = m["d_inner"], m["d_state"], m["head_dim"], m["d_conv"]
    g = m.get("n_groups", 1)
    nh = d_in // hdim
    return {
        "conv_x": jnp.zeros((batch, conv - 1, d_in), cfg.compute_dtype),
        "conv_bc": jnp.zeros((batch, conv - 1, 2 * g * n), cfg.compute_dtype),
        "h": jnp.zeros((batch, nh, n, hdim), jnp.float32),
    }

"""Sharding-constraint helpers usable from mesh-agnostic model code.

``constrain_batch(x)`` pins the leading (batch) dim of an activation to the
data-parallel mesh axes — the single most important hint for XLA's SPMD
partitioner here: without it, the residuals saved by the layer-scan for
backward may be re-sharded onto feature axes (batch-replicated!), inflating
per-device live memory by |data| ×.

The helpers no-op when no mesh is active (CPU unit tests) and adapt to
single-pod ("data") vs multi-pod ("pod", "data") meshes automatically.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


def _current_axis_names() -> Tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover - very old jax
        return ()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return ()
    return tuple(mesh.axis_names)


_BATCH_OVER_MODEL = False  # fsdp_only parallelism: model axis joins DP


def set_parallelism(mode: str):
    """Called by launch.steps before tracing; trace-time static."""
    global _BATCH_OVER_MODEL
    _BATCH_OVER_MODEL = (mode == "fsdp_only")


def batch_axes_in_mesh() -> Optional[Tuple[str, ...]]:
    names = _current_axis_names()
    pool = ("pod", "data", "model") if _BATCH_OVER_MODEL else ("pod", "data")
    axes = tuple(a for a in pool if a in names)
    return axes or None


def constrain(x, *spec_entries):
    """with_sharding_constraint if a mesh is active, else identity."""
    if not _current_axis_names():
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec_entries))


def constrain_batch(x, n_extra: Optional[int] = None):
    """Pin dim0 to the batch axes; remaining dims unconstrained."""
    axes = batch_axes_in_mesh()
    if axes is None:
        return x
    extra = x.ndim - 1 if n_extra is None else n_extra
    if x.shape[0] % _axes_size(axes):
        return x
    return jax.lax.with_sharding_constraint(x, P(axes, *([None] * extra)))


def _axes_size(axes: Tuple[str, ...]) -> int:
    mesh = jax.sharding.get_abstract_mesh()
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain_seq(x):
    """Megatron-style sequence parallelism: the residual stream lives
    S-sharded over `model` between blocks; XLA inserts all-gather before
    the TP matmuls and reduce-scatter after — same bytes as the all-reduce
    but per-device activation residency drops by |model|."""
    names = _current_axis_names()
    if "model" not in names or x.ndim < 3:
        return x
    if x.shape[1] % jax.sharding.get_abstract_mesh().shape["model"]:
        return x
    b_axes = batch_axes_in_mesh()
    b = b_axes if (b_axes and x.shape[0] % _axes_size(b_axes) == 0) else None
    return jax.lax.with_sharding_constraint(
        x, P(b, "model", *([None] * (x.ndim - 2))))


def constrain_decode_qkv(q, k, v, n_kv_heads: int):
    """dh-shard decode q/k/v when kv heads can't shard over `model`."""
    names = _current_axis_names()
    if "model" not in names:
        return q, k, v
    if n_kv_heads % jax.sharding.get_abstract_mesh().shape["model"] == 0:
        return q, k, v  # kv-head sharding is consistent; leave it alone
    return (constrain_last_model(q), constrain_last_model(k),
            constrain_last_model(v))


def constrain_last_model(x):
    """Shard the LAST dim over `model` (if present & divisible), batch on 0.

    Used on decode-path q/k/v so the per-step attention einsums contract a
    model-sharded head_dim against the model-sharded KV cache — without
    this, SPMD repartitions the entire stacked cache (involuntary full
    rematerialization) when kv_heads don't divide the model axis.
    """
    names = _current_axis_names()
    if "model" not in names:
        return x
    mesh = jax.sharding.get_abstract_mesh()
    if x.shape[-1] % mesh.shape["model"]:
        return x
    b_axes = batch_axes_in_mesh()
    b = b_axes if (b_axes and x.shape[0] % _axes_size(b_axes) == 0) else None
    spec = [b] + [None] * (x.ndim - 2) + ["model"]
    return jax.lax.with_sharding_constraint(x, P(*spec))

"""Mixture-of-Experts with D4M-style sparse dispatch.

Top-k gating produces, for every sequence, a sparse associative array
``G : (token × expert) → gate`` (an ``AssocTensor`` in COO form: token ids ×
expert ids with gate values).  Dispatch and combine are then the two
``(+,×)`` semiring contractions

    X_buf = Gᵀ ⊗.⊕ X         (expert, cap, d)  ← gather tokens per expert
    Y     = G  ⊗.⊕ FFN(X_buf) (token, d)        ← weighted combine

realized as sort-based scatter/gather so the expert FFN runs as one dense
MXU-aligned einsum per expert group (the TPU adaptation of the paper's
"defer to bulk sparse linear algebra" strategy — scalar CSR loops become a
sort + two scatters + one big matmul).

Routing is per-sequence so the sort never crosses a batch boundary: under
``pjit`` the batch axis is data-sharded, making dispatch collective-free;
expert weights shard over the ``model`` axis (EP) when ``E % |model| == 0``,
else the per-expert FFN shards its hidden dim (TP).  Combine contracts the
expert axis, so XLA inserts exactly one reduce per MoE block in the EP case.

Two router flavours:
* ``softmax_topk`` (Mixtral): softmax → top-k → renormalize; switch-style
  load-balancing aux loss.
* ``sigmoid_topk`` (DeepSeek-V3): sigmoid affinities, bias-adjusted top-k
  selection (aux-loss-free balancing — the bias is updated outside the
  gradient from per-step expert load), gates renormalized over the selected
  experts; optional always-on shared expert.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, _normal, init_linear, linear


def init_moe(key, cfg) -> Tuple[Params, Params]:
    m = cfg.moe
    d, f, e = cfg.d_model, m["d_ff"], m["n_experts"]
    ks = jax.random.split(key, 6)
    p: Params = {"router": _normal(ks[0], (d, e), d ** -0.5, jnp.float32)}
    s: Params = {"router": ("embed", None)}
    # stacked expert FFNs (swiglu), logical axis "expert" on dim 0
    p["gate"] = _normal(ks[1], (e, d, f), d ** -0.5, cfg.param_dtype)
    p["up"] = _normal(ks[2], (e, d, f), d ** -0.5, cfg.param_dtype)
    p["down"] = _normal(ks[3], (e, f, d), f ** -0.5, cfg.param_dtype)
    s["gate"] = ("expert", "embed", "expert_mlp")
    s["up"] = ("expert", "embed", "expert_mlp")
    s["down"] = ("expert", "expert_mlp", "embed")
    if m.get("router_bias", False):  # DeepSeek aux-loss-free balancing bias
        p["e_bias"] = jnp.zeros((e,), jnp.float32)
        s["e_bias"] = (None,)
    if m.get("shared_expert", 0):
        fs = m["d_ff"] * m["shared_expert"]
        p["shared_gate"], s["shared_gate"] = init_linear(
            ks[4], d, fs, axes=("embed", "mlp"), dtype=cfg.param_dtype)
        p["shared_up"], s["shared_up"] = init_linear(
            jax.random.fold_in(ks[4], 1), d, fs, axes=("embed", "mlp"),
            dtype=cfg.param_dtype)
        p["shared_down"], s["shared_down"] = init_linear(
            ks[5], fs, d, axes=("mlp", "embed"), dtype=cfg.param_dtype)
    return p, s


def _route(p: Params, cfg, x: jnp.ndarray):
    """Router → (gates [B,S,k], expert_idx [B,S,k], aux_loss, load [E])."""
    m = cfg.moe
    e, k = m["n_experts"], m["top_k"]
    # matmul in compute dtype, convert AFTER: upcasting x here would flip
    # the backward residual-stream cotangent (and every dW fed by it) to f32
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [B,S,E]
    if m.get("router_type", "softmax_topk") == "sigmoid_topk":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + p.get("e_bias", 0.0)
        _, idx = jax.lax.top_k(sel_scores, k)
        g = jnp.take_along_axis(scores, idx, axis=-1)
        gates = g / jnp.maximum(g.sum(-1, keepdims=True), 1e-9)
        gates = gates * m.get("routed_scale", 1.0)
        aux = jnp.float32(0.0)  # aux-loss-free balancing
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        g, idx = jax.lax.top_k(probs, k)
        gates = g / jnp.maximum(g.sum(-1, keepdims=True), 1e-9)
        # switch-transformer load-balance aux loss
        frac_tokens = jnp.mean(
            jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(-2), axis=(0, 1))
        frac_probs = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(frac_tokens / k * frac_probs)
    load = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=(0, 1, 2))
    return gates.astype(x.dtype), idx, aux, load


def _dispatch_seq(x_s: jnp.ndarray, idx_s: jnp.ndarray, gate_s: jnp.ndarray,
                  n_experts: int, capacity: int):
    """Per-sequence sort-based dispatch (the D4M Gᵀ⊗.⊕X contraction).

    x_s [S,d], idx_s [S,k], gate_s [S,k] →
    buffer [E,C,d], and combine metadata (token, expert, slot, gate, keep).
    """
    s, k = idx_s.shape
    e_flat = idx_s.reshape(-1)                         # [S*k]
    tok_flat = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    gate_flat = gate_s.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)           # group by expert
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    gate_sorted = gate_flat[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts               # exclusive prefix
    pos = jnp.arange(s * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos < capacity
    # scatter tokens into the expert buffer; overflow slots dropped (OOB)
    buf = jnp.zeros((n_experts, capacity, x_s.shape[-1]), x_s.dtype)
    buf = buf.at[e_sorted, jnp.where(keep, pos, capacity)].set(
        x_s[tok_sorted], mode="drop")
    return buf, (tok_sorted, e_sorted, pos, gate_sorted, keep)


def _combine_seq(y_buf: jnp.ndarray, meta, seq_len: int):
    """Weighted scatter-add back to token order (the G⊗.⊕Y contraction)."""
    tok_sorted, e_sorted, pos, gate_sorted, keep = meta
    vals = y_buf[e_sorted, jnp.where(keep, pos, 0)]
    vals = vals * (gate_sorted * keep.astype(gate_sorted.dtype))[:, None]
    out = jnp.zeros((seq_len, y_buf.shape[-1]), y_buf.dtype)
    return out.at[tok_sorted].add(vals)


def apply_moe(p: Params, cfg, x: jnp.ndarray):
    """x: [B, S, d] → (y [B, S, d], aux_loss, expert_load [E])."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m["n_experts"], m["top_k"]
    cf = m.get("capacity_factor", 1.25)
    cap = int(max(1, round(s * k / e * cf)))
    gates, idx, aux, load = _route(p, cfg, x)

    buf, meta = jax.vmap(
        lambda xs, is_, gs: _dispatch_seq(xs, is_, gs, e, cap))(x, idx, gates)
    # buf: [B, E, C, d] — one dense einsum per projection over all experts.
    # Batch stays data-sharded through dispatch/FFN/combine: without the
    # constraints XLA's backward all-gathers the f32 expert buffers to
    # compute weight grads instead of psum-ing local partials.  Under 2-D
    # expert parallelism the buffers must instead follow the expert axis
    # (the dispatch all-to-all), so we leave placement to SPMD there.
    from .pjit_utils import constrain_batch
    pin = (lambda t: t) if cfg.moe_sharding == "ep2d" else constrain_batch
    buf = pin(buf)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["gate"])) * \
        jnp.einsum("becd,edf->becf", buf, p["up"])
    h = pin(h)
    y_buf = jnp.einsum("becf,efd->becd", h, p["down"])
    y_buf = pin(y_buf)
    y = jax.vmap(lambda yb, mt: _combine_seq(yb, mt, s))(y_buf, meta)

    if "shared_gate" in p:  # DeepSeek shared expert — always on
        y = y + linear(p["shared_down"],
                       jax.nn.silu(linear(p["shared_gate"], x)) *
                       linear(p["shared_up"], x))
    return y, aux, load


def update_router_bias(e_bias: jnp.ndarray, load: jnp.ndarray,
                       rate: float = 1e-3) -> jnp.ndarray:
    """DeepSeek-V3 aux-loss-free balancing: nudge under-loaded experts up.

    Applied OUTSIDE the gradient (in the train step) from per-step loads.
    """
    mean = load.mean()
    return e_bias + rate * jnp.sign(mean - load)

"""Attention variants: GQA (full/causal/sliding-window), MLA, cross-attention.

All softmax attention flows through :func:`chunked_attention` — a
query-chunked formulation whose peak live buffer is ``[B, H, Qc, Sk]`` rather
than the full ``[B, H, Sq, Sk]`` score matrix.  On TPU the Pallas
flash-attention kernel (``repro.kernels.flash_attention``) replaces it when
``cfg.attn_impl == "pallas"``; the chunked jnp path is the XLA-native
reference used for CPU tests and the dry-run (so ``cost_analysis`` reflects
real XLA HLO rather than an opaque custom call).

Decode paths write KV at a dynamic position into a static-shape cache
(sliding-window archs use a ring buffer of the window size, which is what
makes `long_500k` tractable for mixtral-8x22b).  MLA (DeepSeek-V3) caches
only the compressed latent + shared rope key and uses the absorbed-matmul
decode trick, cutting cache bytes per token from ``2·H·dh`` to ``d_c + d_r``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, init_linear, linear, rms_norm_simple, rope_freqs

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# core chunked softmax attention
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, q_positions: jnp.ndarray, k_positions: jnp.ndarray,
                      causal: bool, window: Optional[int] = None,
                      k_valid_len: Optional[jnp.ndarray] = None,
                      chunk: int = 512, impl: str = "reference",
                      sm_scale: Optional[float] = None) -> jnp.ndarray:
    """Softmax attention with GQA broadcast and position-based masking.

    q: [B, Sq, H, Dh]; k/v: [B, Sk, KV, Dh] with H % KV == 0.
    Masks: ``causal`` ⇒ keep k_pos ≤ q_pos;  ``window`` ⇒ also q_pos − k_pos <
    window;  ``k_valid_len`` ⇒ k index < valid length (decode caches).
    """
    if impl == "pallas":  # TPU fast path (tests validate vs this reference)
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, q_positions=q_positions,
                               k_positions=k_positions, causal=causal,
                               window=window, k_valid_len=k_valid_len,
                               sm_scale=sm_scale)
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kv, g, dh)

    @jax.checkpoint  # recompute scores/probs in backward: O(chunk·Sk) residuals → O(chunk·Dh)
    def one_chunk(qc, qpos_c):
        # qc: [B, Qc, KV, G, Dh] → scores [B, KV, G, Qc, Sk]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc.shape[1], k.shape[1]), dtype=bool)
        qp = qpos_c[:, None]
        kp = k_positions[None, :]
        if causal:
            mask &= kp <= qp
        if window is not None:
            mask &= (qp - kp) < window
        if k_valid_len is not None:
            mask &= (jnp.arange(k.shape[1])[None, :] < k_valid_len)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
        out_c = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                           preferred_element_type=jnp.float32)
        return out_c.astype(qc.dtype)  # stack bf16, not f32, under lax.map

    dv = v.shape[-1]  # value head dim may differ from q/k (MLA)
    if sq % chunk != 0:
        chunk = sq  # non-divisible (e.g. whisper's 1500 frames): one block
    if sq <= chunk:
        out = one_chunk(qg, q_positions)
    else:
        n = sq // chunk
        assert sq % chunk == 0, f"Sq={sq} not divisible by chunk={chunk}"
        qs = qg.reshape(b, n, chunk, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
        ps = q_positions.reshape(n, chunk)
        out = jax.lax.map(lambda args: one_chunk(*args), (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kv, g, dv)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (covers chatglm3/qwen3/starcoder2/minicpm/mixtral/
# chameleon/whisper-self/zamba2-shared)
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, *, d_model: Optional[int] = None,
             cross: bool = False) -> Tuple[Params, Params]:
    d = d_model or cfg.d_model
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    bias = cfg.attn_bias
    p, s = {}, {}
    p["wq"], s["wq"] = init_linear(ks[0], d, h * dh, axes=("embed", "heads"), dtype=cfg.param_dtype, bias=bias)
    p["wk"], s["wk"] = init_linear(ks[1], d, kvh * dh, axes=("embed", "heads"), dtype=cfg.param_dtype, bias=bias)
    p["wv"], s["wv"] = init_linear(ks[2], d, kvh * dh, axes=("embed", "heads"), dtype=cfg.param_dtype, bias=bias)
    p["wo"], s["wo"] = init_linear(ks[3], h * dh, d, axes=("heads", "embed"), dtype=cfg.param_dtype, bias=bias)
    if cfg.qk_norm:
        p["q_g"] = jnp.ones((dh,), cfg.param_dtype)
        p["k_g"] = jnp.ones((dh,), cfg.param_dtype)
        s["q_g"] = (None,)
        s["k_g"] = (None,)
    return p, s


def gqa_qkv(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
            *, rope: bool = True):
    b, sq, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, sq, h, dh)
    k = linear(p["wk"], x).reshape(b, sq, kvh, dh)
    v = linear(p["wv"], x).reshape(b, sq, kvh, dh)
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_g"])
        k = rms_norm_simple(k, p["k_g"])
    if rope and cfg.rope_theta is not None:
        rd = cfg.rotary_dim or dh
        cos, sin = rope_freqs(dh, cfg.rope_theta, positions, rotary_dim=rd)
        q = apply_rope(q, cos, sin, rotary_dim=rd)
        k = apply_rope(k, cos, sin, rotary_dim=rd)
    return q, k, v


def gqa_attention(p: Params, cfg, x: jnp.ndarray, *,
                  mode: str, cache: Optional[Params] = None,
                  positions: Optional[jnp.ndarray] = None,
                  causal: bool = True):
    """Self-attention in train/prefill/decode modes.

    Returns ``(out, new_cache)``; cache layout {"k","v": [B, Sc, KV, Dh],
    "len": int32} — for sliding-window configs Sc == window (ring buffer).
    """
    b, sq, _ = x.shape
    window = cfg.window
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)
    q, k, v = gqa_qkv(p, cfg, x, positions)

    if mode == "chunked_prefill":
        # multi-token append: write the chunk's K/V at the cache cursor and
        # attend causally over everything cached so far.  Bounds live
        # activations to O(chunk) — the production long-context prefill path
        # (not supported for ring/windowed caches).
        assert cache is not None and window is None
        pos0 = cache["len"]
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos0, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos0, axis=1)
        out = chunked_attention(
            q, k_cache, v_cache, q_positions=positions,
            k_positions=jnp.arange(k_cache.shape[1], dtype=jnp.int32),
            causal=True, k_valid_len=pos0 + sq, impl=cfg.attn_impl,
            chunk=cfg.attn_chunk)
        out = linear(p["wo"], out.reshape(b, sq, -1))
        return out, {"k": k_cache, "v": v_cache, "len": pos0 + sq}

    if mode in ("train", "prefill"):
        out = chunked_attention(
            q, k, v, q_positions=positions, k_positions=positions,
            causal=causal, window=window, impl=cfg.attn_impl,
            chunk=cfg.attn_chunk)
        new_cache = None
        if mode == "prefill":
            if window is not None:  # keep last `window` tokens, ring order
                cap = min(window, sq)
                kk, vv = k[:, -cap:], v[:, -cap:]
                # ring-align so slot (pos % window) holds position pos
                start = (sq - cap) % window if window else 0
                idx = (jnp.arange(cap) + start) % max(window, 1)
                k_cache = jnp.zeros((b, window, *k.shape[2:]), k.dtype).at[:, idx].set(kk)
                v_cache = jnp.zeros((b, window, *v.shape[2:]), v.dtype).at[:, idx].set(vv)
                new_cache = {"k": k_cache, "v": v_cache,
                             "len": jnp.int32(sq)}
            else:
                new_cache = {"k": k, "v": v, "len": jnp.int32(sq)}
        out = linear(p["wo"], out.reshape(b, sq, -1))
        return out, new_cache

    # decode: sq == 1, append at cache position
    assert cache is not None
    from .pjit_utils import constrain_decode_qkv
    q, k, v = constrain_decode_qkv(q, k, v, cfg.n_kv_heads)
    pos = cache["len"]  # scalar int32: number of tokens already cached
    sc = cache["k"].shape[1]
    slot = pos % sc if window is not None else pos
    k_cache = cache["k"].at[:, slot].set(k[:, 0])
    v_cache = cache["v"].at[:, slot].set(v[:, 0])
    k_pos = _cache_positions(pos, sc, window)
    valid = jnp.minimum(pos + 1, sc)
    out = chunked_attention(
        q, k_cache, v_cache, q_positions=positions, k_positions=k_pos,
        causal=True, window=window, k_valid_len=valid, impl=cfg.attn_impl)
    out = linear(p["wo"], out.reshape(b, sq, -1))
    return out, {"k": k_cache, "v": v_cache, "len": pos + 1}


def _cache_positions(pos, cache_size, window):
    """Absolute positions of each cache slot (ring-aware)."""
    idx = jnp.arange(cache_size, dtype=jnp.int32)
    if window is None:
        return idx
    # slot s holds the most recent token t with t % cache_size == s, t ≤ pos
    cur_slot = pos % cache_size
    age = (cur_slot - idx) % cache_size
    return pos - age


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(p: Params, cfg, x: jnp.ndarray, enc_kv: Params):
    """Attend from decoder states to (precomputed) encoder K/V."""
    b, sq, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, sq, h, dh)
    out = chunked_attention(
        q, enc_kv["k"], enc_kv["v"],
        q_positions=jnp.arange(sq, dtype=jnp.int32),
        k_positions=jnp.arange(enc_kv["k"].shape[1], dtype=jnp.int32),
        causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk)
    return linear(p["wo"], out.reshape(b, sq, -1))


def encode_cross_kv(p: Params, cfg, enc_out: jnp.ndarray) -> Params:
    b, se, _ = enc_out.shape
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    k = linear(p["wk"], enc_out).reshape(b, se, kvh, dh)
    v = linear(p["wv"], enc_out).reshape(b, se, kvh, dh)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg) -> Tuple[Params, Params]:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    dq, dc = m["q_lora_rank"], m["kv_lora_rank"]
    dn, dr, dv = m["qk_nope_dim"], m["qk_rope_dim"], m["v_head_dim"]
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["wdq"], s["wdq"] = init_linear(ks[0], d, dq, axes=("embed", None), dtype=cfg.param_dtype)
    p["q_norm_g"] = jnp.ones((dq,), cfg.param_dtype); s["q_norm_g"] = (None,)
    p["wuq"], s["wuq"] = init_linear(ks[1], dq, h * (dn + dr), axes=(None, "heads"), dtype=cfg.param_dtype)
    p["wdkv"], s["wdkv"] = init_linear(ks[2], d, dc, axes=("embed", None), dtype=cfg.param_dtype)
    p["kv_norm_g"] = jnp.ones((dc,), cfg.param_dtype); s["kv_norm_g"] = (None,)
    p["wkr"], s["wkr"] = init_linear(ks[3], d, dr, axes=("embed", None), dtype=cfg.param_dtype)
    p["wuk"], s["wuk"] = init_linear(ks[4], dc, h * dn, axes=(None, "heads"), dtype=cfg.param_dtype)
    p["wuv"], s["wuv"] = init_linear(ks[5], dc, h * dv, axes=(None, "heads"), dtype=cfg.param_dtype)
    p["wo"], s["wo"] = init_linear(ks[6], h * dv, d, axes=("heads", "embed"), dtype=cfg.param_dtype)
    return p, s


def mla_attention(p: Params, cfg, x: jnp.ndarray, *, mode: str,
                  cache: Optional[Params] = None,
                  positions: Optional[jnp.ndarray] = None):
    """MLA with compressed-latent cache and absorbed decode matmuls."""
    m = cfg.mla
    b, sq, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m["qk_nope_dim"], m["qk_rope_dim"], m["v_head_dim"]
    dc = m["kv_lora_rank"]
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)

    cq = rms_norm_simple(linear(p["wdq"], x), p["q_norm_g"])
    qall = linear(p["wuq"], cq).reshape(b, sq, h, dn + dr)
    q_nope, q_rope = qall[..., :dn], qall[..., dn:]
    ckv = rms_norm_simple(linear(p["wdkv"], x), p["kv_norm_g"])  # [B,S,dc]
    k_rope = linear(p["wkr"], x)  # [B,S,dr] shared across heads

    cos, sin = rope_freqs(dr, cfg.rope_theta, positions, rotary_dim=dr)
    q_rope = apply_rope(q_rope, cos, sin, rotary_dim=dr)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin, rotary_dim=dr)[:, :, 0]

    scale = 1.0 / math.sqrt(dn + dr)

    if mode in ("train", "prefill"):
        # materialized path: per-head K/V from the latent
        k_nope = linear(p["wuk"], ckv).reshape(b, sq, h, dn)
        v = linear(p["wuv"], ckv).reshape(b, sq, h, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, sq, h, dr))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(q, k, v, q_positions=positions,
                                k_positions=positions, causal=True,
                                impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                                sm_scale=scale)
        new_cache = ({"ckv": ckv, "kr": k_rope, "len": jnp.int32(sq)}
                     if mode == "prefill" else None)
        return linear(p["wo"], out.reshape(b, sq, -1)), new_cache

    # decode / chunked_prefill: the latent cache is shared; decode uses the
    # absorbed matmuls (weight-bound), chunked prefill re-materializes
    # per-head K/V from the latent and goes through the memory-bounded
    # chunked_attention (the absorbed form would hold [B,H,C,S] f32 probs).
    assert cache is not None
    from .pjit_utils import constrain_last_model
    pos = cache["len"]
    if sq == 1:
        ckv_cache = cache["ckv"].at[:, pos].set(ckv[:, 0])   # [B,Sc,dc]
        kr_cache = cache["kr"].at[:, pos].set(k_rope[:, 0])  # [B,Sc,dr]
    else:
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), pos, axis=1)
        kr_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), pos, axis=1)

    if mode == "chunked_prefill":
        sc_len = ckv_cache.shape[1]
        k_nope_all = linear(p["wuk"], ckv_cache).reshape(b, sc_len, h, dn)
        v_all = linear(p["wuv"], ckv_cache).reshape(b, sc_len, h, dv)
        k_all = jnp.concatenate(
            [k_nope_all, jnp.broadcast_to(kr_cache[:, :, None, :],
                                          (b, sc_len, h, dr))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(
            q, k_all, v_all, q_positions=positions,
            k_positions=jnp.arange(sc_len, dtype=jnp.int32), causal=True,
            k_valid_len=pos + sq, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
            sm_scale=scale)
        out = linear(p["wo"], out.reshape(b, sq, -1))
        return out, {"ckv": ckv_cache, "kr": kr_cache, "len": pos + sq}
    wuk = p["wuk"]["w"].reshape(dc, h, dn)
    q_abs = jnp.einsum("bqhn,chn->bqhc", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))          # [B,Sq,H,dc]
    # pin q̃ (and q_rope) to the cache's LATENT sharding: head-sharded q̃
    # against a dc-sharded cache makes SPMD re-gather the whole 32k-token
    # latent cache every layer (§Perf deepseek decode_32k)
    q_abs = constrain_last_model(q_abs)
    q_rope = constrain_last_model(q_rope)
    s_nope = jnp.einsum("bqhc,bsc->bhqs", q_abs, ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                        kr_cache.astype(jnp.float32))
    sc_len = ckv_cache.shape[1]
    scores = (s_nope + s_rope) * scale
    q_pos = positions[None, None, :, None]               # absolute positions
    valid = jnp.arange(sc_len)[None, None, None, :] <= q_pos
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhqs,bsc->bqhc", probs, ckv_cache.astype(jnp.float32))
    wuv = p["wuv"]["w"].reshape(dc, h, dv)
    out = jnp.einsum("bqhc,chv->bqhv", lat, wuv.astype(jnp.float32))
    out = linear(p["wo"], out.reshape(b, sq, -1).astype(x.dtype))
    return out, {"ckv": ckv_cache, "kr": kr_cache, "len": pos + sq}

"""Model assembly: one generic LM covering all ten assigned architectures.

A config selects the layer kind (attention+MLP, attention+MoE, Mamba2,
hybrid-with-shared-attention, encoder-decoder); the stack is always a
``lax.scan`` over parameters stacked on a leading ``layers`` axis, so compile
time is O(1) in depth and remat policy is per-scan-step.

Public entry points (used by launch/ and tests):
  * ``init(rng, cfg)``                 → ``(params, specs)``
  * ``forward(params, cfg, tokens, mode, cache, pos, enc_inputs)``
  * ``lm_loss(params, cfg, batch)``    → scalar + aux
  * ``init_cache(cfg, batch, cache_len)``
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (Params, _normal, apply_mlp, apply_norm, embed,
                     init_embedding, init_mlp, init_norm, linear,
                     sinusoidal_positions)
from .pjit_utils import constrain_batch, constrain_seq

# ---------------------------------------------------------------------------
# layer init/apply (single layer; stacked via vmap outside)
# ---------------------------------------------------------------------------


def _residual_scale(cfg) -> float:
    if cfg.scale_depth is None:
        return 1.0
    return cfg.scale_depth / math.sqrt(cfg.n_layers)


def init_decoder_layer(key, cfg, *, use_moe: bool, cross: bool = False):
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["attn_norm"], s["attn_norm"] = init_norm(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
    if cfg.mla is not None:
        p["attn"], s["attn"] = attn.init_mla(ks[0], cfg)
    else:
        p["attn"], s["attn"] = attn.init_gqa(ks[0], cfg)
    if cross:
        p["cross_norm"], s["cross_norm"] = init_norm(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
        p["cross"], s["cross"] = attn.init_gqa(ks[1], cfg, cross=True)
    p["mlp_norm"], s["mlp_norm"] = init_norm(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
    if use_moe:
        p["moe"], s["moe"] = moe_lib.init_moe(ks[2], cfg)
    else:
        p["mlp"], s["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                      act=cfg.act, dtype=cfg.param_dtype,
                                      bias=cfg.attn_bias)
    return p, s


def apply_decoder_layer(p: Params, cfg, x, *, mode: str, cache, positions,
                        use_moe: bool, enc_kv=None, causal: bool = True):
    rs = _residual_scale(cfg)
    h = apply_norm(p["attn_norm"], x, kind=cfg.norm)
    if cfg.mla is not None:
        a_out, new_cache = attn.mla_attention(p["attn"], cfg, h, mode=mode,
                                              cache=cache, positions=positions)
    else:
        a_out, new_cache = attn.gqa_attention(p["attn"], cfg, h, mode=mode,
                                              cache=cache, positions=positions,
                                              causal=causal)
    x = (x + a_out * rs).astype(cfg.compute_dtype)
    if enc_kv is not None:
        h = apply_norm(p["cross_norm"], x, kind=cfg.norm)
        x = (x + attn.cross_attention(p["cross"], cfg, h, enc_kv) * rs
             ).astype(cfg.compute_dtype)
    h = apply_norm(p["mlp_norm"], x, kind=cfg.norm)
    aux = jnp.float32(0.0)
    load = None
    if use_moe:
        m_out, aux, load = moe_lib.apply_moe(p["moe"], cfg, h)
    else:
        m_out = apply_mlp(p["mlp"], h, act=cfg.act)
    x = (x + m_out * rs).astype(cfg.compute_dtype)
    return x, new_cache, aux, load


def init_mamba_layer(key, cfg):
    p, s = {}, {}
    p["norm"], s["norm"] = init_norm(cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
    p["mixer"], s["mixer"] = ssm_lib.init_mamba2(key, cfg)
    return p, s


def apply_mamba_layer(p: Params, cfg, x, *, mode: str, cache):
    h = apply_norm(p["norm"], x, kind=cfg.norm)
    out, new_cache = ssm_lib.mamba2_block(p["mixer"], cfg, h, mode=mode, cache=cache)
    return (x + out).astype(cfg.compute_dtype), new_cache


# ---------------------------------------------------------------------------
# stacked init helpers
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, n: int):
    """vmap an init over layer keys → params stacked on axis 0."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, specs = init_fn(keys[0])
    specs = jax.tree.map(lambda sp: ("layers",) + tuple(sp),
                         specs, is_leaf=lambda t: isinstance(t, tuple))
    return params, specs


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init(rng, cfg) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 8)
    p, s = {}, {}
    p["embed"], s["embed"] = init_embedding(ks[0], cfg.vocab, cfg.d_model,
                                            dtype=cfg.param_dtype)
    if cfg.pos_emb == "learned":
        p["pos"] = _normal(ks[1], (cfg.max_seq, cfg.d_model), 0.02, cfg.param_dtype)
        s["pos"] = (None, "embed")
    p["final_norm"], s["final_norm"] = init_norm(cfg.d_model, kind=cfg.norm,
                                                 dtype=cfg.param_dtype)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = init_embedding(ks[2], cfg.vocab,
                                                    cfg.d_model,
                                                    dtype=cfg.param_dtype)

    fam = cfg.family
    if fam in ("dense", "moe"):
        n_dense = cfg.moe.get("first_dense", 0) if cfg.moe else cfg.n_layers
        n_moe = cfg.n_layers - n_dense
        if n_dense:
            p["dense_stack"], s["dense_stack"] = _stack_init(
                partial(init_decoder_layer, cfg=cfg, use_moe=False), ks[3], n_dense)
        if n_moe:
            p["moe_stack"], s["moe_stack"] = _stack_init(
                partial(init_decoder_layer, cfg=cfg, use_moe=True), ks[4], n_moe)
        if cfg.mtp:
            # DeepSeek-V3 multi-token prediction module: one extra block
            # over Proj([norm(h); norm(emb(t+1))]) predicting t+2
            kp = jax.random.fold_in(ks[5], 7)
            mtp_p, mtp_s = {}, {}
            mtp_p["norm_h"], mtp_s["norm_h"] = init_norm(
                cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
            mtp_p["norm_e"], mtp_s["norm_e"] = init_norm(
                cfg.d_model, kind=cfg.norm, dtype=cfg.param_dtype)
            mtp_p["proj"] = _normal(kp, (2 * cfg.d_model, cfg.d_model),
                                    (2 * cfg.d_model) ** -0.5, cfg.param_dtype)
            mtp_s["proj"] = ("embed", None)
            mtp_p["layer"], mtp_s["layer"] = init_decoder_layer(
                jax.random.fold_in(kp, 1), cfg, use_moe=False)
            p["mtp"], s["mtp"] = mtp_p, mtp_s
    elif fam == "ssm":
        p["mamba_stack"], s["mamba_stack"] = _stack_init(
            partial(init_mamba_layer, cfg=cfg), ks[3], cfg.n_layers)
    elif fam == "hybrid":
        p["mamba_stack"], s["mamba_stack"] = _stack_init(
            partial(init_mamba_layer, cfg=cfg), ks[3], cfg.n_layers)
        # shared attention block (one set of weights, invoked every k layers)
        p["shared"], s["shared"] = init_decoder_layer(ks[4], cfg, use_moe=False)
        hy = cfg.hybrid
        n_inv = (cfg.n_layers + hy["attn_every"] - 1) // hy["attn_every"]
        r = hy.get("lora_rank", 0)
        if r:
            dh_total = cfg.n_heads * cfg.dh
            p["shared_lora"] = {
                "a": _normal(ks[5], (n_inv, cfg.d_model, r), 0.01, cfg.param_dtype),
                "b": jnp.zeros((n_inv, r, dh_total), cfg.param_dtype),
            }
            s["shared_lora"] = {"a": (None, "embed", None), "b": (None, None, "heads")}
    elif fam == "encdec":
        p["enc_stack"], s["enc_stack"] = _stack_init(
            partial(init_decoder_layer, cfg=cfg, use_moe=False),
            ks[3], cfg.encdec["enc_layers"])
        p["dec_stack"], s["dec_stack"] = _stack_init(
            partial(init_decoder_layer, cfg=cfg, use_moe=False, cross=True),
            ks[4], cfg.n_layers)
        p["enc_norm"], s["enc_norm"] = init_norm(cfg.d_model, kind=cfg.norm,
                                                 dtype=cfg.param_dtype)
        p["enc_pos"] = sinusoidal_positions(
            cfg.encdec["enc_frames"], cfg.d_model).astype(cfg.param_dtype)
        s["enc_pos"] = (None, "embed")
    else:
        raise ValueError(f"unknown family {fam}")
    return p, s


# ---------------------------------------------------------------------------
# scanning machinery
# ---------------------------------------------------------------------------

def _scan_stack(layer_apply, stacked_params, x, stacked_cache, cfg):
    """Scan ``layer_apply`` over a stacked parameter pytree (+opt cache)."""

    def body(carry, xs):
        xv, aux_acc = carry
        pl, cl = xs
        pin = constrain_seq if cfg.seq_parallel else constrain_batch
        xv = pin(xv)  # keep residuals data-(or seq-)sharded (see pjit_utils)
        out = layer_apply(pl, xv, cl)
        xv, new_cache, aux = out
        xv = pin(xv)
        return (xv, aux_acc + aux), new_cache

    fn = body
    if cfg.remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    (x, aux), new_caches = jax.lax.scan(
        fn, (x, jnp.float32(0.0)), (stacked_params, stacked_cache))
    return x, aux, new_caches


def _none_like_stack(params_stack):
    """A scan-compatible None cache (broadcast leaf)."""
    n = jax.tree.leaves(params_stack)[0].shape[0]
    return jnp.zeros((n, 0), jnp.float32)  # zero-size xs placeholder


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(params: Params, cfg, tokens: jnp.ndarray, *, mode: str = "train",
            cache: Optional[Params] = None,
            positions: Optional[jnp.ndarray] = None,
            enc_inputs: Optional[jnp.ndarray] = None,
            return_hidden: bool = False):
    """tokens [B,S] int32 → logits [B,S,V] (fp32) or hidden (if requested).

    decode mode: S==1, ``cache`` required, ``positions`` = [1] current pos.
    encdec: ``enc_inputs`` [B, frames, d_model] (stub frontend embeddings)
    required in train/prefill; cached cross-KV used in decode.
    """
    x = embed(params["embed"], tokens, scale=cfg.scale_emb).astype(cfg.compute_dtype)
    x = constrain_batch(x)
    b, sq = tokens.shape
    if positions is None:
        positions = jnp.arange(sq, dtype=jnp.int32)
    if cfg.pos_emb == "learned":
        if mode in ("decode", "chunked_prefill"):
            x = x + params["pos"][positions][None]
        else:
            x = x + params["pos"][:sq][None]
    aux_total = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}

    fam = cfg.family
    if fam in ("dense", "moe"):
        for stack_name, use_moe in (("dense_stack", False), ("moe_stack", True)):
            if stack_name not in params:
                continue
            st_cache = cache[stack_name] if cache is not None else None
            def apply_one(pl, xv, cl, _moe=use_moe):
                cl = cl if isinstance(cl, dict) else None
                xv, nc, aux, _load = apply_decoder_layer(
                    pl, cfg, xv, mode=mode, cache=cl, positions=positions,
                    use_moe=_moe)
                return xv, (nc if nc is not None else
                            _none_like_cache_leaf()), aux
            x, aux, nc = _scan_stack(
                apply_one, params[stack_name], x,
                st_cache if st_cache is not None
                else _none_like_stack(params[stack_name]), cfg)
            aux_total += aux
            if mode in ("prefill", "chunked_prefill", "decode"):
                new_cache[stack_name] = nc
    elif fam == "ssm":
        st_cache = cache["mamba_stack"] if cache is not None else None
        def apply_one(pl, xv, cl):
            cl = cl if isinstance(cl, dict) else None
            xv, nc = apply_mamba_layer(pl, cfg, xv, mode=mode, cache=cl)
            return xv, (nc if nc is not None else _none_like_cache_leaf()), jnp.float32(0.0)
        x, aux, nc = _scan_stack(
            apply_one, params["mamba_stack"], x,
            st_cache if st_cache is not None
            else _none_like_stack(params["mamba_stack"]), cfg)
        if mode in ("prefill", "chunked_prefill", "decode"):
            new_cache["mamba_stack"] = nc
    elif fam == "hybrid":
        x, aux_total, new_cache = _hybrid_forward(
            params, cfg, x, mode=mode, cache=cache, positions=positions)
    elif fam == "encdec":
        x, aux_total, new_cache = _encdec_forward(
            params, cfg, x, mode=mode, cache=cache, positions=positions,
            enc_inputs=enc_inputs)
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, kind=cfg.norm)
    if return_hidden:
        return x, aux_total, (new_cache or None)
    head = params.get("lm_head", params["embed"])
    logits = (x @ head["table"].T.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_scale is not None:
        logits = logits * cfg.logit_scale
    return logits, aux_total, (new_cache or None)


def _none_like_cache_leaf():
    return jnp.zeros((0,), jnp.float32)


# -- hybrid (zamba2): mamba scan with conditional shared attention ------------
#
# One scan over the 81 mamba layers; every `attn_every`-th step additionally
# applies the SHARED attention+MLP block (one weight set, per-invocation LoRA
# delta on wq).  The shared block's KV caches live in the scan CARRY as a
# stacked [n_inv, ...] buffer updated at a dynamic invocation index — caches
# exist only for the ~L/6 invocations, not per layer.

def _hybrid_forward(params, cfg, x, *, mode, cache, positions):
    hy = cfg.hybrid
    every = hy["attn_every"]
    n = cfg.n_layers
    shared = params["shared"]
    lora = params.get("shared_lora")
    window = hy.get("attn_window")
    hy_cfg = cfg.replace(window=window) if window else cfg

    mamba_xs = (cache["mamba_stack"] if cache is not None
                else _none_like_stack(params["mamba_stack"]))
    if mode == "train":
        acache0 = jnp.zeros((0,), jnp.float32)  # unused placeholder
    elif mode == "prefill":
        n_inv = (n + every - 1) // every
        sq = x.shape[1]
        sc = min(sq, window) if window else sq
        acache0 = {
            "k": jnp.zeros((n_inv, x.shape[0], sc, cfg.n_kv_heads, cfg.dh),
                           cfg.compute_dtype),
            "v": jnp.zeros((n_inv, x.shape[0], sc, cfg.n_kv_heads, cfg.dh),
                           cfg.compute_dtype),
            "len": jnp.zeros((n_inv,), jnp.int32)}
    else:
        acache0 = cache["shared_attn"]

    def body(carry, xs):
        xv, aux, acache = carry
        pl, cl, idx = xs
        xv = constrain_batch(xv)
        inv = idx // every

        def with_attn(op):
            xv, acache = op
            pa = _apply_lora_to_attn(shared, lora, inv) if lora is not None else shared
            acl = None
            if mode in ("decode", "chunked_prefill"):
                acl = jax.tree.map(
                    lambda t: jax.lax.dynamic_index_in_dim(t, inv, 0, keepdims=False),
                    acache)
            out, nac, a2, _ = apply_decoder_layer(
                pa, hy_cfg, xv, mode=mode, cache=acl, positions=positions,
                use_moe=False)
            if mode != "train" and nac is not None:
                acache = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), inv, 0),
                    acache, nac)
            return out, acache, a2

        def without(op):
            xv, acache = op
            return xv, acache, jnp.float32(0.0)

        xv, acache, a2 = jax.lax.cond(
            idx % every == 0, with_attn, without, (xv, acache))
        cl_ = cl if isinstance(cl, dict) else None
        xv, ncl = apply_mamba_layer(pl, cfg, xv, mode=mode, cache=cl_)
        return ((xv, aux + a2, acache),
                (ncl if ncl is not None else _none_like_cache_leaf()))

    fn = (jax.checkpoint(body, prevent_cse=False)
          if (cfg.remat == "full" and mode == "train") else body)
    idxs = jnp.arange(n, dtype=jnp.int32)
    (x, aux, acache), new_mamba = jax.lax.scan(
        fn, (x, jnp.float32(0.0), acache0),
        (params["mamba_stack"], mamba_xs, idxs))
    new_cache = {}
    if mode in ("prefill", "chunked_prefill", "decode"):
        new_cache = {"mamba_stack": new_mamba, "shared_attn": acache}
    return x, aux, new_cache


def _apply_lora_to_attn(pa: Params, lora: Params, inv):
    """Add the per-invocation LoRA delta to the shared block's wq."""
    a = lora["a"][inv]
    b = lora["b"][inv]
    attn_p = dict(pa["attn"])
    wq = dict(attn_p["wq"])
    wq["w"] = wq["w"] + (a @ b).astype(wq["w"].dtype)
    attn_p["wq"] = wq
    out = dict(pa)
    out["attn"] = attn_p
    return out


# -- encoder-decoder (whisper) -------------------------------------------------

def _encdec_forward(params, cfg, x, *, mode, cache, positions, enc_inputs):
    aux = jnp.float32(0.0)
    if mode in ("train", "prefill"):
        assert enc_inputs is not None, "encdec needs encoder frame embeddings"
        e = enc_inputs.astype(cfg.compute_dtype) + params["enc_pos"][None]
        def enc_one(pl, xv, cl):
            xv, _, a, _ = apply_decoder_layer(
                pl, cfg, xv, mode="train", cache=None,
                positions=jnp.arange(e.shape[1], dtype=jnp.int32),
                use_moe=False, causal=False)
            return xv, _none_like_cache_leaf(), a
        e, a1, _ = _scan_stack(enc_one, params["enc_stack"], e,
                               _none_like_stack(params["enc_stack"]), cfg)
        e = apply_norm(params["enc_norm"], e, kind=cfg.norm)
        # precompute stacked cross-KV for every decoder layer
        cross_kv = jax.vmap(
            lambda pl: attn.encode_cross_kv(pl["cross"], cfg, e))(
                params["dec_stack"])
    else:
        cross_kv = cache["cross_kv"]

    dec_cache = cache["dec_stack"] if cache is not None else None

    def dec_one_with_kv(pl_and_kv, xv, cl):
        pl, kv = pl_and_kv
        cl = cl if isinstance(cl, dict) else None
        xv, nc, a, _ = apply_decoder_layer(
            pl, cfg, xv, mode=mode, cache=cl, positions=positions,
            use_moe=False, enc_kv=kv)
        return xv, (nc if nc is not None else _none_like_cache_leaf()), a

    x, a2, nc = _scan_stack(
        lambda pl, xv, cl: dec_one_with_kv(pl, xv, cl),
        (params["dec_stack"], cross_kv), x,
        dec_cache if dec_cache is not None
        else _none_like_stack(params["dec_stack"]), cfg)
    new_cache = {}
    if mode in ("prefill", "decode"):
        new_cache = {"dec_stack": nc, "cross_kv": cross_kv}
    return x, aux + a2, new_cache


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int) -> Params:
    """Static-shape decode caches, stacked on the layer axis."""
    cdt = cfg.compute_dtype

    def kv_cache(n_layers, sc):
        return {"k": jnp.zeros((n_layers, batch, sc, cfg.n_kv_heads, cfg.dh), cdt),
                "v": jnp.zeros((n_layers, batch, sc, cfg.n_kv_heads, cfg.dh), cdt),
                "len": jnp.zeros((n_layers,), jnp.int32)}

    sc = min(cache_len, cfg.window) if cfg.window else cache_len
    fam = cfg.family
    if fam in ("dense", "moe"):
        out = {}
        n_dense = cfg.moe.get("first_dense", 0) if cfg.moe else cfg.n_layers
        n_moe = cfg.n_layers - n_dense
        if cfg.mla is not None:
            def mla_cache(n_layers):
                m = cfg.mla
                return {"ckv": jnp.zeros((n_layers, batch, cache_len, m["kv_lora_rank"]), cdt),
                        "kr": jnp.zeros((n_layers, batch, cache_len, m["qk_rope_dim"]), cdt),
                        "len": jnp.zeros((n_layers,), jnp.int32)}
            if n_dense:
                out["dense_stack"] = mla_cache(n_dense)
            if n_moe:
                out["moe_stack"] = mla_cache(n_moe)
        else:
            if n_dense:
                out["dense_stack"] = kv_cache(n_dense, sc)
            if n_moe:
                out["moe_stack"] = kv_cache(n_moe, sc)
        return out
    if fam == "ssm":
        per = ssm_lib.init_ssm_cache(cfg, batch)
        return {"mamba_stack": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), per)}
    if fam == "hybrid":
        per = ssm_lib.init_ssm_cache(cfg, batch)
        hy = cfg.hybrid
        n_inv = (cfg.n_layers + hy["attn_every"] - 1) // hy["attn_every"]
        mamba = {"mamba_stack": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), per)}
        swin = hy.get("attn_window")
        sc_h = min(cache_len, swin) if swin else cache_len
        mamba["shared_attn"] = {
            "k": jnp.zeros((n_inv, batch, sc_h, cfg.n_kv_heads, cfg.dh), cdt),
            "v": jnp.zeros((n_inv, batch, sc_h, cfg.n_kv_heads, cfg.dh), cdt),
            "len": jnp.zeros((n_inv,), jnp.int32)}
        return mamba
    if fam == "encdec":
        return {"dec_stack": kv_cache(cfg.n_layers, sc),
                "cross_kv": {
                    "k": jnp.zeros((cfg.n_layers, batch, cfg.encdec["enc_frames"],
                                    cfg.n_kv_heads, cfg.dh), cdt),
                    "v": jnp.zeros((cfg.n_layers, batch, cfg.encdec["enc_frames"],
                                    cfg.n_kv_heads, cfg.dh), cdt)}}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# losses / steps (pure fns; launch wraps them in pjit)
# ---------------------------------------------------------------------------

def chunked_lm_loss(params, cfg, hidden, labels, mask=None):
    """Sequence-chunked softmax xent: avoids materializing [B,S,V] fp32."""
    head = params.get("lm_head", params["embed"])
    w = head["table"]
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    n = s // c
    assert s % c == 0

    def one(carry, xs):
        h_c, y_c, m_c = xs
        h_c = constrain_batch(h_c)
        logits = (h_c @ w.T.astype(h_c.dtype)).astype(jnp.float32)
        if cfg.logit_scale is not None:
            logits = logits * cfg.logit_scale
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold via one-hot contraction: take_along_axis over a vocab-sharded
        # logits tensor would force XLA to replicate the whole chunk.
        oh = jax.nn.one_hot(y_c, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.sum(logits * oh, axis=-1)
        nll = (logz - gold) * m_c
        return (carry[0] + nll.sum(), carry[1] + m_c.sum()), None

    hs = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(b, n, c).transpose(1, 0, 2)
    ms = (mask if mask is not None
          else jnp.ones_like(labels, jnp.float32)).reshape(b, n, c).transpose(1, 0, 2)
    body = jax.checkpoint(one) if cfg.remat != "none" else one
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hs, ys, ms))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg, batch):
    """batch: {"tokens": [B,S], "labels": [B,S], optional "enc_inputs"}."""
    hidden, aux, _ = forward(params, cfg, batch["tokens"], mode="train",
                             enc_inputs=batch.get("enc_inputs"),
                             return_hidden=True)
    loss = chunked_lm_loss(params, cfg, hidden, batch["labels"],
                           batch.get("loss_mask"))
    metrics = {"xent": loss, "moe_aux": aux}
    if cfg.mtp and "mtp" in params:
        mtp_l = _mtp_loss(params, cfg, hidden, batch["labels"])
        loss = loss + cfg.mtp_weight * mtp_l
        metrics["mtp"] = mtp_l
    moe_w = (cfg.moe or {}).get("aux_weight", 0.0)
    return loss + moe_w * aux, metrics


def _mtp_loss(params, cfg, hidden, labels):
    """DeepSeek-V3 MTP: h'_i = Proj([norm(h_i); norm(emb(t_{i+1}))]) →
    one transformer block → shared head → predict t_{i+2}."""
    mp = params["mtp"]
    b, s, d = hidden.shape
    emb_next = embed(params["embed"], labels).astype(cfg.compute_dtype)
    h = jnp.concatenate([
        apply_norm(mp["norm_h"], hidden, kind=cfg.norm),
        apply_norm(mp["norm_e"], emb_next, kind=cfg.norm)], axis=-1)
    h = (h @ mp["proj"]).astype(cfg.compute_dtype)
    h, _, _, _ = apply_decoder_layer(
        mp["layer"], cfg, h, mode="train", cache=None,
        positions=jnp.arange(s, dtype=jnp.int32), use_moe=False)
    # predict t+2: shift labels left by one; mask the last position
    labels2 = jnp.roll(labels, -1, axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    return chunked_lm_loss(params, cfg, h, labels2, mask)

"""repro.models — pure-JAX model zoo covering the assigned architectures."""
from . import attention, layers, model, moe, ssm

"""Shared neural-net building blocks (pure JAX, no framework deps).

Parameters are plain nested dicts of ``jnp.ndarray``.  Every ``init_*``
returns ``(params, specs)`` where ``specs`` mirrors ``params`` with logical
axis-name tuples; ``repro.launch.sharding`` maps logical names onto mesh axes
(TP/FSDP/EP).  Keeping specs beside params means adding an architecture never
touches the sharding code.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# Logical axis names (mapped to mesh axes in launch/sharding.py):
#   "embed"   — d_model         (FSDP-sharded over data when enabled)
#   "heads"   — attention heads (TP)
#   "kv"      — kv heads        (TP when divisible)
#   "mlp"     — d_ff            (TP)
#   "vocab"   — vocabulary      (TP)
#   "expert"  — MoE experts     (EP → model axis)
#   "layers"  — scan axis       (never sharded)
#   None      — replicated


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_linear(key, d_in: int, d_out: int, *, axes: Tuple, dtype,
                scale: Optional[float] = None, bias: bool = False):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    s = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (axes[1],)
    return p, s


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- normalization -----------------------------------------------------------

def init_norm(d: int, *, kind: str, dtype) -> Tuple[Params, Params]:
    p = {"g": jnp.ones((d,), dtype)}
    s = {"g": ("embed",)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
        s["b"] = ("embed",)
    return p, s


def apply_norm(p: Params, x: jnp.ndarray, *, kind: str,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["g"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_norm_simple(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * g.astype(jnp.float32)).astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, *, act: str, dtype,
             bias: bool = False) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        p_gate, s_gate = init_linear(ks[0], d_model, d_ff, axes=("embed", "mlp"), dtype=dtype)
        p_up, s_up = init_linear(ks[1], d_model, d_ff, axes=("embed", "mlp"), dtype=dtype)
        p_dn, s_dn = init_linear(ks[2], d_ff, d_model, axes=("mlp", "embed"), dtype=dtype)
        return ({"gate": p_gate, "up": p_up, "down": p_dn},
                {"gate": s_gate, "up": s_up, "down": s_dn})
    p_up, s_up = init_linear(ks[0], d_model, d_ff, axes=("embed", "mlp"), dtype=dtype, bias=bias)
    p_dn, s_dn = init_linear(ks[1], d_ff, d_model, axes=("mlp", "embed"), dtype=dtype, bias=bias)
    return {"up": p_up, "down": p_dn}, {"up": s_up, "down": s_dn}


def apply_mlp(p: Params, x: jnp.ndarray, *, act: str) -> jnp.ndarray:
    if act == "swiglu":
        return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
    h = linear(p["up"], x)
    h = jax.nn.gelu(h, approximate=True)
    return linear(p["down"], h)


# -- embeddings ----------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, *, dtype,
                   scale: float = 1.0) -> Tuple[Params, Params]:
    p = {"table": _normal(key, (vocab, d_model), scale, dtype)}
    return p, {"table": ("vocab", "embed")}


def embed(p: Params, ids: jnp.ndarray, *, scale: float = 1.0) -> jnp.ndarray:
    out = p["table"][ids]
    return out * scale if scale != 1.0 else out


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits; fp32 for numerical stability of the softmax/xent."""
    return (x @ p["table"].T.astype(x.dtype)).astype(jnp.float32)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * 2 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- rotary position embeddings --------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jnp.ndarray,
               rotary_dim: Optional[int] = None):
    """cos/sin tables; ``rotary_dim < head_dim`` gives partial ("2d") RoPE."""
    rd = rotary_dim or head_dim
    inv = 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray,
               rotary_dim: Optional[int] = None) -> jnp.ndarray:
    """x: [..., S, H, Dh]; rotate the first ``rotary_dim`` dims pairwise."""
    rd = rotary_dim or x.shape[-1]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    # cos/sin: [..., S, rd/2] → broadcast over heads axis
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    rot = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rot, xp], axis=-1) if rd < x.shape[-1] else rot


# -- misc -------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits fp32 [..., V], labels int [...]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

"""repro.distributed — fault tolerance, straggler mitigation, compression,
and D4M-semiring telemetry for multi-pod runs."""
from .compression import compress_tree, decompress_tree
from .fault_tolerance import (FaultToleranceConfig, HeartbeatMonitor,
                              RestartPolicy, StragglerMitigator, run_resilient)
from .metrics import MetricsStore

__all__ = ["HeartbeatMonitor", "RestartPolicy", "StragglerMitigator",
           "FaultToleranceConfig", "run_resilient", "MetricsStore",
           "compress_tree", "decompress_tree"]

"""Fault tolerance: heartbeats, restart policy, straggler mitigation.

No real cluster exists in this container, so the layer is built against an
abstract ``WorkerPool`` interface and exercised by a simulation harness in
tests (dead workers, slow workers, flapping workers).  The production
binding points are documented inline: on a real deployment the heartbeat
source is the JAX distributed service / GCS health checks and "restart"
means re-scheduling the jobset; everything above that seam — detection
thresholds, restart-with-checkpoint control flow, deterministic data
replay, straggler quorum logic — is the code here, unchanged.

Control flow implemented by :func:`run_resilient`:

  1. step function raises / a heartbeat lapses →
  2. RestartPolicy decides (restart budget, backoff) →
  3. restore latest checkpoint (CheckpointManager, crash-safe) →
  4. data pipeline cursor restored → bitwise-identical batch replay →
  5. training resumes; metrics merge idempotently (MetricsStore ⊕).

Straggler mitigation: per-step worker timings feed an online median/MAD
estimator; workers slower than ``median + k·MAD`` for ``patience``
consecutive steps are marked and their data shard re-dispatched to a hot
spare (backup-worker semantics à la MapReduce speculative execution).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class FaultToleranceConfig:
    heartbeat_timeout_s: float = 60.0
    max_restarts: int = 5
    backoff_s: float = 1.0
    straggler_mad_k: float = 4.0
    straggler_patience: int = 3
    n_hot_spares: int = 1


class HeartbeatMonitor:
    """Tracks last-seen times; on real clusters fed by the RPC layer."""

    def __init__(self, worker_ids: List[str], timeout_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {w: clock() for w in worker_ids}

    def beat(self, worker: str, at: Optional[float] = None):
        self.last_seen[worker] = self.clock() if at is None else at

    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_workers()


class StragglerMitigator:
    """Online median/MAD outlier detector over per-worker step times."""

    def __init__(self, worker_ids: List[str], *, mad_k: float = 4.0,
                 patience: int = 3, window: int = 32):
        self.mad_k = mad_k
        self.patience = patience
        self.window = window
        self.times: Dict[str, List[float]] = {w: [] for w in worker_ids}
        self.strikes: Dict[str, int] = {w: 0 for w in worker_ids}
        self.reassigned: Dict[str, str] = {}

    def record_step(self, step_times: Dict[str, float]) -> List[str]:
        """Feed one step's per-worker wall times; returns NEW stragglers."""
        for w, t in step_times.items():
            buf = self.times[w]
            buf.append(t)
            if len(buf) > self.window:
                buf.pop(0)
        med = float(np.median(list(step_times.values())))
        mad = float(np.median([abs(t - med) for t in step_times.values()]))
        mad = max(mad, 1e-6)
        out = []
        for w, t in step_times.items():
            if t > med + self.mad_k * mad:
                self.strikes[w] += 1
                if self.strikes[w] == self.patience:
                    out.append(w)
            else:
                self.strikes[w] = 0
        return out

    def reassign(self, straggler: str, spare: str):
        """Record a shard re-dispatch (backup-worker execution)."""
        self.reassigned[straggler] = spare


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    _used: int = 0

    def should_restart(self) -> bool:
        return self._used < self.max_restarts

    def on_restart(self) -> float:
        """Returns backoff seconds (exponential)."""
        self._used += 1
        return self.backoff_s * (2 ** (self._used - 1))

    @property
    def restarts_used(self) -> int:
        return self._used


def run_resilient(*, n_steps: int, step_fn, make_state, ckpt_manager,
                  pipeline=None, policy: Optional[RestartPolicy] = None,
                  metrics=None, sleep=time.sleep):
    """Drive ``step_fn(state, batch) -> (state, metrics_dict)`` to n_steps,
    surviving step-fn failures via checkpoint restore + deterministic data
    replay.  Returns (state, steps_completed, restarts_used).

    ``make_state()`` builds fresh state (used only if no checkpoint exists
    at first failure).  This is the exact control flow a real launcher
    runs per-host; only the failure SIGNAL differs (exception here, health
    RPC there).
    """
    policy = policy or RestartPolicy()
    state = make_state()
    step = 0
    while step < n_steps:
        try:
            batch = pipeline.next_batch() if pipeline is not None else None
            state, m = step_fn(state, batch)
            if metrics is not None and m:
                metrics.log(step, m)
            step += 1
            if ckpt_manager is not None and ckpt_manager.should_save(step):
                extra = {"pipeline": pipeline.state_dict()} if pipeline else {}
                ckpt_manager.save_async(step, state, extra=extra)
        except Exception:
            if policy is None or not policy.should_restart():
                raise
            sleep(policy.on_restart())
            try:
                state, step, extra = ckpt_manager.restore_latest(make_state())
                if pipeline is not None and "pipeline" in (extra or {}):
                    pipeline.load_state_dict(extra["pipeline"])
            except FileNotFoundError:
                state, step = make_state(), 0
                if pipeline is not None:
                    pipeline.load_state_dict({"step": 0, "seed":
                                              pipeline.state.seed, "epoch": 0})
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return state, step, policy.restarts_used

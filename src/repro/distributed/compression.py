"""Gradient compression for cross-pod (DCI) all-reduce.

int8 block quantization with **error feedback**: the quantization residual
is carried to the next step so the compressed SGD direction stays unbiased
in the long run (standard EF-SGD construction).  Intended for the gradient
sync across the ``pod`` axis where bandwidth is ~10× scarcer than ICI;
intra-pod reduction stays full-precision.

The quantizer reuses the optimizer's shape-preserving q8 layout so sharded
specs transfer verbatim.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import dequantize_q8, quantize_q8


def compress_tree(grads, error_state: Optional[Any] = None):
    """(compressed, new_error_state).  compressed leaves: {"q","s"}."""
    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                   grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        packed = quantize_q8(corrected)
        deq = dequantize_q8(packed, g.shape)
        return packed, corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def decompress_tree(compressed, shapes_like, dtype=jnp.float32):
    flat_c, tdef = jax.tree.flatten(
        shapes_like)  # structure reference
    flat_packed = tdef.flatten_up_to(compressed)
    return tdef.unflatten([
        dequantize_q8(p, s.shape, dtype) for p, s in zip(flat_packed, flat_c)])

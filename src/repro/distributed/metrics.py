"""Training telemetry as D4M associative arrays.

Metrics are triples ``(step, metric_name) → value`` — an associative array.
Merging across hosts, restarts or duplicated retries is the semiring ⊕:

* idempotent aggregators (``max``/``min``/``last``) make merges retry-safe —
  re-reporting the same step after a restart cannot corrupt history;
* cross-host reduction of counters uses ``sum``; gauges use ``max``.

That uniform merge semantics is what lets the fault-tolerance layer replay
work without bookkeeping — D4M's aggregation-on-collision doing systems
work (§4 of DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core import Assoc


class MetricsStore:
    def __init__(self, aggregate="last"):
        self.table = Assoc()
        self.aggregate = aggregate

    def log(self, step: int, values: Dict[str, float]):
        names = list(values)
        upd = Assoc([float(step)] * len(names), names,
                    [float(values[n]) for n in names])
        self.table = self.table.combine(upd, {"last": lambda a, b: b,
                                              "max": max, "min": min,
                                              "sum": lambda a, b: a + b,
                                              }[self.aggregate]) \
            if self.table.nnz() else upd

    def merge(self, other: "MetricsStore") -> "MetricsStore":
        """Cross-host / cross-restart merge — ⊕ on collisions."""
        out = MetricsStore(self.aggregate)
        if self.table.nnz() and other.table.nnz():
            out.table = self.table.combine(
                other.table, {"last": lambda a, b: b, "max": max,
                              "min": min, "sum": lambda a, b: a + b
                              }[self.aggregate])
        else:
            out.table = (self.table if self.table.nnz() else other.table).copy()
        return out

    def series(self, name: str):
        if self.table.nnz() == 0:
            return np.zeros((0,)), np.zeros((0,))
        col = self.table[:, name]
        r, _, v = col.triples()
        order = np.argsort(r.astype(float))
        return r.astype(float)[order], v[order]

    def to_dict(self) -> Dict:
        r, c, v = self.table.triples()
        return {"rows": r.tolist(), "cols": c.tolist(), "vals": v.tolist(),
                "aggregate": self.aggregate}

    @staticmethod
    def from_dict(d: Dict) -> "MetricsStore":
        ms = MetricsStore(d.get("aggregate", "last"))
        if d["rows"]:
            ms.table = Assoc(d["rows"], d["cols"], d["vals"])
        return ms

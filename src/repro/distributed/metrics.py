"""Training telemetry as D4M associative arrays.

Metrics are triples ``(step, metric_name) → value`` — an associative array.
Merging across hosts, restarts or duplicated retries is the semiring ⊕:

* idempotent aggregators (``max``/``min``/``last``) make merges retry-safe —
  re-reporting the same step after a restart cannot corrupt history;
* cross-host reduction of counters uses ``sum``; gauges use ``max``.

That uniform merge semantics is what lets the fault-tolerance layer replay
work without bookkeeping — D4M's aggregation-on-collision doing systems
work (§4 of DESIGN.md).

``log()`` is **buffered**: updates append to a pending triple buffer and
are folded into the table in one batched ``Assoc`` construction + at most
one ``combine`` on the next read (``flush()``).  The old implementation
rebuilt the whole table per ``log`` call — O(n²) over a run; a serve
worker logging per request made that quadratic cost per *request*.  The ⊕
semantics are unchanged: ``canonicalize_np`` merges duplicate (step, name)
runs left-to-right in stable input order, so order-sensitive aggregates
(``last``) see updates exactly as the sequential implementation did.
"""
from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np

from repro.core import Assoc

_COMBINE = {"last": lambda a, b: b, "max": max, "min": min,
            "sum": lambda a, b: a + b}


class MetricsStore:
    def __init__(self, aggregate="last"):
        self._table = Assoc()
        self.aggregate = aggregate
        self._pending_steps: List[float] = []
        self._pending_names: List[str] = []
        self._pending_vals: List[float] = []
        self._lock = threading.RLock()
        # incremented once per Assoc.combine call — the regression tests
        # pin "one combine per flush, zero per log"
        self.combine_calls = 0

    # -- writes (cheap: append-only) ----------------------------------------
    def log(self, step: int, values: Dict[str, float]):
        with self._lock:
            for n in values:
                self._pending_steps.append(float(step))
                self._pending_names.append(n)
                self._pending_vals.append(float(values[n]))

    # -- the batched fold ---------------------------------------------------
    def flush(self) -> None:
        """Fold every pending update into the table: one batched Assoc
        construction (intra-batch collisions resolved by ⊕ in log order)
        plus at most one ``combine`` against the existing table."""
        with self._lock:
            if not self._pending_steps:
                return
            upd = Assoc(self._pending_steps, self._pending_names,
                        self._pending_vals, aggregate=self.aggregate)
            self._pending_steps = []
            self._pending_names = []
            self._pending_vals = []
            if self._table.nnz():
                self._table = self._table.combine(
                    upd, _COMBINE[self.aggregate])
                self.combine_calls += 1
            else:
                self._table = upd

    @property
    def table(self) -> Assoc:
        """The materialized metrics table (flushes pending updates)."""
        self.flush()
        return self._table

    @table.setter
    def table(self, value: Assoc) -> None:
        with self._lock:
            self._table = value
            self._pending_steps = []
            self._pending_names = []
            self._pending_vals = []

    # -- reads --------------------------------------------------------------
    def merge(self, other: "MetricsStore") -> "MetricsStore":
        """Cross-host / cross-restart merge — ⊕ on collisions."""
        out = MetricsStore(self.aggregate)
        mine, theirs = self.table, other.table
        if mine.nnz() and theirs.nnz():
            out.table = mine.combine(theirs, _COMBINE[self.aggregate])
        else:
            out.table = (mine if mine.nnz() else theirs).copy()
        return out

    def series(self, name: str):
        table = self.table
        if table.nnz() == 0:
            return np.zeros((0,)), np.zeros((0,))
        col = table[:, name]
        r, _, v = col.triples()
        order = np.argsort(r.astype(float))
        return r.astype(float)[order], v[order]

    def to_dict(self) -> Dict:
        r, c, v = self.table.triples()
        return {"rows": r.tolist(), "cols": c.tolist(), "vals": v.tolist(),
                "aggregate": self.aggregate}

    @staticmethod
    def from_dict(d: Dict) -> "MetricsStore":
        ms = MetricsStore(d.get("aggregate", "last"))
        if d["rows"]:
            ms.table = Assoc(d["rows"], d["cols"], d["vals"])
        return ms

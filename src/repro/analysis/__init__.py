"""repro.analysis — static verification of the D4M performance contracts.

The paper's performance story rests on structural invariants the layer
docstrings only *state*: shard-local paths run with **zero collectives**,
selection **never densifies**, the fused spgemm epilogues spend exactly
**one** psum-family collective.  This package makes those claims machine
checked on every compiled program:

* :mod:`~repro.analysis.hlo_contracts` — the loop-aware HLO walker (grown
  out of ``launch/hlo_static``): lowers a jitted/shard_mapped program and
  counts collectives by family (``while``-trip aware), host round-trips,
  and the dense-intermediate footprint against a tile budget.
* :mod:`~repro.analysis.contracts` — the ``@contract(...)`` decorator and
  registry declaring the invariants at the API, plus the verifier that
  sweeps probes against lowered programs.
* :mod:`~repro.analysis.probes` — per-entry-point probe functions that
  lower each decorated API's compiled program(s) on an ``AbstractMesh``
  (no devices, no TPU needed).
* :mod:`~repro.analysis.lint` — the host-side AST lint forbidding known
  anti-patterns (host materialization inside shard_map bodies, Python
  loops over nnz, kernels missing the ref/interpret/pallas triple).

``tools/d4mcheck`` and the ``tests/test_contracts.py`` sweep are the two
consumers; both fail on any contract violation or lint finding.
"""
from .contracts import (CONTRACT_REGISTRY, Contract, Violation, contract,
                        verify_all, verify_entry)
from .hlo_contracts import ProgramReport, analyze_program, lower_hlo

_LINT_API = ("Finding", "lint_file", "lint_paths")


def __getattr__(name):
    # lint loads lazily so `python -m repro.analysis.lint` doesn't import
    # the module twice (runpy's sys.modules warning)
    if name in _LINT_API:
        from . import lint
        return getattr(lint, name)
    raise AttributeError(name)

__all__ = [
    "contract", "Contract", "CONTRACT_REGISTRY", "Violation",
    "verify_entry", "verify_all",
    "ProgramReport", "analyze_program", "lower_hlo",
    "Finding", "lint_file", "lint_paths",
]

"""Contract probes: lower each decorated API's compiled programs.

A probe is a zero-argument callable registered under a contract's name.
It yields, per compiled program behind that entry point, a
``(label, hlo_text)`` pair — the *pre-optimization* HLO of the program,
obtained by ``.lower(...)`` over ``jax.ShapeDtypeStruct`` arguments and
(for the sharded layer) an 8-way ``AbstractMesh`` — plus optional
:class:`~repro.analysis.contracts.RetraceAudit` items asserting the
entry's trace cache doesn't grow on structurally identical repeat
calls.  Nothing here needs devices or a TPU: no program executes except
the (tiny, CPU) retrace-audit calls.

Probe shapes are chosen so the densification detector has teeth: COO
capacities are small (64–512 triples) while keyspaces are large (4096
ranks per axis), so a program that builds anything ``O(nr·nc)`` jumps
~100× above the ``8 × max_input`` budget.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Iterable, List

from .contracts import RetraceAudit
from .hlo_contracts import lower_hlo

#: contract name -> probe
PROBES: Dict[str, Callable[[], Iterable]] = {}

# probe geometry: nnz capacity per (shard|tensor) and keyspace extent.
_CAP = 64
_NKEYS = 4096
_NSHARDS = 8


def probe_for(name: str):
    def deco(fn):
        PROBES[name] = fn
        return fn
    return deco


# --------------------------------------------------------------------------
# Shared fixtures (built lazily, cached: probes import core on first use)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _abstract_mesh():
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", _NSHARDS),))


def _sds(shape, dtype):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _coo_dict_sds(cap: int = _CAP):
    """ShapeDtypeStruct tree of one sharded COO local dict."""
    import jax.numpy as jnp
    return {"rows": _sds((_NSHARDS, cap), jnp.int32),
            "cols": _sds((_NSHARDS, cap), jnp.int32),
            "vals": _sds((_NSHARDS, cap), jnp.float32),
            "nnz": _sds((_NSHARDS,), jnp.int32)}


def _b_triples_sds(nnz: int = _CAP):
    import jax.numpy as jnp
    return (_sds((nnz,), jnp.int32), _sds((nnz,), jnp.int32),
            _sds((nnz,), jnp.float32))


def _sel_args_sds(row_gather: bool, col_gather: bool, k_boxes: int = 1):
    """(bounds, rmask, cmask) abstract args matching _compiled_selection."""
    import jax.numpy as jnp
    bounds = _sds((k_boxes, 4), jnp.int32)
    rmask = _sds((_NKEYS if row_gather else 1,), jnp.bool_)
    cmask = _sds((_NKEYS if col_gather else 1,), jnp.bool_)
    return bounds, rmask, cmask


@functools.lru_cache(maxsize=1)
def _device_tensor():
    """A concrete small-capacity AssocTensor over large keyspaces.

    Eager-layer probes need a real pytree (its keyspaces are static aux
    consumed at trace time); 64 stored triples over 4096×4096 key ranks
    keep the build trivial while making densification unmissable.
    """
    import numpy as np
    from repro.core.assoc_tensor import AssocTensor
    from repro.core.keyspace import KeySpace

    all_keys = np.array([f"k{i:04d}" for i in range(_NKEYS)])
    space = KeySpace(all_keys)
    idx = np.arange(_CAP) * (_NKEYS // _CAP)
    return AssocTensor.from_triples(
        all_keys[idx], all_keys[(idx * 7) % _NKEYS],
        np.arange(_CAP, dtype=np.float32) + 1.0,
        capacity=_CAP, row_space=space, col_space=space)


def _selector_kinds():
    """One selector pair per device dispatch kind (range/multirange/
    hybrid/gather), matching ``select.plan_boxes``'s four paths."""
    from repro.core.select import All, Keys, Range

    t = _device_tensor()
    keys = t.row_space.keys
    scattered = list(keys[::5][:40])       # >4 interval runs -> gather
    tworuns = list(keys[10:20]) + list(keys[100:110])   # 2 runs -> boxes
    return [
        ("range", (Range(keys[4], keys[2000]), All())),
        ("multirange", (Keys(tworuns), All())),
        ("hybrid", (Range(keys[4], keys[2000]), Keys(scattered))),
        ("gather", (Keys(scattered), Keys(scattered))),
    ]


# --------------------------------------------------------------------------
# AssocTensor (single device)
# --------------------------------------------------------------------------

@probe_for("AssocTensor.__getitem__")
def _probe_tensor_getitem():
    import jax

    t = _device_tensor()
    for label, sel in _selector_kinds():
        yield label, lower_hlo(jax.jit(lambda x, s=sel: x._select_eager(s)), t)


@probe_for("AssocTensor.__setitem__")
def _probe_tensor_setitem():
    import jax
    import jax.numpy as jnp

    t = _device_tensor()

    def assign(x, val, s):
        # the functional core of __setitem__ (which mutates the wrapper)
        keep = x._selection_keep(s)
        return jnp.where(keep, val, x.vals)

    for label, sel in _selector_kinds():
        yield label, lower_hlo(jax.jit(lambda x, v, s=sel: assign(x, v, s)),
                               t, jnp.float32(0))


# --------------------------------------------------------------------------
# spgemm kernel programs (single device; the host-driven planner around
# them is eager by design, so the compiled contract lives in the kernels)
# --------------------------------------------------------------------------

def _pairlist_args_sds(n_pairs: int = 16, n_a: int = 8, n_b: int = 8):
    import jax.numpy as jnp
    return (_sds((n_a, 128, 128), jnp.float32),
            _sds((n_b, 128, 128), jnp.float32),
            _sds((n_pairs,), jnp.int32), _sds((n_pairs,), jnp.int32),
            _sds((n_pairs,), jnp.int32))


@probe_for("spgemm.matmul")
def _probe_spgemm_matmul():
    from repro.kernels.bsr_spgemm import ops

    a, b, pa, pb, pc = _pairlist_args_sds()
    yield "bsr_pairlist", lower_hlo(
        ops.bsr_pairlist, a, b, pa, pb, pc, n_c=4,
        semiring="plus_times", impl="ref")

    def first():
        _pairlist_call(ops)

    def again():
        _pairlist_call(ops)

    yield RetraceAudit(label="bsr_pairlist-jit", first=first, again=again,
                       size=lambda: ops.bsr_pairlist._cache_size())


def _pairlist_call(ops):
    import jax.numpy as jnp
    a = jnp.zeros((2, 128, 128), jnp.float32)
    b = jnp.zeros((2, 128, 128), jnp.float32)
    p = jnp.zeros((2,), jnp.int32)
    ops.bsr_pairlist(a, b, p, p, p, n_c=1, semiring="plus_times",
                     impl="ref").block_until_ready()


@probe_for("spgemm.matmul_reduce")
def _probe_spgemm_matmul_reduce():
    from repro.kernels.bsr_spgemm import ops

    a, b, pa, pb, po = _pairlist_args_sds()
    for axis in (1, 0):
        yield f"bsr_pairlist_reduce-axis{axis}", lower_hlo(
            ops.bsr_pairlist_reduce, a, b, pa, pb, po, n_o=4,
            axis=axis, semiring="plus_times", impl="ref")


# --------------------------------------------------------------------------
# DistAssoc (8-way AbstractMesh: shard_map programs lower with no devices)
# --------------------------------------------------------------------------

def _plus_times():
    from repro.core.semiring import PLUS_TIMES, get_semiring
    return get_semiring(PLUS_TIMES)


@probe_for("DistAssoc.__getitem__")
def _probe_dist_getitem():
    from repro.core.dist_assoc import _select_prog

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    for label, (rg, cg, k) in [("range", (False, False, 1)),
                               ("multirange", (False, False, 3)),
                               ("hybrid", (False, True, 1)),
                               ("gather", (True, True, 1))]:
        prog = _select_prog(mesh, rg, cg)
        yield label, lower_hlo(prog, a, *_sel_args_sds(rg, cg, k))

    def run():
        _select_prog(mesh, False, False)

    yield RetraceAudit(label="select-prog-cache", first=run, again=run,
                       size=lambda: _select_prog.cache_info().currsize)


@probe_for("DistAssoc.__setitem__")
def _probe_dist_setitem():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _setvals_prog

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    for label, (rg, cg) in [("range", (False, False)),
                            ("gather", (True, True))]:
        prog = _setvals_prog(mesh, rg, cg)
        yield label, lower_hlo(prog, a, *_sel_args_sds(rg, cg),
                               _sds((), jnp.float32))

    def run():
        _setvals_prog(mesh, False, False)

    yield RetraceAudit(label="setvals-prog-cache", first=run, again=run,
                       size=lambda: _setvals_prog.cache_info().currsize)


@probe_for("DistAssoc.add")
def _probe_dist_add():
    from repro.core.dist_assoc import _ewise_prog

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    yield "ewise-add", lower_hlo(_ewise_prog(mesh, _plus_times(), "add"),
                                 a, a)


@probe_for("DistAssoc.mul")
def _probe_dist_mul():
    from repro.core.dist_assoc import _ewise_prog

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    yield "ewise-mul", lower_hlo(_ewise_prog(mesh, _plus_times(), "mul"),
                                 a, a)


@probe_for("DistAssoc.matmul")
def _probe_dist_matmul():
    from repro.core.dist_assoc import _matmul_prog

    mesh = _abstract_mesh()
    a = {k: v for k, v in _coo_dict_sds().items() if k != "nnz"}
    prog = _matmul_prog(mesh, _plus_times(), 256, 256)
    yield "coo-expand-join", lower_hlo(prog, a, *_b_triples_sds())

    def run():
        _matmul_prog(mesh, _plus_times(), 256, 256)

    yield RetraceAudit(label="matmul-prog-cache", first=run, again=run,
                       size=lambda: _matmul_prog.cache_info().currsize)


@probe_for("DistAssoc.matmul_reduce")
def _probe_dist_matmul_reduce():
    from repro.core.dist_assoc import _matmul_reduce_prog

    mesh = _abstract_mesh()
    a = {k: v for k, v in _coo_dict_sds().items() if k != "nnz"}
    for axis in (1, 0):
        prog = _matmul_reduce_prog(mesh, _plus_times(), 256, _NKEYS, axis)
        yield f"axis{axis}", lower_hlo(prog, a, *_b_triples_sds())


def _probe_reduce_epilogue():
    # sqin/sqout's collective claim IS the fused matmul_reduce program
    # (reduce=None delegates to matmul, checked under its own contract)
    from repro.core.dist_assoc import _matmul_reduce_prog

    mesh = _abstract_mesh()
    a = {k: v for k, v in _coo_dict_sds().items() if k != "nnz"}
    prog = _matmul_reduce_prog(mesh, _plus_times(), 256, _NKEYS, 1)
    yield "reduce-epilogue", lower_hlo(prog, a, *_b_triples_sds())


PROBES["DistAssoc.sqin"] = _probe_reduce_epilogue
PROBES["DistAssoc.sqout"] = _probe_reduce_epilogue


@probe_for("DistAssoc.col_reduce")
def _probe_dist_col_reduce():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _col_reduce_prog

    mesh = _abstract_mesh()
    prog = _col_reduce_prog(mesh, _plus_times(), _NKEYS, jnp.float32)
    yield "col-reduce", lower_hlo(prog, _sds((_NSHARDS, _CAP), jnp.int32),
                                  _sds((_NSHARDS, _CAP), jnp.float32),
                                  _sds((_NSHARDS, _CAP), jnp.int32))


@probe_for("DistAssoc.row_reduce")
def _probe_dist_row_reduce():
    # same compiled program as col_reduce, keyed by the row ranks
    yield from _probe_dist_col_reduce()


@probe_for("DistAssoc.col_degree")
def _probe_dist_col_degree():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _col_degree_prog

    mesh = _abstract_mesh()
    prog = _col_degree_prog(mesh, _NKEYS)
    yield "col-degree", lower_hlo(prog, _sds((_NSHARDS, _CAP), jnp.int32),
                                  _sds((_NSHARDS, _CAP), jnp.int32))


# --------------------------------------------------------------------------
# Serve path: the server's execution entry point dispatches the same
# compiled programs as the eager layers, so its contract is checked over
# the shard-local programs a query mix reaches — selection (range +
# gather dispatch kinds), ewise ⊕, and the replicated-B matmul of a hot
# `A[sel, :] @ B` query.  (Fused matmul-*reduce* carries its one
# legitimate all-reduce and is budgeted under DistAssoc.matmul_reduce;
# the serve contract asserts the serve layer itself ADDS no collective.)
# --------------------------------------------------------------------------

@probe_for("serve.execute")
def _probe_serve_execute():
    from repro.core.dist_assoc import (_ewise_prog, _matmul_prog,
                                       _select_prog)

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    for label, (rg, cg, k) in [("select-range", (False, False, 1)),
                               ("select-gather", (True, True, 1))]:
        prog = _select_prog(mesh, rg, cg)
        yield label, lower_hlo(prog, a, *_sel_args_sds(rg, cg, k))
    yield "ewise-add", lower_hlo(_ewise_prog(mesh, _plus_times(), "add"),
                                 a, a)
    a_mm = {k: v for k, v in a.items() if k != "nnz"}
    prog = _matmul_prog(mesh, _plus_times(), 256, 256)
    yield "matmul", lower_hlo(prog, a_mm, *_b_triples_sds())

    def run():
        _select_prog(mesh, False, False)

    # repeated identical serve queries must not retrace the dispatch
    yield RetraceAudit(label="serve-repeat-query", first=run, again=run,
                       size=lambda: _select_prog.cache_info().currsize)


# --------------------------------------------------------------------------
# Sharded-B distribution strategies (exact collective budgets: the cost
# model may only ever choose between programs that are provably no
# chattier than declared — replicate 0, all_to_all 1, 2D pc−1)
# --------------------------------------------------------------------------

def _a2a_args_sds():
    import jax.numpy as jnp
    ar = _sds((_NSHARDS, _CAP), jnp.int32)
    av = _sds((_NSHARDS, _CAP), jnp.float32)
    b = {k: v for k, v in _coo_dict_sds().items() if k != "nnz"}
    bm = _sds((_NKEYS,), jnp.int32)
    return ar, av, b, bm


@probe_for("dist.matmul_all_to_all")
def _probe_dist_matmul_a2a():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _matmul_a2a_prog

    mesh = _abstract_mesh()
    ar, av, b, bm = _a2a_args_sds()
    prog = _matmul_a2a_prog(mesh, _plus_times(), 256, 64, 256, _NSHARDS)
    yield "a2a-exchange", lower_hlo(prog, ar, ar, av, b, bm,
                                    _sds((_NSHARDS + 1,), jnp.int32))

    def run():
        _matmul_a2a_prog(mesh, _plus_times(), 256, 64, 256, _NSHARDS)

    yield RetraceAudit(label="a2a-prog-cache", first=run, again=run,
                       size=lambda: _matmul_a2a_prog.cache_info().currsize)


@probe_for("dist.matmul_2d")
def _probe_dist_matmul_2d():
    from repro.core.dist_assoc import _matmul_ring_prog

    mesh = _abstract_mesh()
    a = {k: v for k, v in _coo_dict_sds().items() if k != "nnz"}
    # 2×4 grid over the 8-shard mesh: exactly pc−1 = 3 ring ppermutes
    prog = _matmul_ring_prog(mesh, _plus_times(), 2, 4, 256, 256)
    yield "ring-2x4", lower_hlo(prog, a, a)

    def run():
        _matmul_ring_prog(mesh, _plus_times(), 2, 4, 256, 256)

    yield RetraceAudit(label="ring-prog-cache", first=run, again=run,
                       size=lambda: _matmul_ring_prog.cache_info().currsize)


@probe_for("dist.matmul_reduce_all_to_all")
def _probe_dist_matmul_reduce_a2a():
    from repro.core.dist_assoc import _matmul_reduce_a2a_prog

    mesh = _abstract_mesh()
    ar, av, b, bm = _a2a_args_sds()
    for axis in (1, 0):
        prog = _matmul_reduce_a2a_prog(mesh, _plus_times(), 256, _NKEYS,
                                       axis)
        yield f"axis{axis}", lower_hlo(prog, ar, ar, av, b, bm)


@probe_for("dist.matmul_bsr")
def _probe_dist_matmul_bsr():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _matmul_bsr_prog

    mesh = _abstract_mesh()
    n_a, n_c, n_pairs = 2, 2, 16
    prog = _matmul_bsr_prog(mesh, _plus_times(), n_a, n_c, _NKEYS, _NKEYS,
                            256, "ref")
    ints = _sds((_NSHARDS, _CAP), jnp.int32)
    pint = _sds((_NSHARDS, n_pairs), jnp.int32)
    yield "bsr-one-program", lower_hlo(
        prog, _sds((_NSHARDS, _CAP), jnp.float32), ints, ints, ints,
        _sds((n_a, 128, 128), jnp.float32), pint, pint, pint,
        _sds((_NSHARDS, n_c, 2), jnp.int32))

    def run():
        _matmul_bsr_prog(mesh, _plus_times(), n_a, n_c, _NKEYS, _NKEYS,
                         256, "ref")

    yield RetraceAudit(label="bsr-prog-cache", first=run, again=run,
                       size=lambda: _matmul_bsr_prog.cache_info().currsize)


# --------------------------------------------------------------------------
# Dynamic ingest (repro.ingest): the LSM write/read path.  The append
# canonicalize and both merge-on-read programs must be zero-collective
# (delta batches are pre-routed to their owning row shard on host) and
# never densify (the overlay output is O(capb + capd), never O(nr·nc));
# small COO capacities over 4096-rank keyspaces keep the detector sharp.
# --------------------------------------------------------------------------

@probe_for("ingest.append")
def _probe_ingest_append():
    from repro.ingest.merge import _delta_canon_prog

    r, c, v = _b_triples_sds()
    yield "delta-canon", lower_hlo(_delta_canon_prog("sum"), r, c, v)

    def run():
        _delta_canon_prog("sum")

    yield RetraceAudit(label="append-prog-cache", first=run, again=run,
                       size=lambda: _delta_canon_prog.cache_info().currsize)


@probe_for("ingest.merge_read")
def _probe_ingest_merge_read():
    import jax.numpy as jnp
    from repro.ingest.merge import _merge_read_prog

    br, bc, bv = _b_triples_sds()
    dr, dc, dv = _b_triples_sds()
    prog = _merge_read_prog("sum")
    yield "overlay-merge", lower_hlo(prog, br, bc, bv, dr, dc, dv,
                                     _sds((), jnp.int32))

    def run():
        _merge_read_prog("sum")

    yield RetraceAudit(label="merge-prog-cache", first=run, again=run,
                       size=lambda: _merge_read_prog.cache_info().currsize)


@probe_for("ingest.dist_merge_read")
def _probe_ingest_dist_merge():
    import jax.numpy as jnp
    from repro.ingest.merge import _dist_merge_prog

    mesh = _abstract_mesh()
    a = _coo_dict_sds()
    d = _sds((_NSHARDS, _CAP), jnp.int32)
    dv = _sds((_NSHARDS, _CAP), jnp.float32)
    kmap = _sds((_NKEYS,), jnp.int32)
    for label, rerank in [("shard-local", False), ("reranked", True)]:
        prog = _dist_merge_prog(mesh, "sum", rerank)
        yield label, lower_hlo(prog, a, d, d, dv, kmap, kmap)

    def run():
        _dist_merge_prog(mesh, "sum", True)

    yield RetraceAudit(label="dist-merge-prog-cache", first=run, again=run,
                       size=lambda: _dist_merge_prog.cache_info().currsize)


@probe_for("DistAssoc.matmul_dense_vec")
def _probe_dist_matvec():
    import jax.numpy as jnp
    from repro.core.dist_assoc import _matvec_prog

    mesh = _abstract_mesh()
    prog = _matvec_prog(mesh, _plus_times(), _NKEYS, jnp.float32)
    yield "matvec", lower_hlo(prog, _sds((_NSHARDS, _CAP), jnp.int32),
                              _sds((_NSHARDS, _CAP), jnp.int32),
                              _sds((_NSHARDS, _CAP), jnp.float32),
                              _sds((_NKEYS,), jnp.float32))

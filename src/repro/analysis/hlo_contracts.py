"""Loop-aware HLO contract walker.

Grown out of ``launch/hlo_static.py``'s roofline analyzer: the same
regex parse of HLO text into computations and the same call-graph walk
from ENTRY with ``while``-trip multipliers, but aimed at *contract
verification* instead of FLOP/byte estimation.  Given the lowered text
of a jitted/shard_mapped program it reports:

* **collectives by family** — ``all-reduce`` / ``all-gather`` /
  ``reduce-scatter`` / ``all-to-all`` / ``collective-permute``, counted
  through ``call``/``while``/``conditional`` bodies with the loop trip
  count as a multiplier (a ``while`` of psums counts N×, exactly the
  case a naive text grep undercounts).
* **host round-trips** — ``infeed``/``outfeed``/``send``/``recv`` plus
  ``custom-call``s whose target is a host callback.  The partitioner's
  own ``Sharding``/``SPMDFullToShardShape``/``SPMDShardToFullShape``
  markers and TPU kernel custom-calls are *not* host transfers.
* **dense-intermediate footprint** — the largest non-parameter buffer
  materialized anywhere in the program, in elements.  Compared against
  a tile budget this is the densification detector: a sparse-COO
  program that suddenly builds an ``nr×nc`` dense intermediate jumps
  orders of magnitude above ``8 ×`` its biggest input.

Both HLO header formats are accepted: post-optimization text
(``name (args) -> result {``, what ``compiled.as_text()`` emits) and
pre-optimization text (bare ``name {`` headers, what
``jit(f).lower(...).as_text(dialect="hlo")`` emits).  Contract probes
use the latter — it needs no devices, so the checks run on any host.

``launch/hlo_static.py`` imports the parser from here; this module
deliberately depends on nothing but the stdlib (JAX is imported lazily
inside :func:`lower_hlo` only).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# --------------------------------------------------------------------------
# Shared HLO text parser (used by launch.hlo_static as well)
# --------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Post-opt header:  `%name (p: f32[2]) -> f32[2] {`   (ENTRY optional)
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# Pre-opt header:   `name {`  /  `ENTRY main.42 {`
_COMP_HDR_BARE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
# single-name references (`body=region_0.15`) and brace lists
# (`branch_computations={a, b}`) parse separately: a combined name class
# with `,`/space would swallow the following `, body=` keyword and drop
# the reference entirely.
_CALLED_ONE = re.compile(
    r"(?:condition|body|to_apply|fusion)=%?([\w\.\-_]+)")
_CALLED_LIST = re.compile(
    r"(?:called_computations|branch_computations)=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w\.\-_]+)")
_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


class Op:
    __slots__ = ("name", "shape", "kind", "rest", "operands", "called")

    def __init__(self, name, shape, kind, rest):
        self.name = name
        self.shape = shape
        self.kind = kind
        self.rest = rest
        self.operands = []
        self.called = []


def parse_hlo(text: str) -> Dict[str, List[Op]]:
    """Parse HLO text into ``{computation_name: [Op, ...]}``.

    Accepts both post-optimization headers (``name (...) -> ... {``) and
    pre-optimization bare headers (``name {``).  The ENTRY computation is
    aliased as ``__entry__``.
    """
    comps: Dict[str, List[Op]] = {}
    cur: Optional[str] = None
    entry_name = None
    for line in text.splitlines():
        s = line.strip()
        h = None
        if s.endswith("{") and " = " not in s:
            h = _COMP_HDR.match(s)
            if h is None and "->" not in s:
                h = _COMP_HDR_BARE.match(s)
        if h:
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry_name = cur
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, kind, rest = m.groups()
        op = Op(name, shape, kind, rest)
        # operand names: up to the closing paren of the op call
        paren = rest.split(")")[0]
        op.operands = _OPERAND.findall(paren)
        for cm in _CALLED_ONE.finditer(rest):
            op.called.append(cm.group(1))
        for cm in _CALLED_LIST.finditer(rest):
            for c in cm.group(1).split(","):
                c = c.strip().lstrip("%")
                if c:
                    op.called.append(c)
        comps[cur].append(op)
    if entry_name is not None and entry_name != "__entry__":
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """Trip count of a lax.scan while: max integer constant in condition."""
    best = 1
    for op in comps.get(cond_name, []):
        m = re.search(r"\bconstant\((\d+)\)", f"{op.kind}({op.rest}")
        if m:
            best = max(best, int(m.group(1)))
    return best


# --------------------------------------------------------------------------
# Contract analysis
# --------------------------------------------------------------------------

COLLECTIVE_FAMILIES = ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")

_HOST_TRANSFER_KINDS = ("infeed", "outfeed", "send", "recv")

# Partitioner bookkeeping and on-device kernel launches: custom-calls that
# are NOT host round-trips.
_DEVICE_LOCAL_TARGETS = frozenset({
    "Sharding", "SPMDFullToShardShape", "SPMDShardToFullShape",
    "AllocateBuffer", "MoveToDevice", "MoveToHost", "LayoutConstraint",
})
_DEVICE_LOCAL_TARGET_PREFIXES = ("tpu_custom_call", "mosaic", "triton",
                                 "cu_", "__cublas", "annotate")

# Buffers that are bookkeeping, not materialized intermediates.
_NON_MATERIAL_KINDS = frozenset({
    "parameter", "get-tuple-element", "tuple", "after-all", "token",
    "partition-id", "replica-id", "opt-barrier",
})


def _is_host_custom_call(rest: str) -> bool:
    m = _TARGET_RE.search(rest)
    if not m:
        return False
    target = m.group(1)
    if target in _DEVICE_LOCAL_TARGETS:
        return False
    if any(target.startswith(p) for p in _DEVICE_LOCAL_TARGET_PREFIXES):
        return False
    return "callback" in target.lower() or "host" in target.lower()


@dataclasses.dataclass
class ProgramReport:
    """What the contract walker found in one lowered program."""
    collective_counts: Dict[str, float]      # family -> trip-weighted count
    host_transfers: float                    # trip-weighted count
    max_intermediate_elems: int              # largest materialized buffer
    max_intermediate_op: str                 # "kind shape" of that buffer
    max_input_elems: int                     # largest ENTRY parameter
    while_trip_total: int                    # Σ trips over reachable whiles

    @property
    def collectives_total(self) -> float:
        return sum(self.collective_counts.values())

    def dense_budget_default(self) -> int:
        """Densification threshold when the contract declares none: a COO
        program may pad/stack/concat its inputs but never build anything
        ~O(nr·nc); 8× the biggest input (floor 64 Ki elems) separates the
        two regimes by orders of magnitude for the probe sizes used here."""
        return max(8 * self.max_input_elems, 1 << 16)

    def summary(self) -> str:
        colls = {k: v for k, v in self.collective_counts.items() if v}
        return (f"collectives={self.collectives_total:g} {colls or '{}'} "
                f"host_transfers={self.host_transfers:g} "
                f"max_intermediate={self.max_intermediate_elems} elems "
                f"({self.max_intermediate_op}) "
                f"max_input={self.max_input_elems} elems")


def analyze_program(text: str) -> ProgramReport:
    """Walk a lowered HLO program and report its contract-relevant facts.

    Unlike :func:`repro.launch.hlo_static.analyze` (a roofline estimator
    that only attributes HBM traffic at fusion boundaries), this walk
    counts collectives and host transfers through *every* reachable
    computation — ``call`` bodies included, which is where shard_map
    bodies land in pre-optimization HLO — and multiplies through
    ``while`` trip counts at every nesting level.
    """
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:  # fallback: biggest computation
        entry = max(comps.values(), key=len) if comps else []

    coll_counts: Dict[str, float] = defaultdict(float)
    host = 0.0
    max_inter = 0
    max_inter_op = ""
    trip_total = 0
    seen_stack: List[str] = []

    max_input = 0
    for op in entry:
        if op.kind == "parameter":
            e, _ = _shape_elems_bytes(op.shape)
            max_input = max(max_input, e)

    def walk(ops: List[Op], mult: float, is_entry: bool) -> None:
        nonlocal host, max_inter, max_inter_op, trip_total
        for op in ops:
            kind = op.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVE_FAMILIES and not kind.endswith("-done"):
                coll_counts[base] += mult
            if base in _HOST_TRANSFER_KINDS and not kind.endswith("-done"):
                host += mult
            elif kind == "custom-call" and _is_host_custom_call(op.rest):
                host += mult
            if kind not in _NON_MATERIAL_KINDS and not (
                    is_entry and kind == "parameter"):
                e, _ = _shape_elems_bytes(op.shape)
                if e > max_inter:
                    max_inter = e
                    max_inter_op = f"{kind} {op.shape.split('{')[0].strip()}"
            # Recurse through the whole call graph; `while` bodies get the
            # trip count as a multiplier, everything else inherits `mult`.
            if kind == "while":
                mc = re.search(r"condition=\{?%?([\w\.\-_]+)", op.rest)
                trips = _trip_count(comps, mc.group(1)) if mc else 1
                trip_total += trips
                for c in op.called:
                    if c in comps and c not in seen_stack:
                        seen_stack.append(c)
                        walk(comps[c], mult * trips, False)
                        seen_stack.pop()
            else:
                for c in op.called:
                    if c in comps and c not in seen_stack:
                        seen_stack.append(c)
                        walk(comps[c], mult, False)
                        seen_stack.pop()

    walk(entry, 1.0, True)
    return ProgramReport(
        collective_counts=dict(coll_counts),
        host_transfers=host,
        max_intermediate_elems=max_inter,
        max_intermediate_op=max_inter_op,
        max_input_elems=max_input,
        while_trip_total=trip_total,
    )


def lower_hlo(fn, *args, **kwargs) -> str:
    """Lower a (jitted) function to pre-optimization HLO text.

    Works without any devices: pass ``jax.ShapeDtypeStruct`` arguments
    and (for shard_map programs) build the jit over an ``AbstractMesh``.
    """
    import jax

    if not hasattr(fn, "lower"):
        fn = jax.jit(fn)
    lowered = fn.lower(*args, **kwargs)
    try:
        return lowered.as_text(dialect="hlo")
    except TypeError:  # older jax: no dialect kwarg
        return lowered.as_text()


def analyze_fn(fn, *args, **kwargs) -> ProgramReport:
    """Convenience: lower ``fn(*args)`` and analyze the program."""
    return analyze_program(lower_hlo(fn, *args, **kwargs))

"""d4mlint — AST lint for host/device anti-patterns.

The HLO contract checker (:mod:`~repro.analysis.hlo_contracts`) catches
what a *compiled* program does; this pass catches what never reaches the
compiler: host-side Python that silently materializes traced values or
serializes over nnz.  Rules, each an ``ast`` walk over device scopes —
functions decorated with ``jax.jit``/``shard_map`` (or passed to
``shard_map(...)``/``pallas_call(...)``), including their nested defs:

* **D4M101** — host materialization of a traced value inside a device
  scope: ``np.asarray`` / ``np.array`` / ``np.<anything>`` calls on
  names bound inside the scope.  NumPy on a tracer either fails or
  silently constant-folds a transfer; device code uses ``jnp``.
* **D4M102** — explicit host round-trips in device scope:
  ``jax.device_get`` / ``.block_until_ready()`` / ``.item()`` /
  ``float()`` / ``int()`` on expressions.  These synchronize the stream
  the contract checker proves we never need.
* **D4M103** — a Python ``for``/``while`` loop over nnz-like bounds
  (``range(... nnz ...)`` / ``range(len(rows))`` …) in a device scope:
  serializes a vectorizable sweep into O(nnz) dispatches/trace length.
* **D4M104** — a kernel ``ops.py`` (``src/repro/kernels/*/ops.py``)
  missing the ref/interpret/pallas dispatch triple: every kernel entry
  must be runnable on CPU (``ref``), debuggable (``interpret``), and
  fast (``pallas``).

Suppressions::

    # d4mlint: disable=D4M101,D4M103     (file-level, any line)
    some_call()  # d4mlint: ignore[D4M102]   (this line only)

Run it: ``python -m repro.analysis.lint [paths...]`` (defaults to
``src/repro``); exits 1 on findings.  ``tools/d4mcheck`` runs it after
the contract sweep, and CI fails on any new finding.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Set

RULES = {
    "D4M101": "numpy host materialization inside a device scope",
    "D4M102": "host round-trip (device_get/block_until_ready/item) "
              "inside a device scope",
    "D4M103": "Python loop over nnz inside a device scope",
    "D4M104": "kernel ops.py missing the ref/interpret/pallas "
              "dispatch triple",
}

_DISABLE_RE = re.compile(r"#\s*d4mlint:\s*disable=([\w,\s]+)")
_IGNORE_RE = re.compile(r"#\s*d4mlint:\s*ignore\[([\w,\s]+)\]")
_NNZ_NAME = re.compile(r"nnz|n_nz|num_nonzero", re.I)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# --------------------------------------------------------------------------
# Device-scope discovery
# --------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``jax.jit`` -> "jax.jit")."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return _dotted(node.func)
    return ""


_DEVICE_DECOS = ("jit", "shard_map", "pmap", "vmap_of_jit", "kernel")


def _is_device_decorator(deco: ast.AST) -> bool:
    name = _dotted(deco)
    last = name.rsplit(".", 1)[-1]
    if last in ("jit", "shard_map", "pmap"):
        return True
    # functools.partial(shard_map, ...) / partial(jax.jit, ...)
    if isinstance(deco, ast.Call) and _dotted(deco.func).endswith("partial"):
        for arg in deco.args[:1]:
            if _dotted(arg).rsplit(".", 1)[-1] in ("jit", "shard_map",
                                                   "pmap"):
                return True
    return False


def _collect_device_scopes(tree: ast.Module) -> Set[ast.AST]:
    """Function defs whose body traces on device: decorated with
    jit/shard_map (incl. via partial) or passed to shard_map()/
    pallas_call(); nested defs inherit the scope."""
    scopes: Set[ast.AST] = set()
    defs_by_name = {}

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs_by_name.setdefault(node.name, node)
            if any(_is_device_decorator(d) for d in node.decorator_list):
                scopes.add(node)
        elif isinstance(node, ast.Call):
            callee = _dotted(node.func).rsplit(".", 1)[-1]
            if callee in ("shard_map", "pallas_call"):
                for arg in node.args[:1]:
                    target = defs_by_name.get(_dotted(arg))
                    if target is not None:
                        scopes.add(target)
                    elif isinstance(arg, ast.Lambda):
                        scopes.add(arg)

    # close over nested function defs
    out: Set[ast.AST] = set()
    for scope in scopes:
        for node in ast.walk(scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                out.add(node)
    return out


# --------------------------------------------------------------------------
# Rules
# --------------------------------------------------------------------------

def _scope_findings(scope: ast.AST, path: str) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(scope):
        if isinstance(node, ast.Call):
            name = _dotted(node.func)
            parts = name.split(".")
            if parts[0] in ("np", "numpy") and len(parts) > 1:
                out.append(Finding(
                    path, node.lineno, "D4M101",
                    f"`{name}(...)` on (potentially traced) values — "
                    f"use jnp inside jit/shard_map bodies"))
            last = parts[-1]
            if last in ("device_get", "block_until_ready", "item"):
                out.append(Finding(
                    path, node.lineno, "D4M102",
                    f"`{name}(...)` forces a host round-trip inside a "
                    f"device scope"))
        elif isinstance(node, (ast.For, ast.While)):
            bound = ""
            if isinstance(node, ast.For) and isinstance(node.iter, ast.Call):
                if _dotted(node.iter.func).rsplit(".", 1)[-1] == "range":
                    bound = ast.dump(node.iter)
            elif isinstance(node, ast.While):
                bound = ast.dump(node.test)
            if bound and _NNZ_NAME.search(bound):
                out.append(Finding(
                    path, node.lineno, "D4M103",
                    "Python loop bounded by nnz in a device scope — "
                    "O(nnz) trace length; vectorize or lax.scan"))
    return out


def _kernel_triple_findings(tree: ast.Module, text: str,
                            path: str) -> List[Finding]:
    """D4M104: kernels/*/ops.py must dispatch ref AND interpret AND
    pallas (string-literal impl names in the module)."""
    p = Path(path)
    if p.name != "ops.py" or "kernels" not in p.parts:
        return []
    impls = set(re.findall(r'"(ref|interpret|pallas)"', text))
    missing = {"ref", "interpret", "pallas"} - impls
    if missing:
        return [Finding(
            path, 1, "D4M104",
            f"kernel dispatch triple incomplete: no "
            f"{'/'.join(sorted(missing))} path (every kernel needs "
            f"ref + interpret + pallas)")]
    return []


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def _suppressions(text: str):
    disabled: Set[str] = set()
    line_ignores = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _DISABLE_RE.search(line)
        if m:
            disabled.update(r.strip() for r in m.group(1).split(",")
                            if r.strip())
        m = _IGNORE_RE.search(line)
        if m:
            line_ignores[i] = {r.strip() for r in m.group(1).split(",")
                               if r.strip()}
    return disabled, line_ignores


def lint_file(path: str, text: Optional[str] = None) -> List[Finding]:
    if text is None:
        text = Path(path).read_text()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, "D4M000",
                        f"syntax error: {e.msg}")]
    disabled, line_ignores = _suppressions(text)

    findings: List[Finding] = []
    seen = set()
    for scope in _collect_device_scopes(tree):
        for f in _scope_findings(scope, path):
            key = (f.line, f.rule, f.message)
            if key not in seen:          # nested scopes overlap
                seen.add(key)
                findings.append(f)
    findings.extend(_kernel_triple_findings(tree, text, path))

    return sorted(
        (f for f in findings
         if f.rule not in disabled
         and f.rule not in line_ignores.get(f.line, ())),
        key=lambda f: (f.line, f.rule))


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint files / directory trees (``*.py``, recursively)."""
    out: List[Finding] = []
    for p in paths:
        path = Path(p)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            out.extend(lint_file(str(f)))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(argv if argv is not None else sys.argv[1:])
    paths = args or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    print(f"d4mlint: {len(findings)} finding(s) in "
          f"{', '.join(paths)}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""``@contract`` — declared performance invariants, and their verifier.

The decorator attaches a :class:`Contract` to a public API function and
registers it by qualified name::

    @contract(collectives=0, densify=False, host_transfers=0)
    def __getitem__(self, key): ...

A contract makes three kinds of claim about every program the API
compiles:

* ``collectives=N`` — the trip-weighted count of psum-family ops
  (all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute) is exactly ``N``.  ``None`` means unchecked.
* ``host_transfers=N`` — infeed/outfeed/send/recv/host-callback count
  is exactly ``N`` (``None`` = unchecked).
* ``densify=False`` — no intermediate buffer exceeds the dense budget
  (``dense_budget`` elems if given, else ``8 ×`` the largest input,
  floor 64 Ki — see :meth:`ProgramReport.dense_budget_default`).

Verification is *static*: a probe (see :mod:`repro.analysis.probes`)
lowers the compiled program(s) behind the entry point on an
``AbstractMesh`` — no devices, no TPU, nothing executes — and the
:mod:`~repro.analysis.hlo_contracts` walker checks the claims against
the HLO.  Probes may also return ``RetraceAudit`` items asserting the
entry point's trace cache is keyed correctly (a second structurally
identical call must not recompile).

The decorator itself costs one attribute write at import time; the
wrapped function is returned unchanged (no runtime indirection on hot
paths).  This module depends on nothing outside the stdlib so `core`
can import it freely.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from .hlo_contracts import ProgramReport, analyze_program

CONTRACT_ATTR = "__d4m_contract__"

#: qualified entry name -> Contract
CONTRACT_REGISTRY: Dict[str, "Contract"] = {}


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declared invariants for one API entry point."""
    name: str                                 # registry key (qualname)
    collectives: Optional[int] = None         # exact trip-weighted count
    host_transfers: Optional[int] = 0         # exact count (None=unchecked)
    densify: bool = False                     # True = allowed to densify
    dense_budget: Optional[int] = None        # elems; None = derived default
    note: str = ""                            # one-liner for reports

    def check(self, report: ProgramReport,
              program: str = "") -> List["Violation"]:
        """Check one lowered program's report against this contract."""
        out: List[Violation] = []
        where = f"{self.name}" + (f"[{program}]" if program else "")
        if self.collectives is not None:
            got = report.collectives_total
            if got != self.collectives:
                fams = {k: v for k, v in report.collective_counts.items() if v}
                out.append(Violation(
                    entry=where, kind="collectives",
                    message=(f"expected exactly {self.collectives} "
                             f"collective(s), compiled program has {got:g} "
                             f"{fams or ''}")))
        if self.host_transfers is not None:
            if report.host_transfers != self.host_transfers:
                out.append(Violation(
                    entry=where, kind="host_transfers",
                    message=(f"expected {self.host_transfers} host "
                             f"round-trip(s), compiled program has "
                             f"{report.host_transfers:g}")))
        if not self.densify:
            budget = (self.dense_budget if self.dense_budget is not None
                      else report.dense_budget_default())
            if report.max_intermediate_elems > budget:
                out.append(Violation(
                    entry=where, kind="densify",
                    message=(f"dense intermediate: "
                             f"{report.max_intermediate_elems} elems "
                             f"({report.max_intermediate_op}) exceeds the "
                             f"tile budget of {budget} elems — the program "
                             f"densifies")))
        return out


@dataclasses.dataclass(frozen=True)
class Violation:
    entry: str
    kind: str          # "collectives" | "host_transfers" | "densify" |
                       # "recompile" | "probe"
    message: str

    def __str__(self) -> str:
        return f"{self.entry}: [{self.kind}] {self.message}"


@dataclasses.dataclass(frozen=True)
class RetraceAudit:
    """A probe's recompilation claim: ``calls()`` exercises the entry's
    trace cache twice with equal-keyed arguments; the cache must not grow
    between the first and second round (``sizes()`` -> int)."""
    label: str
    first: Callable[[], None]
    again: Callable[[], None]
    size: Callable[[], int]


def contract(collectives: Optional[int] = None,
             host_transfers: Optional[int] = 0,
             densify: bool = False,
             dense_budget: Optional[int] = None,
             note: str = "",
             name: Optional[str] = None):
    """Declare invariants on an API entry point (registers it for
    ``tools/d4mcheck`` and the test sweep; returns ``fn`` unchanged)."""
    def deco(fn):
        key = name or getattr(fn, "__qualname__", fn.__name__)
        c = Contract(name=key, collectives=collectives,
                     host_transfers=host_transfers, densify=densify,
                     dense_budget=dense_budget, note=note)
        setattr(fn, CONTRACT_ATTR, c)
        CONTRACT_REGISTRY[key] = c
        return fn
    return deco


def _ensure_registry() -> None:
    """Import the decorated modules so their contracts register."""
    import repro.core.assoc_tensor   # noqa: F401
    import repro.core.dist_assoc     # noqa: F401
    import repro.core.spgemm         # noqa: F401
    import repro.ingest.merge        # noqa: F401
    import repro.serve.engine        # noqa: F401


def verify_entry(name: str) -> List[Violation]:
    """Statically verify one registered entry point.

    Lowers each program its probe yields and checks the contract; also
    runs the probe's retrace audits.  Returns all violations (empty list
    = contract holds).
    """
    from . import probes

    _ensure_registry()
    c = CONTRACT_REGISTRY.get(name)
    if c is None:
        raise KeyError(f"no @contract registered under {name!r}")
    probe = probes.PROBES.get(name)
    if probe is None:
        return [Violation(entry=name, kind="probe",
                          message="no probe registered — contract is "
                                  "declared but unverifiable")]
    out: List[Violation] = []
    for item in probe():
        if isinstance(item, RetraceAudit):
            item.first()
            before = item.size()
            item.again()
            after = item.size()
            if after != before:
                out.append(Violation(
                    entry=f"{name}[{item.label}]", kind="recompile",
                    message=(f"trace cache grew {before} -> {after} on a "
                             f"structurally identical repeat call — the "
                             f"cache key is wrong (recompilation on every "
                             f"call)")))
            continue
        label, hlo_text = item
        out.extend(c.check(analyze_program(hlo_text), program=label))
    return out


def verify_all(names: Optional[List[str]] = None,
               ) -> Dict[str, List[Violation]]:
    """Sweep the whole registry (or the given subset).

    Returns ``{entry_name: [violations...]}`` with an entry for every
    checked name, so callers can report clean passes too.
    """
    _ensure_registry()
    if names is None:
        names = sorted(CONTRACT_REGISTRY)
    return {n: verify_entry(n) for n in names}

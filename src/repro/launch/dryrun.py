import os
# while-loop LICM on the CPU placeholder backend hoists per-layer converts /
# repartitions of scan-stacked buffers OUT of the loop, materializing whole
# [L, ...] copies (observed: +2.5× peak memory).  The TPU backend schedules
# these in-loop; disabling the pass makes the CPU memory analysis faithful.
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_disable_hlo_passes=while-loop-invariant-code-motion"
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be imported/run before any other jax-touching import — the two lines
above pin 512 placeholder host devices before jax locks the device count.

Usage (one cell per process; the sweep driver is benchmarks/dryrun_sweep.py):

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen3-1.7b --shape train_4k [--multi-pod] \
        [--out results.jsonl] [--fsdp/--no-fsdp] [--policy fp32|bf16|q8]

Emits one JSON record: compile status, memory_analysis, cost_analysis,
per-kind collective bytes, the three roofline terms, MODEL_FLOPS ratio.
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fsdp: bool = True, policy: str = "", extra: str = "",
             overrides: str = "") -> dict:
    """``overrides``: comma-separated knobs for §Perf hillclimbing, e.g.
    ``parallelism=fsdp_only,attn_chunk=1024,seq_parallel=1,
    capacity_factor=1.0,residual_budget=2e9,remat=none``."""
    import jax
    from repro.configs import get_config, shapes_for
    from repro.launch import hlo_analysis as HA
    from repro.launch import hlo_static as HS
    from repro.launch import steps as S
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = {s.name: s for s in shapes_for(arch)}.get(shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "fsdp": fsdp, "policy": policy or None, "extra": extra or None}
    if shape is None:
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k needs sub-quadratic attention; "
                         "this is a pure full-attention arch (see DESIGN.md)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    opts = S.default_train_options(cfg)
    if policy:
        opts = S.TrainOptions(**{**opts.__dict__, "opt_state_policy": policy})
    if not fsdp:
        opts = S.TrainOptions(**{**opts.__dict__, "fsdp": False})

    # §Perf knobs
    cfg_over, opt_over = {}, {}
    for kv in (overrides.split(",") if overrides else []):
        k, v = kv.split("=")
        if k in ("parallelism", "opt_state_policy", "grad_accum_dtype"):
            opt_over[k] = v
        elif k in ("microbatch",):
            opt_over[k] = int(v)
        elif k == "residual_budget":
            opt_over[k] = float(v)
        elif k in ("attn_chunk", "loss_chunk", "prefill_chunk"):
            cfg_over[k] = int(v)
        elif k == "seq_parallel":
            cfg_over[k] = bool(int(v))
        elif k == "remat":
            cfg_over[k] = v
        elif k == "capacity_factor":
            cfg_over["moe"] = {**cfg.moe, "capacity_factor": float(v)}
        elif k == "window":
            cfg_over[k] = int(v) if int(v) > 0 else None
        elif k == "moe_sharding":
            cfg_over[k] = v
        else:
            raise KeyError(f"unknown override {k}")
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    if opt_over:
        opts = S.TrainOptions(**{**opts.__dict__, **opt_over})
    if overrides:
        rec["extra"] = ((extra + ";") if extra else "") + overrides

    t0 = time.time()
    if hasattr(jax, "set_mesh"):  # newer jax; 0.4.x relies on `with mesh:`
        jax.set_mesh(mesh)
    with mesh:
        jitted, args = S.build_jitted(cfg, shape, mesh, opts)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    # static analysis with while-trip multipliers (cost_analysis counts scan
    # bodies once — undercounting by ~n_layers; see hlo_static docstring)
    st = HS.analyze(hlo)
    coll = {"per_kind": st["collective_bytes"],
            "counts": st["collective_counts"],
            "total": st["collective_total"]}
    terms = HA.roofline_terms(
        {"flops": st["flops"], "bytes accessed": st["hbm_bytes"]},
        coll, n_chips)
    n_total = S.est_param_count(cfg)
    n_active = HA.active_param_count(cfg, n_total)
    mflops = HA.model_flops(cfg, shape, n_active)
    hlo_flops_total = terms["hlo_flops_per_chip"] * n_chips

    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0),
            # The CPU placeholder backend has no native bf16: every bf16 dot
            # and its activation chain is upcast to f32, inflating temp by
            # up to 2× vs the TPU compile.  Arguments (params/opt/caches)
            # keep their true dtypes.  tpu_adjusted halves temps — an
            # *upper bound* on the TPU-side peak is peak_bytes, a best
            # estimate is tpu_adjusted_bytes.
            "tpu_adjusted_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0) // 2,
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if k in cost},
        "collectives": coll,
        "roofline": terms,
        "model_flops_total": mflops,
        "hlo_flops_total": hlo_flops_total,
        "useful_flops_ratio": (mflops / hlo_flops_total
                               if hlo_flops_total else None),
        "params_total": n_total,
        "params_active": n_active,
    })
    rec["dominant"] = HA.dominant_term(terms)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--policy", default="")
    ap.add_argument("--out", default="")
    ap.add_argument("--extra", default="", help="free-form tag for §Perf runs")
    ap.add_argument("--overrides", default="",
                    help="comma-separated cfg/opts knobs (see run_cell)")
    args = ap.parse_args()

    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       fsdp=args.fsdp, policy=args.policy, extra=args.extra,
                       overrides=args.overrides)
    except Exception as exc:  # noqa: BLE001 — record the failure, don't die
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": repr(exc),
               "trace": traceback.format_exc()[-2000:]}
    line = json.dumps(rec)
    if args.out:
        with open(args.out, "a") as f:
            f.write(line + "\n")
    print(line[:600] if rec.get("status") == "ok" else line[:3000])
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())

"""Logical-axis → mesh-axis translation (TP / FSDP / EP / SP rules).

Model code annotates every parameter with logical axis names (see
``repro.models.layers``); this module turns those into ``PartitionSpec``s
for a concrete mesh, checking divisibility so non-shardable dims degrade to
replication instead of failing at compile (e.g. minicpm's prime-ish vocab
122753, mamba2-130m's 24 SSM heads on a 16-way model axis).

Policy (baseline — §Perf iterates on it):
  * TP over ``model``: heads/kv/mlp/vocab (+ expert hidden when
    ``moe_sharding == "tp"``); EP over ``model``: expert axis when
    ``moe_sharding == "ep"``.
  * FSDP over ``data``: the "embed" axis of every ≥2-D parameter — combined
    with TP this fully shards large weights over the whole pod; XLA inserts
    the per-layer all-gathers inside the scan (ZeRO-3 behaviour).
  * DP over ``("pod", "data")``: batch dims of inputs/activations; the
    ``pod`` axis never shards parameters (gradient all-reduce crosses DCI
    once per step; parameter collectives stay on ICI).
  * Optimizer moments inherit the param spec leaf-wise (q8 scales drop the
    last axis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import batch_axes


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def logical_rules(cfg, *, fsdp: bool = True, fsdp_over_pod: bool = False,
                  parallelism: str = "2d") -> Dict[str, Any]:
    ep = (cfg.moe_sharding == "ep") if cfg.moe else False
    embed = None
    if fsdp:
        # ≥300B models must shard parameters across pods too (ZeRO over
        # DCI): a 671B AdamW state cannot fit one pod's aggregate HBM.
        embed = ("pod", "data") if fsdp_over_pod else "data"
    if parallelism == "fsdp_only":
        # §Perf: for small models TP's per-layer activation all-reduces
        # dominate; fold the model axis into data parallelism instead —
        # params fully sharded over BOTH axes, zero TP collectives.
        return {
            "layers": None,
            "embed": ("data", "model") if fsdp else None,
            "heads": None, "kv": None, "mlp": None, "vocab": None,
            "expert": "model" if ep else None, "expert_mlp": None,
            None: None,
        }
    ep2d = bool(cfg.moe) and cfg.moe_sharding == "ep2d"
    # 2-D expert parallelism: experts over data×model (DeepSeek's EP-256
    # deployment) — each chip OWNS its experts outright, so no per-layer
    # FSDP weight gather; dispatch becomes an all-to-all.
    expert_axis: Any = (("data", "model") if ep2d
                        else ("model" if ep else None))
    return {
        "layers": None,
        "embed": embed,
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": expert_axis,
        "expert_mlp": "model" if not (ep or ep2d) else None,
        None: None,
    }


def spec_for_shape(shape: Tuple[int, ...], logical: Tuple, rules, mesh: Mesh,
                   *, keep_1d_replicated: bool = True) -> P:
    """Translate one logical tuple, dropping axes that don't divide."""
    if len(logical) != len(shape):
        raise ValueError(f"logical {logical} vs shape {shape}")
    if keep_1d_replicated and len(shape) < 2:
        return P()
    out = []
    used = set()
    for dim, name in zip(shape, logical):
        mesh_axis = rules.get(name)
        if isinstance(mesh_axis, tuple):  # e.g. FSDP over ("pod", "data")
            axes = tuple(a for a in mesh_axis if a in mesh.axis_names)
            sz = 1
            for a in axes:
                sz *= _axis_size(mesh, a)
            if axes and not (set(axes) & used) and dim % sz == 0:
                out.append(axes)
                used.update(axes)
            elif axes and dim % _axis_size(mesh, axes[-1]) == 0 \
                    and axes[-1] not in used:
                out.append(axes[-1])
                used.add(axes[-1])
            else:
                out.append(None)
            continue
        if (mesh_axis is None or mesh_axis in used
                or dim % _axis_size(mesh, mesh_axis) != 0):
            out.append(None)
        else:
            out.append(mesh_axis)
            used.add(mesh_axis)
    return P(*out)


def param_specs(shapes_tree, logical_tree, cfg, mesh: Mesh, *,
                fsdp: bool = True, fsdp_over_pod: bool = False,
                parallelism: str = "2d"):
    """PartitionSpec pytree for params given shapes + logical annotations."""
    rules = logical_rules(cfg, fsdp=fsdp, fsdp_over_pod=fsdp_over_pod,
                          parallelism=parallelism)

    def one(logical, shape_like):
        shape = tuple(shape_like.shape)
        return spec_for_shape(shape, tuple(logical), rules, mesh)

    # logical_tree drives flattening: its leaves are tuples of axis names,
    # which jax would otherwise treat as internal nodes.
    return jax.tree.map(one, logical_tree, shapes_tree,
                        is_leaf=lambda t: isinstance(t, tuple) and all(
                            isinstance(x, (str, type(None))) for x in t))


def shard_tree(shapes_tree, specs_tree, mesh: Mesh):
    return jax.tree.map(lambda _, s: NamedSharding(mesh, s),
                        shapes_tree, specs_tree,
                        is_leaf=lambda t: isinstance(t, P))


def batch_spec(global_batch: int, mesh: Mesh, ndim: int = 2,
               parallelism: str = "2d") -> P:
    """Shard the batch dim over (pod, data) when divisible, else degrade.

    fsdp_only parallelism additionally folds `model` into the batch axes.
    """
    axes = [a for a in batch_axes(mesh)]
    if parallelism == "fsdp_only":
        axes.append("model")
    while axes and global_batch % int(np.prod([_axis_size(mesh, a) for a in axes])):
        axes.pop()  # drop innermost first (pod kept longest? drop data first)
    b_axes = tuple(axes) if axes else None
    rest = [None] * (ndim - 1)
    return P(b_axes, *rest)


def opt_state_specs(param_specs_tree, opt_state_shapes):
    """Optimizer-state specs mirroring param specs.

    m/v inherit the param's spec; q8 scale tensors ("s") drop the last axis
    spec entry; count is replicated.
    """
    def mom(ps, st):
        if isinstance(st, dict) and set(st) == {"q", "s"}:
            s_spec = P(*ps[:-1], None) if len(ps) else P()
            return {"q": ps, "s": s_spec}
        return ps

    return {
        "m": jax.tree.map(mom, param_specs_tree, opt_state_shapes["m"],
                          is_leaf=lambda t: isinstance(t, P)),
        "v": jax.tree.map(mom, param_specs_tree, opt_state_shapes["v"],
                          is_leaf=lambda t: isinstance(t, P)),
        "count": P(),
    }


# ---------------------------------------------------------------------------
# cache specs (decode/prefill)
# ---------------------------------------------------------------------------

def cache_specs(cfg, cache_shapes, mesh: Mesh, global_batch: int):
    """Shardings for the decode caches built by models.model.init_cache.

    Leaves look like [L, B, S, ...]: batch over (pod, data) when divisible;
    the trailing feature axis over `model` when divisible (kv heads for GQA,
    the compressed latent for MLA — which is what makes a 61-layer 32k MLA
    cache fit); `len` counters replicated.
    """
    b_ax = batch_spec(global_batch, mesh, ndim=1)[0]
    model_size = _axis_size(mesh, "model")

    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        if name == "len":
            return P()
        if name in ("k", "v"):  # [L, B, S, KV, dh]
            kv, dh = shape[3], shape[4]
            if kv % model_size == 0:
                return P(None, b_ax, None, "model", None)
            if dh % model_size == 0:
                # head-dim-sharded cache: scores/PV contract dh → one small
                # psum per step, but the cache memory divides by |model|
                # (crucial when kv_heads < |model|, e.g. GQA kv=2..8)
                return P(None, b_ax, None, None, "model")
            return P(None, b_ax, None, None, None)
        if name == "ckv":       # [L, B, S, dc] — shard the latent (MLA)
            return P(None, b_ax, None,
                     "model" if shape[3] % model_size == 0 else None)
        if name == "kr":        # [L, B, S, dr]
            return P(None, b_ax, None,
                     "model" if shape[3] % model_size == 0 else None)
        if name == "h":         # [L, B, H, N, P] — SSM state
            hshard = ("model" if (cfg.shard_ssm_heads and
                                  shape[2] % model_size == 0) else None)
            return P(None, b_ax, hshard, None, None)
        if name in ("conv_x", "conv_bc"):  # [L, B, K-1, C]
            c = shape[3]
            cshard = "model" if (name == "conv_x" and c % model_size == 0) else None
            return P(None, b_ax, None, cshard)
        # fallback: batch on axis 1 if it matches
        return P(*([None] + [b_ax] + [None] * (len(shape) - 2)))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)

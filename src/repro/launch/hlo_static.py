"""Static HLO analyzer with loop-trip multipliers.

``compiled.cost_analysis()`` (and any naive text scan) counts a ``while``
body ONCE — but every layer stack here is a ``lax.scan``, so FLOPs, HBM
bytes and collective bytes would be undercounted by ~n_layers.  This module
parses the post-SPMD HLO text into computations, walks the call graph from
ENTRY, multiplies through ``while`` trip counts (recovered from the loop
condition's comparison constant), and accumulates:

  * ``flops``            — 2·M·N·K for every dot (+ batch dims), the
                           dominant term; convolutions approximated the same
                           way (window product as K).
  * ``hbm_bytes``        — Σ (operand + result bytes) over *HBM-boundary*
                           ops: fusions, dots, collectives, copies,
                           gather/scatter/dynamic-slice/DUS, sort, reduce.
                           Ops inside fusion bodies don't touch HBM and are
                           excluded (roofline convention).
  * ``collective_bytes`` — per-kind result bytes × ring algorithm factor.

Shapes are post-SPMD = per-device, so all outputs are per-chip quantities.
This is a structural estimate (buffer reuse and fusion boundaries are
approximations) — exactly the granularity a dry-run roofline needs.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# The HLO text parser (computation split, op regexes, shape sizing, while
# trip-count recovery) is shared with the contract checker; it lives in
# repro.analysis.hlo_contracts and accepts both post-optimization headers
# (what this roofline path consumes) and pre-optimization bare headers.
from repro.analysis.hlo_contracts import (_DTYPE_BYTES, _SHAPE_RE, Op,  # noqa: F401
                                          _shape_dims, _shape_elems_bytes,
                                          _trip_count, parse_hlo)

_ALGO_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}
_COLL_BASE = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")

_HBM_OPS_PREFIX = (
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "sort", "reduce", "transpose",
    "broadcast", "iota", "concatenate", "slice", "reverse", "pad", "select",
    "add", "multiply", "subtract", "divide", "exponential", "rsqrt", "tanh",
    "convert", "compare", "maximum", "minimum", "log", "custom-call",
) + _COLL_BASE


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contracting dims from lhs
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if m and op.operands:
        lhs_shape = shapes.get(op.operands[0], "")
        lhs_dims = _shape_dims(lhs_shape)
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    rhs = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
    k = 1
    for d in _shape_dims(rhs):
        k *= d
    return 2.0 * out_elems * max(k, 1) / max(out_dims[-1] if out_dims else 1, 1)


def analyze(text: str, detail: bool = False) -> Dict[str, float]:
    """detail=True adds ``top_hbm``: the 15 largest HBM-traffic op groups
    keyed by (kind, result shape) — used by §Perf to attribute the memory
    term (e.g. how much is attention-score traffic)."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:  # fallback: biggest computation
        entry = max(comps.values(), key=len) if comps else []

    flops = 0.0
    hbm = 0.0
    hbm_by: Dict[str, float] = defaultdict(float)
    coll: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)
    fusion_bodies = set()
    for cs in comps.values():
        for op in cs:
            if op.kind == "fusion":
                fusion_bodies.update(op.called)

    seen_stack = []

    def walk(ops: List[Op], mult: float, in_fusion: bool):
        nonlocal flops, hbm
        shapes = {op.name: op.shape for op in ops}
        for op in ops:
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if kind == "dot":
                flops += mult * _dot_flops(op, shapes)
            elif kind == "convolution":
                flops += mult * _conv_flops(op, shapes)
            if not in_fusion:
                if base in _COLL_BASE and not kind.endswith("-done"):
                    _, b = _shape_elems_bytes(op.shape)
                    coll[base] += mult * b * _ALGO_FACTOR[base]
                    coll_counts[base] += mult
                if (not kind.endswith("-done")
                        and any(kind.startswith(p) for p in _HBM_OPS_PREFIX)):
                    _, ob = _shape_elems_bytes(op.shape)
                    opb = [_shape_elems_bytes(shapes.get(o, ""))[1]
                           for o in op.operands]
                    if kind == "dynamic-update-slice":
                        # in-place: traffic = 2 × update slice, not the buffer
                        upd = opb[1] if len(opb) > 1 else 0
                        contrib = mult * 2 * upd
                    elif kind == "dynamic-slice":
                        contrib = mult * 2 * ob
                    elif kind == "copy":
                        contrib = mult * 2 * ob
                    elif kind == "fusion" and "dynamic-update-slice" in op.name:
                        # in-place update fusion: result aliases the big
                        # operand; count only the non-aliased operands twice
                        big = max(opb) if opb else 0
                        contrib = mult * 2 * (sum(opb) - big)
                    else:
                        contrib = mult * (ob + sum(opb))
                    hbm += contrib
                    if detail:
                        shp = op.shape.split("{")[0].strip()
                        hbm_by[f"{kind}:{shp}"] += contrib
            # recurse
            if kind == "while":
                body, cond = None, None
                mb = re.search(r"body=%?([\w\.\-_]+)", op.rest)
                mc = re.search(r"condition=%?([\w\.\-_]+)", op.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                trips = _trip_count(comps, cond) if cond else 1
                if body in comps and body not in seen_stack:
                    seen_stack.append(body)
                    walk(comps[body], mult * trips, in_fusion)
                    seen_stack.pop()
            elif kind == "fusion":
                for c in op.called:
                    if c in comps and c not in seen_stack:
                        seen_stack.append(c)
                        walk(comps[c], mult, True)
                        seen_stack.pop()
            elif kind in ("call", "conditional", "map", "reduce", "sort",
                          "scatter", "reduce-window", "select-and-scatter",
                          "custom-call", "all-reduce", "reduce-scatter"):
                for c in op.called:
                    if c in comps and c not in seen_stack:
                        seen_stack.append(c)
                        walk(comps[c], mult, True)
                        seen_stack.pop()

    walk(entry, 1.0, False)
    out = {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_counts": dict(coll_counts),
        "collective_total": sum(coll.values()),
    }
    if detail:
        out["top_hbm"] = sorted(hbm_by.items(), key=lambda kv: -kv[1])[:15]
    return out

"""Serving driver: prefill + batched decode with static-shape caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Demonstrates the inference path the decode_* dry-run cells lower: one
prefill builds the KV/SSM caches at fixed capacity, then a jitted
single-token step is iterated.  Request batching is static-shape (padded
slots), the production pattern for TPU serving.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import model as M
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(remat="none")
    mesh = make_host_mesh(1, 1)
    rng = jax.random.PRNGKey(args.seed)
    params, _ = M.init(rng, cfg)

    b, p, g = args.batch, args.prompt_len, args.gen
    cache_len = p + g
    prompts = jax.random.randint(rng, (b, p), 0, cfg.vocab, dtype=jnp.int32)
    enc = (jax.random.normal(rng, (b, cfg.encdec["enc_frames"], cfg.d_model),
                             jnp.float32).astype(cfg.compute_dtype)
           if cfg.encdec else None)

    serve_step = jax.jit(S.make_serve_step(cfg), donate_argnums=(1,))

    with mesh:
        # prefill: build caches at decode capacity by running token-by-token
        # for non-divisible prompt lengths (smoke scale), or via the prefill
        # step + host-side repack at production scale.
        cache = M.init_cache(cfg, b, cache_len)
        t0 = time.time()
        tok = prompts[:, :1]
        logits = None
        for t in range(p):
            logits, cache = serve_step(params, cache, prompts[:, t:t + 1],
                                       jnp.int32(t))
        t_prefill = time.time() - t0

        # decode loop (greedy)
        out_tokens = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for t in range(p, p + g):
            out_tokens.append(np.asarray(tok))
            logits, cache = serve_step(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"[serve] batch={b} prefill({p} tok)={t_prefill:.2f}s "
          f"decode {g} tok in {t_decode:.2f}s "
          f"({1000 * t_decode / g:.1f} ms/tok/batch)")
    print(f"[serve] sample generated ids: {gen[0][:16].tolist()}")
    assert gen.shape == (b, g) and np.isfinite(np.asarray(logits)).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""End-to-end training driver (CPU-runnable at smoke scale, mesh-generic).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --ckpt-dir /tmp/run1

Wires every subsystem: D4M data pipeline → pjit train step (sharded via
launch.sharding) → AdamW + schedule → async checkpointing → fault-tolerant
step loop → D4M metrics telemetry.  ``--simulate-failure N`` kills the step
function at step N to exercise restore-and-replay end-to-end (the same path
tests/test_fault_tolerance.py asserts on).
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import CorpusPipeline, synth_corpus
from repro.distributed import MetricsStore, RestartPolicy, run_resilient
from repro.models import model as M
from repro.optim import adamw_init, make_schedule
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.replace(remat="none" if args.smoke else cfg.remat)
    mesh = make_host_mesh(1, 1)
    opts = S.TrainOptions(peak_lr=args.lr)
    # MiniCPM contributes the WSD schedule — honour it by default
    sched_kind = "wsd" if (cfg.name.startswith("minicpm")
                           and args.schedule == "cosine") else args.schedule
    schedule = make_schedule(sched_kind, peak_lr=args.lr,
                             warmup=max(args.steps // 20, 2),
                             total=args.steps)

    docs = synth_corpus(n_docs=64, seed=args.seed)
    pipeline = CorpusPipeline(docs, seq_len=args.seq_len,
                              batch_per_shard=args.batch, seed=args.seed)
    print(f"[data] corpus nnz={pipeline.table.nnz()} "
          f"vocab={len(pipeline.tokenizer.table)}")
    if cfg.vocab < len(pipeline.tokenizer.table):
        raise SystemExit("smoke vocab smaller than tokenizer table")

    rng = jax.random.PRNGKey(args.seed)
    train_step_base = S.make_train_step(cfg, opts)

    @jax.jit
    def train_step(state, batch):
        params, opt_state, step = state
        lr = schedule(step)
        # close over schedule by rebuilding opts-less update: reuse base fn
        # (its lr is peak; rescale grads-equivalent by lr/peak inside adamw
        # would be wrong — instead call the step fn pieces directly)
        (loss, metrics), grads = jax.value_and_grad(
            M.lm_loss, has_aux=True)(params, cfg, batch)
        from repro.optim import adamw_update, clip_by_global_norm
        grads, gnorm = clip_by_global_norm(grads, opts.max_grad_norm)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, b1=opts.b1, b2=opts.b2,
            weight_decay=opts.weight_decay,
            state_policy=opts.opt_state_policy)
        return ((params, opt_state, step + 1),
                {"loss": loss, "grad_norm": gnorm, "lr": lr})

    def make_state():
        params, _ = M.init(rng, cfg)
        opt_state = adamw_init(params, state_policy=opts.opt_state_policy)
        return (params, opt_state, jnp.int32(0))

    metrics = MetricsStore("last")
    ckpt = (CheckpointManager(args.ckpt_dir, save_interval_steps=args.ckpt_every)
            if args.ckpt_dir else None)

    fail_at = args.simulate_failure
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if fail_at >= 0 and calls["n"] == fail_at:
            raise RuntimeError("simulated worker failure")
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = train_step(state, jb)
        return state, {k: float(v) for k, v in m.items()}

    t0 = time.time()
    with mesh:
        state, steps_done, restarts = run_resilient(
            n_steps=args.steps, step_fn=step_fn, make_state=make_state,
            ckpt_manager=ckpt, pipeline=pipeline,
            policy=RestartPolicy(max_restarts=3, backoff_s=0.01),
            metrics=metrics)
    dt = time.time() - t0
    steps_s, losses = metrics.series("loss")
    print(f"[train] {steps_done} steps in {dt:.1f}s "
          f"({dt / max(steps_done,1):.2f} s/step), restarts={restarts}")
    if len(losses) >= 2:
        print(f"[train] loss {losses[0]:.3f} → {losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""repro.launch — mesh construction, sharding rules, step builders, dry-run."""

"""Roofline-term extraction from a compiled dry-run artifact.

``compiled.cost_analysis()`` supplies HLO FLOPs and bytes accessed;
collective traffic is NOT in cost_analysis, so we parse the post-SPMD HLO
text and sum operand bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute, applying standard ring-algorithm byte
multipliers.  Post-SPMD HLO shapes are per-device, so the sums are already
per-chip quantities — exactly what the roofline denominator wants.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Any, Dict

# -- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link (≈, per the brief)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  "bf16[16,4096,448]{2,1,0}"  or  "f32[128]"  or tuple "(bf16[..], ..)"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

# ring-algorithm per-chip byte multipliers (n = group size, large-n limit)
_ALGO_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,          # (n-1)/n ≈ 1
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind per-chip collective bytes (algo-factored) + raw counts."""
    out: Dict[str, float] = {k: 0.0 for k in _ALGO_FACTOR}
    counts: Dict[str, int] = {k: 0 for k in _ALGO_FACTOR}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        result_shape, kind = m.group(1), m.group(2)
        b = _shape_bytes(result_shape)
        out[kind] += b * _ALGO_FACTOR[kind]
        counts[kind] += 1
    out_total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total": out_total}


def roofline_terms(cost: Dict[str, Any], coll: Dict[str, Any],
                   n_chips: int, *, ici_links: int = 4) -> Dict[str, float]:
    """The three roofline terms in seconds (per step, per chip).

    cost_analysis flops/bytes on a post-SPMD module are per-device program
    quantities; collective bytes likewise.  ici_links: v5e has 4 ICI links
    per chip on a 2-D torus (x±, y±).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    coll_b = float(coll["total"])
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll_b / (ICI_BW * ici_links),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_hbm,
        "collective_bytes_per_chip": coll_b,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    three = {k: terms[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(three, key=three.get)


def model_flops(cfg, shape, n_active_params: float) -> float:
    """6·N·D (N = active params, D = tokens processed by the step)."""
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * d  # forward only
    return 2.0 * n_active_params * shape.global_batch  # decode: 1 tok/seq


def active_param_count(cfg, total_params: float) -> float:
    """MoE: only top-k experts (+ shared + dense layers) count as active."""
    if not cfg.moe:
        return total_params
    mo = cfg.moe
    d = cfg.d_model
    per_expert = 3 * d * mo["d_ff"]
    n_moe_layers = cfg.n_layers - mo.get("first_dense", 0)
    routed_total = mo["n_experts"] * per_expert * n_moe_layers
    routed_active = mo["top_k"] * per_expert * n_moe_layers
    return total_params - routed_total + routed_active

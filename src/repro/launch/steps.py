"""Step builders: train / prefill / serve, with input_specs for the dry-run.

Everything here is mesh-agnostic pure functions plus a thin layer that
computes in/out shardings and returns ``jax.jit`` objects ready to
``.lower().compile()`` (dry-run) or execute (real run).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — the same
pattern the dry-run brief prescribes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as M
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from . import sharding as shd
from .mesh import batch_axes


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    peak_lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    max_grad_norm: float = 1.0
    opt_state_policy: str = "fp32"   # fp32 | bf16 | q8
    fsdp: bool = True
    microbatch: int = 0              # >0: grad-accumulation chunks
    grad_accum_dtype: str = "fp32"   # fp32 | bf16 (≥300B models)
    fsdp_over_pod: bool = False      # ZeRO across pods (≥300B models)
    parallelism: str = "2d"          # 2d (TP×FSDP) | fsdp_only (§Perf)
    residual_budget: float = 4e9     # microbatch sizing target
    offload_opt_state: bool = False  # pinned_host moments (TPU target only:
    #                                  the CPU dry-run backend cannot compile
    #                                  device-placement annotations)


def default_train_options(cfg: ModelConfig) -> TrainOptions:
    """Size-adaptive defaults: big models get low-precision moments."""
    n = est_param_count(cfg)
    if n > 3e11:
        return TrainOptions(opt_state_policy="q8", grad_accum_dtype="bf16",
                            fsdp_over_pod=True)
    if n > 2e10:
        return TrainOptions(opt_state_policy="bf16")
    return TrainOptions()


def auto_microbatch(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    residual_budget: float = 4e9,
                    parallelism: str = "2d") -> int:
    """Grad-accumulation chunks bounding the saved-residual footprint.

    The layer-scan saves one d_model residual per layer per live token
    (full-remat policy), i.e. ``L·d·2B`` bytes/token — the dominant live
    train buffer.  Choose the smallest power-of-two split keeping that
    under ``residual_budget`` per device.
    """
    from .mesh import batch_axes
    axes = list(batch_axes(mesh))
    if parallelism == "fsdp_only":
        axes.append("model")
    data_sz = 1
    for a in axes:
        data_sz *= mesh.shape[a]
    b_local = max(shape.global_batch // data_sz, 1)
    tokens = b_local * shape.seq_len
    per_token = cfg.n_layers * cfg.d_model * 2  # bf16 residual per layer
    tokens_budget = max(int(residual_budget / per_token), shape.seq_len)
    mb = 1
    while (tokens // mb > tokens_budget and mb < b_local
           and b_local % (mb * 2) == 0):
        mb *= 2
    return mb


def est_param_count(cfg: ModelConfig) -> float:
    """Closed-form parameter estimate (embeddings + stacks)."""
    d = cfg.d_model
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    per_attn = d * cfg.n_heads * cfg.dh * 2 + d * cfg.n_kv_heads * cfg.dh * 2
    if cfg.mla:
        m = cfg.mla
        per_attn = (d * m["q_lora_rank"]
                    + m["q_lora_rank"] * cfg.n_heads * (m["qk_nope_dim"] + m["qk_rope_dim"])
                    + d * (m["kv_lora_rank"] + m["qk_rope_dim"])
                    + m["kv_lora_rank"] * cfg.n_heads * (m["qk_nope_dim"] + m["v_head_dim"])
                    + cfg.n_heads * m["v_head_dim"] * d)
    mlp_mult = 3 if cfg.act == "swiglu" else 2
    per_mlp = mlp_mult * d * cfg.d_ff
    if cfg.family == "ssm" or cfg.family == "hybrid":
        s = cfg.ssm
        per_ssm = d * s["d_inner"] * 3 + 2 * d * s["d_state"] * 2
        n = cfg.n_layers * per_ssm + emb
        if cfg.family == "hybrid":
            n += per_attn + per_mlp
        return n
    if cfg.moe:
        mo = cfg.moe
        per_moe = mo["n_experts"] * 3 * d * mo["d_ff"] + \
            mo.get("shared_expert", 0) * 3 * d * mo["d_ff"] + d * mo["n_experts"]
        nd = mo.get("first_dense", 0)
        return emb + nd * (per_attn + per_mlp) + \
            (cfg.n_layers - nd) * (per_attn + per_moe)
    n_stacks = 1 + (cfg.encdec["enc_layers"] / cfg.n_layers if cfg.encdec else 0)
    return emb + cfg.n_layers * n_stacks * (per_attn + per_mlp)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
        if cfg.encdec:
            batch["enc_inputs"] = sds(
                (b, cfg.encdec["enc_frames"], cfg.d_model), jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32)}
        if cfg.encdec:
            out["enc_inputs"] = sds(
                (b, cfg.encdec["enc_frames"], cfg.d_model), jnp.bfloat16)
        return out
    # decode: one new token against a cache of seq_len
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    return {"tokens": sds((b, 1), jnp.int32),
            "pos": sds((), jnp.int32),
            "cache": cache_shapes}


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 parallelism: str = "2d") -> Dict[str, Any]:
    bsp = shd.batch_spec(shape.global_batch, mesh, ndim=2,
                         parallelism=parallelism)
    if shape.kind == "train":
        specs = {"tokens": bsp, "labels": bsp}
        if cfg.encdec:
            specs["enc_inputs"] = P(bsp[0], None, None)
        return {"batch": specs}
    if shape.kind == "prefill":
        out = {"tokens": bsp}
        if cfg.encdec:
            out["enc_inputs"] = P(bsp[0], None, None)
        return out
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, shape.global_batch,
                                                       shape.seq_len))
    return {"tokens": bsp, "pos": P(),
            "cache": shd.cache_specs(cfg, cache_shapes, mesh,
                                     shape.global_batch)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opts: TrainOptions):
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        if opts.microbatch and opts.microbatch > 1:
            loss, metrics, grads = _accumulated_grads(
                params, cfg, batch, opts.microbatch,
                acc_dtype=jnp.bfloat16 if opts.grad_accum_dtype == "bf16"
                else jnp.float32)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                M.lm_loss, has_aux=True)(params, cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, opts.max_grad_norm)
        lr = opts.peak_lr  # schedules applied by the driver via closure/arg
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr, b1=opts.b1, b2=opts.b2,
            weight_decay=opts.weight_decay,
            state_policy=opts.opt_state_policy)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def _accumulated_grads(params, cfg, batch, n_micro: int,
                       acc_dtype=jnp.float32):
    """Gradient accumulation over batch-split microbatches (lax.scan).

    ``acc_dtype=bf16`` halves the standing accumulator for ≥300B models
    (precision loss ≈ log2(n_micro)/2 bits; tested in tests/test_optim.py).
    """
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    micro = jax.tree.map(split, batch)

    def one(carry, mb):
        (loss, metrics), grads = jax.value_and_grad(
            M.lm_loss, has_aux=True)(params, cfg, mb)
        acc_loss, acc_grads = carry
        acc_grads = jax.tree.map(
            lambda a, g: (a + g.astype(acc_dtype)).astype(acc_dtype),
            acc_grads, grads)
        return (acc_loss + loss, acc_grads), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
    (loss, grads), metrics = jax.lax.scan(one, (jnp.float32(0.0), zeros), micro)
    scale = 1.0 / n_micro  # n_micro is a power of two: exact in bf16
    return (loss * scale,
            jax.tree.map(lambda m: m[-1], metrics),
            jax.tree.map(lambda g: g * jnp.asarray(scale, g.dtype), grads))


def make_prefill_step(cfg: ModelConfig):
    if cfg.prefill_chunk:
        return _make_chunked_prefill_step(cfg, cfg.prefill_chunk)

    def prefill_step(params, tokens, enc_inputs=None):
        # hidden → unembed ONLY the last position: avoids materializing the
        # [B, S, V] logits tensor (40+ GB at 32k × 150k vocab).
        hidden, _, cache = M.forward(params, cfg, tokens, mode="prefill",
                                     enc_inputs=enc_inputs,
                                     return_hidden=True)
        head = params.get("lm_head", params["embed"])
        last = hidden[:, -1:]
        logits = (last @ head["table"].T.astype(last.dtype)).astype(jnp.float32)
        if cfg.logit_scale is not None:
            logits = logits * cfg.logit_scale
        return logits[:, 0], cache
    return prefill_step


def _make_chunked_prefill_step(cfg: ModelConfig, chunk: int):
    """Window-wise prefill: live activations bound to O(chunk) instead of
    O(S) — the long-context production path (closes the deepseek
    prefill_32k memory cell).  Not supported for enc-dec / windowed caches.
    """
    assert cfg.encdec is None and cfg.window is None

    def prefill_step(params, tokens, enc_inputs=None):
        b, s = tokens.shape
        assert s % chunk == 0, (s, chunk)
        cache = M.init_cache(cfg, b, s)
        toks = tokens.reshape(b, s // chunk, chunk).transpose(1, 0, 2)

        def body(carry, tok_c):
            cache, pos0 = carry
            positions = pos0 + jnp.arange(chunk, dtype=jnp.int32)
            hidden, _, cache = M.forward(
                params, cfg, tok_c, mode="chunked_prefill", cache=cache,
                positions=positions, return_hidden=True)
            return (cache, pos0 + jnp.int32(chunk)), hidden[:, -1]

        (cache, _), lasts = jax.lax.scan(body, (cache, jnp.int32(0)), toks)
        last = lasts[-1][:, None]
        head = params.get("lm_head", params["embed"])
        logits = (last @ head["table"].T.astype(last.dtype)).astype(jnp.float32)
        if cfg.logit_scale is not None:
            logits = logits * cfg.logit_scale
        return logits[:, 0], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, tokens [B,1], pos) → logits, cache."""
    def serve_step(params, cache, tokens, pos):
        positions = pos[None].astype(jnp.int32)
        logits, _, new_cache = M.forward(params, cfg, tokens, mode="decode",
                                         cache=cache, positions=positions)
        return logits[:, 0], new_cache
    return serve_step


# ---------------------------------------------------------------------------
# jit assembly for a concrete mesh (used by dryrun + real drivers)
# ---------------------------------------------------------------------------

def _as_shardings(tree, mesh):
    """PartitionSpec leaves → NamedSharding (mesh-bound)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_jitted(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 opts: Optional[TrainOptions] = None):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs), out_tag)."""
    opts = opts or default_train_options(cfg)
    from repro.models.pjit_utils import set_parallelism
    set_parallelism(opts.parallelism)
    param_shapes, logical = M_init_specs(cfg)
    pspecs_raw = shd.param_specs(param_shapes, logical, cfg, mesh,
                                 fsdp=opts.fsdp,
                                 fsdp_over_pod=opts.fsdp_over_pod,
                                 parallelism=opts.parallelism)
    ins = input_specs(cfg, shape)
    pspecs = _as_shardings(pspecs_raw, mesh)
    bspecs = _as_shardings(
        batch_pspecs(cfg, shape, mesh, parallelism=opts.parallelism), mesh)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(
            partial(adamw_init, state_policy=opts.opt_state_policy),
            param_shapes)
        ospecs = _as_shardings(shd.opt_state_specs(pspecs_raw, opt_shapes),
                               mesh)
        if opts.microbatch == 0:
            opts = dataclasses.replace(
                opts, microbatch=auto_microbatch(
                    cfg, shape, mesh, residual_budget=opts.residual_budget,
                    parallelism=opts.parallelism))
        fn = make_train_step(cfg, opts)
        jitted = jax.jit(
            fn,
            in_shardings=(pspecs, ospecs, bspecs["batch"]),
            out_shardings=(pspecs, ospecs, None),
            donate_argnums=(0, 1),
        )
        args = (param_shapes, opt_shapes, ins["batch"])
        return jitted, args
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        if cfg.encdec:
            jitted = jax.jit(fn, in_shardings=(pspecs, bspecs["tokens"],
                                               bspecs["enc_inputs"]),
                             out_shardings=None)
            args = (param_shapes, ins["tokens"], ins["enc_inputs"])
        else:
            jitted = jax.jit(fn, in_shardings=(pspecs, bspecs["tokens"]),
                             out_shardings=None)
            args = (param_shapes, ins["tokens"])
        return jitted, args
    # decode
    fn = make_serve_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(pspecs, bspecs["cache"], bspecs["tokens"], bspecs["pos"]),
        out_shardings=(None, bspecs["cache"]),
        donate_argnums=(1,),
    )
    args = (param_shapes, ins["cache"], ins["tokens"], ins["pos"])
    return jitted, args


def M_init_specs(cfg):
    """Logical specs without materializing params (init under eval_shape)."""
    shapes, specs = None, None

    def capture(key):
        nonlocal specs
        p, s = M.init(key, cfg)
        specs = s
        return p

    shapes = jax.eval_shape(capture, jax.random.PRNGKey(0))
    return shapes, specs

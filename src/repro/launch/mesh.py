"""Production mesh construction.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialization — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, while tests and benches must keep seeing 1 device.

Mesh geometry (TPU v5e pods of 256 chips):
  * single-pod:  (16, 16)    axes ("data", "model")
  * multi-pod:   (2, 16, 16) axes ("pod", "data", "model")

``pod`` composes with ``data`` for batch/gradient parallelism (DP across
pods over DCI; FSDP parameter sharding stays intra-pod over ICI), so adding
pods never changes per-tensor shardings — the basis of elastic scaling.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that jointly shard the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)

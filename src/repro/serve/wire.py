"""Expression wire format: LazyExpr graphs + Selector trees ⇄ JSON.

Clients do not hold table data — they build expressions over
:class:`TableRef` leaves (``TableRef("edges")[sel, :] @ TableRef("feat")``)
and ship the *graph*.  The payload is a flat node list in topological
order::

    {"version": 1,
     "nodes": [{"op": "table", "name": "edges"},
               {"op": "select", "child": 0, "row": {...}, "col": {...}},
               {"op": "matmul", "a": 1, "b": 1, "semiring": "plus_times"}],
     "root": 2}

Design rules, all load-bearing for the server:

* **References point backwards.**  A node may only reference earlier list
  positions; a forward or self reference is rejected as a cycle (an
  expression DAG serialized by :func:`to_wire` is always topological, so
  any violation means a malformed/adversarial payload, not a bug here).
* **Shared subtrees serialize once.**  :func:`to_wire` hash-conses on the
  structural ``key()``, so a repeated subexpression is one node referenced
  twice — and deserializes back into one shared node, keeping the
  planner's hash-consing effective server-side.
* **Semirings travel by registry name**, tables by registry name; both
  resolve (or fail with a structured :class:`WireError`) at decode time.
* **No code crosses the wire.**  ``Where`` predicates are referenced by a
  server-registered name (:func:`register_predicate`); an unregistered
  callable is rejected at *serialization* time, and an unknown name at
  decode time.  Nothing in a payload is ever evaluated.

Every decode error raises :class:`WireError` with a machine-readable
``code`` (``unknown_table``, ``unknown_semiring``, ``cycle``,
``bad_payload``, …) so the HTTP layer can return structured 400s
instead of 500s.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.expr import (EwiseAdd, EwiseMul, LazyExpr, MatMul, Reduce,
                             Select, Source, Transpose)
from repro.core.select import (All, And, Keys, Mask, Match, Not, Or,
                               Positions, Range, Selector, StartsWith,
                               Where, as_selector)
from repro.core.semiring import get_semiring

__all__ = ["WIRE_VERSION", "WireError", "TableRef", "to_wire", "from_wire",
           "sel_to_wire", "sel_from_wire", "register_predicate",
           "table_names", "ingest_to_wire", "ingest_from_wire"]

WIRE_VERSION = 1


class WireError(ValueError):
    """Structured wire-format rejection: ``code`` is machine-readable."""

    def __init__(self, code: str, message: str):
        self.code = code
        super().__init__(message)

    def to_dict(self) -> dict:
        return {"code": self.code, "message": str(self)}


class TableRef(LazyExpr):
    """Expression leaf naming a resident server table (no data attached).

    Clients compose queries over these; the server's decoder rebinds them
    to the registry's resident arrays.
    """

    def __init__(self, name: str):
        self.name = str(name)

    def key(self) -> tuple:
        return ("table", self.name)

    def __repr__(self) -> str:
        return f"TableRef({self.name!r})"


# ---------------------------------------------------------------------------
# Named predicates (the only way a Where crosses the wire)
# ---------------------------------------------------------------------------

_PREDICATES: Dict[str, Callable] = {}
_PREDICATE_NAMES: Dict[int, str] = {}


def register_predicate(name: str, fn: Callable) -> Callable:
    """Register a ``Where`` predicate under a wire-safe name (both sides
    of the wire must register the same name to round-trip)."""
    _PREDICATES[str(name)] = fn
    _PREDICATE_NAMES[id(fn)] = str(name)
    return fn


# ---------------------------------------------------------------------------
# Selector ⇄ JSON
# ---------------------------------------------------------------------------

def _keylist(arr: np.ndarray) -> list:
    return [str(k) for k in arr] if arr.dtype.kind in ("U", "S", "O") \
        else [float(k) for k in arr]


def sel_to_wire(sel) -> dict:
    """Serialize any selector argument (Selector instance or raw
    ``__getitem__`` form: strings, ints, slices, arrays, 2-tuples)."""
    try:
        s = as_selector(sel)
    except TypeError as exc:
        raise WireError("bad_selector",
                        f"not a serializable selector: {sel!r} ({exc})")
    if isinstance(s, All):
        return {"sel": "all"}
    if isinstance(s, Keys):
        return {"sel": "keys", "keys": _keylist(s.keys)}
    if isinstance(s, Positions):
        if isinstance(s.pos, slice):
            return {"sel": "positions",
                    "slice": [s.pos.start, s.pos.stop, s.pos.step]}
        return {"sel": "positions", "pos": [int(p) for p in s.pos]}
    if isinstance(s, Range):
        def bound(x):
            return None if x is None else (
                str(x) if isinstance(x, str) else float(x))
        return {"sel": "range", "lo": bound(s.lo), "hi": bound(s.hi),
                "inclusive": list(s.inclusive)}
    if isinstance(s, StartsWith):
        return {"sel": "startswith", "prefixes": list(s.prefixes)}
    if isinstance(s, Match):
        return {"sel": "match", "pattern": s.pattern, "flags": int(s.flags)}
    if isinstance(s, Mask):
        return {"sel": "mask", "bits": [bool(b) for b in s.bits]}
    if isinstance(s, Where):
        name = _PREDICATE_NAMES.get(id(s.fn))
        if name is None:
            raise WireError(
                "unserializable_selector",
                "Where predicates cross the wire by registered name only "
                "(register_predicate); arbitrary callables do not "
                "serialize")
        return {"sel": "where", "name": name}
    if isinstance(s, (And, Or)):
        return {"sel": "and" if isinstance(s, And) else "or",
                "a": sel_to_wire(s.a), "b": sel_to_wire(s.b)}
    if isinstance(s, Not):
        return {"sel": "not", "a": sel_to_wire(s.a)}
    raise WireError("bad_selector",
                    f"unknown selector type {type(s).__name__}")


def sel_from_wire(d: Any) -> Selector:
    """Decode a selector wire dict; raises WireError on malformed input."""
    if not isinstance(d, dict) or "sel" not in d:
        raise WireError("bad_payload",
                        f"selector must be a dict with a 'sel' tag, "
                        f"got {type(d).__name__}")
    kind = d["sel"]
    try:
        if kind == "all":
            return All()
        if kind == "keys":
            return Keys(list(d["keys"]))
        if kind == "positions":
            if "slice" in d:
                start, stop, step = d["slice"]
                return Positions(slice(start, stop, step))
            return Positions([int(p) for p in d["pos"]])
        if kind == "range":
            inc = d.get("inclusive", [True, True])
            return Range(d.get("lo"), d.get("hi"),
                         inclusive=(bool(inc[0]), bool(inc[1])))
        if kind == "startswith":
            return StartsWith([str(p) for p in d["prefixes"]])
        if kind == "match":
            return Match(str(d["pattern"]), int(d.get("flags", 0)))
        if kind == "mask":
            return Mask([bool(b) for b in d["bits"]])
        if kind == "where":
            fn = _PREDICATES.get(str(d.get("name")))
            if fn is None:
                raise WireError(
                    "unknown_predicate",
                    f"no predicate registered under {d.get('name')!r}")
            return Where(fn)
        if kind in ("and", "or"):
            a, b = sel_from_wire(d["a"]), sel_from_wire(d["b"])
            return And(a, b) if kind == "and" else Or(a, b)
        if kind == "not":
            return Not(sel_from_wire(d["a"]))
    except WireError:
        raise
    except Exception as exc:   # malformed fields, bad regex, wrong types
        raise WireError("bad_payload",
                        f"malformed {kind!r} selector: {exc}") from exc
    raise WireError("bad_selector", f"unknown selector kind {kind!r}")


# ---------------------------------------------------------------------------
# Expression graph ⇄ JSON
# ---------------------------------------------------------------------------

def to_wire(expr: LazyExpr, names: Optional[Dict[int, str]] = None) -> dict:
    """Serialize an expression graph to the wire payload.

    ``TableRef`` leaves carry their own name; ``Source`` leaves (server-
    side graphs over resident arrays) need ``names`` mapping
    ``id(array) -> table name``.  Shared subtrees (same structural key)
    serialize once and are referenced by node id.
    """
    if not isinstance(expr, LazyExpr):
        raise WireError("bad_payload",
                        f"not an expression: {type(expr).__name__}")
    nodes: List[dict] = []
    index: Dict[tuple, int] = {}

    def visit(node: LazyExpr) -> int:
        k = node.key()
        if k in index:
            return index[k]
        if isinstance(node, TableRef):
            d = {"op": "table", "name": node.name}
        elif isinstance(node, Source):
            name = (names or {}).get(id(node.array))
            if name is None:
                raise WireError(
                    "unknown_table",
                    "Source array has no table name; pass names={id(a): "
                    "name} or build the graph over TableRef leaves")
            d = {"op": "table", "name": name}
        elif isinstance(node, Select):
            d = {"op": "select", "child": visit(node.child),
                 "row": sel_to_wire(node.row_sel),
                 "col": sel_to_wire(node.col_sel)}
        elif isinstance(node, (EwiseAdd, EwiseMul, MatMul)):
            d = {"op": node.tag, "a": visit(node.a), "b": visit(node.b),
                 "semiring": node.semiring.name}
        elif isinstance(node, Reduce):
            d = {"op": "reduce", "child": visit(node.child),
                 "axis": node.axis, "semiring": node.semiring.name}
        elif isinstance(node, Transpose):
            d = {"op": "transpose", "child": visit(node.child)}
        else:
            raise WireError("bad_payload",
                            f"node type {type(node).__name__} does not "
                            f"serialize (planner-internal node?)")
        nid = len(nodes)
        nodes.append(d)
        index[k] = nid
        return nid

    root = visit(expr)
    return {"version": WIRE_VERSION, "nodes": nodes, "root": root}


def _ref(d: dict, field: str, pos: int, decoded: list) -> LazyExpr:
    """Resolve a child reference: must be an int pointing at an EARLIER
    node — forward/self references cannot arise from a DAG and are
    rejected as cycles."""
    ref = d.get(field)
    if not isinstance(ref, int) or isinstance(ref, bool):
        raise WireError("bad_payload",
                        f"node {pos}: field {field!r} must be an int node "
                        f"id, got {ref!r}")
    if ref < 0 or ref >= len(decoded) or ref >= pos:
        if 0 <= ref < pos or ref < 0:
            raise WireError("bad_payload",
                            f"node {pos}: reference {ref} out of range")
        raise WireError("cycle",
                        f"node {pos}: reference {ref} is not an earlier "
                        f"node — the payload graph has a cycle or forward "
                        f"reference")
    return decoded[ref]


def _semiring(d: dict, pos: int):
    name = d.get("semiring", "plus_times")
    try:
        return get_semiring(name)
    except KeyError as exc:
        raise WireError("unknown_semiring", str(exc)) from exc


def from_wire(payload: Any,
              resolve: Optional[Callable[[str], Any]] = None) -> LazyExpr:
    """Decode a wire payload into an expression graph.

    ``resolve(name) -> array`` binds table leaves to resident arrays
    (server side); ``resolve=None`` keeps them as :class:`TableRef`
    placeholders (client-side round trip).  Raises :class:`WireError`
    with a structured code on any malformed input.
    """
    if not isinstance(payload, dict):
        raise WireError("bad_payload",
                        f"payload must be a dict, got "
                        f"{type(payload).__name__}")
    if payload.get("version") != WIRE_VERSION:
        raise WireError("bad_version",
                        f"unsupported wire version "
                        f"{payload.get('version')!r} (expected "
                        f"{WIRE_VERSION})")
    nodes = payload.get("nodes")
    if not isinstance(nodes, list) or not nodes:
        raise WireError("bad_payload", "payload needs a nonempty 'nodes' "
                                       "list")
    decoded: List[LazyExpr] = []
    for pos, d in enumerate(nodes):
        if not isinstance(d, dict) or "op" not in d:
            raise WireError("bad_payload",
                            f"node {pos} must be a dict with an 'op' tag")
        op = d["op"]
        if op == "table":
            name = d.get("name")
            if not isinstance(name, str) or not name:
                raise WireError("bad_payload",
                                f"node {pos}: table node needs a string "
                                f"'name'")
            if resolve is None:
                decoded.append(TableRef(name))
            else:
                decoded.append(Source(resolve(name)))
        elif op == "select":
            child = _ref(d, "child", pos, decoded)
            decoded.append(Select(child, sel_from_wire(d.get("row")),
                                  sel_from_wire(d.get("col"))))
        elif op in ("ewise_add", "ewise_mul", "matmul"):
            a = _ref(d, "a", pos, decoded)
            b = _ref(d, "b", pos, decoded)
            cls = {"ewise_add": EwiseAdd, "ewise_mul": EwiseMul,
                   "matmul": MatMul}[op]
            decoded.append(cls(a, b, semiring=_semiring(d, pos)))
        elif op == "reduce":
            child = _ref(d, "child", pos, decoded)
            axis = d.get("axis")
            if axis not in (None, 0, 1):
                raise WireError("bad_payload",
                                f"node {pos}: reduce axis must be null, 0 "
                                f"or 1, got {axis!r}")
            decoded.append(Reduce(child, axis,
                                  semiring=_semiring(d, pos)))
        elif op == "transpose":
            decoded.append(Transpose(_ref(d, "child", pos, decoded)))
        else:
            raise WireError("unknown_op", f"node {pos}: unknown op {op!r}")
    root = payload.get("root")
    if not isinstance(root, int) or isinstance(root, bool) \
            or not (0 <= root < len(decoded)):
        raise WireError("bad_payload",
                        f"'root' must be a valid node id, got {root!r}")
    return decoded[root]


# ---------------------------------------------------------------------------
# Ingest batches ⇄ JSON (the POST /ingest payload)
# ---------------------------------------------------------------------------

def ingest_to_wire(table: str, rows, cols, vals) -> dict:
    """Serialize one triple batch against a registry ingest table::

        {"version": 1,
         "ingest": {"table": "edges",
                    "rows": [...], "cols": [...], "vals": [...]}}

    Keys may be strings or numbers; values must be numbers for device/
    dist tables (the server enforces the layer rule at insert time).
    """
    def _k(x):
        return str(x) if isinstance(x, str) or (
            hasattr(x, "dtype") and np.asarray(x).dtype.kind in "USO") \
            else float(x)

    return {"version": WIRE_VERSION,
            "ingest": {"table": str(table),
                       "rows": [_k(x) for x in rows],
                       "cols": [_k(x) for x in cols],
                       "vals": [str(v) if isinstance(v, str) else float(v)
                                for v in vals]}}


def _ingest_axis(batch: dict, field: str) -> np.ndarray:
    xs = batch.get(field)
    if not isinstance(xs, list) or not xs:
        raise WireError("bad_batch",
                        f"ingest batch needs a nonempty {field!r} list")
    if all(isinstance(x, str) for x in xs):
        return np.asarray(xs, dtype=str)
    if all(isinstance(x, (int, float)) and not isinstance(x, bool)
           for x in xs):
        return np.asarray(xs, dtype=np.float64)
    raise WireError("bad_batch",
                    f"ingest batch {field!r} must be all-string or "
                    f"all-numeric scalars")


def ingest_from_wire(payload: Any):
    """Decode + validate an ingest payload → ``(table, rows, cols, vals)``
    numpy arrays.  Raises :class:`WireError` (code ``bad_batch`` for a
    malformed batch) — invalid batches never reach the engine queue."""
    if not isinstance(payload, dict):
        raise WireError("bad_payload",
                        f"payload must be a dict, got "
                        f"{type(payload).__name__}")
    if payload.get("version") != WIRE_VERSION:
        raise WireError("bad_version",
                        f"unsupported wire version "
                        f"{payload.get('version')!r} (expected "
                        f"{WIRE_VERSION})")
    batch = payload.get("ingest")
    if not isinstance(batch, dict):
        raise WireError("bad_payload",
                        "ingest payload needs an 'ingest' dict")
    name = batch.get("table")
    if not isinstance(name, str) or not name:
        raise WireError("bad_batch",
                        "ingest batch needs a string 'table' name")
    rows = _ingest_axis(batch, "rows")
    cols = _ingest_axis(batch, "cols")
    vals = _ingest_axis(batch, "vals")
    if not (len(rows) == len(cols) == len(vals)):
        raise WireError("bad_batch",
                        f"rows/cols/vals must have equal length, got "
                        f"{len(rows)}/{len(cols)}/{len(vals)}")
    return name, rows, cols, vals


def table_names(payload: Any) -> tuple:
    """The sorted table names a (structurally valid) payload references —
    the admission-batching compatibility key, computable without binding
    any arrays."""
    if not isinstance(payload, dict) or not isinstance(
            payload.get("nodes"), list):
        raise WireError("bad_payload", "payload must be a dict with a "
                                       "'nodes' list")
    out = set()
    for d in payload["nodes"]:
        if isinstance(d, dict) and d.get("op") == "table":
            name = d.get("name")
            if isinstance(name, str):
                out.add(name)
    return tuple(sorted(out))

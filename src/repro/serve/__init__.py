"""repro.serve — D4M-as-a-service: the resident sharded query server.

The D4M line's endgame was always a database engine serving queries over
resident associative arrays (D4M: Bringing Associative Arrays to Database
Engines, arXiv:1508.07371; D4M 3.0, arXiv:1702.03253).  This package is
that layer for the reproduction: a long-lived process holds named
``Assoc``/``AssocTensor``/``DistAssoc`` tables resident (device tables
stay pinned on the mesh), clients ship *expression graphs* — not data —
over a JSON wire format, and the server plans each graph through the
existing ``plan.optimize()`` so structurally repeated queries hit the
cross-collect ``_PLAN_CACHE`` across requests and clients.

* :mod:`~repro.serve.wire`     — LazyExpr/Selector ⇄ JSON wire format
  (``TableRef`` leaves name resident tables; semirings by registry name).
* :mod:`~repro.serve.registry` — named resident tables, loaded once at
  startup from triples files or generator configs.
* :mod:`~repro.serve.engine`   — worker pool + admission/batching queue:
  compatible queued queries (same table set / same layer) are admitted as
  a batch so the mesh stays busy; per-request timing; per-worker
  ``MetricsStore`` telemetry ⊕-merged at read time.
* :mod:`~repro.serve.server`   — stdlib ``ThreadingHTTPServer`` JSON
  transport (``/query``, ``/ingest``, ``/tables``, ``/stats``,
  ``/health``) + CLI.
* :mod:`~repro.serve.client`   — thin stdlib HTTP client.

Dynamic ingest (:mod:`repro.ingest`) plugs in here: a table registered
as an :class:`~repro.ingest.IngestTable` accepts ``POST /ingest`` triple
batches, queries against it resolve to its merge-on-read snapshot, and
the engine runs a background compactor.
"""
from .wire import (TableRef, WireError, from_wire, to_wire, sel_from_wire,
                   sel_to_wire, register_predicate, ingest_from_wire,
                   ingest_to_wire)
from .registry import TableRegistry
from .engine import Engine, serve_execute
from .server import D4MServer, start_server
from .client import D4MClient, ServerError

__all__ = [
    "TableRef", "WireError", "from_wire", "to_wire", "sel_from_wire",
    "sel_to_wire", "register_predicate", "ingest_from_wire",
    "ingest_to_wire", "TableRegistry", "Engine", "serve_execute",
    "D4MServer", "start_server", "D4MClient", "ServerError",
]

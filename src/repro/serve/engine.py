"""Query engine: worker pool, admission/batching queue, live metrics.

The execution model is the D4M 3.0 server loop grown onto the lazy
planner:

* **admission batching** — queued queries are *compatible* when they
  touch the same table set on the same layer(s).  A worker admitting work
  takes the oldest request plus up to ``max_batch - 1`` compatible queued
  requests and executes them back-to-back, so a burst of same-shape
  traffic runs against warm trace caches and a warm plan cache instead of
  interleaving with unrelated shapes (``DISPATCH``/jit caches are keyed
  by structure; interleaving thrashes them).  Batch sizes are recorded —
  ``/stats`` exposes the distribution.
* **cross-request plan caching** — every query executes through
  ``LazyExpr.collect()``, i.e. ``plan.optimize()`` memoized by the
  graph's structural key in ``_PLAN_CACHE``.  Resident tables make the
  ``Source`` identity stable, and the wire format preserves selector
  structure, so two clients sending the same query — or one client
  repeating it — plan once (``PLAN_STATS['plan_hits']`` counts this).
* **⊕-merged telemetry** — each worker logs into its own
  :class:`~repro.distributed.metrics.MetricsStore` (no cross-thread
  contention); a ``/stats`` read ⊕-merges the per-worker stores on
  demand — the D4M aggregation-on-collision semantics doing the
  cross-thread reduction that a conventional metrics library needs locks
  for.

Ingest batches (``POST /ingest``) flow through the same queue under
disjoint admission keys — ``("ingest", table)`` vs ``("query", ...)`` —
so a mutation never batches with reads on the table it mutates; queries
over ingest tables bind their merge-on-read snapshot at execution time.
When the registry holds ingest tables the engine also runs a background
:class:`~repro.ingest.Compactor`.

The execution entry point :func:`serve_execute` carries a ``@contract``:
shard-local serve queries inherit the zero-collective / never-densify
budgets of the ops they dispatch, and ``tools/d4mcheck`` sweeps the serve
path like any other entry point.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.contracts import contract
from repro.distributed.metrics import MetricsStore

from .registry import TableRegistry
from .wire import WireError, from_wire, ingest_from_wire, table_names

__all__ = ["Engine", "QueryError", "serve_execute", "format_result"]


class QueryError(Exception):
    """Execution-time failure of a structurally valid query (wraps the
    underlying exception with a structured code for the transport)."""

    def __init__(self, code: str, message: str):
        self.code = code
        super().__init__(message)

    def to_dict(self) -> dict:
        return {"code": self.code, "message": str(self)}


@contract(collectives=0, densify=False, name="serve.execute",
          note="shard-local serve queries: zero collectives, no "
               "densification — budgets inherited from the dispatched ops")
def serve_execute(expr):
    """THE server execution entry point: optimize (plan-cached) +
    execute one decoded expression graph."""
    return expr.collect()


def format_result(res, limit: Optional[int] = None) -> Dict[str, Any]:
    """Layer-native result → JSON-safe payload.

    Arrays return COO triples (gathered to host — the result of a query
    is small by design; resident operands never move), reductions return
    dense vectors or scalars.
    """
    import jax.numpy as jnp

    from repro.core import Assoc, AssocTensor, DistAssoc

    if isinstance(res, (AssocTensor, DistAssoc)):
        res = res.to_assoc()
    if isinstance(res, Assoc) or res is None:
        if res is None:
            res = Assoc()
        r, c, v = res.triples()
        n = len(r)
        truncated = limit is not None and n > limit
        if truncated:
            r, c, v = r[:limit], c[:limit], v[:limit]
        return {"kind": "triples", "nnz": n,
                "rows": [x.item() if hasattr(x, "item") else x
                         for x in r.tolist()],
                "cols": [x.item() if hasattr(x, "item") else x
                         for x in c.tolist()],
                "vals": v.tolist(), "truncated": truncated}
    if isinstance(res, (jnp.ndarray, np.ndarray)):
        arr = np.asarray(res)
        if arr.ndim == 0:
            return {"kind": "scalar", "val": float(arr)}
        return {"kind": "vector", "n": int(arr.shape[0]),
                "vals": [float(x) for x in arr]}
    if isinstance(res, (float, int, np.floating, np.integer)):
        return {"kind": "scalar", "val": float(res)}
    raise QueryError("bad_result",
                     f"unformattable result type {type(res).__name__}")


class _Request:
    """One admitted request (query or ingest batch) + its future-ish
    result.  ``expr`` is ``None`` for ingest requests and for queries
    over ingest tables (those bind at execution time so the merge-on-read
    snapshot reflects every mutation admitted ahead of them)."""

    __slots__ = ("payload", "expr", "options", "batch_key", "t_enqueue",
                 "event", "result", "error", "timing", "batch_size",
                 "kind", "data")

    def __init__(self, payload, expr, options, batch_key, *,
                 kind: str = "query", data=None):
        self.payload = payload
        self.expr = expr
        self.options = options
        self.batch_key = batch_key
        self.kind = kind
        self.data = data
        self.t_enqueue = time.perf_counter()
        self.event = threading.Event()
        self.result: Optional[dict] = None
        self.error: Optional[Exception] = None
        self.timing: Dict[str, float] = {}
        self.batch_size = 1

    def wait(self, timeout: Optional[float] = None) -> dict:
        if not self.event.wait(timeout):
            raise QueryError("timeout", "query did not complete in time")
        if self.error is not None:
            raise self.error
        assert self.result is not None
        return self.result


class Engine:
    """Worker pool + admission queue over a :class:`TableRegistry`."""

    def __init__(self, registry: TableRegistry, *, workers: int = 4,
                 max_batch: int = 8, batch_window_s: float = 0.0,
                 default_limit: Optional[int] = 100_000,
                 compact_interval_s: float = 0.05,
                 compact_idle_s: float = 0.25):
        self.registry = registry
        self.workers = max(1, int(workers))
        self.max_batch = max(1, int(max_batch))
        self.batch_window_s = float(batch_window_s)
        self.default_limit = default_limit
        self.compact_interval_s = float(compact_interval_s)
        self.compact_idle_s = float(compact_idle_s)
        self._compactor = None
        self._queue: deque = deque()
        self._cv = threading.Condition()
        self._threads: List[threading.Thread] = []
        self._stop = False
        self._started = False
        # per-worker stores: single-writer each, ⊕-merged on /stats reads
        self._stores = [MetricsStore("sum") for _ in range(self.workers)]
        self._latencies: deque = deque(maxlen=2048)   # recent, for p50/p99
        self._lat_lock = threading.Lock()
        self.t_start = time.time()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Engine":
        if self._started:
            return self
        self._started = True
        self._stop = False
        for i in range(self.workers):
            t = threading.Thread(target=self._worker_loop, args=(i,),
                                 name=f"d4m-serve-worker-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        if self.registry.ingest_names() and self.compact_interval_s > 0:
            from repro.ingest import Compactor
            self._compactor = Compactor(
                self.registry, interval_s=self.compact_interval_s,
                idle_s=self.compact_idle_s).start()
        return self

    def stop(self) -> None:
        if self._compactor is not None:
            self._compactor.stop()
            self._compactor = None
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads.clear()
        self._started = False

    def __enter__(self) -> "Engine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission ----------------------------------------------------------
    def _admission_key(self, payload) -> tuple:
        """Compatibility key: ``("query", table names, their layers)``.
        Same key ⇒ same resident operands and same execution layer ⇒
        batchable.  The ``"query"`` tag keeps the key space disjoint from
        ingest admission keys (``("ingest", table)``), so a mutation never
        batches with reads on the table it mutates."""
        tables = table_names(payload)
        if not tables:
            raise WireError("bad_payload",
                            "query references no tables")
        layers = tuple(self.registry.layer_of(n) for n in tables)
        return ("query", tables, layers)

    def submit(self, payload, options: Optional[dict] = None) -> _Request:
        """Validate + enqueue one wire payload; returns the request handle
        (``.wait()`` for the result).  Malformed payloads raise
        :class:`WireError` synchronously — they never enter the queue.

        Queries over read-only tables bind their ``Source`` arrays here
        (plan-cache keys resolve once); queries touching an ingest table
        only *validate* here and bind at execution time, so the snapshot
        they read reflects mutations admitted ahead of them."""
        if not self._started:
            raise RuntimeError("engine not started")
        from_wire(payload, resolve=None)        # structural validation first
        key = self._admission_key(payload)      # then table-name checks
        tables = key[1]
        if any(self.registry.is_ingest(n) for n in tables):
            expr = None                         # bind at execution time
        else:
            expr = from_wire(payload, resolve=self.registry.resolve)
        req = _Request(payload, expr, dict(options or {}), key)
        with self._cv:
            self._queue.append(req)
            self._cv.notify()
        return req

    def submit_ingest(self, payload,
                      options: Optional[dict] = None) -> _Request:
        """Validate + enqueue one ingest batch (the POST /ingest body).
        Decoding and table checks are synchronous — ``WireError`` codes
        ``bad_batch`` / ``not_ingestable`` / ``unknown_table`` never enter
        the queue.  The admission key is ``("ingest", table)``: disjoint
        from every query key, so a mutation batch is only ever admitted
        with other mutations of the same table (applied in queue order).

        Ordering: within one synchronous client connection ingest→query
        is read-your-writes (the client holds the ingest response before
        it sends the read).  Across connections the only guarantee is
        queue order of *admission*; concurrent workers may overlap an
        ingest with an independent query."""
        if not self._started:
            raise RuntimeError("engine not started")
        name, rows, cols, vals = ingest_from_wire(payload)
        self.registry.ingest_table(name)        # raises if not ingestable
        req = _Request(payload, None, dict(options or {}),
                       ("ingest", name), kind="ingest",
                       data=(name, rows, cols, vals))
        with self._cv:
            self._queue.append(req)
            self._cv.notify()
        return req

    def query(self, payload, options: Optional[dict] = None,
              timeout: Optional[float] = 120.0) -> dict:
        """Synchronous submit + wait (the in-process client path)."""
        return self.submit(payload, options).wait(timeout)

    def ingest(self, payload, options: Optional[dict] = None,
               timeout: Optional[float] = 120.0) -> dict:
        """Synchronous ingest submit + wait."""
        return self.submit_ingest(payload, options).wait(timeout)

    # -- the worker ---------------------------------------------------------
    def _take_batch(self) -> List[_Request]:
        """Admit the oldest request + up to ``max_batch - 1`` compatible
        queued requests (same admission key), preserving queue order for
        the rest."""
        with self._cv:
            while not self._queue and not self._stop:
                self._cv.wait(timeout=0.1)
            if self._stop and not self._queue:
                return []
            head = self._queue.popleft()
            batch = [head]
            if self.max_batch > 1:
                keep = deque()
                while self._queue and len(batch) < self.max_batch:
                    r = self._queue.popleft()
                    if r.batch_key == head.batch_key:
                        batch.append(r)
                    else:
                        keep.append(r)
                self._queue.extendleft(reversed(keep))
        if (len(batch) < self.max_batch and self.batch_window_s > 0):
            # optional accumulation window: let same-shape stragglers join
            time.sleep(self.batch_window_s)
            with self._cv:
                keep = deque()
                while self._queue and len(batch) < self.max_batch:
                    r = self._queue.popleft()
                    if r.batch_key == head.batch_key:
                        batch.append(r)
                    else:
                        keep.append(r)
                self._queue.extendleft(reversed(keep))
        return batch

    def _worker_loop(self, idx: int) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                if self._stop:
                    return
                continue
            # re-read per iteration: reset_stats() swaps the store list
            store = self._stores[idx]
            store.log(0, {"batches": 1.0, "batch_n": float(len(batch))})
            for req in batch:
                req.batch_size = len(batch)
                t0 = time.perf_counter()
                try:
                    if req.kind == "ingest":
                        name, rows, cols, vals = req.data
                        table = self.registry.ingest_table(name)
                        out = table.insert(rows, cols, vals)
                        body = {"kind": "ingest", "table": name,
                                "version": table.version, **out}
                        store.log(0, {"ingests": 1.0,
                                      "ingest_triples":
                                          float(out["accepted"])})
                    else:
                        if req.expr is None:    # ingest-table query: bind now
                            req.expr = from_wire(
                                req.payload, resolve=self.registry.resolve)
                        res = serve_execute(req.expr)
                        limit = req.options.get("limit", self.default_limit)
                        body = format_result(res, limit=limit)
                except (WireError, QueryError) as exc:
                    req.error = exc
                except Exception as exc:   # execution-time type errors etc.
                    req.error = QueryError("execution_error",
                                           f"{type(exc).__name__}: {exc}")
                else:
                    t1 = time.perf_counter()
                    req.timing = {
                        "queue_s": round(t0 - req.t_enqueue, 6),
                        "exec_s": round(t1 - t0, 6),
                        "total_s": round(t1 - req.t_enqueue, 6),
                    }
                    req.result = {"result": body, "timing": req.timing,
                                  "batch": req.batch_size}
                t_total = time.perf_counter() - req.t_enqueue
                store.log(0, {"requests": 1.0,
                              "errors": 1.0 if req.error else 0.0,
                              "latency_s": t_total})
                with self._lat_lock:
                    self._latencies.append(t_total)
                req.event.set()

    # -- telemetry ----------------------------------------------------------
    def metrics(self) -> MetricsStore:
        """⊕-merge of every worker's store (one ``combine`` per worker)."""
        merged = MetricsStore("sum")
        for s in self._stores:
            merged = merged.merge(s)
        return merged

    def stats(self) -> Dict[str, Any]:
        """The /stats body: server counters + core telemetry dicts."""
        from repro.core import (CACHE_STATS, DISPATCH_STATS, PLAN_STATS,
                                UNION_STATS)

        merged = self.metrics()
        server: Dict[str, float] = {}
        if merged.table.nnz():
            _, names, vals = merged.table.triples()
            for n, v in zip(names.tolist(), vals.tolist()):
                server[str(n)] = server.get(str(n), 0.0) + float(v)
        with self._lat_lock:
            lats = sorted(self._latencies)
        if lats:
            server["p50_s"] = float(np.percentile(lats, 50))
            server["p99_s"] = float(np.percentile(lats, 99))
        n_req = server.get("requests", 0.0)
        if server.get("batches"):
            server["batch_mean"] = server["batch_n"] / server["batches"]
        server["uptime_s"] = time.time() - self.t_start
        if n_req and server.get("latency_s") is not None:
            server["latency_mean_s"] = server["latency_s"] / n_req
        out = {
            "server": server,
            "plan": dict(PLAN_STATS),
            "cache": dict(CACHE_STATS),
            "union": dict(UNION_STATS),
            "dispatch": dict(DISPATCH_STATS),
            "queue_depth": len(self._queue),
            "workers": self.workers,
        }
        ingest_names = self.registry.ingest_names()
        if ingest_names:
            out["ingest"] = {n: self.registry.ingest_table(n).info()
                             for n in ingest_names}
        return out

    def reset_stats(self) -> None:
        """Zero core + server telemetry (a fresh measurement window —
        the bench harness calls this between hot/cold mixes)."""
        from repro.core import reset_all_stats
        reset_all_stats()
        self._stores = [MetricsStore("sum") for _ in range(self.workers)]
        with self._lat_lock:
            self._latencies.clear()

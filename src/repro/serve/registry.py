"""Resident table registry: named associative arrays pinned for serving.

Tables are loaded ONCE at startup — from triples files (TSV/CSV
``row<TAB>col<TAB>val`` lines) or generator configs — and stay resident
for the server's lifetime: host ``Assoc`` in process memory, device
``AssocTensor`` pinned in device memory, ``DistAssoc`` row-sharded across
the mesh.  Queries reference tables by name through the wire format; the
registry is the resolver that binds :class:`~repro.serve.wire.TableRef`
leaves to the resident arrays, so the planner's ``_PLAN_CACHE`` keys
(which include ``id(array)``) are stable across requests and clients.

Spec format (one dict per table, JSON-friendly)::

    {"name": "edges", "path": "edges.tsv", "layer": "device"}
    {"name": "rand",  "generator": "random", "n": 512, "nnz": 4096,
     "seed": 0, "layer": "host"}

``layer`` is ``host`` (default) / ``device`` / ``dist``; ``dist`` shards
over ``mesh`` (default: a 1-D ``data`` mesh over every visible device).
``"ingest": true`` wraps the loaded array in an
:class:`~repro.ingest.IngestTable` so ``POST /ingest`` can mutate it;
queries against an ingest table resolve to its merge-on-read
``snapshot()`` (stable object identity between mutations, so the plan
cache still hits).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from .wire import WireError

__all__ = ["TableRegistry", "load_triples_file", "generate_triples"]


def load_triples_file(path: str):
    """Parse a triples file: one ``row<sep>col<sep>val`` line each
    (separator: tab, or comma when no tab present); ``#`` comments and
    blank lines skipped.  Values parse as float when possible, else
    string."""
    rows: List[str] = []
    cols: List[str] = []
    vals: List[Any] = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split("\t") if "\t" in line else line.split(",")
            if len(parts) != 3:
                raise ValueError(
                    f"{path}:{ln}: expected 'row<sep>col<sep>val', got "
                    f"{line!r}")
            rows.append(parts[0].strip())
            cols.append(parts[1].strip())
            vals.append(parts[2].strip())
    try:
        vals_arr: np.ndarray = np.asarray([float(v) for v in vals])
    except ValueError:
        vals_arr = np.asarray(vals, dtype=str)
    return np.asarray(rows, dtype=str), np.asarray(cols, dtype=str), vals_arr


def generate_triples(spec: Dict[str, Any]):
    """Deterministic synthetic tables for benches/demos.

    ``generator="random"``: ``nnz`` triples over an ``n × n`` string
    keyspace.  ``dist="clustered"`` (default) draws keys zipf-ishly so the
    COO has the clustered block structure the BSR planner likes;
    ``"uniform"`` draws uniformly.
    """
    kind = spec.get("generator", "random")
    if kind != "random":
        raise ValueError(f"unknown generator {kind!r}")
    n = int(spec.get("n", 256))
    nnz = int(spec.get("nnz", 4 * n))
    rng = np.random.default_rng(int(spec.get("seed", 0)))
    if spec.get("dist", "clustered") == "clustered":
        # quadratic warp concentrates mass at low ranks (hub keys)
        r = (rng.uniform(0, 1, nnz) ** 2 * n).astype(np.int64) % n
        c = (rng.uniform(0, 1, nnz) ** 2 * n).astype(np.int64) % n
    else:
        r = rng.integers(0, n, nnz)
        c = rng.integers(0, n, nnz)
    width = len(str(max(n - 1, 1)))
    rows = np.asarray([f"r{v:0{width}d}" for v in r])
    cols = np.asarray([f"c{v:0{width}d}" for v in c])
    vals = rng.uniform(0.5, 5.0, nnz)
    return rows, cols, vals


def _default_mesh():
    import jax
    return jax.make_mesh((len(jax.devices()),), ("data",))


class TableRegistry:
    """Named resident tables + the wire resolver over them."""

    def __init__(self):
        self._tables: Dict[str, Any] = {}
        self._lock = threading.RLock()

    # -- registration -------------------------------------------------------
    def register(self, name: str, array) -> Any:
        from repro.core import Assoc, AssocTensor, DistAssoc
        from repro.ingest import IngestTable
        if not isinstance(array, (Assoc, AssocTensor, DistAssoc,
                                  IngestTable)):
            raise TypeError(
                f"table {name!r}: expected Assoc/AssocTensor/DistAssoc/"
                f"IngestTable, got {type(array).__name__}")
        if isinstance(array, IngestTable) and not array.name:
            array.name = str(name)
        with self._lock:
            self._tables[str(name)] = array
        return array

    def load(self, spec: Dict[str, Any], mesh=None) -> Any:
        """Load one table from a spec dict (``path`` or ``generator``)."""
        name = spec.get("name")
        if not name:
            raise ValueError(f"table spec needs a 'name': {spec!r}")
        if "path" in spec:
            rows, cols, vals = load_triples_file(spec["path"])
        else:
            rows, cols, vals = generate_triples(spec)
        layer = spec.get("layer", "host")
        aggregate = spec.get("aggregate", "sum")
        if layer == "host":
            from repro.core import Assoc
            arr = Assoc(rows, cols, vals, aggregate=aggregate)
        elif layer == "device":
            from repro.core import AssocTensor
            arr = AssocTensor.from_triples(rows, cols, vals,
                                           aggregate=aggregate)
        elif layer == "dist":
            from repro.core import DistAssoc
            arr = DistAssoc.from_triples(rows, cols, vals,
                                         mesh or _default_mesh(),
                                         aggregate=aggregate)
        else:
            raise ValueError(f"table {name!r}: unknown layer {layer!r}")
        if spec.get("ingest"):
            from repro.ingest import IngestTable
            arr = IngestTable(
                arr, aggregate=aggregate,
                compact_threshold=int(spec.get("compact_threshold", 4096)),
                name=name)
        return self.register(name, arr)

    @classmethod
    def from_specs(cls, specs: Iterable[Dict[str, Any]],
                   mesh=None) -> "TableRegistry":
        reg = cls()
        for spec in specs:
            reg.load(spec, mesh=mesh)
        return reg

    # -- lookup -------------------------------------------------------------
    def get(self, name: str):
        with self._lock:
            arr = self._tables.get(str(name))
        if arr is None:
            raise WireError("unknown_table",
                            f"no table registered under {name!r}; "
                            f"known: {self.names()}")
        return arr

    def resolve(self, name: str):
        """The ``from_wire`` resolver.  Plain tables resolve to the
        resident array itself; ingest tables resolve to their current
        merge-on-read :meth:`~repro.ingest.IngestTable.snapshot` (memoized
        per mutation, so ``id(array)`` — and with it every plan-cache
        key — is stable between writes)."""
        from repro.ingest import IngestTable
        arr = self.get(name)
        if isinstance(arr, IngestTable):
            return arr.snapshot()
        return arr

    # -- ingest accessors ----------------------------------------------------
    def is_ingest(self, name: str) -> bool:
        from repro.ingest import IngestTable
        return isinstance(self.get(name), IngestTable)

    def ingest_table(self, name: str):
        """The raw :class:`~repro.ingest.IngestTable` (for mutation);
        raises ``WireError("not_ingestable")`` on a read-only table."""
        from repro.ingest import IngestTable
        arr = self.get(name)
        if not isinstance(arr, IngestTable):
            raise WireError(
                "not_ingestable",
                f"table {name!r} is a read-only {type(arr).__name__}; "
                f"register it with ingest=true to accept mutations")
        return arr

    def ingest_names(self) -> List[str]:
        from repro.ingest import IngestTable
        with self._lock:
            return sorted(n for n, a in self._tables.items()
                          if isinstance(a, IngestTable))

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._tables)

    def wire_names(self) -> Dict[int, str]:
        """``id(array) -> name`` map for serializing server-side graphs."""
        from repro.ingest import IngestTable
        with self._lock:
            out = {}
            for n, a in self._tables.items():
                out[id(a)] = n
                if isinstance(a, IngestTable):
                    out[id(a.base)] = n
            return out

    def layer_of(self, name: str) -> str:
        from repro.core.plan import _layer
        from repro.ingest import IngestTable
        arr = self.get(name)
        if isinstance(arr, IngestTable):
            arr = arr.base
        return _layer(arr)

    # -- introspection (the /tables endpoint) -------------------------------
    def info(self, name: str) -> Dict[str, Any]:
        from repro.ingest import IngestTable
        arr = self.get(name)
        if isinstance(arr, IngestTable):
            base_info = self._array_info(name, arr.base)
            base_info.update(arr.info())
            return base_info
        return self._array_info(name, arr)

    def _array_info(self, name: str, arr) -> Dict[str, Any]:
        from repro.core import Assoc, AssocTensor, DistAssoc
        if isinstance(arr, Assoc):
            return {"name": name, "layer": "host", "shape": list(arr.shape),
                    "nnz": int(arr.nnz()), "numeric": bool(arr.numeric)}
        if isinstance(arr, AssocTensor):
            return {"name": name, "layer": "device",
                    "shape": [len(arr.row_space), len(arr.col_space)],
                    "nnz": int(arr.nnz_host()),
                    "numeric": bool(arr.numeric)}
        assert isinstance(arr, DistAssoc)
        loc = arr.local
        return {"name": name, "layer": "dist",
                "shape": [len(loc.row_space), len(loc.col_space)],
                "nnz": int(np.asarray(loc.nnz).sum()),
                "numeric": bool(loc.numeric),
                "shards": int(arr.mesh.shape["data"])}

    def list_info(self) -> List[Dict[str, Any]]:
        return [self.info(n) for n in self.names()]

    def __len__(self) -> int:
        with self._lock:
            return len(self._tables)

    def __contains__(self, name) -> bool:
        with self._lock:
            return str(name) in self._tables

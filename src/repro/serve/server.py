"""HTTP transport for the query engine (stdlib ``ThreadingHTTPServer``).

Endpoints (all JSON):

* ``POST /query``  — body ``{"expr": <wire payload>, "options": {...}}``;
  200 → ``{"result": ..., "timing": {...}, "batch": k}``; malformed
  payloads → 400 with ``{"error": {"code", "message"}}`` (never a bare
  500 for wire errors).
* ``POST /ingest`` — body ``{"ingest": {"table", "rows", "cols",
  "vals"}}`` (see :func:`~repro.serve.wire.ingest_to_wire`); 200 →
  ``{"result": {"kind": "ingest", "accepted", "delta_depth",
  "version"}}``; malformed batches → 400 ``bad_batch``, read-only
  tables → 400 ``not_ingestable``.
* ``GET /tables``  — registry listing (name/layer/shape/nnz per table).
* ``GET /stats``   — server request/latency/batch metrics ⊕-merged across
  workers + the core telemetry dicts (``plan``/``cache``/``union``/
  ``dispatch``) — ``plan.plan_hits`` is the cross-request plan-cache
  signal.
* ``POST /stats/reset`` — zero the measurement window (bench harness).
* ``GET /health``  — liveness + table count.

CLI::

    python -m repro.serve.server --tables tables.json --port 8642 \
        --workers 4 --max-batch 8

where ``tables.json`` is a list of registry spec dicts (see
:mod:`~repro.serve.registry`), or inline JSON starting with ``[``/``{``.
"""
from __future__ import annotations

import argparse
import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .engine import Engine, QueryError
from .registry import TableRegistry
from .wire import WireError

__all__ = ["D4MServer", "start_server", "main"]

_MAX_BODY = 64 * 1024 * 1024


class _Handler(BaseHTTPRequestHandler):
    server_version = "d4m-serve/1"
    protocol_version = "HTTP/1.1"

    # silence per-request stderr logging (the server is long-lived)
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    @property
    def engine(self) -> Engine:
        return self.server.engine          # type: ignore[attr-defined]

    def _send(self, status: int, body: dict) -> None:
        data = json.dumps(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _error(self, status: int, code: str, message: str) -> None:
        self._send(status, {"error": {"code": code, "message": message}})

    def do_GET(self) -> None:  # noqa: N802
        try:
            if self.path == "/health":
                self._send(200, {"status": "ok",
                                 "tables": len(self.engine.registry)})
            elif self.path == "/tables":
                self._send(200,
                           {"tables": self.engine.registry.list_info()})
            elif self.path == "/stats":
                self._send(200, self.engine.stats())
            else:
                self._error(404, "not_found", f"no endpoint {self.path!r}")
        except Exception as exc:   # pragma: no cover - defensive
            self._error(500, "internal", f"{type(exc).__name__}: {exc}")

    def do_POST(self) -> None:  # noqa: N802
        try:
            if self.path == "/stats/reset":
                self.engine.reset_stats()
                self._send(200, {"status": "reset"})
                return
            if self.path not in ("/query", "/ingest"):
                self._error(404, "not_found", f"no endpoint {self.path!r}")
                return
            length = int(self.headers.get("Content-Length", 0))
            if length <= 0 or length > _MAX_BODY:
                self._error(400, "bad_payload",
                            f"Content-Length {length} out of range")
                return
            try:
                body = json.loads(self.rfile.read(length))
            except (ValueError, UnicodeDecodeError) as exc:
                self._error(400, "bad_payload", f"invalid JSON: {exc}")
                return
            if not isinstance(body, dict):
                self._error(400, "bad_payload", "body must be a JSON dict")
                return
            options = body.get("options") or {}
            if not isinstance(options, dict):
                self._error(400, "bad_payload", "'options' must be a dict")
                return
            try:
                if self.path == "/ingest":
                    # accept either a bare wire payload or {"ingest": ...}
                    # nested like /query's {"expr": ...}
                    payload = body if "ingest" in body else body.get("expr")
                    req = self.engine.submit_ingest(payload, options)
                else:
                    if "expr" not in body:
                        self._error(400, "bad_payload",
                                    "body must be {'expr': <wire payload>, "
                                    "'options': {...}?}")
                        return
                    req = self.engine.submit(body["expr"], options)
                out = req.wait(timeout=float(options.get("timeout_s", 120)))
            except WireError as exc:
                self._error(400, exc.code, str(exc))
                return
            except QueryError as exc:
                status = 504 if exc.code == "timeout" else 422
                self._error(status, exc.code, str(exc))
                return
            self._send(200, out)
        except Exception as exc:   # pragma: no cover - defensive
            self._error(500, "internal", f"{type(exc).__name__}: {exc}")


class D4MServer(ThreadingHTTPServer):
    """HTTP server owning an :class:`Engine` (and through it the resident
    table registry)."""

    daemon_threads = True

    def __init__(self, registry: TableRegistry, host: str = "127.0.0.1",
                 port: int = 0, *, workers: int = 4, max_batch: int = 8,
                 batch_window_s: float = 0.0):
        self.engine = Engine(registry, workers=workers, max_batch=max_batch,
                             batch_window_s=batch_window_s)
        super().__init__((host, port), _Handler)
        self._serve_thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.server_address[0]}:{self.port}"

    def start_background(self) -> "D4MServer":
        self.engine.start()
        self._serve_thread = threading.Thread(
            target=self.serve_forever, name="d4m-serve-http", daemon=True)
        self._serve_thread.start()
        return self

    def close(self) -> None:
        self.shutdown()
        self.engine.stop()
        self.server_close()
        if self._serve_thread is not None:
            self._serve_thread.join(timeout=5.0)
            self._serve_thread = None


def start_server(registry: TableRegistry, *, host: str = "127.0.0.1",
                 port: int = 0, workers: int = 4, max_batch: int = 8,
                 batch_window_s: float = 0.0) -> D4MServer:
    """Boot a server on a background thread; ``port=0`` picks a free
    port.  Caller owns ``server.close()``."""
    return D4MServer(registry, host, port, workers=workers,
                     max_batch=max_batch,
                     batch_window_s=batch_window_s).start_background()


def _load_specs(arg: str):
    if arg.lstrip().startswith(("[", "{")):
        specs = json.loads(arg)
    else:
        with open(arg) as f:
            specs = json.load(f)
    if isinstance(specs, dict):
        specs = [specs]
    return specs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="D4M query server over resident associative arrays")
    ap.add_argument("--tables", required=True,
                    help="path to a JSON list of table specs, or inline "
                         "JSON ('[{\"name\": ...}]')")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8642)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--batch-window-ms", type=float, default=0.0)
    args = ap.parse_args(argv)

    registry = TableRegistry.from_specs(_load_specs(args.tables))
    server = D4MServer(registry, args.host, args.port,
                       workers=args.workers, max_batch=args.max_batch,
                       batch_window_s=args.batch_window_ms / 1e3)
    server.engine.start()
    print(f"[d4m-serve] {len(registry)} table(s) resident "
          f"({', '.join(registry.names())}); serving on {server.url} "
          f"with {args.workers} worker(s), max_batch={args.max_batch}")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Thin stdlib HTTP client for the D4M query server.

Build queries over :class:`~repro.serve.wire.TableRef` leaves — the
client never holds table data::

    from repro.serve import D4MClient, TableRef
    from repro.core import StartsWith

    c = D4MClient("http://127.0.0.1:8642")
    A, B = TableRef("edges"), TableRef("feat")
    out = c.query((A[StartsWith("r0"), :] @ B).sum(axis=1))
    out["result"]["vals"]     # the reduced vector
    out["timing"]["exec_s"]   # server-side execution time
"""
from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Any, Dict, Optional

from repro.core.expr import LazyExpr

from .wire import ingest_to_wire, to_wire

__all__ = ["D4MClient", "ServerError"]


class ServerError(Exception):
    """Structured error returned by the server (code + HTTP status)."""

    def __init__(self, status: int, code: str, message: str):
        self.status = status
        self.code = code
        super().__init__(f"[{status}/{code}] {message}")


class D4MClient:
    def __init__(self, base_url: str, timeout: float = 120.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # -- plumbing -----------------------------------------------------------
    def _request(self, path: str, body: Optional[dict] = None) -> dict:
        url = self.base_url + path
        data = None if body is None else json.dumps(body).encode()
        req = urllib.request.Request(
            url, data=data,
            headers={"Content-Type": "application/json"} if data else {},
            method="POST" if data is not None else "GET")
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read())
        except urllib.error.HTTPError as exc:
            try:
                err = json.loads(exc.read()).get("error", {})
            except Exception:
                err = {}
            raise ServerError(exc.code, err.get("code", "http_error"),
                              err.get("message", str(exc))) from exc

    # -- API ----------------------------------------------------------------
    def query(self, expr, options: Optional[Dict[str, Any]] = None) -> dict:
        """POST one query; ``expr`` is a TableRef expression or an
        already-serialized wire payload dict."""
        payload = to_wire(expr) if isinstance(expr, LazyExpr) else expr
        body: Dict[str, Any] = {"expr": payload}
        if options:
            body["options"] = options
        return self._request("/query", body)

    def ingest(self, table: str, rows, cols, vals,
               options: Optional[Dict[str, Any]] = None) -> dict:
        """POST one triple batch against a registered ingest table;
        returns ``{"result": {"kind": "ingest", "accepted",
        "delta_depth", "version", ...}, "timing": ...}``."""
        body: Dict[str, Any] = ingest_to_wire(table, rows, cols, vals)
        if options:
            body["options"] = options
        return self._request("/ingest", body)

    def tables(self) -> list:
        return self._request("/tables")["tables"]

    def stats(self) -> dict:
        return self._request("/stats")

    def reset_stats(self) -> dict:
        return self._request("/stats/reset", body={})

    def health(self) -> dict:
        return self._request("/health")

"""repro — D4M (Dynamic Distributed Dimensional Data Model) on JAX/TPU.

Reproduction + TPU-native extension of Jananthan et al., "Python
Implementation of the Dynamic Distributed Dimensional Data Model"
(IEEE HPEC 2022).  See README.md / DESIGN.md / EXPERIMENTS.md.
"""

__version__ = "0.1.0"

"""Checkpointing: async host-side writes, manifest-driven elastic restore.

Design (1000+-node posture):
* **Step path never blocks on disk.**  ``save()`` device→host copies the
  (sharded) arrays, then a background thread serializes.  The train loop
  keeps stepping; ``wait()`` joins before the next save or at shutdown.
* **Manifest-driven layout.**  Each leaf is stored as ``<ckpt>/arrays/<id>.npy``
  plus a JSON manifest recording the pytree structure, global shapes,
  dtypes and the mesh-axis spec it was sharded with.  Restore therefore
  never depends on the saving topology: a checkpoint written on a 16×16
  mesh restores onto 2×16×16 (or a CPU test mesh) by re-sharding each leaf
  from its global array — **elastic scaling**.
* **Atomicity / crash-safety.**  Writes go to ``<dir>.tmp`` then
  ``os.replace`` to the final name; a half-written checkpoint is never
  visible.  ``latest_step`` scans only committed manifests; restart-after-
  failure (see repro.distributed.fault_tolerance) always lands on a
  complete checkpoint.
* **What's inside.**  params, optimizer state, RNG, data-pipeline cursor,
  and the D4M metrics telemetry — everything needed for exact resume.

On a real multi-host deployment each host writes only its addressable
shards (process-local ``.npy`` per shard index); here the single-process
dry-run gathers to host numpy, which is the same code path jax takes for
``jax.device_get`` on fully-addressable arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # registers bfloat16/float8 numpy dtypes for save/load
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Dict[str, Any],
                    *, extra: Optional[Dict] = None) -> str:
    """Synchronous core writer (the async manager wraps this)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    arrays_dir = os.path.join(tmp, "arrays")
    os.makedirs(arrays_dir, exist_ok=True)

    leaves, _ = _flatten_with_paths(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i:05d}.npy"
        np.save(os.path.join(arrays_dir, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, target_state: Dict[str, Any],
                       *, step: Optional[int] = None,
                       shardings: Optional[Dict] = None):
    """Restore into the structure of ``target_state``.

    ``shardings`` (optional pytree of NamedSharding) re-shards each leaf
    onto the CURRENT mesh — the elastic path: leaf global shapes are mesh-
    independent, so any axis resize that divides evenly restores cleanly.
    Returns (state, step, extra).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    base = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(base, "manifest.json")))

    leaves, treedef = _flatten_with_paths(target_state)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]
    new_leaves = []
    for i, (key, leaf) in enumerate(leaves):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(base, "arrays", meta["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {np.shape(leaf)}")
        dt = np.dtype(meta["dtype"])  # ml_dtypes handles bfloat16/fp8 names
        if arr.dtype != dt:
            arr = (arr.view(dt) if arr.dtype.kind == "V"
                   and arr.dtype.itemsize == dt.itemsize else arr.astype(dt))
        if shard_leaves is not None:
            new_leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            new_leaves.append(jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(treedef, [l for l in new_leaves])
    return state, step, manifest["extra"]


class CheckpointManager:
    """Async manager: non-blocking saves, bounded retention, crash-safe."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 save_interval_steps: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.interval = save_interval_steps
        self._thread: Optional[threading.Thread] = None
        self._last_saved: Optional[int] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step % self.interval == 0 and step != (self._last_saved or -1)

    def save_async(self, step: int, state: Dict, *, extra=None):
        self.wait()
        # device→host copy happens HERE (cheap, synchronous) so the caller
        # may donate/mutate device buffers immediately afterwards
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def _work():
            save_checkpoint(self.dir, step, host_state, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
        self._last_saved = step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target_state, *, shardings=None):
        self.wait()  # an in-flight async save must land before we look
        return restore_checkpoint(self.dir, target_state, shardings=shardings)

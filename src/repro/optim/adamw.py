"""AdamW with selectable moment-state precision (fp32 / bf16 / int8-blocked).

At 671B parameters the fp32 Adam moments alone are 5.4 TB — more than a
256-chip v5e pod's aggregate HBM once params and activations join.  The
framework therefore supports *quantized optimizer state*: moments stored in
bf16, or int8 with per-block (128-element) fp32 scales — the standard 8-bit
Adam construction (block-wise dynamic quantization, dequantize → update →
requantize each step).  Precision is a per-run policy (`TrainOptions`),
tested against fp32 AdamW on small problems in tests/test_optim.py.

State layout mirrors the param pytree: each leaf is either an array (fp32 /
bf16 moments) or a dict {"q": int8[...], "s": f32[..., n_blocks]} (int8).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Q8_BLOCK = 128


# ---------------------------------------------------------------------------
# int8 block quantization
# ---------------------------------------------------------------------------

def quantize_q8(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Shape-preserving int8 quantization with per-last-dim-block scales.

    ``q`` keeps the param's shape (so its sharding spec applies verbatim —
    crucial under FSDP: a flat repack would cross shard boundaries and
    trigger resharding collectives in the optimizer).  ``s`` has shape
    ``x.shape[:-1] + (ceil(last/128),)``.
    """
    x32 = x.astype(jnp.float32)
    last = x.shape[-1] if x.ndim else 1
    pad = (-last) % Q8_BLOCK
    xp = jnp.pad(x32.reshape(x32.shape or (1,)), [(0, 0)] * (max(x32.ndim, 1) - 1) + [(0, pad)])
    nblk = (last + pad) // Q8_BLOCK
    blocks = xp.reshape(xp.shape[:-1] + (nblk, Q8_BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.reshape(xp.shape)[..., :last].astype(jnp.int8)
    return {"q": q.reshape(x.shape), "s": scale}


def dequantize_q8(packed: Dict[str, jnp.ndarray], shape, dtype=jnp.float32):
    q, s = packed["q"], packed["s"]
    last = shape[-1] if shape else 1
    pad = (-last) % Q8_BLOCK
    qp = jnp.pad(q.astype(jnp.float32).reshape(q.shape or (1,)),
                 [(0, 0)] * (max(q.ndim, 1) - 1) + [(0, pad)])
    nblk = (last + pad) // Q8_BLOCK
    blocks = qp.reshape(qp.shape[:-1] + (nblk, Q8_BLOCK)) * s[..., None]
    return blocks.reshape(qp.shape)[..., :last].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# moment-state storage policies
# ---------------------------------------------------------------------------

def _store(x: jnp.ndarray, policy: str):
    if policy == "fp32":
        return x.astype(jnp.float32)
    if policy == "bf16":
        return x.astype(jnp.bfloat16)
    if policy == "q8":
        return quantize_q8(x)
    raise ValueError(policy)


def _load(stored, shape, policy: str) -> jnp.ndarray:
    if policy == "q8":
        return dequantize_q8(stored, shape)
    return stored.astype(jnp.float32)


def _zeros_like_stored(p: jnp.ndarray, policy: str):
    if policy == "q8":
        last = p.shape[-1] if p.ndim else 1
        nblk = (last + Q8_BLOCK - 1) // Q8_BLOCK
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(p.shape[:-1] + (nblk,), jnp.float32)}
    dt = jnp.float32 if policy == "fp32" else jnp.bfloat16
    return jnp.zeros(p.shape, dt)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _policies(state_policy: str):
    """Per-moment storage: the second moment is ratio-sensitive (a block's
    small v entries quantize to 0 → exploding m/√v steps), so 'q8' means
    m:int8 + v:bf16 — the memory win stays (3 B vs 8 B per param)."""
    if state_policy == "q8":
        return "q8", "bf16"
    return state_policy, state_policy


def adamw_init(params, *, state_policy: str = "fp32"):
    mp, vp = _policies(state_policy)
    return {
        "m": jax.tree.map(lambda p: _zeros_like_stored(p, mp), params),
        "v": jax.tree.map(lambda p: _zeros_like_stored(p, vp), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, state_policy: str = "fp32"):
    """One AdamW step.  Returns (new_params, new_opt_state).

    Math runs in fp32 regardless of storage policy; params are updated in
    their own dtype (bf16 master-less update — adequate with wd in fp32 and
    tested; switch params to fp32 for exact parity runs).
    """
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    m_policy, v_policy = _policies(state_policy)

    def upd(p, g, m_st, v_st):
        g32 = g.astype(jnp.float32)
        m = _load(m_st, p.shape, m_policy)
        v = _load(v_st, p.shape, v_policy)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + weight_decay * p32)
        return p_new.astype(p.dtype), _store(m, m_policy), _store(v, v_policy)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])

    # Huge stacked leaves (e.g. [58, 256, 7168, 2048] expert weights) would
    # materialize several fp32 temporaries of the whole leaf at once; run
    # the update layer-by-layer over the leading scan axis instead so the
    # fp32 working set is 1/L of the leaf.
    CHUNK_THRESHOLD = 64 * 1024 * 1024  # elements

    def upd_leaf(p, g, m, v):
        # Only layer-stacked leaves ([L, ...] with small L) — mapping a 2-D
        # embedding table over its vocab axis would mean 100k+ iterations.
        leading_ok = (
            p.ndim >= 3 and p.size > CHUNK_THRESHOLD and p.shape[0] <= 128
            and g.shape[:1] == p.shape[:1]
            and all(x["q"].shape[:1] == p.shape[:1] if isinstance(x, dict)
                    else x.shape[:1] == p.shape[:1] for x in (m, v)))
        if leading_ok:
            return jax.lax.map(lambda a: upd(*a), (p, g, m, v))
        return upd(p, g, m, v)

    out = [upd_leaf(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}

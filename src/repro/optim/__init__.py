"""repro.optim — AdamW with quantized-state options, schedules, clipping."""
from .adamw import adamw_init, adamw_update, quantize_q8, dequantize_q8
from .clip import clip_by_global_norm, global_norm
from .schedules import cosine_schedule, make_schedule, wsd_schedule

__all__ = ["adamw_init", "adamw_update", "quantize_q8", "dequantize_q8",
           "clip_by_global_norm", "global_norm", "cosine_schedule",
           "wsd_schedule", "make_schedule"]

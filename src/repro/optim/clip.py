"""Global-norm gradient clipping (fp32 accumulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    # scale in the grad's own dtype: an f32 upcast would transiently double
    # the grad tree (hundreds of GB at 671B params)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn

"""Learning-rate schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM).

WSD is the schedule the MiniCPM paper contributes: linear warmup → long
constant ("stable") phase → short exponential/linear decay tail.  Unlike
cosine it decouples total-token count from the decay horizon, which is what
makes mid-flight restarts and continued pretraining cheap — a property the
fault-tolerance layer exploits (restarting inside the stable phase does not
perturb the schedule).
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: MiniCPM §4 (decay tail = last `decay_frac`)."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    # exponential decay tail: lr = peak * final_frac^(t/T_decay)
    t = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1),
                 0.0, 1.0)
    dec = peak_lr * jnp.power(final_frac, t)
    stable = jnp.full_like(step, peak_lr)
    out = jnp.where(step < warmup, warm,
                    jnp.where(step < decay_start, stable, dec))
    return out


def make_schedule(kind: str, **kw):
    fn = {"cosine": cosine_schedule, "wsd": wsd_schedule}[kind]
    return lambda step: fn(step, **kw)

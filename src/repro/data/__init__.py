"""repro.data — D4M-backed ingest → tokenized batches."""
from .pipeline import CorpusPipeline, PipelineState, synth_corpus
from .tokenizer import ByteTokenizer

__all__ = ["CorpusPipeline", "PipelineState", "synth_corpus", "ByteTokenizer"]

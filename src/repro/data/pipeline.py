"""D4M-backed data pipeline: triple ingest → associative arrays → batches.

This is the paper's technology doing the framework's data work:

1. **Ingest**: documents arrive as ``(doc_id, position, token)`` triples —
   the canonical D4M representation — and are held as an ``Assoc`` whose
   constructor performs dedup/aggregation exactly as §II.A prescribes.
2. **Statistics**: corpus-level artifacts are semiring algebra on that
   array: term-document counts are ``A.logical().sum(0)``, co-occurrence is
   the classic ``AᵀA`` (``sqin``), doc-similarity ``AAᵀ`` (``sqout``).
3. **Sharding**: the *Distributed* D — the doc keyspace is row-partitioned
   across data-parallel hosts by rank range (Accumulo tablet splits, mapped
   onto the mesh's data axis).  Each host draws only from its shard.
4. **Determinism & elasticity**: batch order is a pure function of
   ``(seed, step, shard)``; the cursor state is three integers,
   checkpointed with the model, so same-topology restarts replay
   token-exactly (tests/test_data.py).  Re-sharding to a different host
   count deterministically yields a *different but valid* schedule over
   the same corpus — doc ranges re-partition cleanly (tested).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Assoc, KeySpace
from .tokenizer import ByteTokenizer


@dataclasses.dataclass
class PipelineState:
    """Checkpointable cursor: everything needed for exact-token resume."""
    step: int = 0
    seed: int = 0
    epoch: int = 0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "PipelineState":
        return PipelineState(**d)


def synth_corpus(n_docs: int = 64, seed: int = 0) -> List[str]:
    """Deterministic synthetic corpus (zipf-ish word soup)."""
    rng = np.random.default_rng(seed)
    vocab = [f"w{i:03d}" for i in range(200)]
    p = 1.0 / np.arange(1, len(vocab) + 1)
    p /= p.sum()
    return [" ".join(rng.choice(vocab, size=rng.integers(8, 40), p=p))
            for _ in range(n_docs)]


class CorpusPipeline:
    """Triple-store corpus → fixed-length token batches for one host shard."""

    def __init__(self, docs: List[str], *, tokenizer: Optional[ByteTokenizer] = None,
                 seq_len: int = 128, batch_per_shard: int = 4,
                 shard: int = 0, n_shards: int = 1, seed: int = 0):
        self.tokenizer = tokenizer or ByteTokenizer().fit(docs)
        self.seq_len = seq_len
        self.batch = batch_per_shard
        self.shard, self.n_shards = shard, n_shards
        self.state = PipelineState(seed=seed)

        # --- D4M ingest: (doc, pos, token) triples → Assoc ---------------
        rows, cols, vals = [], [], []
        self._token_streams: List[np.ndarray] = []
        for d_i, doc in enumerate(docs):
            ids = self.tokenizer.encode(doc)
            self._token_streams.append(ids)
            rows.extend([f"doc{d_i:06d}"] * len(ids))
            cols.extend(range(len(ids)))
            vals.extend(ids.astype(float) + 1.0)  # +1: token id 0 is valid
        self.table = Assoc(rows, cols, vals, aggregate="last")

        # row-keyspace sharding: this host's contiguous doc-rank range
        self.doc_space = KeySpace(np.asarray(
            [f"doc{d_i:06d}" for d_i in range(len(docs))]))
        per = (len(docs) + n_shards - 1) // n_shards
        self.doc_lo, self.doc_hi = shard * per, min((shard + 1) * per, len(docs))

        # flat token stream for this shard (documents joined)
        ids = [self._token_streams[i] for i in range(self.doc_lo, self.doc_hi)]
        self.flat = (np.concatenate(ids) if ids
                     else np.zeros((1,), np.int32))

    # --- corpus statistics (the paper's analytics idioms) -----------------
    def term_doc(self) -> Assoc:
        """token × doc incidence (Aᵀ as an associative array)."""
        return self.table.logical().transpose()

    def cooccurrence(self) -> Assoc:
        """position-free token co-occurrence via AᵀA (sqin)."""
        return self.table.logical().sqin()

    def doc_similarity(self) -> Assoc:
        return self.table.logical().sqout()

    # --- batching ----------------------------------------------------------
    def _offsets_for(self, step: int) -> np.ndarray:
        """Deterministic window starts for (seed, step) — order-independent
        of when/where it's called, so resume/elastic replay is exact."""
        rng = np.random.default_rng(
            (self.state.seed * 1_000_003 + step) * (self.shard + 1))
        hi = max(len(self.flat) - self.seq_len - 1, 1)
        return rng.integers(0, hi, size=self.batch)

    def next_batch(self) -> Dict[str, np.ndarray]:
        offs = self._offsets_for(self.state.step)
        toks = np.stack([self.flat[o:o + self.seq_len] for o in offs])
        labels = np.stack([self.flat[o + 1:o + self.seq_len + 1] for o in offs])
        self.state.step += 1
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    # --- checkpoint/elastic ------------------------------------------------
    def state_dict(self) -> Dict:
        return self.state.to_dict()

    def load_state_dict(self, d: Dict):
        self.state = PipelineState.from_dict(d)

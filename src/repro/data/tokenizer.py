"""Byte-level tokenizer with a D4M vocabulary table.

The vocabulary *is* an associative array ``V : token × "id" → rank`` — the
KeySpace mechanics the device arrays use (sorted-unique + rank) double as
the token dictionary, which is exactly the D4M worldview: a tokenizer is a
1-column table.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

from repro.core import Assoc, KeySpace


class ByteTokenizer:
    """UTF-8 byte tokenizer + merged word vocabulary built via Assoc.

    Real deployments would plug a trained BPE here; the framework needs a
    deterministic, dependency-free tokenizer whose vocab is D4M-native.
    """

    def __init__(self, vocab_size: int = 512, specials: tuple = ("<pad>", "<bos>", "<eos>")):
        self.vocab_size = vocab_size
        self.specials = specials

    def fit(self, docs: Iterable[str]) -> "ByteTokenizer":
        # count words with constructor aggregation (collisions ⊕= sum)
        words: List[str] = []
        for d in docs:
            words.extend(d.split())
        if words:
            counts = Assoc(words, ["count"] * len(words), [1.0] * len(words),
                           aggregate="sum")
            r, _, v = counts.triples()
            order = np.argsort(-v)
            top = r[order][: self.vocab_size - 256 - len(self.specials)]
        else:
            top = np.asarray([], dtype=str)
        toks = list(self.specials) + [f"<0x{i:02x}>" for i in range(256)] + \
            top.astype(str).tolist()
        self.table = KeySpace(np.asarray(toks))
        self.pad_id = int(self.table.rank(np.asarray(["<pad>"]))[0][0])
        self.bos_id = int(self.table.rank(np.asarray(["<bos>"]))[0][0])
        self.eos_id = int(self.table.rank(np.asarray(["<eos>"]))[0][0])
        return self

    def encode(self, text: str) -> np.ndarray:
        out = [self.bos_id]
        for w in text.split():
            ranks, found = self.table.rank(np.asarray([w]), strict=False)
            if len(ranks) and found.all():
                out.append(int(ranks[0]))
            else:
                for b in w.encode("utf-8"):
                    r, _ = self.table.rank(np.asarray([f"<0x{b:02x}>"]))
                    out.append(int(r[0]))
        out.append(self.eos_id)
        return np.asarray(out, dtype=np.int32)

    def decode(self, ids: np.ndarray) -> str:
        toks = [str(self.table[int(i)]) for i in ids]
        return " ".join(t for t in toks if not t.startswith("<"))
